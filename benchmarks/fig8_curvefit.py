"""Fig. 8(b) — bucket-select curvefit error vs the circuit oracle.

Reproduces the paper's claim: < 3% error on random per-pixel (I, W) draws,
and quantifies the win over the step-1 generic fit alone.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.core.curvefit import fit_bucket_model, predict_hard, predict_sigmoid
from repro.core.device_models import CircuitParams, analog_dot_product


def run() -> list[Row]:
    params = CircuitParams()
    fit_us = time_fn(lambda: fit_bucket_model(params), iters=3, warmup=0)
    model = fit_bucket_model(params)

    rng = np.random.default_rng(42)
    parts_i, parts_w = [], []
    for a, b in [(1, 1), (5, 1), (1, 5), (8, 1), (12, 1)]:
        parts_i.append(rng.beta(a, b, (1500, 75)))
        parts_w.append(rng.beta(a, b, (1500, 75)))
    I = jnp.asarray(np.concatenate(parts_i), jnp.float32)
    W = jnp.asarray(np.concatenate(parts_w), jnp.float32)
    v_true = analog_dot_product(I, W, params)

    rows: list[Row] = [("fig8_fit_time", fit_us, "one-off model fit")]
    for name, fn in (("hard", predict_hard), ("sigmoid", predict_sigmoid)):
        us = time_fn(lambda fn=fn: fn(model, I, W))
        err = np.abs(np.asarray(fn(model, I, W) - v_true)) / params.v_sat
        rows.append(
            (f"fig8b_bucket_{name}", us,
             f"mean={err.mean()*100:.3f}% p99={np.quantile(err, 0.99)*100:.3f}% "
             f"max={err.max()*100:.3f}% (paper bound: <3%)")
        )
    err_avg = np.abs(np.asarray(model.f_avg(I.mean(-1), W.mean(-1)) - v_true)) / params.v_sat
    rows.append(
        ("fig8b_generic_fit_only", 0.0,
         f"mean={err_avg.mean()*100:.3f}% max={err_avg.max()*100:.3f}% (why buckets exist)")
    )
    return rows
