"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import jax

Row = tuple[str, float, str]  # (name, us_per_call, derived)


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: list[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
