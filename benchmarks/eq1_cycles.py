"""Eq. 1 — convolution cycle counts N_C = 2 h_o c_o lcm(S, n)/S, swept over
stride and kernel size, validated against the explicit RS/SW/ColP schedule.
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core import mapping


def run() -> list[Row]:
    rows: list[Row] = []
    for n in (3, 5):
        for s in range(1, n + 1):
            spec = mapping.FPCASpec(
                image_h=64, image_w=64, out_channels=8, kernel=n, stride=s, max_kernel=n
            )
            n_c = mapping.n_cycles(spec)
            explicit = sum(1 for _ in mapping.schedule(spec))
            phases = spec.horizontal_phases
            rows.append(
                (f"eq1_n{n}_s{s}", 0.0,
                 f"N_C={n_c} schedule={explicit} match={n_c == explicit} "
                 f"phases=lcm({s};{n})/{s}={phases}")
            )
    return rows
