"""Benchmark harness — one module per paper table/figure + system reports.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig8 eq1   # substring filter
"""

from __future__ import annotations

import sys
import traceback

from benchmarks.common import emit

MODULES = [
    "benchmarks.fig7_transfer",
    "benchmarks.fig8_curvefit",
    "benchmarks.fig9_tradeoffs",
    "benchmarks.eq1_cycles",
    "benchmarks.kernel_bench",
    "benchmarks.stream_bench",
    "benchmarks.model_bench",
    "benchmarks.fleet_bench",
    "benchmarks.roofline_report",
]


def main() -> None:
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    failures = 0
    print("name,us_per_call,derived")
    for modname in MODULES:
        if filters and not any(f in modname for f in filters):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            emit(mod.run())
        except Exception:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{modname},0.0,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
