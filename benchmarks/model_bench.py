"""End-to-end classifier benchmark: the whole model program (FPCA analog
frontend + digital CNN head) served as per-frame class logits.

Three serving modes of the same trained-architecture network
(`configs/fpca_cnn`-style head on a c_o=32 frontend):

* **batched dense**  — `CompiledModel.run` on a frame batch (ONE fused
  frontend+head jit per batch: the offline / high-throughput path);
* **streaming dense** — `StreamServer` with gating off (per-tick logits,
  every window executed);
* **streaming delta-gated** — the skip-aware head path: kept windows are
  patched into each stream's effective activation map, so every tick still
  yields class logits while skipped windows never execute.

Records classifier frames/sec for each mode, the masked-over-dense
streaming speedup (the acceptance number: streaming classification must
beat dense on the synthetic low-change scene), and the head's
FLOPs/latency/energy accounting (`analysis.model_streaming_report`) to
``BENCH_model.json`` at the repo root — diff against the batch-frontend
baseline with ``python -m benchmarks.perf_compare --model``.

Two model-zoo lanes ride along: **detection** (the zoo's ``fpca_detect``
arch streaming per-tick per-cell class scores + boxes through the same
skip-aware head path) and **events** (the delta gate's changed blocks as an
address-event stream, moving vs static scene — a zero-event static scene
records the ``None`` fps sentinel, never inf/nan, per the strict-JSON
writer contract).
"""

from __future__ import annotations

import time
from pathlib import Path

import jax
import numpy as np

from benchmarks._util import write_json
from benchmarks.common import Row, time_fn
from repro.core import analysis
from repro.core.curvefit import fit_bucket_model
from repro.core.mapping import FPCASpec, output_dims
from repro.data.pipeline import SyntheticMovingObject
from repro.fpca import DeltaGateConfig, DenseSpec, build_model, telemetry
from repro.fpca import compile as fpca_compile
from repro.configs.fpca_cnn import make_model_program
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.observe import fleet_report
from repro.serving.streaming import StreamServer

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_model.json"
TELEMETRY_JSONL = Path(__file__).resolve().parents[1] / "telemetry_model.jsonl"

# Same operating point as stream_bench: c_o = 32 puts real matmul-bank work
# behind every window, so the masked win measures compute, not dispatch.
H = 160
C_O = 32
N_FRAMES = 48
N_STREAMS = 2
BATCH = 16
GATE = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=24)


def _serve(
    pipe: FPCAPipeline, cams: dict, gating: bool, config: str = "cls"
) -> tuple[float, StreamServer]:
    server = StreamServer(pipe, GATE, depth=2, gating=gating)
    for name in cams:
        server.add_stream(name, config)
    ticks = (
        {name: cam.frame_at(t) for name, cam in cams.items()}
        for t in range(N_FRAMES)
    )
    t0 = time.perf_counter()
    for _ in server.run(ticks):
        pass
    return time.perf_counter() - t0, server


def run() -> list[Row]:
    bucket_model = fit_bucket_model(n_pixels=75)
    spec = FPCASpec(image_h=H, image_w=H, out_channels=C_O, kernel=5, stride=5)
    model = make_model_program(
        spec, head=(DenseSpec(64, activation="relu"), DenseSpec(2))
    )
    rng = np.random.default_rng(0)
    kernel = (rng.normal(size=model.frontend.kernel_shape) * 0.2).astype(np.float32)
    head_params = model.init_head(jax.random.PRNGKey(0))

    # batched dense classification through the fused handle
    m = fpca_compile(model, backend="basis", weights=kernel,
                     head_params=head_params, model=bucket_model)
    frames = rng.uniform(0, 1, (BATCH, H, H, 3)).astype(np.float32)
    us_batched = time_fn(lambda: m.run(frames), iters=5)
    fps_batched = BATCH / (us_batched * 1e-6)

    # streaming: dense vs delta-gated, per-tick logits either way
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cls", model, kernel, head_params=head_params)
    cams = {
        f"cam{i}": SyntheticMovingObject((H, H), seed=i + 1)
        for i in range(N_STREAMS)
    }
    _serve(pipe, cams, gating=True)     # warm-up (compiles)
    _serve(pipe, cams, gating=False)
    pipe.reset_bucket_state()
    t_gated, server = _serve(pipe, cams, gating=True)
    t_dense, _ = _serve(pipe, cams, gating=False)

    # scan-segment lane: per-tick logits with the gate AND the skip-aware
    # head inside ONE lax.scan launch per stream (K = N_FRAMES); the probe
    # pass compiles the masked-dense scan and sizes the row bucket for the
    # timed pass (servo-at-segment-boundary semantics)
    frame_stacks = {
        name: np.stack([cam.frame_at(t) for t in range(N_FRAMES)])
        for name, cam in cams.items()
    }

    def _serve_scan(m_bucket=None, config="cls"):
        srv = StreamServer(pipe, GATE, depth=2, gating=True)
        for name in frame_stacks:
            srv.add_stream(name, config)
        t0 = time.perf_counter()
        for name, stack in frame_stacks.items():
            srv.run_segment(name, stack, m_bucket=m_bucket)
        return time.perf_counter() - t0, srv

    _, probe = _serve_scan()
    scan_bucket = max(
        probe.sessions[n]._segment_state.suggested_bucket or 1
        for n in frame_stacks
    )
    _serve_scan(m_bucket=scan_bucket)    # warm-up
    t_scan, _ = _serve_scan(m_bucket=scan_bucket)
    fps_scan = N_FRAMES * N_STREAMS / t_scan

    # telemetry lane: same scan workload with a live session (uploaded by
    # the CI bench-smoke job next to the stream bench's JSONL)
    telemetry.enable(
        TELEMETRY_JSONL, device_time_rate=4,
        run_labels={"bench": "model_scan_segment"},
    )
    t_scan_tel, tel_server = _serve_scan(m_bucket=scan_bucket)
    fleet = fleet_report(tel_server)
    n_events = telemetry.session().events_written
    telemetry.disable()

    # detection lane: the zoo's fpca_detect arch on the SAME frontend spec
    # and kernel, streamed delta-gated with an event tap attached — per-tick
    # per-coarse-cell class scores + box regression through the skip-aware
    # patched-head path
    det_model = build_model(
        {"arch": "fpca_detect", "spec": spec, "n_classes": 2, "width": 8}
    )
    det_params = det_model.init_head(jax.random.PRNGKey(1))
    pipe.register("det", det_model, kernel, head_params=det_params)
    det_frames = [cams["cam0"].frame_at(t) for t in range(N_FRAMES)]

    def _serve_det(stack):
        srv = StreamServer(pipe, GATE, depth=2, gating=True)
        srv.add_stream("cam0", "det", events=True)
        t0 = time.perf_counter()
        for r in srv.serve("cam0", stack):
            assert r.detections is not None and r.events is not None
        return time.perf_counter() - t0, srv

    _serve_det(det_frames)               # warm-up (compiles)
    t_det, det_srv = _serve_det(det_frames)
    fps_det = N_FRAMES / t_det
    ev = det_srv.event_taps["cam0"].stats
    # event lanes: moving scene vs an all-static scene.  A zero-event lane
    # records the None fps sentinel — the strict-JSON writer (allow_nan
    # off) forbids inf/nan, and 0/t would misread as "measured zero rate"
    events_per_s = ev.events / t_det if ev.events else None
    t_static, static_srv = _serve_det([det_frames[0]] * 8)
    sev = static_srv.event_taps["cam0"].stats
    static_events_per_s = sev.events / t_static if sev.events else None

    n_served = N_FRAMES * N_STREAMS
    fps_gated = n_served / t_gated
    fps_dense = n_served / t_dense
    s = server.stats
    kept_frac = s.windows_kept / s.windows_total
    h_o, w_o = output_dims(spec)
    rep = analysis.model_streaming_report(
        model, list(server.sessions["cam0"].block_masks)
    )

    # quantised int8 lanes: the SAME classifier compiled precision="int8" —
    # LUT-collapsed bucket transfer in the basis frontend + int8 head with
    # exact int32 accumulation, activation scales calibrated on the batched
    # frames' counts.  Parity vs f32 is bounded, not bit-exact (pinned in
    # tests/test_quant.py); the lanes here record the measured numbers.
    from repro.models.quant import logit_parity, quantize_head_params

    model_i8 = model.replace(precision="int8")
    fe_cal = fpca_compile(model.frontend, backend="basis", weights=kernel,
                          model=bucket_model)
    head_params_i8 = quantize_head_params(
        model_i8, head_params, sample_counts=fe_cal.run(frames)
    )
    m_i8 = fpca_compile(model_i8, backend="basis", weights=kernel,
                        head_params=head_params_i8, model=bucket_model)
    us_batched_i8 = time_fn(lambda: m_i8.run(frames), iters=5)
    fps_batched_i8 = BATCH / (us_batched_i8 * 1e-6)
    parity = logit_parity(np.asarray(m.run(frames)), np.asarray(m_i8.run(frames)))

    pipe.register("cls8", model_i8, kernel, head_params=head_params_i8)
    _serve(pipe, cams, gating=True, config="cls8")      # warm-up (compiles)
    pipe.reset_bucket_state()
    t_gated_i8, _ = _serve(pipe, cams, gating=True, config="cls8")
    fps_gated_i8 = n_served / t_gated_i8

    _, probe_i8 = _serve_scan(config="cls8")
    scan_bucket_i8 = max(
        probe_i8.sessions[n]._segment_state.suggested_bucket or 1
        for n in frame_stacks
    )
    _serve_scan(m_bucket=scan_bucket_i8, config="cls8")  # warm-up
    t_scan_i8, _ = _serve_scan(m_bucket=scan_bucket_i8, config="cls8")
    fps_scan_i8 = n_served / t_scan_i8
    head_model = analysis.head_report(model)

    record = {
        "workload": {
            "streams": N_STREAMS, "frames_per_stream": N_FRAMES,
            "batch": BATCH, "image": [H, H, 3],
            "spec": {"kernel": spec.kernel, "stride": spec.stride,
                     "out_channels": spec.out_channels, "binning": spec.binning},
            "windows_per_frame": h_o * w_o,
            "head": [str(layer) for layer in model.head],
            "n_classes": model.n_classes,
            "gate": {"threshold": GATE.threshold, "hysteresis": GATE.hysteresis,
                     "keyframe_interval": GATE.keyframe_interval},
        },
        "backend": "basis (XLA lowering of the Pallas kernel math)",
        "batched_dense": {"us_per_batch": us_batched, "frames_per_s": fps_batched},
        "stream_dense": {"s_total": t_dense, "frames_per_s": fps_dense},
        "stream_masked": {"s_total": t_gated, "frames_per_s": fps_gated},
        "scan_segment": {
            "s_total": t_scan,
            "frames_per_s": fps_scan,
            "segment_length": N_FRAMES,
            "m_bucket": scan_bucket,
            "speedup_vs_per_tick_masked": fps_scan / fps_gated,
        },
        "speedup_masked_vs_dense": fps_gated / fps_dense,
        "kept_window_frac": kept_frac,
        "head": {
            "macs_per_frame": rep["head_macs_per_frame"],
            "flops_per_frame": rep["head_flops_per_frame"],
            "params": rep["head_params"],
            "t_head_per_frame": rep["t_head_total"] / rep["frames"],
            "e_head_per_frame": rep["e_head_total"] / rep["frames"],
        },
        "sensor_model": {
            "energy_vs_dense": rep["energy_vs_dense"],
            "model_energy_vs_dense": rep["model_energy_vs_dense"],
            "model_latency_vs_dense": rep["model_latency_vs_dense"],
            "model_fps_effective": rep["model_fps_effective"],
        },
        "detection": {
            "arch": "fpca_detect",
            "s_total": t_det,
            "frames_per_s": fps_det,
            "grid": [h_o, w_o],
            "n_classes": det_model.detect_classes,
            "head_macs_per_frame": analysis.head_flops(det_model)["macs"],
        },
        "events": {
            "moving_scene": {
                "ticks": ev.ticks, "events": ev.events,
                "events_pos": ev.events_pos, "events_neg": ev.events_neg,
                "events_per_s": events_per_s,
            },
            "static_scene": {
                "ticks": sev.ticks, "events": sev.events,
                "events_per_s": static_events_per_s,
            },
        },
        "telemetry": {
            "jsonl": TELEMETRY_JSONL.name,
            "events": n_events,
            "s_total_enabled": t_scan_tel,
            "enabled_overhead_frac": t_scan_tel / t_scan - 1.0,
            "fleet_report": fleet,
        },
        "quantised_int8": {
            "batched": {
                "us_per_batch": us_batched_i8,
                "frames_per_s": fps_batched_i8,
                "speedup_vs_f32": fps_batched_i8 / fps_batched,
            },
            "stream_masked": {
                "s_total": t_gated_i8,
                "frames_per_s": fps_gated_i8,
                "speedup_vs_f32": fps_gated_i8 / fps_gated,
            },
            "scan_segment": {
                "s_total": t_scan_i8,
                "frames_per_s": fps_scan_i8,
                "m_bucket": scan_bucket_i8,
                "speedup_vs_f32": fps_scan_i8 / fps_scan,
            },
            "parity": {
                "max_abs_divergence": float(parity["max_abs_divergence"]),
                "top1_agreement": float(parity["top1_agreement"]),
            },
            "head_model": {
                "t_head_f32": head_model["t_head_f32"],
                "t_head_int8": head_model["t_head_int8"],
                "e_head_f32": head_model["e_head_f32"],
                "e_head_int8": head_model["e_head_int8"],
                "int8_speedup": head_model["int8_speedup"],
                "int8_energy_ratio": head_model["int8_energy_ratio"],
            },
        },
    }
    write_json(BENCH_JSON, record)

    return [
        ("model_e2e_batched", us_batched,
         f"B={BATCH} {H}x{H} -> {fps_batched:.0f} frames/s fused "
         f"frontend+head (json: {BENCH_JSON.name})"),
        ("model_stream_delta_gated", t_gated / n_served * 1e6,
         f"{N_STREAMS}x{N_FRAMES} frames -> {fps_gated:.0f} frames/s "
         f"kept={kept_frac:.1%} "
         f"speedup_vs_dense={record['speedup_masked_vs_dense']:.2f}x "
         f"(logits every tick)"),
        ("model_stream_dense", t_dense / n_served * 1e6,
         f"{fps_dense:.0f} frames/s"),
        ("model_scan_segment", t_scan / n_served * 1e6,
         f"K={N_FRAMES} lax.scan segments -> {fps_scan:.0f} frames/s "
         f"(bucket {scan_bucket}, "
         f"{fps_scan / fps_gated:.2f}x per-tick masked, logits every tick)"),
        ("model_head_cost", 0.0,
         f"{rep['head_macs_per_frame']/1e6:.2f} MMAC/frame "
         f"({rep['head_params']/1e3:.0f}k params)"),
        ("model_detect_stream", t_det / N_FRAMES * 1e6,
         f"fpca_detect {h_o}x{w_o} grid -> {fps_det:.0f} frames/s "
         f"(scores+boxes every tick)"),
        ("model_event_stream", 0.0,
         f"{ev.events} events/{ev.ticks} ticks "
         f"(+{ev.events_pos}/-{ev.events_neg}); static scene "
         f"{sev.events} events"),
        ("model_e2e_batched_int8", us_batched_i8,
         f"B={BATCH} int8 -> {fps_batched_i8:.0f} frames/s "
         f"({fps_batched_i8 / fps_batched:.2f}x f32, max |dlogit| "
         f"{parity['max_abs_divergence']:.3f}, top-1 agree "
         f"{parity['top1_agreement']:.2f})"),
        ("model_stream_masked_int8", t_gated_i8 / n_served * 1e6,
         f"{fps_gated_i8:.0f} frames/s "
         f"({fps_gated_i8 / fps_gated:.2f}x f32 masked)"),
        ("model_scan_segment_int8", t_scan_i8 / n_served * 1e6,
         f"{fps_scan_i8:.0f} frames/s "
         f"({fps_scan_i8 / fps_scan:.2f}x f32 scan, bucket {scan_bucket_i8})"),
    ]
