"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts.  §Perf and the narrative sections are hand-authored and
preserved (everything outside the AUTOGEN markers).

    PYTHONPATH=src python -m benchmarks.make_experiments
"""

from __future__ import annotations

import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ARTIFACTS = ROOT / "artifacts" / "dryrun"
EXPERIMENTS = ROOT / "EXPERIMENTS.md"

ARCH_ORDER = [
    "granite-moe-3b-a800m", "qwen2-moe-a2.7b", "seamless-m4t-medium",
    "internvl2-76b", "h2o-danube-1.8b", "phi3-medium-14b", "qwen3-1.7b",
    "yi-9b", "zamba2-7b", "mamba2-2.7b", "fpca-frontend",
]
SHAPE_ORDER = [
    "train_4k", "prefill_32k", "decode_32k", "long_500k",
    "video_1080", "sensor_4k",
]


def _load(tag: str, mesh: str) -> dict[tuple[str, str], dict]:
    out = {}
    for p in sorted((ARTIFACTS / tag).glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        arch, shape, _ = p.stem.split("__")
        out[(arch, shape)] = rec
    return out


def _fmt_bytes(x: float) -> str:
    if x >= 1e9:
        return f"{x/1e9:.2f}G"
    if x >= 1e6:
        return f"{x/1e6:.1f}M"
    return f"{x/1e3:.0f}K"


def dryrun_table(tag: str = "baseline") -> str:
    lines = [
        "| arch | shape | mesh | compile | HLO GFLOPs/dev | bytes/dev | temp HBM/dev | wire bytes/dev | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for mesh in ("single", "multi"):
        recs = _load(tag, mesh)
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                rec = recs.get((arch, shape))
                if rec is None:
                    if (arch == "fpca-frontend") != (shape in ("video_1080", "sensor_4k")):
                        continue  # shape not defined for this arch
                    lines.append(f"| {arch} | {shape} | {mesh} | — | — | — | — | — | MISSING |")
                elif "skipped" in rec:
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | — | — | — | — | — | skipped (full-attn; DESIGN.md §4) |"
                    )
                else:
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | {rec['compile_s']}s "
                        f"| {rec['flops_per_device']/1e9:.1f} "
                        f"| {_fmt_bytes(rec['bytes_per_device'])} "
                        f"| {_fmt_bytes(rec['memory']['temp_bytes'])} "
                        f"| {_fmt_bytes(rec['collectives']['total_wire_bytes'])} "
                        f"| ok |"
                    )
    return "\n".join(lines)


def _lever(rec: dict) -> str:
    """One sentence: what would move the dominant term down (per assignment)."""
    t = rec["terms"]
    dom = t["dominant"]
    shape = rec["shape"]
    arch = rec["arch"]
    kind = (
        "train" if "train" in shape else
        "prefill" if "prefill" in shape else
        "frontend" if shape in ("video_1080", "sensor_4k") else "decode"
    )
    if dom == "collective_s":
        if kind == "decode":
            return "serve with fsdp=False + seq-sharded cache (§Perf T2: 54x)"
        if "moe" in arch or "granite" in arch or "qwen2" in arch:
            return "local MoE dispatch + capacity 1.0 (§Perf T1: -45%); EP blocked by E%16"
        return "cut FSDP gather rounds: fewer microbatches or selective remat"
    if dom == "memory_s":
        if kind == "frontend":
            return "row-group layout sharding + fused phases + bf16 (§Perf T3: 30x)"
        if kind == "decode":
            return "HBM-bound weights+cache reads: int8/kv-quant or larger batch"
        if rec["useful_flop_ratio"] < 0.5:
            return "recompute + padding waste: selective remat; pad-free head sharding"
        return "fuse epilogues into matmuls; bf16 activations end-to-end"
    return "raise arithmetic intensity: bigger per-device tiles (less TP padding)"


def roofline_table(tag: str = "baseline") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant | MODEL_FLOPS/HLO | roofline MFU | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    recs = _load(tag, "single")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None or "skipped" in rec:
                continue
            if (arch == "fpca-frontend") != (shape in ("video_1080", "sensor_4k")):
                continue
            t = rec["terms"]
            lines.append(
                f"| {arch} | {shape} "
                f"| {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} "
                f"| {t['collective_s']*1e3:.2f} | {t['dominant'].replace('_s','')} "
                f"| {rec['useful_flop_ratio']:.2f} | {rec['roofline_mfu']*100:.1f}% "
                f"| {_lever(rec)} |"
            )
    return "\n".join(lines)


def replace_block(text: str, marker: str, content: str) -> str:
    pattern = re.compile(
        rf"(<!-- AUTOGEN:{marker} -->).*?(<!-- /AUTOGEN:{marker} -->)", re.DOTALL
    )
    repl = rf"\1\n{content}\n\2"
    if not pattern.search(text):
        raise SystemExit(f"marker {marker} not found in EXPERIMENTS.md")
    return pattern.sub(repl, text)


def main() -> None:
    text = EXPERIMENTS.read_text()
    text = replace_block(text, "dryrun", dryrun_table())
    text = replace_block(text, "roofline", roofline_table())
    EXPERIMENTS.write_text(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
