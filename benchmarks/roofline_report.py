"""Roofline table from dry-run artifacts (artifacts/dryrun/<tag>/*.json).

Not a timing benchmark: it summarises the compiled-artifact analysis that
EXPERIMENTS.md §Roofline reports (terms in ms, dominant bottleneck, useful
FLOP ratio, roofline-bounded MFU).
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import Row

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_records(tag: str = "baseline", mesh: str = "single") -> list[dict]:
    recs = []
    for p in sorted((ARTIFACTS / tag).glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if "skipped" not in rec:
            recs.append(rec)
    return recs


def run() -> list[Row]:
    rows: list[Row] = []
    for mesh in ("single", "multi"):
        recs = load_records(mesh=mesh)
        if not recs:
            rows.append((f"roofline_{mesh}", 0.0, "no artifacts — run launch/dryrun.py"))
            continue
        for r in recs:
            t = r["terms"]
            rows.append(
                (f"roofline_{mesh}_{r['arch']}_{r['shape']}", 0.0,
                 f"compute={t['compute_s']*1e3:.1f}ms memory={t['memory_s']*1e3:.1f}ms "
                 f"collective={t['collective_s']*1e3:.1f}ms dom={t['dominant'].replace('_s','')} "
                 f"useful={r['useful_flop_ratio']:.2f} mfu_bound={r['roofline_mfu']:.3f}")
            )
    return rows
