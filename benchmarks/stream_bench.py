"""Streaming frontend benchmark: delta-gated vs dense serving throughput.

A synthetic moving-object stream (small frame-to-frame change fraction —
the paper's continuous-vision regime) runs through the double-buffered
:class:`~repro.serving.streaming.StreamServer` twice: once with the temporal
delta gate compacting windows in-kernel, once dense.  Records frames/sec,
the kept/skipped window fractions, and the masked-over-dense speedup to
``BENCH_stream.json`` at the repo root — compare against the PR-1 batch
baseline with ``python -m benchmarks.perf_compare --stream``.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import Row
from repro.core.curvefit import fit_bucket_model
from repro.core.mapping import FPCASpec, output_dims
from repro.data.pipeline import SyntheticMovingObject
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.streaming import DeltaGateConfig, StreamServer

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_stream.json"

# c_o = 32 puts real matmul-bank work behind every window (the Fig. 9
# "savings erased at c_o=32" operating point) — small channel counts are
# dispatch-overhead-bound on CPU and would understate the masked win.
H = 160
C_O = 32
N_FRAMES = 48
N_STREAMS = 2
GATE = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=24)


def _serve(pipe: FPCAPipeline, cams: dict, gating: bool) -> tuple[float, StreamServer]:
    server = StreamServer(pipe, GATE, depth=2, gating=gating)
    for name in cams:
        server.add_stream(name, "cam")
    ticks = (
        {name: cam.frame_at(t) for name, cam in cams.items()}
        for t in range(N_FRAMES)
    )
    t0 = time.perf_counter()
    for _ in server.run(ticks):
        pass
    return time.perf_counter() - t0, server


def run() -> list[Row]:
    model = fit_bucket_model(n_pixels=75)
    spec = FPCASpec(image_h=H, image_w=H, out_channels=C_O, kernel=5, stride=5)
    rng = np.random.default_rng(0)
    kernel = (rng.normal(size=(C_O, 5, 5, 3)) * 0.2).astype(np.float32)
    pipe = FPCAPipeline(model, backend="basis")
    pipe.register("cam", spec, kernel)
    cams = {
        f"cam{i}": SyntheticMovingObject((H, H), seed=i + 1)
        for i in range(N_STREAMS)
    }

    # warm both paths (compiles), then time
    _serve(pipe, cams, gating=True)
    _serve(pipe, cams, gating=False)
    t_gated, server = _serve(pipe, cams, gating=True)
    t_dense, _ = _serve(pipe, cams, gating=False)

    frames = N_FRAMES * N_STREAMS
    fps_gated = frames / t_gated
    fps_dense = frames / t_dense
    s = server.stats
    kept_frac = s.windows_kept / s.windows_total
    h_o, w_o = output_dims(spec)
    rep = server.sessions["cam0"].energy_report()

    record = {
        "workload": {
            "streams": N_STREAMS, "frames_per_stream": N_FRAMES,
            "image": [H, H, 3],
            "spec": {"kernel": spec.kernel, "stride": spec.stride,
                     "out_channels": spec.out_channels, "binning": spec.binning},
            "windows_per_frame": h_o * w_o,
            "gate": {"threshold": GATE.threshold, "hysteresis": GATE.hysteresis,
                     "keyframe_interval": GATE.keyframe_interval},
        },
        "backend": "basis (XLA lowering of the Pallas kernel math)",
        "masked": {"s_total": t_gated, "frames_per_s": fps_gated},
        "dense": {"s_total": t_dense, "frames_per_s": fps_dense},
        "speedup_masked_vs_dense": fps_gated / fps_dense,
        "kept_window_frac": kept_frac,
        "skipped_window_frac": 1.0 - kept_frac,
        "sensor_model": {
            "energy_vs_dense": rep["energy_vs_dense"],
            "latency_vs_dense": rep["latency_vs_dense"],
            "fps_effective": rep["fps_effective"],
        },
    }
    BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")

    us_gated = t_gated / frames * 1e6
    us_dense = t_dense / frames * 1e6
    return [
        ("stream_delta_gated", us_gated,
         f"{N_STREAMS}x{N_FRAMES} frames {H}x{H} -> {fps_gated:.0f} frames/s "
         f"kept={kept_frac:.1%} speedup_vs_dense="
         f"{record['speedup_masked_vs_dense']:.2f}x (json: {BENCH_JSON.name})"),
        ("stream_dense", us_dense, f"{fps_dense:.0f} frames/s"),
    ]
