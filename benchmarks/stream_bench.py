"""Streaming frontend benchmark: delta-gated vs dense serving throughput,
plus the adaptive control plane on top.

A synthetic moving-object stream (small frame-to-frame change fraction —
the paper's continuous-vision regime) runs through the double-buffered
:class:`~repro.serving.streaming.StreamServer` three ways: dense, delta-gated
with the stateless (flapping) row bucket, and delta-gated with sticky bucket
hysteresis (``bucket_patience``).  Records frames/sec, the kept/skipped
window fractions, the masked-over-dense speedup, the executable bucket
switch counts (sticky vs flap), and a keep-fraction servo convergence trace
(:class:`~repro.serving.control.GateController` against a 0.15 budget) to
``BENCH_stream.json`` at the repo root — compare against the PR-1 batch
baseline with ``python -m benchmarks.perf_compare --stream``.
"""

from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from benchmarks._util import write_json
from benchmarks.common import Row
from repro.core.curvefit import fit_bucket_model
from repro.core.mapping import FPCASpec, output_dims
from repro.data.pipeline import SyntheticMovingObject
from repro.fpca import DeltaGateConfig, GateControllerConfig, telemetry
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.observe import fleet_report
from repro.serving.streaming import StreamServer

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_stream.json"
TELEMETRY_JSONL = Path(__file__).resolve().parents[1] / "telemetry_stream.jsonl"

# c_o = 32 puts real matmul-bank work behind every window (the Fig. 9
# "savings erased at c_o=32" operating point) — small channel counts are
# dispatch-overhead-bound on CPU and would understate the masked win.
H = 160
C_O = 32
N_FRAMES = 48
N_STREAMS = 2
GATE = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=24)
BUCKET_PATIENCE = 4
# servo scene: blob big enough that the 0.15 budget is inside the gate's
# reachable kept-fraction range at this resolution
CONTROLLER = GateControllerConfig(target=0.15)
# energy servo: same loop closed on analysis.frontend_energy's
# executed-energy fraction (cycle-granular RS/SW gating + IO term) instead
# of the raw kept-window fraction — the budget a battery deployment sets
CONTROLLER_ENERGY = GateControllerConfig(target=0.15, metric="energy")
SERVO_RADIUS = 18.0


def _serve(
    pipe: FPCAPipeline,
    cams: dict,
    gating: bool,
    controller: GateControllerConfig | None = None,
) -> tuple[float, StreamServer]:
    server = StreamServer(pipe, GATE, depth=2, gating=gating, controller=controller)
    for name in cams:
        server.add_stream(name, "cam")
    ticks = (
        {name: cam.frame_at(t) for name, cam in cams.items()}
        for t in range(N_FRAMES)
    )
    t0 = time.perf_counter()
    for _ in server.run(ticks):
        pass
    return time.perf_counter() - t0, server


def _serve_scan(
    pipe: FPCAPipeline,
    frame_stacks: dict[str, np.ndarray],
    m_bucket: int | None = None,
) -> tuple[float, StreamServer]:
    """All N_FRAMES ticks of every stream served as ONE compiled
    ``lax.scan`` segment per stream (K = N_FRAMES, gate inside the carry)."""
    server = StreamServer(pipe, GATE, depth=2, gating=True)
    for name in frame_stacks:
        server.add_stream(name, "cam")
    t0 = time.perf_counter()
    for name, stack in frame_stacks.items():
        server.run_segment(name, stack, m_bucket=m_bucket)
    return time.perf_counter() - t0, server


def run() -> list[Row]:
    model = fit_bucket_model(n_pixels=75)
    spec = FPCASpec(image_h=H, image_w=H, out_channels=C_O, kernel=5, stride=5)
    rng = np.random.default_rng(0)
    kernel = (rng.normal(size=(C_O, 5, 5, 3)) * 0.2).astype(np.float32)

    def make_pipe(patience: int) -> FPCAPipeline:
        pipe = FPCAPipeline(model, backend="basis", bucket_patience=patience)
        pipe.register("cam", spec, kernel)
        return pipe

    pipe_flap = make_pipe(1)            # stateless buckets: the PR-2 behaviour
    pipe_sticky = make_pipe(BUCKET_PATIENCE)
    cams = {
        f"cam{i}": SyntheticMovingObject((H, H), seed=i + 1)
        for i in range(N_STREAMS)
    }

    # warm both pipelines (compiles), then time; bucket-switch counts are
    # measured over the timed serve only (stats delta)
    _serve(pipe_flap, cams, gating=True)
    _serve(pipe_flap, cams, gating=False)
    _serve(pipe_sticky, cams, gating=True)

    # reset sticky state so each timed pass replays exactly the bucket
    # sequence its warm-up compiled (and switch counts are self-contained)
    pipe_flap.reset_bucket_state()
    sw0 = pipe_flap.stats.bucket_switches
    t_gated, server = _serve(pipe_flap, cams, gating=True)
    switches_flap = pipe_flap.stats.bucket_switches - sw0
    t_dense, _ = _serve(pipe_flap, cams, gating=False)
    pipe_sticky.reset_bucket_state()
    sw0 = pipe_sticky.stats.bucket_switches
    df0 = pipe_sticky.stats.bucket_shrinks_deferred
    t_sticky, _ = _serve(pipe_sticky, cams, gating=True)
    switches_sticky = pipe_sticky.stats.bucket_switches - sw0
    shrinks_deferred = pipe_sticky.stats.bucket_shrinks_deferred - df0

    # scan-segment lane: the same gated workload, but every stream's
    # N_FRAMES ticks come from ONE device-compiled lax.scan launch.  The
    # probe pass realises each scene's kept counts (and compiles the
    # masked-dense scan); the timed pass serves the pow2 row bucket those
    # counts suggest — the servo-picks-the-bucket-between-segments contract.
    frame_stacks = {
        name: np.stack([cam.frame_at(t) for t in range(N_FRAMES)])
        for name, cam in cams.items()
    }
    _, probe = _serve_scan(pipe_flap, frame_stacks)
    scan_bucket = max(
        probe.sessions[n]._segment_state.suggested_bucket or 1
        for n in frame_stacks
    )
    _serve_scan(pipe_flap, frame_stacks, m_bucket=scan_bucket)   # warm-up
    t_scan, scan_server = _serve_scan(
        pipe_flap, frame_stacks, m_bucket=scan_bucket
    )
    fps_scan = N_FRAMES * N_STREAMS / t_scan

    # telemetry lane: the SAME scan workload with a live session (JSONL +
    # sampled honest device time) — what the CI bench-smoke job uploads —
    # plus the zero-overhead-when-disabled guard for the hot tick path
    telemetry.enable(
        TELEMETRY_JSONL, device_time_rate=4,
        run_labels={"bench": "stream_scan_segment"},
    )
    t_scan_tel, tel_server = _serve_scan(
        pipe_flap, frame_stacks, m_bucket=scan_bucket
    )
    fleet = fleet_report(tel_server)
    n_events = telemetry.session().events_written
    telemetry.disable()

    # disabled-mode overhead: measured per-crossing cost of the disabled
    # hooks (span() null return + the instrumented-launch is-None check)
    # times the hook crossings the timed scan lane actually makes, as a
    # fraction of its wall time.  The guard (<= 2%) is asserted over the
    # committed artifact by tests/test_bench_schema.py.
    n_iter = 200_000
    fields = {"stream": "cam0"}
    t0 = time.perf_counter()
    for _ in range(n_iter):
        with telemetry.span("serve_segment", fields):
            pass
    hook_cost_s = (time.perf_counter() - t0) / n_iter
    # per segment: serve_segment + run_segment spans, the run_segment
    # dispatch enabled() check, and one instrumented launch — x streams
    hook_crossings = 4 * N_STREAMS
    disabled_overhead_frac = hook_cost_s * hook_crossings / t_scan

    # keep-fraction servo convergence (one camera, servo-friendly scene)
    servo_cams = {"cam0": SyntheticMovingObject((H, H), seed=1, radius=SERVO_RADIUS)}
    _, servo_server = _serve(pipe_sticky, servo_cams, gating=True, controller=CONTROLLER)
    ctl = servo_server.sessions["cam0"].controller
    assert ctl is not None

    # energy-budget servo on the same scene: the controller observes the
    # sensor-model executed-energy fraction per tick instead of the kept
    # fraction (ROADMAP open item: servo the "energy" metric end to end)
    _, servo_e_server = _serve(
        pipe_sticky, servo_cams, gating=True, controller=CONTROLLER_ENERGY
    )
    ctl_e = servo_e_server.sessions["cam0"].controller
    assert ctl_e is not None

    frames = N_FRAMES * N_STREAMS
    fps_gated = frames / t_gated
    fps_dense = frames / t_dense
    fps_sticky = frames / t_sticky
    s = server.stats
    kept_frac = s.windows_kept / s.windows_total
    h_o, w_o = output_dims(spec)
    rep = server.sessions["cam0"].energy_report()

    record = {
        "workload": {
            "streams": N_STREAMS, "frames_per_stream": N_FRAMES,
            "image": [H, H, 3],
            "spec": {"kernel": spec.kernel, "stride": spec.stride,
                     "out_channels": spec.out_channels, "binning": spec.binning},
            "windows_per_frame": h_o * w_o,
            "gate": {"threshold": GATE.threshold, "hysteresis": GATE.hysteresis,
                     "keyframe_interval": GATE.keyframe_interval},
        },
        "backend": "basis (XLA lowering of the Pallas kernel math)",
        "masked": {"s_total": t_gated, "frames_per_s": fps_gated},
        "dense": {"s_total": t_dense, "frames_per_s": fps_dense},
        "scan_segment": {
            "s_total": t_scan,
            "frames_per_s": fps_scan,
            "segment_length": N_FRAMES,
            "m_bucket": scan_bucket,
            "kept_window_frac": (
                scan_server.stats.windows_kept
                / max(scan_server.stats.windows_total, 1)
            ),
            "launches_skipped": scan_server.stats.launches_skipped,
            "speedup_vs_per_tick_masked": None,  # filled below
        },
        "speedup_masked_vs_dense": fps_gated / fps_dense,
        "kept_window_frac": kept_frac,
        "skipped_window_frac": 1.0 - kept_frac,
        "sticky_buckets": {
            "patience": BUCKET_PATIENCE,
            "switches_flap": switches_flap,
            "switches_sticky": switches_sticky,
            "shrinks_deferred": shrinks_deferred,
            "s_total": t_sticky,
            "frames_per_s": fps_sticky,
        },
        "controller": {
            "target_kept_frac": CONTROLLER.target,
            "metric": CONTROLLER.metric,
            "servo_radius": SERVO_RADIUS,
            "converged_tick": ctl.converged_tick(rel_tol=0.2),
            "ticks": len(ctl.history),
            "final_threshold": ctl.threshold,
            "final_ema": ctl.ema,
            "history": [
                {"tick": h["tick"], "threshold": round(h["threshold"], 6),
                 "ema": None if h["ema"] is None else round(h["ema"], 4)}
                for h in ctl.history
            ],
        },
        "controller_energy": {
            "target_energy_frac": CONTROLLER_ENERGY.target,
            "metric": CONTROLLER_ENERGY.metric,
            "servo_radius": SERVO_RADIUS,
            "converged_tick": ctl_e.converged_tick(rel_tol=0.2),
            "ticks": len(ctl_e.history),
            "final_threshold": ctl_e.threshold,
            "final_ema": ctl_e.ema,
            "history": [
                {"tick": h["tick"], "threshold": round(h["threshold"], 6),
                 "ema": None if h["ema"] is None else round(h["ema"], 4)}
                for h in ctl_e.history
            ],
        },
        "sensor_model": {
            "energy_vs_dense": rep["energy_vs_dense"],
            "latency_vs_dense": rep["latency_vs_dense"],
            "fps_effective": rep["fps_effective"],
        },
        "telemetry": {
            "jsonl": TELEMETRY_JSONL.name,
            "events": n_events,
            "s_total_enabled": t_scan_tel,
            "enabled_overhead_frac": t_scan_tel / t_scan - 1.0,
            "disabled_hook_cost_s": hook_cost_s,
            "hook_crossings": hook_crossings,
            "disabled_overhead_frac": disabled_overhead_frac,
            "fleet_report": fleet,
        },
    }
    record["scan_segment"]["speedup_vs_per_tick_masked"] = fps_scan / fps_gated
    write_json(BENCH_JSON, record)

    us_gated = t_gated / frames * 1e6
    us_dense = t_dense / frames * 1e6
    return [
        ("stream_scan_segment", t_scan / frames * 1e6,
         f"K={N_FRAMES} lax.scan segments -> {fps_scan:.0f} frames/s "
         f"(bucket {scan_bucket}, "
         f"{fps_scan / fps_gated:.2f}x per-tick masked)"),
        ("stream_delta_gated", us_gated,
         f"{N_STREAMS}x{N_FRAMES} frames {H}x{H} -> {fps_gated:.0f} frames/s "
         f"kept={kept_frac:.1%} speedup_vs_dense="
         f"{record['speedup_masked_vs_dense']:.2f}x (json: {BENCH_JSON.name})"),
        ("stream_dense", us_dense, f"{fps_dense:.0f} frames/s"),
        ("stream_sticky_buckets", t_sticky / frames * 1e6,
         f"{fps_sticky:.0f} frames/s  bucket switches {switches_sticky} "
         f"(vs {switches_flap} stateless)"),
        ("stream_servo", 0.0,
         f"kept->{CONTROLLER.target:.2f} budget converged at tick "
         f"{record['controller']['converged_tick']} "
         f"(thr {ctl.threshold:.4f}, ema {ctl.ema:.3f})"),
        ("stream_servo_energy", 0.0,
         f"energy->{CONTROLLER_ENERGY.target:.2f} budget converged at tick "
         f"{record['controller_energy']['converged_tick']} "
         f"(thr {ctl_e.threshold:.4f}, ema {ctl_e.ema:.3f})"),
        ("stream_telemetry", 0.0,
         f"disabled hooks {disabled_overhead_frac:.2e} of scan lane, "
         f"{n_events} JSONL events when enabled "
         f"(jsonl: {TELEMETRY_JSONL.name})"),
    ]
