"""Compare performance artifacts.

Dry-run mode — two artifact tags (baseline vs a hillclimb variant):

    PYTHONPATH=src python -m benchmarks.perf_compare baseline hc_granite_dots \
        --cell granite-moe-3b-a800m__train_4k__single

Stream mode — diff the streaming benchmark (``BENCH_stream.json``, delta-gated
video serving) against the PR-1 batch-frontend baseline
(``BENCH_frontend.json``):

    PYTHONPATH=src python -m benchmarks.perf_compare --stream

Model mode — diff the end-to-end classifier benchmark (``BENCH_model.json``,
fused frontend + digital head) against the frontend-only baseline:

    PYTHONPATH=src python -m benchmarks.perf_compare --model

Telemetry mode — render the fleet report and overhead-guard numbers the
benches recorded under their ``telemetry`` sections:

    PYTHONPATH=src python -m benchmarks.perf_compare --telemetry
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
ARTIFACTS = REPO / "artifacts" / "dryrun"


def load(tag: str, cell: str) -> dict:
    p = ARTIFACTS / tag / f"{cell}.json"
    return json.loads(p.read_text())


def fmt(rec: dict) -> str:
    t = rec["terms"]
    return (
        f"compute={t['compute_s']*1e3:9.2f}ms memory={t['memory_s']*1e3:9.2f}ms "
        f"collective={t['collective_s']*1e3:9.2f}ms bound={t['bound_s']*1e3:9.2f}ms "
        f"dom={t['dominant'].replace('_s',''):10s} useful={rec['useful_flop_ratio']:.3f} "
        f"mfu={rec['roofline_mfu']*100:.2f}% temp={rec['memory']['temp_bytes']/1e9:.1f}GB"
    )


def compare_stream(frontend_path: Path, stream_path: Path) -> None:
    """Streaming (delta-gated video) vs the batched-frontend baseline."""
    fe = json.loads(frontend_path.read_text())
    st = json.loads(stream_path.read_text())
    print(f"baseline  ({frontend_path.name}): "
          f"{fe['frames_per_s']:8.1f} frames/s  batch={fe['workload']['batch']} "
          f"image={fe['workload']['image']} "
          f"windows/frame={fe['workload']['windows_per_frame']}")
    print(f"stream    ({stream_path.name}): "
          f"{st['masked']['frames_per_s']:8.1f} frames/s (delta-gated)  "
          f"{st['dense']['frames_per_s']:8.1f} frames/s (dense)  "
          f"streams={st['workload']['streams']} image={st['workload']['image']}")
    print(f"  masked vs dense streaming : {st['speedup_masked_vs_dense']:.2f}x "
          f"(kept {st['kept_window_frac']:.1%} of windows)")
    print(f"  masked stream vs baseline : "
          f"{st['masked']['frames_per_s'] / fe['frames_per_s']:.2f}x frames/s")
    print(f"  sensor-model accounting   : "
          f"energy {st['sensor_model']['energy_vs_dense']:.2f}x, "
          f"latency {st['sensor_model']['latency_vs_dense']:.2f}x dense")
    # control-plane fields (absent in pre-adaptive BENCH_stream.json files)
    sb = st.get("sticky_buckets")
    if sb:
        print(f"  sticky buckets (K={sb['patience']}) : "
              f"{sb['switches_sticky']} executable switches "
              f"vs {sb['switches_flap']} stateless "
              f"({sb['shrinks_deferred']} shrinks deferred, "
              f"{sb['frames_per_s']:.1f} frames/s)")
    ctl = st.get("controller")
    if ctl:
        conv = ctl["converged_tick"]
        conv_s = f"tick {conv}" if conv is not None else "never"
        print(f"  keep-fraction servo       : target {ctl['target_kept_frac']:.2f} "
              f"converged at {conv_s} / {ctl['ticks']} ticks "
              f"(final thr {ctl['final_threshold']:.4f}, "
              f"ema {ctl['final_ema']:.3f})")
    scan = st.get("scan_segment")
    if scan:
        print(f"  scan-segment lane         : "
              f"{scan['frames_per_s']:.1f} frames/s "
              f"(K={scan['segment_length']} lax.scan, bucket "
              f"{scan['m_bucket']}, "
              f"{scan['speedup_vs_per_tick_masked']:.2f}x per-tick masked)")
    ctl_e = st.get("controller_energy")
    if ctl_e:
        conv = ctl_e["converged_tick"]
        conv_s = f"tick {conv}" if conv is not None else "never"
        print(f"  energy-budget servo       : target {ctl_e['target_energy_frac']:.2f} "
              f"converged at {conv_s} / {ctl_e['ticks']} ticks "
              f"(final thr {ctl_e['final_threshold']:.4f}, "
              f"ema {ctl_e['final_ema']:.3f})")


def _fps(v) -> str:
    """Render an fps figure; ``None`` is the zero-work sentinel (an idle
    stream executed nothing — the rate is undefined, shown as ``–``)."""
    return "–" if v is None else f"{v:.0f}"


def compare_model(frontend_path: Path, model_path: Path) -> None:
    """Whole-model classifier (frontend + head) vs the frontend baseline."""
    fe = json.loads(frontend_path.read_text())
    md = json.loads(model_path.read_text())
    head = md["head"]
    print(f"baseline  ({frontend_path.name}): "
          f"{fe['frames_per_s']:8.1f} frames/s (frontend only)  "
          f"batch={fe['workload']['batch']} image={fe['workload']['image']}")
    print(f"model     ({model_path.name}): "
          f"{md['batched_dense']['frames_per_s']:8.1f} frames/s batched  "
          f"image={md['workload']['image']} "
          f"head={'+'.join(md['workload']['head'])}")
    print(f"  streaming classification   : "
          f"{md['stream_masked']['frames_per_s']:8.1f} frames/s delta-gated vs "
          f"{md['stream_dense']['frames_per_s']:8.1f} dense -> "
          f"{md['speedup_masked_vs_dense']:.2f}x "
          f"(kept {md['kept_window_frac']:.1%} of windows, logits every tick)")
    scan = md.get("scan_segment")
    if scan:
        print(f"  scan-segment lane          : "
              f"{scan['frames_per_s']:.1f} frames/s "
              f"(K={scan['segment_length']} lax.scan, bucket "
              f"{scan['m_bucket']}, "
              f"{scan['speedup_vs_per_tick_masked']:.2f}x per-tick masked)")
    print(f"  digital head per frame     : "
          f"{head['macs_per_frame']/1e6:.2f} MMAC "
          f"({head['params']/1e3:.0f}k params, "
          f"{head['t_head_per_frame']*1e6:.1f} us, "
          f"{head['e_head_per_frame']*1e6:.2f} uJ)")
    sm = md["sensor_model"]
    print(f"  sensor-model accounting    : frontend energy "
          f"{sm['energy_vs_dense']:.2f}x dense, whole model "
          f"{sm['model_energy_vs_dense']:.2f}x energy / "
          f"{sm['model_latency_vs_dense']:.2f}x latency, "
          f"fps_effective {_fps(sm['model_fps_effective'])}")
    # int8 lanes (absent in pre-quantisation BENCH_model.json files)
    q = md.get("quantised_int8")
    if q:
        par = q["parity"]
        print(f"  int8 batched               : "
              f"{q['batched']['frames_per_s']:8.1f} frames/s "
              f"({q['batched']['speedup_vs_f32']:.2f}x f32 fused)")
        print(f"  int8 stream / scan         : "
              f"{q['stream_masked']['frames_per_s']:8.1f} frames/s masked "
              f"({q['stream_masked']['speedup_vs_f32']:.2f}x f32), "
              f"{q['scan_segment']['frames_per_s']:.1f} frames/s scan "
              f"({q['scan_segment']['speedup_vs_f32']:.2f}x f32)")
        print(f"  int8 parity vs f32         : max |dlogit| "
              f"{par['max_abs_divergence']:.4f}, top-1 agreement "
              f"{par['top1_agreement']:.2f}")
        hm = q["head_model"]
        print(f"  int8 head datapath model   : {hm['int8_speedup']:.1f}x "
              f"latency, {hm['int8_energy_ratio']:.2f}x energy per frame")


def show_telemetry(path: Path) -> None:
    """Render the ``telemetry`` section a bench recorded (fleet table,
    overhead guard, JSONL pointer) — ``--telemetry`` mode."""
    rec = json.loads(path.read_text())
    tel = rec.get("telemetry")
    if not tel:
        print(f"telemetry ({path.name}): no telemetry section — "
              f"re-run the bench to record one")
        return
    print(f"telemetry ({path.name}): {tel['events']} JSONL events "
          f"-> {tel['jsonl']}")
    if "disabled_overhead_frac" in tel:
        print(f"  disabled-hook overhead    : "
              f"{tel['disabled_overhead_frac']:.2e} of the scan lane "
              f"(guard: <= 0.02)")
    print(f"  enabled-session overhead  : "
          f"{tel['enabled_overhead_frac']:+.1%} scan wall time")
    from repro.serving.observe import render_fleet_report
    print(render_fleet_report(tel["fleet_report"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("base_tag", nargs="?")
    ap.add_argument("new_tag", nargs="?")
    ap.add_argument("--cell")
    ap.add_argument("--stream", action="store_true",
                    help="diff BENCH_stream.json vs BENCH_frontend.json")
    ap.add_argument("--model", action="store_true",
                    help="diff BENCH_model.json vs BENCH_frontend.json")
    ap.add_argument("--telemetry", action="store_true",
                    help="render the telemetry sections (fleet report, "
                         "overhead guard) of BENCH_stream/BENCH_model")
    ap.add_argument("--frontend-json", type=Path, default=REPO / "BENCH_frontend.json")
    ap.add_argument("--stream-json", type=Path, default=REPO / "BENCH_stream.json")
    ap.add_argument("--model-json", type=Path, default=REPO / "BENCH_model.json")
    args = ap.parse_args()
    if args.stream:
        compare_stream(args.frontend_json, args.stream_json)
    if args.model:
        compare_model(args.frontend_json, args.model_json)
    if args.telemetry:
        for p in (args.stream_json, args.model_json):
            if p.exists():
                show_telemetry(p)
    if args.stream or args.model or args.telemetry:
        return
    if not (args.base_tag and args.new_tag and args.cell):
        ap.error("dry-run mode needs base_tag, new_tag and --cell "
                 "(or pass --stream / --model)")
    a = load(args.base_tag, args.cell)
    b = load(args.new_tag, args.cell)
    print(f"cell: {args.cell}")
    print(f"  {args.base_tag:>16s}: {fmt(a)}")
    print(f"  {args.new_tag:>16s}: {fmt(b)}")
    ta, tb = a["terms"], b["terms"]
    for k in ("compute_s", "memory_s", "collective_s", "bound_s"):
        if ta[k] > 0:
            print(f"  {k:14s}: {tb[k]/ta[k]:.3f}x")
    print(f"  mfu: {a['roofline_mfu']*100:.2f}% -> {b['roofline_mfu']*100:.2f}% "
          f"({b['roofline_mfu']/max(a['roofline_mfu'],1e-12):.2f}x)")


if __name__ == "__main__":
    main()
