"""Compare two dry-run artifact tags (baseline vs a hillclimb variant).

    PYTHONPATH=src python -m benchmarks.perf_compare baseline hc_granite_dots \
        --cell granite-moe-3b-a800m__train_4k__single
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(tag: str, cell: str) -> dict:
    p = ARTIFACTS / tag / f"{cell}.json"
    return json.loads(p.read_text())


def fmt(rec: dict) -> str:
    t = rec["terms"]
    return (
        f"compute={t['compute_s']*1e3:9.2f}ms memory={t['memory_s']*1e3:9.2f}ms "
        f"collective={t['collective_s']*1e3:9.2f}ms bound={t['bound_s']*1e3:9.2f}ms "
        f"dom={t['dominant'].replace('_s',''):10s} useful={rec['useful_flop_ratio']:.3f} "
        f"mfu={rec['roofline_mfu']*100:.2f}% temp={rec['memory']['temp_bytes']/1e9:.1f}GB"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("base_tag")
    ap.add_argument("new_tag")
    ap.add_argument("--cell", required=True)
    args = ap.parse_args()
    a = load(args.base_tag, args.cell)
    b = load(args.new_tag, args.cell)
    print(f"cell: {args.cell}")
    print(f"  {args.base_tag:>16s}: {fmt(a)}")
    print(f"  {args.new_tag:>16s}: {fmt(b)}")
    ta, tb = a["terms"], b["terms"]
    for k in ("compute_s", "memory_s", "collective_s", "bound_s"):
        if ta[k] > 0:
            print(f"  {k:14s}: {tb[k]/ta[k]:.3f}x")
    print(f"  mfu: {a['roofline_mfu']*100:.2f}% -> {b['roofline_mfu']*100:.2f}% "
          f"({b['roofline_mfu']/max(a['roofline_mfu'],1e-12):.2f}x)")


if __name__ == "__main__":
    main()
