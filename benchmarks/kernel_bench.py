"""fpca_conv execution-path comparison on CPU (jit-compiled XLA):

* ``oracle``      — fixed-point circuit solve (deployment ground truth);
* ``bucket_ref``  — paper's sigmoid bucket model, naive per-pixel layout
                    (the pre-TPU-adaptation formulation);
* ``basis_form``  — the kernel's basis-expanded matmul-bank math in pure
                    jnp (what the Pallas kernel executes per tile).

The interesting derived number is the speedup of the basis form over the
naive bucket evaluation — the payoff of the MXU-native reformulation
(DESIGN.md §2); Pallas interpret-mode timings are not meaningful and are
not reported.

Also runs the **end-to-end batched frontend** benchmark: a frame batch
through the serving pipeline (images -> windows -> fused kernel -> SS-ADC
counts) versus a per-image loop, recorded to ``BENCH_frontend.json`` at the
repo root.
"""

from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import write_json
from benchmarks.common import Row, time_fn
from repro.core.adc import ADCConfig
from repro.core.curvefit import fit_bucket_model, predict_sigmoid
from repro.core.device_models import CircuitParams, analog_dot_product
from repro.kernels.fpca_conv.kernel import _bucket_tables, precompute_weight_planes
from repro.kernels.fpca_conv.ref import fpca_conv_ref

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_frontend.json"


def _basis_form(patches, w, model):
    """The kernel's math (one weight phase) as a flat jnp program."""
    mask = jnp.ones((patches.shape[1],), jnp.float32)
    planes = precompute_weight_planes(w, mask, model)
    tables = _bucket_tables(model)
    n_real = patches.shape[1]
    x = patches
    x2, x3 = x * x, x * x * x
    xp = {1: x, 2: x2, 3: x3}
    maskv = mask[:, None]
    rv = {a: xp[a] @ maskv for a in (1, 2, 3)}
    mean_i = rv[1] / n_real
    a_i = jnp.concatenate([mean_i ** int(a) for a, _ in model.f_avg.exps], axis=1)
    mm = {(a, b): xp[a] @ planes["w_pows"][b - 1] for (a, b) in ((1, 1), (1, 2), (2, 1))}
    v_est = a_i @ planes["aw"]
    xg = v_est / model.v_range
    edges = np.arange(model.n_buckets, dtype=np.float32) / model.n_buckets
    v_pred = jnp.zeros_like(xg)
    for i in range(model.n_buckets):
        gate = (
            jax.nn.sigmoid(model.sharpness * (xg - edges[i]))
            + jax.nn.sigmoid(model.sharpness * (edges[i] + 1.0 / model.n_buckets - xg))
            - 1.0
        )
        acc = jnp.full_like(xg, tables["const"][i])
        for (a, b), c in tables["by_pair"].items():
            ci = float(c[i])
            if a == 0:
                acc += ci * planes["cs"][b][None, :]
            elif b == 0:
                acc += ci * rv[a]
            else:
                acc += ci * mm[(a, b)]
        v_pred += gate * acc
    return v_pred


def _frontend_rows(model) -> list[Row]:
    """End-to-end batched frontend throughput (serving pipeline, basis
    backend — the CPU-lowered form of the Pallas kernel's math); writes
    ``BENCH_frontend.json``."""
    from repro.core.fpca_sim import fpca_forward
    from repro.core.mapping import FPCASpec, output_dims
    from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest

    B, H = 32, 120
    spec = FPCASpec(image_h=H, image_w=H, out_channels=8, kernel=5, stride=5)
    rng = np.random.default_rng(0)
    kernel = jnp.asarray(rng.normal(size=(8, 5, 5, 3)) * 0.2, jnp.float32)
    frames = rng.uniform(0, 1, (B, H, H, 3)).astype(np.float32)

    pipe = FPCAPipeline(model, backend="basis")
    pipe.register("bench", spec, kernel)
    reqs = [FrontendRequest("bench", frames[i]) for i in range(B)]
    us_batched = time_fn(lambda: pipe.serve(reqs), iters=5)

    # per-image loop over the same fused backend: what batching buys
    # (a real B-iteration loop, not an extrapolated singleton timing)
    singles = [[FrontendRequest("bench", frames[i])] for i in range(B)]
    us_loop = time_fn(lambda: [pipe.serve(s) for s in singles], iters=3)

    # dense reference simulation, batched (the pre-kernel path)
    ref = jax.jit(
        lambda imgs: fpca_forward(
            imgs, kernel, spec, model=model, mode="bucket_sigmoid", hard=True
        )["counts"]
    )
    us_ref = time_fn(ref, jnp.asarray(frames), iters=5)

    h_o, w_o = output_dims(spec)
    frames_per_s = B / (us_batched * 1e-6)
    record = {
        "workload": {
            "batch": B, "image": [H, H, 3],
            "spec": {"kernel": spec.kernel, "stride": spec.stride,
                     "out_channels": spec.out_channels, "binning": spec.binning},
            "windows_per_frame": h_o * w_o,
        },
        "backend": "basis (XLA lowering of the Pallas kernel math)",
        "us_per_batch": us_batched,
        "frames_per_s": frames_per_s,
        "windows_per_s": frames_per_s * h_o * w_o,
        "us_per_image_loop": us_loop,
        "speedup_vs_per_image_loop": us_loop / us_batched,
        "us_dense_reference_batch": us_ref,
        "speedup_vs_dense_reference": us_ref / us_batched,
    }
    write_json(BENCH_JSON, record)
    return [
        ("frontend_e2e_batched", us_batched,
         f"B={B} {H}x{H} -> {frames_per_s:.0f} frames/s "
         f"speedup_vs_loop={record['speedup_vs_per_image_loop']:.1f}x "
         f"(json: {BENCH_JSON.name})"),
        ("frontend_e2e_per_image_loop", us_loop, f"B={B} singleton submits"),
        ("frontend_e2e_dense_reference", us_ref,
         f"speedup_of_kernel={record['speedup_vs_dense_reference']:.1f}x"),
    ]


def run() -> list[Row]:
    params = CircuitParams()
    model = fit_bucket_model(params)
    rng = np.random.default_rng(0)
    M, N, C = 4096, 75, 64
    patches = jnp.asarray(rng.uniform(0, 1, (M, N)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, (N, C)), jnp.float32)

    oracle = jax.jit(
        lambda p, ww: analog_dot_product(
            jnp.broadcast_to(p[:, None, :], (M, C, N)), ww.T[None], params
        )
    )
    naive = jax.jit(
        lambda p, ww: predict_sigmoid(
            model, jnp.broadcast_to(p[:, None, :], (M, C, N)), ww.T[None]
        )
    )
    basis = jax.jit(lambda p, ww: _basis_form(p, ww, model))

    us_oracle = time_fn(oracle, patches, w, iters=5)
    us_naive = time_fn(naive, patches, w, iters=5)
    us_basis = time_fn(basis, patches, w, iters=5)

    # correctness tie-back: basis form == naive bucket model
    err = float(jnp.max(jnp.abs(basis(patches, w) - naive(patches, w))))

    rows: list[Row] = [
        ("kernel_oracle_fixed_point", us_oracle, f"M={M} C={C} (deploy ground truth)"),
        ("kernel_bucket_naive", us_naive, "per-pixel polynomial layout"),
        ("kernel_bucket_basis_form", us_basis,
         f"speedup_vs_naive={us_naive/us_basis:.1f}x max|dV|={err:.2e} "
         "(MXU-native matmul-bank reformulation)"),
    ]
    rows += _frontend_rows(model)
    return rows
