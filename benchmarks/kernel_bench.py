"""fpca_conv execution-path comparison on CPU (jit-compiled XLA):

* ``oracle``      — fixed-point circuit solve (deployment ground truth);
* ``bucket_ref``  — paper's sigmoid bucket model, naive per-pixel layout
                    (the pre-TPU-adaptation formulation);
* ``basis_form``  — the kernel's basis-expanded matmul-bank math in pure
                    jnp (what the Pallas kernel executes per tile).

The interesting derived number is the speedup of the basis form over the
naive bucket evaluation — the payoff of the MXU-native reformulation
(DESIGN.md §2); Pallas interpret-mode timings are not meaningful and are
not reported.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.core.adc import ADCConfig
from repro.core.curvefit import fit_bucket_model, predict_sigmoid
from repro.core.device_models import CircuitParams, analog_dot_product
from repro.kernels.fpca_conv.kernel import _bucket_tables, precompute_weight_planes
from repro.kernels.fpca_conv.ref import fpca_conv_ref


def _basis_form(patches, w, model):
    """The kernel's math (one weight phase) as a flat jnp program."""
    mask = jnp.ones((patches.shape[1],), jnp.float32)
    planes = precompute_weight_planes(w, mask, model)
    tables = _bucket_tables(model)
    n_real = patches.shape[1]
    x = patches
    x2, x3 = x * x, x * x * x
    xp = {1: x, 2: x2, 3: x3}
    maskv = mask[:, None]
    rv = {a: xp[a] @ maskv for a in (1, 2, 3)}
    mean_i = rv[1] / n_real
    a_i = jnp.concatenate([mean_i ** int(a) for a, _ in model.f_avg.exps], axis=1)
    mm = {(a, b): xp[a] @ planes["w_pows"][b - 1] for (a, b) in ((1, 1), (1, 2), (2, 1))}
    v_est = a_i @ planes["aw"]
    xg = v_est / model.v_range
    edges = np.arange(model.n_buckets, dtype=np.float32) / model.n_buckets
    v_pred = jnp.zeros_like(xg)
    for i in range(model.n_buckets):
        gate = (
            jax.nn.sigmoid(model.sharpness * (xg - edges[i]))
            + jax.nn.sigmoid(model.sharpness * (edges[i] + 1.0 / model.n_buckets - xg))
            - 1.0
        )
        acc = jnp.full_like(xg, tables["const"][i])
        for (a, b), c in tables["by_pair"].items():
            ci = float(c[i])
            if a == 0:
                acc += ci * planes["cs"][b][None, :]
            elif b == 0:
                acc += ci * rv[a]
            else:
                acc += ci * mm[(a, b)]
        v_pred += gate * acc
    return v_pred


def run() -> list[Row]:
    params = CircuitParams()
    model = fit_bucket_model(params)
    rng = np.random.default_rng(0)
    M, N, C = 4096, 75, 64
    patches = jnp.asarray(rng.uniform(0, 1, (M, N)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, (N, C)), jnp.float32)

    oracle = jax.jit(
        lambda p, ww: analog_dot_product(
            jnp.broadcast_to(p[:, None, :], (M, C, N)), ww.T[None], params
        )
    )
    naive = jax.jit(
        lambda p, ww: predict_sigmoid(
            model, jnp.broadcast_to(p[:, None, :], (M, C, N)), ww.T[None]
        )
    )
    basis = jax.jit(lambda p, ww: _basis_form(p, ww, model))

    us_oracle = time_fn(oracle, patches, w, iters=5)
    us_naive = time_fn(naive, patches, w, iters=5)
    us_basis = time_fn(basis, patches, w, iters=5)

    # correctness tie-back: basis form == naive bucket model
    err = float(jnp.max(jnp.abs(basis(patches, w) - naive(patches, w))))

    rows: list[Row] = [
        ("kernel_oracle_fixed_point", us_oracle, f"M={M} C={C} (deploy ground truth)"),
        ("kernel_bucket_naive", us_naive, "per-pixel polynomial layout"),
        ("kernel_bucket_basis_form", us_basis,
         f"speedup_vs_naive={us_naive/us_basis:.1f}x max|dV|={err:.2e} "
         "(MXU-native matmul-bank reformulation)"),
    ]
    return rows
