"""Fleet serving benchmark: weak scaling over (emulated) devices + budget
arbitration traces.

Three lanes, all through :class:`repro.serving.fleet.FleetController` on one
:class:`~repro.serving.streaming.StreamServer`:

* **Weak scaling** — 2 streams per device, the fused union-masked batch
  sharded over ``make_host_mesh(data=d)`` for d in 1..8.  The emulated
  devices all share one physical CPU, so the honest ideal is not more
  aggregate FLOPs but a *flat per-stream service time* as the fleet grows
  8x: one fused launch per tick regardless of stream count, and the
  per-stream host increment (vmapped fleet gating, mask building) small
  against the fixed dispatch cost.  Reported as ``stream_ticks_per_s`` per
  point and ``efficiency = rate(d) / rate(1)`` — linear weak scaling means
  serving 8x the streams costs 8x the wall clock, i.e. efficiency 1.0;
  the acceptance bar is >= 0.8 (within 20% of linear).

* **Starved vs greedy** — a busy moving-blob stream and a fully static
  stream under one 0.6 kept-fraction budget: arbitration shifts budget to
  the busy scene (its activity EMA rises), the static stream decays toward
  the floor, and the realised fleet-total kept fraction lands within +/-20%
  of the budget once the per-stream servos converge.  The allocation trace
  (one row per rebalance) is recorded for the artifact.

* **Idle stream** — an admitted stream that never serves a frame (0
  executed windows) flows through :func:`fleet_report` and the artifact
  writer with ``None`` sentinels, never ``Infinity`` (strict RFC 8259).

Writes ``BENCH_fleet.json`` at the repo root; the CI api-surface job runs
the ``-m fleet`` test lane under the same
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` this module forces.
"""

from __future__ import annotations

import os
import sys

# 8 emulated host devices for the weak-scaling sweep — must be set before
# the first jax import anywhere in the process; respect an existing forcing
# (the CI job exports its own) and never fight an already-initialised jax.
# Under ``python -m benchmarks.run`` the harness has already imported jax,
# so the full sweep needs the flag in the job environment (as CI sets it);
# without it the sweep adapts to however many devices exist.
if (
    "jax" not in sys.modules
    and "--xla_force_host_platform_device_count"
    not in os.environ.get("XLA_FLAGS", "")
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

import time
from pathlib import Path

import jax
import numpy as np

from benchmarks._util import write_json
from benchmarks.common import Row
from repro.core.mapping import FPCASpec, output_dims
from repro.data.pipeline import SyntheticMovingObject
from repro.fpca import DeltaGateConfig, GateControllerConfig
from repro.launch.mesh import make_host_mesh
from repro.serving.fleet import (
    FleetAdmissionError,
    FleetConfig,
    FleetController,
)
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.observe import fleet_report
from repro.serving.streaming import StreamServer

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_fleet.json"

H = 48
C_O = 8
GATE = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=12)
CONTROLLER = GateControllerConfig(target=0.3)

# weak-scaling sweep
STREAMS_PER_DEVICE = 2
WARMUP_TICKS = 6
TIMED_TICKS = 24

# arbitration lane
ARB_CONFIG = FleetConfig(budget=0.6, floor=0.1, ceiling=0.9, rebalance_ticks=6)
ARB_TICKS = 96
ARB_TAIL = 32          # converged window the kept-fraction claim is made on


def _spec() -> FPCASpec:
    return FPCASpec(image_h=H, image_w=H, out_channels=C_O, kernel=5, stride=5)


def _kernel() -> np.ndarray:
    rng = np.random.default_rng(0)
    return (rng.normal(size=(C_O, 5, 5, 3)) * 0.2).astype(np.float32)


def _pipe(mesh=None) -> FPCAPipeline:
    pipe = FPCAPipeline(backend="basis", mesh=mesh)
    pipe.register("cam", _spec(), _kernel())
    return pipe


def _fleet(config: FleetConfig, mesh=None):
    pipe = _pipe(mesh)
    server = StreamServer(pipe, GATE, depth=2, controller=CONTROLLER)
    return pipe, server, FleetController(server, config)


def _weak_scaling() -> list[dict]:
    points = []
    n_devices = jax.device_count()
    for d in (1, 2, 4, 8):
        if d > n_devices:
            break
        n_streams = STREAMS_PER_DEVICE * d
        # weak scaling = constant per-stream workload: the budget grows with
        # the fleet so every stream holds the same 0.3 kept-fraction target
        # at every point (a fixed total budget would thin per-stream targets
        # as streams join and measure bucket-switch recompiles, not serving)
        pipe, server, fc = _fleet(
            FleetConfig(budget=0.3 * n_streams, floor=0.02),
            mesh=make_host_mesh(data=d),
        )
        cams = {
            f"s{i}": SyntheticMovingObject((H, H), seed=i)
            for i in range(n_streams)
        }
        for sid in cams:
            fc.add_stream(sid, "cam")

        def _ticks(lo: int, hi: int):
            return (
                {sid: cam.frame_at(t) for sid, cam in cams.items()}
                for t in range(lo, hi)
            )

        for _ in fc.run(_ticks(0, WARMUP_TICKS)):       # compile + warm
            pass
        t0 = time.perf_counter()
        for _ in fc.run(_ticks(WARMUP_TICKS, WARMUP_TICKS + TIMED_TICKS)):
            pass
        elapsed = time.perf_counter() - t0
        handles = list(pipe._handles.values())
        assert handles and all(h.data_parallelism == d for h in handles)
        points.append({
            "devices": d,
            "streams": n_streams,
            "timed_ticks": TIMED_TICKS,
            "s_total": elapsed,
            "stream_ticks_per_s": n_streams * TIMED_TICKS / elapsed,
            "ticks_per_s": TIMED_TICKS / elapsed,
            "kept_window_frac": (
                server.stats.windows_kept / max(server.stats.windows_total, 1)
            ),
        })
    base = points[0]["stream_ticks_per_s"]
    for p in points:
        p["efficiency"] = p["stream_ticks_per_s"] / base
    return points


def _arbitration():
    pipe, server, fc = _fleet(ARB_CONFIG)
    fc.add_stream("busy", "cam")
    fc.add_stream("static", "cam")
    busy = SyntheticMovingObject((H, H), seed=1, radius=9.0)
    rng = np.random.default_rng(2)
    static = np.clip(
        np.kron(rng.uniform(0.1, 0.6, (H // 8, H // 8, 3)), np.ones((8, 8, 1))),
        0, 1,
    ).astype(np.float32)
    kept_total: list[float] = []
    trace: list[dict] = []
    last_rebalance = -1
    for results in fc.run(
        {"busy": busy.frame_at(t), "static": static} for t in range(ARB_TICKS)
    ):
        kept_total.append(sum(r.kept_fraction for r in results))
        if fc.rebalances != last_rebalance:     # one trace row per re-solve
            last_rebalance = fc.rebalances
            m = fc._members
            trace.append({
                "tick": len(kept_total) - 1,
                "busy": round(m["busy"].allocation, 4),
                "static": round(m["static"].allocation, 4),
                "busy_activity": (
                    None if m["busy"].activity is None
                    else round(m["busy"].activity, 4)
                ),
            })
    tail = float(np.mean(kept_total[-ARB_TAIL:]))
    return pipe, server, fc, {
        "budget": ARB_CONFIG.budget,
        "floor": ARB_CONFIG.floor,
        "rebalance_ticks": ARB_CONFIG.rebalance_ticks,
        "ticks": ARB_TICKS,
        "allocation_trace": trace,
        "busy_final_allocation": fc._members["busy"].allocation,
        "static_final_allocation": fc._members["static"].allocation,
        "kept_fraction_total_tail": tail,
        "kept_vs_budget": tail / ARB_CONFIG.budget,
        "within_20pct_of_budget": bool(
            abs(tail / ARB_CONFIG.budget - 1.0) <= 0.2
        ),
    }


def run() -> list[Row]:
    scaling = _weak_scaling()

    pipe, server, fc, arb = _arbitration()
    # idle-stream lane on the same fleet: admitted, never served a frame
    fc.add_stream("idle", "cam")
    table = fc.arbitration_table()
    idle_row = next(r for r in table["streams"] if r["stream"] == "idle")
    # admission lane: fill to capacity, count the rejection
    rejected = 0
    try:
        for i in range(fc.capacity + 1):
            fc.add_stream(f"fill{i}", "cam")
    except FleetAdmissionError:
        rejected = 1
    report = fleet_report(server, fleet=fc)

    record = {
        "workload": {
            "image": [H, H, 3],
            "spec": {"kernel": 5, "stride": 5, "out_channels": C_O},
            "windows_per_frame": int(np.prod(output_dims(_spec()))),
            "gate": {
                "threshold": GATE.threshold,
                "hysteresis": GATE.hysteresis,
                "keyframe_interval": GATE.keyframe_interval,
            },
            "streams_per_device": STREAMS_PER_DEVICE,
        },
        "backend": "basis (XLA lowering of the Pallas kernel math)",
        "devices": jax.device_count(),
        "weak_scaling": {
            "points": scaling,
            # linear = flat per-stream service time as fleet grows with the
            # device count (all emulated devices share one physical CPU)
            "efficiency_at_max": scaling[-1]["efficiency"],
            "within_20pct_of_linear": bool(
                scaling[-1]["efficiency"] >= 0.8
            ),
        },
        "arbitration": arb,
        "idle_stream": {
            "activity": idle_row["activity"],            # None sentinel
            "ticks_observed": idle_row["ticks_observed"],
            "allocation": idle_row["allocation"],
        },
        "admission": {
            "capacity": fc.capacity,
            "admitted": table["admitted"],
            "rejected_over_capacity": rejected,
            "rejections_total": fc.rejections,
        },
        "fleet_report": report,
    }
    write_json(BENCH_JSON, record)

    top = scaling[-1]
    return [
        ("fleet_weak_scaling",
         top["s_total"] / (top["streams"] * top["timed_ticks"]) * 1e6,
         f"{top['streams']} streams on {top['devices']} devices -> "
         f"{top['stream_ticks_per_s']:.0f} stream-ticks/s "
         f"(efficiency {top['efficiency']:.2f} vs 1-device, "
         f"json: {BENCH_JSON.name})"),
        ("fleet_arbitration", 0.0,
         f"busy {arb['busy_final_allocation']:.3f} / static "
         f"{arb['static_final_allocation']:.3f} of budget "
         f"{arb['budget']}, realised kept "
         f"{arb['kept_fraction_total_tail']:.3f} "
         f"({arb['kept_vs_budget']:.0%} of budget)"),
        ("fleet_admission", 0.0,
         f"capacity {fc.capacity}, {table['admitted']} admitted, "
         f"{fc.rejections} rejected; idle stream activity="
         f"{idle_row['activity']} round-trips strict JSON"),
    ]
