"""Shared benchmark-artifact helpers: strict-JSON record writing.

Every ``BENCH_*.json`` at the repo root goes through :func:`write_json`.
The executed-window accounting spells undefined samples (fps with zero work
executed) as the repo-wide ``None`` sentinel, but a pathological record
could still carry ``inf``/NaN from raw arithmetic — and bare ``json.dumps``
would emit the non-standard ``Infinity`` / ``NaN`` tokens that strict
RFC 8259 parsers (and most CI tooling) reject.  ``jsonable`` maps every
non-finite float to ``None`` first, and ``allow_nan=False`` guarantees
nothing non-standard can ever slip into an artifact.
"""

from __future__ import annotations

import json
from pathlib import Path

# single source for the non-finite-float sanitiser: the telemetry JSONL
# writer and the benchmark artifacts must agree on what strict JSON means
from repro.fpca.telemetry import jsonable

__all__ = ["jsonable", "write_json"]


def write_json(path: Path, record: dict) -> None:
    """Write one benchmark record as strict RFC 8259 JSON."""
    path.write_text(
        json.dumps(jsonable(record), indent=2, allow_nan=False) + "\n"
    )
