"""Shared benchmark-artifact helpers: strict-JSON record writing.

Every ``BENCH_*.json`` at the repo root goes through :func:`write_json`:
the executed-window accounting legitimately reports ``fps = inf`` for
all-skipped histories (and a pathological record could carry NaN), but bare
``json.dumps`` would emit the non-standard ``Infinity`` / ``NaN`` tokens
that strict RFC 8259 parsers (and most CI tooling) reject.  ``jsonable``
maps every non-finite float to ``None`` first, and ``allow_nan=False``
guarantees nothing non-standard can ever slip into an artifact.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np


def jsonable(obj):
    """Recursively map non-finite floats (inf / -inf / NaN) to None."""
    if isinstance(obj, dict):
        return {k: jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        obj = obj.item()
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def write_json(path: Path, record: dict) -> None:
    """Write one benchmark record as strict RFC 8259 JSON."""
    path.write_text(
        json.dumps(jsonable(record), indent=2, allow_nan=False) + "\n"
    )
