"""Fig. 9 — frontend energy (a), max frame rate (b), bandwidth reduction (c)
vs stride size, for several output-channel counts and binning factors
(kernel 5x5, 224x224 RGB input; constants per paper §5 / DESIGN.md §7).
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.core import analysis, mapping


def _spec(stride: int, c_o: int, binning: int = 1) -> mapping.FPCASpec:
    return mapping.FPCASpec(
        image_h=224, image_w=224, out_channels=c_o, kernel=5, stride=stride, binning=binning
    )


def run() -> list[Row]:
    rows: list[Row] = []
    base = analysis.conventional_cis(224, 224)
    rows.append(
        ("fig9_baseline_rgb_cis", 0.0,
         f"E={base['e_total']*1e6:.1f}uJ fps={base['fps']:.1f}")
    )
    for c_o in (4, 8, 16, 32):
        for stride in (1, 2, 3, 4, 5):
            spec = _spec(stride, c_o)
            e = analysis.frontend_energy(spec)
            lat = analysis.frontend_latency(spec)
            br = analysis.bandwidth_reduction(spec)
            rows.append(
                (f"fig9_c{c_o}_s{stride}", 0.0,
                 f"E={e['e_total']*1e6:.1f}uJ ({e['e_total']/base['e_total']:.2f}x base) "
                 f"fps={lat['fps']:.2f} BR={br:.1f} N_C={e['n_cycles']}")
            )
    for binning in (2, 4):
        spec = _spec(5, 8, binning)
        lat = analysis.frontend_latency(spec)
        rows.append(
            (f"fig9b_bin{binning}x{binning}_c8_s5", 0.0,
             f"fps={lat['fps']:.2f} (binning recovers frame rate)")
        )
    # region skipping (paper §3.4.5): half-frame skip halves cycles/energy
    import numpy as np

    spec = _spec(5, 8)
    mask = np.zeros((28, 28), dtype=bool)
    mask[:14] = True
    e_skip = analysis.frontend_energy(spec, block_mask=mask)
    e_full = analysis.frontend_energy(spec)
    rows.append(
        ("fig9_region_skip_half", 0.0,
         f"E={e_skip['e_total']*1e6:.1f}uJ vs {e_full['e_total']*1e6:.1f}uJ "
         f"({e_skip['e_total']/e_full['e_total']:.2f}x)")
    )
    return rows
