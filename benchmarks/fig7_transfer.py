"""Fig. 7 — transfer curves + linearity of the analog convolution.

(a)/(b): single-pixel output vs weight / vs light intensity;
(d)/(e): 75-pixel convolution output;
(c)/(f): ideal-dot-product linearity (r^2) incl. metal-line sweep 0-5 mm.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.core.device_models import CircuitParams, analog_dot_product


def run() -> list[Row]:
    rows: list[Row] = []
    params = CircuitParams()
    sweep = jnp.linspace(0.05, 1.0, 33)

    # single pixel (Fig 7a/b)
    for i_fix in (0.25, 0.5, 1.0):
        v = analog_dot_product(jnp.full((33, 1), i_fix), sweep[:, None], params)
        rows.append(
            (f"fig7a_single_px_I={i_fix}", 0.0,
             f"v_range=[{float(v.min()):.3f};{float(v.max()):.3f}]V monotonic={bool(jnp.all(jnp.diff(v) >= 0))}")
        )

    # 75-pixel conv (Fig 7d-f) + linearity scatter
    rng = np.random.default_rng(0)
    I = jnp.asarray(rng.uniform(0, 1, (4096, 75)), jnp.float32)
    W = jnp.asarray(rng.uniform(0, 1, (4096, 75)), jnp.float32)
    us = time_fn(lambda: analog_dot_product(I, W, params))
    ideal = np.asarray(jnp.sum(I * W, axis=-1))
    for r_mm in (0.0, 2.5, 5.0):
        v = np.asarray(analog_dot_product(I, W, params.replace(r_metal_mm=r_mm)))
        r2 = np.corrcoef(ideal, v)[0, 1] ** 2
        rows.append((f"fig7f_conv75_r={r_mm}mm", us, f"linearity_r2={r2:.4f}"))
    v0 = np.asarray(analog_dot_product(I, W, params))
    v5 = np.asarray(analog_dot_product(I, W, params.replace(r_metal_mm=5.0)))
    rows.append(
        ("fig7f_metal_line_effect", 0.0,
         f"max|dV|_0to5mm={np.abs(v5 - v0).max() * 1e3:.2f}mV (paper: minor)")
    )
    return rows
