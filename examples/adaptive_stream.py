"""Adaptive streaming control plane: keep-fraction servo, sticky buckets,
multi-config fan-out.

    PYTHONPATH=src python examples/adaptive_stream.py

A synthetic camera watches a scene with one moving object.  Instead of the
fixed gate threshold of ``stream_video.py``, a per-stream
:class:`~repro.serving.control.GateController` closed-loop servos the
threshold until the stream settles at a **kept-window budget** (15% here) —
the field-programmable knob a deployment would tie to its frame-rate or
energy envelope.  The pipeline's sticky row buckets
(``bucket_patience``) ride out the bucket flapping that keyframes and busy
ticks would otherwise cause, and the camera is fanned out to TWO programmed
configurations (an "edges" and a "blobs" kernel bank) served by ONE
channel-stacked fused call per tick.

The whole run serves under a live telemetry session
(``telemetry.enable``): every serve tick is a traced span, every servo
actuation is a JSONL event, and the closing fleet report / Prometheus
snapshot come straight off the same registry cells the stats objects
read — nothing is recorded twice.
"""

import tempfile
from pathlib import Path

import numpy as np

from repro.core.curvefit import fit_bucket_model
from repro.core.mapping import FPCASpec
from repro.data.pipeline import SyntheticMovingObject
from repro.fpca import DeltaGateConfig, GateControllerConfig, telemetry
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.observe import fleet_report, render_fleet_report
from repro.serving.streaming import StreamServer

H = W = 96
N_FRAMES = 40
TARGET = 0.15


def main() -> None:
    print("fitting bucket-select curvefit model (one-off calibration)...")
    model = fit_bucket_model(n_pixels=75)
    spec = FPCASpec(image_h=H, image_w=W, out_channels=8, kernel=5, stride=5)
    rng = np.random.default_rng(0)
    k_edges = rng.normal(size=(8, 5, 5, 3)).astype(np.float32) * 0.2
    k_blobs = rng.normal(size=(4, 5, 5, 3)).astype(np.float32) * 0.2

    pipe = FPCAPipeline(model, backend="basis", bucket_patience=4)
    pipe.register("edges", spec, k_edges)
    pipe.register("blobs", spec, k_blobs)

    server = StreamServer(
        pipe,
        DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=0),
        controller=GateControllerConfig(target=TARGET),
    )
    # one camera, fanned to BOTH configs: one stacked kernel call per tick.
    # Each config gets its OWN gate + servo (per-config thresholds): "edges"
    # servos to the tight budget, "blobs" to a looser one — the fused call
    # executes the union mask, each config's counts honour its own gate.
    server.add_stream(
        "cam0", ("edges", "blobs"),
        gate={
            "edges": DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=0),
            "blobs": DeltaGateConfig(threshold=0.05, hysteresis=1, keyframe_interval=0),
        },
        controller={
            "edges": GateControllerConfig(target=TARGET),
            "blobs": GateControllerConfig(target=2 * TARGET),
        },
    )
    cam = SyntheticMovingObject((H, W), seed=1, radius=12.0)

    jsonl = Path(tempfile.gettempdir()) / "adaptive_stream_telemetry.jsonl"
    telemetry.enable(jsonl, device_time_rate=8,
                     run_labels={"example": "adaptive_stream"})

    print(f"\nservoing gate threshold to a {TARGET:.0%} kept-window budget:")
    print(f"{'tick':>4} {'threshold':>10} {'kept EMA':>9}  configs served")
    n_results = 0
    for results in server.run({"cam0": cam.frame_at(t)} for t in range(N_FRAMES)):
        n_results += len(results)
        ctl = server.sessions["cam0"].controller
        h = ctl.history[-1]
        if h["tick"] % 4 == 0:
            ema = "---" if h["ema"] is None else f"{h['ema']:9.3f}"
            served = ", ".join(
                f"{r.config}{tuple(r.counts.shape)}" for r in results
            )
            print(f"{h['tick']:>4} {h['threshold']:>10.4f} {ema}  {served}")

    session = server.sessions["cam0"]
    ctl = session.controller                      # primary config ("edges")
    conv = ctl.converged_tick(rel_tol=0.2)
    print(f"\nedges converged to ±20% of budget at tick {conv} "
          f"(final threshold {ctl.threshold:.4f}, EMA {ctl.ema:.3f})")
    ctl_b = session.state_for("blobs").controller
    print(f"blobs servoed independently to its own {2*TARGET:.0%} budget "
          f"(threshold {ctl_b.threshold:.4f}, EMA {ctl_b.ema:.3f})")
    print(f"fan-out: {pipe.stats.fanout_batches} stacked calls served "
          f"{n_results} (stream, config) results")
    print(f"sticky buckets: {server.stats.bucket_switches} executable "
          f"switches, {server.stats.bucket_shrinks_deferred} shrinks deferred"
          f" (patience {pipe.bucket_patience})")
    print(f"all-skipped ticks short-circuited: {server.stats.launches_skipped}")

    rep = server.sessions["cam0"].energy_report()
    print(f"\nsensor accounting over {rep['frames']} frames: "
          f"kept {rep['kept_window_frac']:.1%} of windows, "
          f"energy {rep['energy_vs_dense']:.2f}x dense")

    # -- telemetry export surfaces --------------------------------------
    print("\nfleet report (per stream x config):")
    print(render_fleet_report(fleet_report(server)))
    n_events = telemetry.session().events_written
    telemetry.disable()
    events = telemetry.read_jsonl(jsonl)
    spans = sum(1 for e in events if e["event"] == "span")
    servo = sum(1 for e in events if e["event"] == "servo_actuate")
    print(f"\ntelemetry: {n_events} JSONL events -> {jsonl} "
          f"({spans} spans, {servo} servo actuations)")
    snap = telemetry.registry().render()
    line = next(l for l in snap.splitlines()
                if l.startswith("fpca_gate_threshold"))
    print(f"prometheus snapshot: {len(snap.splitlines())} lines, e.g. {line}")


if __name__ == "__main__":
    main()
