"""Quickstart: the FPCA pipeline end to end on one image.

    PYTHONPATH=src python examples/quickstart.py

1. fit the bucket-select curvefit model against the circuit oracle;
2. run a 5x5x3, 8-channel, stride-5 in-pixel convolution on a synthetic
   image through the full analog pipeline (NVM encoding -> bitline reads ->
   SS-ADC up/down counting -> ReLU'd counts);
3. report model error, linearity and the frontend energy/latency/bandwidth
   numbers for this configuration (paper Fig. 7/8/9).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ADCConfig,
    CircuitParams,
    FPCASpec,
    WeightEncoding,
    analog_dot_product,
    bandwidth_reduction,
    fit_bucket_model,
    fpca_forward,
    frontend_energy,
    frontend_latency,
    predict_sigmoid,
)


def main() -> None:
    params = CircuitParams()
    print("fitting bucket-select curvefit model (one-off)...")
    model = fit_bucket_model(params)

    rng = np.random.default_rng(0)
    I = jnp.asarray(rng.uniform(0, 1, (512, 75)), jnp.float32)
    W = jnp.asarray(rng.uniform(0, 1, (512, 75)), jnp.float32)
    err = jnp.abs(predict_sigmoid(model, I, W) - analog_dot_product(I, W, params))
    print(f"bucket model max error: {float(err.max())*100:.2f}% of full scale (paper: <3%)")

    spec = FPCASpec(image_h=120, image_w=120, out_channels=8, kernel=5, stride=5)
    image = jnp.asarray(rng.uniform(0, 1, (120, 120, 3)), jnp.float32)
    kernel = jnp.asarray(rng.normal(0, 0.2, (8, 5, 5, 3)), jnp.float32)
    out = fpca_forward(
        image, kernel, spec, circuit=params, model=model,
        adc=ADCConfig(), enc=WeightEncoding(), mode="bucket_sigmoid",
    )
    counts = out["counts"]
    print(f"activation map: {counts.shape}, counts in [{float(counts.min()):.0f}, "
          f"{float(counts.max()):.0f}] (8-bit SS-ADC)")

    e = frontend_energy(spec)
    lat = frontend_latency(spec)
    print(f"frontend: N_C={e['n_cycles']} cycles, E={e['e_total']*1e6:.2f} uJ/frame, "
          f"{lat['fps']:.1f} fps, BR={bandwidth_reduction(spec):.1f}x")


if __name__ == "__main__":
    main()
