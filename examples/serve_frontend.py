"""Serve a heterogeneous FPCA frontend workload through the batched pipeline.

    PYTHONPATH=src python examples/serve_frontend.py

Registers three field-programmed configurations on one simulated pixel array
(dense 5x5 stride-5, overlapping 3x3 stride-2, and a binned low-power mode),
then streams a shuffled mix of frames through the spec-bucketed scheduler:

* requests are grouped per configuration and served as one fused batched
  kernel call each;
* every compile signature is one explicit ``repro.fpca.CompiledFrontend``
  handle; all handles share one bounded LRU executable cache — reprogramming
  weights does not recompile;
* on TPU the Pallas kernel serves; this script uses the XLA basis-form
  backend so it runs fast on any host.
"""

import time

import numpy as np

from repro import fpca
from repro.core.curvefit import fit_bucket_model
from repro.core.mapping import FPCASpec
from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest


def main() -> None:
    print("fitting bucket-select curvefit model (one-off calibration)...")
    model = fit_bucket_model(n_pixels=75)

    rng = np.random.default_rng(0)
    spec = FPCASpec(image_h=80, image_w=80, out_channels=8, kernel=5, stride=5)

    # -- the unified API on one handle: compile -> run -> reprogram ----------
    kernel = rng.normal(size=(8, 5, 5, 3)).astype(np.float32) * 0.2
    fe = fpca.compile(fpca.FPCAProgram(spec=spec), backend="basis",
                      weights=kernel, model=model)
    batch = rng.uniform(0, 1, (4, 80, 80, 3)).astype(np.float32)
    counts = fe.run(batch)
    fe.reprogram(rng.normal(size=(8, 5, 5, 3)).astype(np.float32) * 0.2)
    counts = fe.run(batch)                      # same executable, new weights
    info = fe.cache_info()
    print(f"compiled handle: {counts.shape} counts; cache {info.misses} "
          f"compiles across {fe.stats.reprograms} reprograms "
          f"(hits={info.hits})")

    # -- heterogeneous fleet serving through the pipeline layer --------------
    pipe = FPCAPipeline(model, backend="basis", cache_capacity=4)
    configs = {
        "dense_5x5": spec,
        "overlap_3x3": FPCASpec(image_h=80, image_w=80, out_channels=8, kernel=3, stride=2),
        "binned_lowpower": FPCASpec(
            image_h=80, image_w=80, out_channels=8, kernel=5, stride=5, binning=2
        ),
    }
    for name, s in configs.items():
        k = s.kernel
        cfg = pipe.register(
            name, s,
            rng.normal(size=(s.out_channels, k, k, 3)).astype(np.float32) * 0.2,
        )
        print(f"registered {name}: out_shape={cfg.out_shape}")

    names = list(configs)
    requests = [
        FrontendRequest(
            config=names[int(rng.integers(len(names)))],
            image=rng.uniform(0, 1, (80, 80, 3)).astype(np.float32),
        )
        for _ in range(48)
    ]

    t0 = time.perf_counter()
    results = pipe.serve(requests)   # cold: includes compiles
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    results = pipe.serve(requests)   # warm: pure serving
    t_warm = time.perf_counter() - t0

    print(f"served {len(results)} frames across {len(configs)} specs")
    print(f"cold {t_cold*1e3:.0f} ms, warm {t_warm*1e3:.1f} ms "
          f"({len(results)/t_warm:.0f} frames/s warm)")
    s = pipe.stats
    print(f"stats: {s.requests} requests in {s.batches} fused batches, "
          f"cache {s.cache_hits} hits / {s.cache_misses} misses / "
          f"{s.evictions} evictions")


if __name__ == "__main__":
    main()
