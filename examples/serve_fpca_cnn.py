"""Serve a whole FPCA model — analog frontend + digital CNN head — through
ONE ``fpca.compile()``.

    PYTHONPATH=src python examples/serve_fpca_cnn.py                  # fresh net
    PYTHONPATH=src python examples/serve_fpca_cnn.py --weights m.npz  # trained
    PYTHONPATH=src python examples/serve_fpca_cnn.py --image-h 24 --frames 6

``--weights`` takes the bundle ``examples/train_fpca_cnn.py --export``
writes (the hw-aware trained network); without it a freshly-initialised
network on the same architecture is served (the serving path is identical).

What it demonstrates, end to end:

1. **compile once** — ``fpca.compile(FPCAModelProgram)`` returns a
   ``CompiledModel`` whose ``.run()`` produces class logits from raw frames
   through one fused jit (frontend kernel + jnp head), bit-identical to
   composing a frontend handle with the reference head apply;
2. **reprogram cheaply** — rewriting the NVM planes *or* the head weights
   never recompiles (asserted via ``cache_info()``);
3. **stream with skip-aware classification** — each delta-gated tick patches
   its kept windows into the running effective activation map, so the head
   yields a per-tick class decision even when most windows are skipped;
4. **fleet serving** — the same model program registered into
   ``FPCAPipeline`` / ``StreamServer`` (logits in ``StreamFrameResult``),
   with the head's FLOPs/latency accounted next to the executed-window
   stats by ``analysis.model_streaming_report``.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from repro.configs.fpca_cnn import make_model_program
from repro.core import analysis
from repro.core.adc import ADCConfig
from repro.core.fpca_sim import WeightEncoding
from repro.core.mapping import FPCASpec
from repro.data.pipeline import SyntheticMovingObject
from repro.fpca import DeltaGateConfig, FPCAModelProgram, compile as fpca_compile
from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest
from repro.serving.streaming import StreamServer


def load_export(path: str) -> tuple[FPCAModelProgram, dict]:
    """Rebuild the model program + parameters train_fpca_cnn.py exported."""
    bundle = np.load(path)
    meta = json.loads(bytes(bundle["meta"]).decode())
    spec = FPCASpec(
        image_h=meta["image_h"], image_w=meta["image_w"],
        out_channels=meta["out_channels"], kernel=meta["kernel"],
        stride=meta["stride"], max_kernel=meta["max_kernel"],
    )
    model = make_model_program(
        spec,
        adc=ADCConfig(bits=meta["adc_bits"]),
        enc=WeightEncoding(n_levels=meta["nvm_levels"]),
        input_scale=meta["input_scale"],
    )
    head_params = []
    i = 0
    while f"head{i}_w" in bundle:
        head_params.append({"w": bundle[f"head{i}_w"], "b": bundle[f"head{i}_b"]})
        i += 1
    out = {
        "kernel": bundle["kernel"],
        "bn_offset": bundle["bn_offset"],
        "head_params": head_params,
    }
    if "quant_scales" in bundle:
        out["quant_scales"] = bundle["quant_scales"]
    return model, out


def fresh_network(image_h: int, seed: int = 0) -> tuple[FPCAModelProgram, dict]:
    spec = FPCASpec(image_h=image_h, image_w=image_h, out_channels=8,
                    kernel=5, stride=5, max_kernel=5)
    model = make_model_program(spec)
    rng = np.random.default_rng(seed)
    kernel = (rng.normal(size=model.frontend.kernel_shape) * 0.2).astype(np.float32)
    return model, {
        "kernel": kernel,
        "bn_offset": np.zeros((spec.out_channels,), np.float32),
        "head_params": model.init_head(jax.random.PRNGKey(seed)),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--weights", metavar="NPZ",
                    help="bundle from train_fpca_cnn.py --export")
    ap.add_argument("--image-h", type=int, default=60,
                    help="sensor size for the fresh-network path")
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--backend", default="basis")
    ap.add_argument("--precision", choices=("f32", "int8"), default="f32",
                    help="int8 serves the calibrated quantised lowering "
                         "(bounded parity vs the f32 reference)")
    args = ap.parse_args()

    if args.weights:
        model, params = load_export(args.weights)
        print(f"loaded trained export {args.weights}")
    else:
        model, params = fresh_network(args.image_h)
        print("serving a freshly-initialised network (pass --weights for the "
              "trained one)")

    serve_model, serve_head = model, params["head_params"]
    if args.precision == "int8":
        from repro.models.quant import quantize_head_params, unpack_act_scales

        serve_model = model.replace(precision="int8")
        act_scales = (unpack_act_scales(model, params["quant_scales"])
                      if "quant_scales" in params else None)
        serve_head = quantize_head_params(
            serve_model, params["head_params"], act_scales=act_scales
        )
        print("precision: int8 "
              + ("(export-calibrated activation scales)" if act_scales
                 else "(data-free full-scale calibration)"))
    spec = model.spec
    print(f"model: {spec.image_h}x{spec.image_w}x{spec.in_channels} "
          f"-> frontend {model.frontend.out_shape} -> head "
          f"{' -> '.join(str(s) for s in model.head_shapes()[1:])} "
          f"({model.n_classes} classes)")

    # 1. compile the WHOLE model once; serve a batch of frames as logits
    m = fpca_compile(
        serve_model, backend=args.backend, weights=params["kernel"],
        bn_offset=params["bn_offset"], head_params=serve_head,
    )
    rng = np.random.default_rng(1)
    batch = rng.uniform(0, 1, (8, spec.image_h, spec.image_w, 3)).astype(np.float32)
    logits = np.asarray(m.run(batch))
    print(f"batched run: {batch.shape[0]} frames -> logits {logits.shape}, "
          f"classes {np.argmax(logits, -1).tolist()}")

    # parity: f32 fused executable is bit-identical to frontend handle +
    # reference head apply; int8 is parity-BOUNDED against that f32 reference
    fe = fpca_compile(model.frontend, backend=args.backend,
                      weights=params["kernel"], bn_offset=params["bn_offset"],
                      model=m.model)
    ref = np.asarray(model.apply_head(params["head_params"], fe.run(batch)))
    if args.precision == "int8":
        from repro.models.quant import logit_parity

        par = logit_parity(ref, logits)
        print(f"parity (int8 vs f32 reference): max |dlogit| "
              f"{par['max_abs_divergence']:.4f}, top-1 agreement "
              f"{par['top1_agreement']:.2f}")
    else:
        assert np.array_equal(logits, ref), "fused logits diverge from reference"
        print("parity: fused frontend+head jit is bit-identical to the "
              "composed reference")

    # 2. reprogram NVM planes AND head weights: guaranteed zero recompiles
    misses = m.cache_info().misses
    m.reprogram(params["kernel"] * 0.9,
                head_params=jax.tree_util.tree_map(lambda a: a * 1.1,
                                                   params["head_params"]))
    m.run(batch)
    assert m.cache_info().misses == misses, "reprogram must never recompile"
    print(f"reprogram (NVM + head): zero recompiles "
          f"(cache misses still {misses})")
    m.reprogram(params["kernel"], params["bn_offset"], head_params=serve_head)

    # 3. skip-aware streaming classification off the handle
    cam = SyntheticMovingObject((spec.image_h, spec.image_w), seed=3)
    gate = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=0)
    h_o, w_o, _ = model.frontend.out_shape
    kept = 0
    for r in m.stream((cam.frame_at(t) for t in range(args.frames)), gate=gate):
        kept += r.kept_windows
        if r.frame_idx < 4 or r.frame_idx == args.frames - 1:
            print(f"  tick {r.frame_idx:3d}: kept {r.kept_windows:3d}/"
                  f"{r.total_windows} windows -> class "
                  f"{r.predicted_class} (logits {np.round(r.logits, 2)})")
    total = args.frames * h_o * w_o
    print(f"stream: executed {kept}/{max(total, 1)} windows "
          f"({kept/max(total, 1):.1%}) with a class decision every tick")

    # 4. fleet path: pipeline + StreamServer, head cost accounted
    pipe = FPCAPipeline(m.model, backend=args.backend)
    pipe.register("vww", serve_model, params["kernel"], params["bn_offset"],
                  head_params=serve_head)
    out = pipe.serve([FrontendRequest("vww", batch[0])])
    print(f"pipeline serve: logits {np.asarray(out[0]).shape} "
          f"(class {int(np.argmax(np.asarray(out[0])))})")
    server = StreamServer(pipe, gate)
    server.add_stream("cam0", "vww")
    session = server.sessions["cam0"]
    for results in server.run({"cam0": cam.frame_at(t)}
                              for t in range(args.frames)):
        pass
    print(f"server: {server.stats.frames} frames, kept "
          f"{server.stats.windows_kept}/{server.stats.windows_total} windows")
    if session.block_masks:
        rep = analysis.model_streaming_report(model, list(session.block_masks))
        print(f"accounting: frontend energy {rep['energy_vs_dense']:.2f}x "
              f"dense, head {rep['head_macs_per_frame']/1e3:.1f} kMAC/frame, "
              f"model fps_effective {rep['model_fps_effective']:.0f}")


if __name__ == "__main__":
    main()
