"""Streaming video through the FPCA frontend: temporal delta gating + the
async double-buffered serving loop.

    PYTHONPATH=src python examples/stream_video.py

Two synthetic cameras watch scenes where only a small moving object changes
frame-to-frame.  Each stream's :class:`StreamSession` compares every frame
against its predecessor at region-skip block granularity; only changed
blocks (plus hysteresis and periodic keyframes) are read out, and the keep
mask is compacted *inside* the fused kernel, so skipped windows never
execute.  Both cameras fan into one device batch per tick, and up to two
ticks are in flight at once (host gating for frame t+1 overlaps device
compute for frame t).
"""

import time

import numpy as np

from repro.core.curvefit import fit_bucket_model
from repro.core.mapping import FPCASpec
from repro.data.pipeline import SyntheticMovingObject
from repro.fpca import DeltaGateConfig
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.streaming import StreamServer

H = W = 96
N_FRAMES = 48


def main() -> None:
    print("fitting bucket-select curvefit model (one-off calibration)...")
    model = fit_bucket_model(n_pixels=75)
    spec = FPCASpec(image_h=H, image_w=W, out_channels=8, kernel=5, stride=5)
    rng = np.random.default_rng(0)
    kernel = rng.normal(size=(8, 5, 5, 3)).astype(np.float32) * 0.2

    pipe = FPCAPipeline(model, backend="basis")
    pipe.register("cam", spec, kernel)

    cams = {
        "lobby": SyntheticMovingObject((H, W), seed=1, speed=0.15),
        "dock": SyntheticMovingObject((H, W), seed=2, speed=0.23),
    }
    gate = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=24)

    def ticks():
        for t in range(N_FRAMES):
            yield {name: cam.frame_at(t) for name, cam in cams.items()}

    def run(gating: bool) -> tuple[float, StreamServer]:
        server = StreamServer(pipe, gate, depth=2, gating=gating)
        for name in cams:
            server.add_stream(name, "cam")
        t0 = time.perf_counter()
        for results in server.run(ticks()):
            pass
        return time.perf_counter() - t0, server

    run(gating=True)                      # warm the executable cache
    t_gated, server = run(gating=True)
    t_dense, _ = run(gating=False)

    fps_gated = N_FRAMES * len(cams) / t_gated
    fps_dense = N_FRAMES * len(cams) / t_dense
    s = server.stats
    print(f"\n{len(cams)} cameras x {N_FRAMES} frames, depth-2 double buffering")
    print(f"delta-gated: {t_gated*1e3:7.1f} ms  ({fps_gated:6.0f} frames/s)")
    print(f"dense:       {t_dense*1e3:7.1f} ms  ({fps_dense:6.0f} frames/s)")
    print(f"speedup: {t_dense/t_gated:.2f}x  "
          f"kept windows: {s.windows_kept}/{s.windows_total} "
          f"({s.windows_kept/s.windows_total:.1%})")

    rep = server.sessions["lobby"].energy_report()
    print(f"\nlobby sensor accounting over {rep['frames']} frames "
          f"(executed windows only):")
    print(f"  cycles {rep['executed_cycles']}, "
          f"energy {rep['e_total']*1e6:.1f} uJ "
          f"({rep['energy_vs_dense']:.2f}x dense), "
          f"sensor-side fps {rep['fps_effective']:.0f} "
          f"({1/rep['latency_vs_dense']:.2f}x dense)")


if __name__ == "__main__":
    main()
