"""Region skipping (paper §3.4.5): content-driven block masks cut frontend
energy while preserving the activations that matter.

    PYTHONPATH=src python examples/region_skipping.py

Pipeline: a cheap binned-brightness saliency pass
(:func:`repro.serving.saliency.saliency_mask`) picks the 8x8 blocks worth
reading; the mask is pushed *into* the fused kernel — kept windows are
compacted into a static bucket before the matmul bank runs, so skipped
windows never execute (compute-real savings, not post-hoc zeroing).  The
dense reference simulation is the bit-exact oracle on the kept region.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import fpca
from repro.core import analysis, mapping
from repro.core.curvefit import fit_bucket_model
from repro.core.device_models import CircuitParams
from repro.core.fpca_sim import fpca_forward
from repro.data.pipeline import SyntheticVWW
from repro.serving.saliency import saliency_mask

SPEC = mapping.FPCASpec(
    image_h=64, image_w=64, out_channels=8, kernel=5, stride=5, skip_block=8
)


def main() -> None:
    circuit = CircuitParams()
    model = fit_bucket_model(circuit)
    data = SyntheticVWW((64, 64))
    batch = data.batch_at(0, 4)

    e_full = analysis.frontend_energy(SPEC)
    print(f"full frame: N_C={e_full['n_cycles']} E={e_full['e_total']*1e6:.2f} uJ")

    # one compiled handle serves every masked frame (the mask is runtime
    # state: it never recompiles, only re-buckets)
    fe = fpca.compile(
        fpca.FPCAProgram(spec=SPEC, circuit=circuit), backend="basis",
        weights=_kernel(), model=model,
    )

    for i, img in enumerate(batch["images"]):
        mask = saliency_mask(img, SPEC)
        e_skip = analysis.frontend_energy(SPEC, block_mask=mask)
        # dense reference: every window evaluated, skipped region zeroed
        full = fpca_forward(
            jnp.asarray(img), _kernel(), SPEC, circuit=circuit, model=model,
            mode="bucket_sigmoid",
        )["counts"]
        # fused serving path: the mask compacts the window list IN-KERNEL
        skip = fe.run(jnp.asarray(img), block_mask=mask)
        active = jnp.asarray(mapping.active_window_mask(SPEC, mask))
        same = bool(jnp.all(full[active] == skip[active]))
        zeroed = bool(jnp.all(skip[~active] == 0))
        n_win = active.size
        print(
            f"image {i}: kept {mask.mean()*100:.0f}% blocks -> "
            f"windows {int(active.sum())}/{n_win} executed, "
            f"N_C {e_skip['n_cycles']} ({e_skip['n_cycles']/e_full['n_cycles']:.2f}x), "
            f"E {e_skip['e_total']*1e6:.2f} uJ ({e_skip['e_total']/e_full['e_total']:.2f}x), "
            f"kept-region identical={same}, skipped zeroed={zeroed}"
        )


def _kernel():
    return jax.random.normal(jax.random.PRNGKey(0), (8, 5, 5, 3)) * 0.2


if __name__ == "__main__":
    main()
