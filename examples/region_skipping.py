"""Region skipping (paper §3.4.5): content-driven block masks cut frontend
energy while preserving the activations that matter.

    PYTHONPATH=src python examples/region_skipping.py

Pipeline: a cheap binned-brightness saliency pass picks the 8x8 blocks worth
reading; the FPCA frontend then only fires RS/SW lines for those blocks.
We report the energy/cycle savings (Eq. 1/2) and verify activations inside
the kept region are bit-identical to a full readout.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, mapping
from repro.core.curvefit import fit_bucket_model
from repro.core.device_models import CircuitParams
from repro.core.fpca_sim import fpca_forward
from repro.data.pipeline import SyntheticVWW

SPEC = mapping.FPCASpec(
    image_h=64, image_w=64, out_channels=8, kernel=5, stride=5, skip_block=8
)


def saliency_mask(image: np.ndarray, keep_frac: float = 0.4) -> np.ndarray:
    """Block-wise brightness variance -> keep the liveliest blocks."""
    b = SPEC.skip_block
    h, w, _ = image.shape
    blocks = image[: h // b * b, : w // b * b].reshape(h // b, b, w // b, b, 3)
    var = blocks.var(axis=(1, 3, 4))
    k = max(1, int(keep_frac * var.size))
    thresh = np.partition(var.ravel(), -k)[-k]
    return var >= thresh


def main() -> None:
    circuit = CircuitParams()
    model = fit_bucket_model(circuit)
    data = SyntheticVWW((64, 64))
    batch = data.batch_at(0, 4)

    e_full = analysis.frontend_energy(SPEC)
    print(f"full frame: N_C={e_full['n_cycles']} E={e_full['e_total']*1e6:.2f} uJ")

    for i, img in enumerate(batch["images"]):
        mask = saliency_mask(img)
        e_skip = analysis.frontend_energy(SPEC, block_mask=mask)
        full = fpca_forward(
            jnp.asarray(img), _kernel(), SPEC, circuit=circuit, model=model,
            mode="bucket_sigmoid",
        )["counts"]
        skip = fpca_forward(
            jnp.asarray(img), _kernel(), SPEC, circuit=circuit, model=model,
            mode="bucket_sigmoid", block_mask=mask,
        )["counts"]
        active = jnp.asarray(mapping.active_window_mask(SPEC, mask))
        same = bool(jnp.all(full[active] == skip[active]))
        zeroed = bool(jnp.all(skip[~active] == 0))
        print(
            f"image {i}: kept {mask.mean()*100:.0f}% blocks -> "
            f"N_C {e_skip['n_cycles']} ({e_skip['n_cycles']/e_full['n_cycles']:.2f}x), "
            f"E {e_skip['e_total']*1e6:.2f} uJ ({e_skip['e_total']/e_full['e_total']:.2f}x), "
            f"kept-region identical={same}, skipped zeroed={zeroed}"
        )


def _kernel():
    return jax.random.normal(jax.random.PRNGKey(0), (8, 5, 5, 3)) * 0.2


if __name__ == "__main__":
    main()
