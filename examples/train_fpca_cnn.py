"""Hardware/algorithm co-design on the paper's native workload: train a
small VWW-class classifier whose first layer IS the FPCA analog frontend.

    PYTHONPATH=src python examples/train_fpca_cnn.py [--steps 150]

Two trainings of the same network, both *deployed* on the circuit oracle
(hard NVM quantisation + analog non-linearity + 8-bit SS-ADC):

* **hw-aware**  — trained THROUGH the differentiable sigmoid bucket model
                  (+ STEs), the paper's §4 contribution;
* **naive**     — trained with an ideal float convolution, then dropped onto
                  the analog hardware.

The gap in deployed accuracy is the reason the bucket-select model exists.

Hardware regime: extreme-edge — 4-bit SS-ADC, 8-level (3-bit) NVM weights.
(With the paper's 8-bit ADC / 16-level NVM the analog path is benign enough
that naive training survives deployment — we report that finding too; run
with --adc-bits 8 --nvm-levels 16 to reproduce it.)
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.curvefit import fit_bucket_model
from repro.core.device_models import CircuitParams
from repro.core.frontend import FPCAFrontend
from repro.core.mapping import FPCASpec, output_dims
from repro.fpca import FPCAProgram
from repro.data.pipeline import SyntheticVWW
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw

SPEC = FPCASpec(image_h=60, image_w=60, out_channels=8, kernel=5, stride=5)


def init_head(key, h, w, c, n_hidden=64, n_classes=2):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (h * w * c, n_hidden)) * (h * w * c) ** -0.5,
        "b1": jnp.zeros((n_hidden,)),
        "w2": jax.random.normal(k2, (n_hidden, n_classes)) * n_hidden ** -0.5,
        "b2": jnp.zeros((n_classes,)),
    }


def head_apply(p, acts):
    x = acts.reshape(acts.shape[0], -1)
    x = jax.nn.relu(x @ p["w1"] + p["b1"])
    return x @ p["w2"] + p["b2"]


def ideal_frontend(kernel, images):
    """Float conv + ReLU over the same physical 5x5 window grid."""
    out = jax.lax.conv_general_dilated(
        images.transpose(0, 3, 1, 2),
        kernel.transpose(0, 3, 1, 2),
        window_strides=(SPEC.stride, SPEC.stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ).transpose(0, 2, 3, 1)
    return jax.nn.relu(out)


def train(mode: str, layer: FPCAFrontend, data: SyntheticVWW, steps: int, batch: int, seed=0):
    key = jax.random.PRNGKey(seed)
    params = {
        "frontend": layer.init(key),
        "head": init_head(jax.random.PRNGKey(seed + 1), *layer.out_shape),
    }
    opt = init_adamw(params)
    opt_cfg = AdamWConfig(lr=2e-3, weight_decay=0.01, warmup_steps=10, total_steps=steps)

    def loss_fn(p, images, labels):
        if mode == "hw_aware":
            acts = layer.apply(p["frontend"], images, train=True)
        else:
            acts = ideal_frontend(p["frontend"]["kernel"], images)
        logits = head_apply(p["head"], acts)
        onehot = jax.nn.one_hot(labels, 2)
        return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    for step in range(steps):
        b = data.batch_at(step, batch)
        loss, grads = grad_fn(params, jnp.asarray(b["images"]), jnp.asarray(b["labels"]))
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        if (step + 1) % 25 == 0:
            print(f"  [{mode}] step {step+1:4d} loss {float(loss):.4f}", flush=True)
    return params


def deployed_accuracy(layer: FPCAFrontend, params, data: SyntheticVWW, n=512) -> float:
    """Evaluate on the circuit oracle (the real hardware semantics)."""
    correct = 0
    eval_fn = jax.jit(
        lambda imgs: head_apply(
            params["head"], layer.apply(params["frontend"], imgs, train=False)
        )
    )
    for step in range(n // 128):
        b = data.batch_at(10_000 + step, 128)
        pred = np.argmax(np.asarray(eval_fn(jnp.asarray(b["images"]))), -1)
        correct += int((pred == b["labels"]).sum())
    return correct / n


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--adc-bits", type=int, default=4)
    ap.add_argument("--nvm-levels", type=int, default=8)
    args = ap.parse_args()

    from repro.core.adc import ADCConfig
    from repro.core.fpca_sim import WeightEncoding

    circuit = CircuitParams()
    print("fitting bucket model...")
    model = fit_bucket_model(circuit)
    layer = FPCAFrontend(
        FPCAProgram(
            spec=SPEC,
            circuit=circuit,
            adc=ADCConfig(bits=args.adc_bits),
            enc=WeightEncoding(n_levels=args.nvm_levels),
        ),
        model=model,
    )
    print(f"frontend: {SPEC.image_h}x{SPEC.image_w}x3 -> {layer.out_shape}, "
          f"calibration r2={layer.calibration_r2:.4f}")
    data = SyntheticVWW((SPEC.image_h, SPEC.image_w))

    results = {}
    for mode in ("hw_aware", "naive"):
        t0 = time.time()
        print(f"training ({mode}) ...")
        params = train(mode, layer, data, args.steps, args.batch)
        acc = deployed_accuracy(layer, params, data)
        results[mode] = acc
        print(f"  [{mode}] deployed-on-circuit accuracy: {acc*100:.1f}% "
              f"({time.time()-t0:.0f}s)")

    gap = results["hw_aware"] - results["naive"]
    print(f"\nco-design gap (hw-aware - naive, both deployed on analog oracle): "
          f"{gap*100:+.1f} points")


if __name__ == "__main__":
    main()
