"""Hardware/algorithm co-design on the paper's native workload: train a
small VWW-class classifier whose first layer IS the FPCA analog frontend.

    PYTHONPATH=src python examples/train_fpca_cnn.py [--steps 150]

Two trainings of the same network, both *deployed* on the circuit oracle
(hard NVM quantisation + analog non-linearity + 8-bit SS-ADC):

* **hw-aware**  — trained THROUGH the differentiable sigmoid bucket model
                  (+ STEs), the paper's §4 contribution;
* **naive**     — trained with an ideal float convolution, then dropped onto
                  the analog hardware.

The gap in deployed accuracy is the reason the bucket-select model exists.

Hardware regime: extreme-edge — 4-bit SS-ADC, 8-level (3-bit) NVM weights.
(With the paper's 8-bit ADC / 16-level NVM the analog path is benign enough
that naive training survives deployment — we report that finding too; run
with --adc-bits 8 --nvm-levels 16 to reproduce it.)

Serving the result: ``--export model.npz`` saves the trained hw-aware
network as an ``repro.fpca.FPCAModelProgram`` parameter bundle (NVM kernel +
BN offsets + head weights + the counts->units digital gain), which
``examples/serve_fpca_cnn.py --weights model.npz`` compiles into ONE fused
frontend+head executable (``fpca.compile``) and serves batched and as a
delta-gated stream with per-tick class logits.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.curvefit import fit_bucket_model
from repro.core.device_models import CircuitParams
from repro.core.frontend import FPCAFrontend
from repro.core.mapping import FPCASpec, output_dims
from repro.fpca import FPCAModelProgram, FPCAProgram
from repro.configs.fpca_cnn import HEAD, N_CLASSES, N_HIDDEN
from repro.data.pipeline import SyntheticVWW
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw

SPEC = FPCASpec(image_h=60, image_w=60, out_channels=8, kernel=5, stride=5)


# the trained MLP IS configs.fpca_cnn.HEAD — deriving its dims from there
# keeps the --export model program and the training head in lockstep
def init_head(key, h, w, c, n_hidden=N_HIDDEN, n_classes=N_CLASSES):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (h * w * c, n_hidden)) * (h * w * c) ** -0.5,
        "b1": jnp.zeros((n_hidden,)),
        "w2": jax.random.normal(k2, (n_hidden, n_classes)) * n_hidden ** -0.5,
        "b2": jnp.zeros((n_classes,)),
    }


def head_apply(p, acts):
    x = acts.reshape(acts.shape[0], -1)
    x = jax.nn.relu(x @ p["w1"] + p["b1"])
    return x @ p["w2"] + p["b2"]


def ideal_frontend(kernel, images):
    """Float conv + ReLU over the same physical 5x5 window grid."""
    out = jax.lax.conv_general_dilated(
        images.transpose(0, 3, 1, 2),
        kernel.transpose(0, 3, 1, 2),
        window_strides=(SPEC.stride, SPEC.stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ).transpose(0, 2, 3, 1)
    return jax.nn.relu(out)


def train(mode: str, layer: FPCAFrontend, data: SyntheticVWW, steps: int, batch: int, seed=0):
    key = jax.random.PRNGKey(seed)
    params = {
        "frontend": layer.init(key),
        "head": init_head(jax.random.PRNGKey(seed + 1), *layer.out_shape),
    }
    opt = init_adamw(params)
    opt_cfg = AdamWConfig(lr=2e-3, weight_decay=0.01, warmup_steps=10, total_steps=steps)

    def loss_fn(p, images, labels):
        if mode == "hw_aware":
            acts = layer.apply(p["frontend"], images, train=True)
        else:
            acts = ideal_frontend(p["frontend"]["kernel"], images)
        logits = head_apply(p["head"], acts)
        onehot = jax.nn.one_hot(labels, N_CLASSES)
        return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    for step in range(steps):
        b = data.batch_at(step, batch)
        loss, grads = grad_fn(params, jnp.asarray(b["images"]), jnp.asarray(b["labels"]))
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        if (step + 1) % 25 == 0:
            print(f"  [{mode}] step {step+1:4d} loss {float(loss):.4f}", flush=True)
    return params


def deployed_accuracy(layer: FPCAFrontend, params, data: SyntheticVWW, n=512) -> float:
    """Evaluate on the circuit oracle (the real hardware semantics)."""
    correct = 0
    eval_fn = jax.jit(
        lambda imgs: head_apply(
            params["head"], layer.apply(params["frontend"], imgs, train=False)
        )
    )
    for step in range(n // 128):
        b = data.batch_at(10_000 + step, 128)
        pred = np.argmax(np.asarray(eval_fn(jnp.asarray(b["images"]))), -1)
        correct += int((pred == b["labels"]).sum())
    return correct / n


def export_model_program(
    layer: FPCAFrontend, params: dict
) -> tuple[FPCAModelProgram, list[dict]]:
    """The trained network as a compileable model program + head pytree.

    The head consumed activations in convolution units
    (``counts * adc.lsb * gain``), so the export bakes that digital gain in
    as the model's ``input_scale`` — ``fpca.compile(model)`` then serves the
    exact trained computation from raw SS-ADC counts.
    """
    model = FPCAModelProgram(
        frontend=layer.config,
        head=HEAD,
        input_scale=float(layer.config.adc.lsb * layer.gain),
    )
    head_params = [
        {"w": params["head"]["w1"], "b": params["head"]["b1"]},
        {"w": params["head"]["w2"], "b": params["head"]["b2"]},
    ]
    return model, head_params


def save_export(
    path: str, layer: FPCAFrontend, params: dict, calib_images=None
) -> None:
    """Serialize the export for examples/serve_fpca_cnn.py (npz bundle).

    When ``calib_images`` is given, the bundle also carries per-stage int8
    activation scales (``quant_scales``) calibrated by running the trained
    f32 head on the circuit-oracle counts for those images —
    ``serve_fpca_cnn.py --precision int8`` picks them up to serve the
    quantised lowering with data-calibrated (not worst-case) scales.
    """
    model, head_params = export_model_program(layer, params)
    spec, adc, enc = layer.config.spec, layer.config.adc, layer.config.enc
    meta = {
        "image_h": spec.image_h, "image_w": spec.image_w,
        "out_channels": spec.out_channels, "kernel": spec.kernel,
        "stride": spec.stride, "max_kernel": spec.max_kernel,
        "adc_bits": adc.bits, "nvm_levels": enc.n_levels,
        "input_scale": model.input_scale,
    }
    arrays = {
        "kernel": np.asarray(params["frontend"]["kernel"], np.float32),
        "bn_offset": np.asarray(params["frontend"]["bn_offset"], np.float32),
        "meta": np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8),
    }
    for i, p in enumerate(head_params):
        arrays[f"head{i}_w"] = np.asarray(p["w"], np.float32)
        arrays[f"head{i}_b"] = np.asarray(p["b"], np.float32)
    if calib_images is not None:
        from repro.models.quant import calibrate_head_scales, pack_act_scales

        # the frontend oracle emits activation units (counts * input_scale);
        # the model program consumes raw counts, so divide the scale back out
        acts = layer.apply(params["frontend"], jnp.asarray(calib_images),
                           train=False)
        counts = jnp.asarray(acts) / jnp.float32(model.input_scale)
        scales = calibrate_head_scales(
            model, model.bind_head_params(head_params), counts
        )
        arrays["quant_scales"] = pack_act_scales(model, scales)
    np.savez(path, **arrays)
    print(f"exported FPCAModelProgram parameters -> {path} "
          f"(serve with examples/serve_fpca_cnn.py --weights {path})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--adc-bits", type=int, default=4)
    ap.add_argument("--nvm-levels", type=int, default=8)
    ap.add_argument("--export", metavar="PATH",
                    help="save the trained hw-aware network as an "
                         "FPCAModelProgram bundle for serve_fpca_cnn.py")
    args = ap.parse_args()

    from repro.core.adc import ADCConfig
    from repro.core.fpca_sim import WeightEncoding

    circuit = CircuitParams()
    print("fitting bucket model...")
    model = fit_bucket_model(circuit)
    layer = FPCAFrontend(
        FPCAProgram(
            spec=SPEC,
            circuit=circuit,
            adc=ADCConfig(bits=args.adc_bits),
            enc=WeightEncoding(n_levels=args.nvm_levels),
        ),
        model=model,
    )
    print(f"frontend: {SPEC.image_h}x{SPEC.image_w}x3 -> {layer.out_shape}, "
          f"calibration r2={layer.calibration_r2:.4f}")
    data = SyntheticVWW((SPEC.image_h, SPEC.image_w))

    results = {}
    for mode in ("hw_aware", "naive"):
        t0 = time.time()
        print(f"training ({mode}) ...")
        params = train(mode, layer, data, args.steps, args.batch)
        acc = deployed_accuracy(layer, params, data)
        results[mode] = acc
        print(f"  [{mode}] deployed-on-circuit accuracy: {acc*100:.1f}% "
              f"({time.time()-t0:.0f}s)")
        if mode == "hw_aware" and args.export:
            save_export(args.export, layer, params,
                        calib_images=data.batch_at(0, args.batch)["images"])

    gap = results["hw_aware"] - results["naive"]
    print(f"\nco-design gap (hw-aware - naive, both deployed on analog oracle): "
          f"{gap*100:+.1f} points")


if __name__ == "__main__":
    main()
