"""Batched LM serving demo: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b --tokens 32

Uses the reduced (smoke) config so it runs on CPU in seconds; the same
serve_step functions are what the decode dry-run cells lower at full scale.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduce_for_smoke
from repro.models.transformer import init_model
from repro.serving.serve_step import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduce_for_smoke(ARCHS[args.arch])
    params = init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.tokens + 8

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    frontend = None
    if cfg.family == "vlm":
        frontend = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.frontend_tokens, cfg.frontend_dim)
        )
    elif cfg.family == "encdec":
        frontend = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, args.prompt_len, cfg.frontend_dim)
        )

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len, remat="none"))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    tok, logits, cache = prefill(params, prompts, frontend)
    tok = tok[:, None]
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in {t_prefill*1e3:.0f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")

    pos0 = args.prompt_len + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    outputs = [tok]
    t0 = time.time()
    for step in range(args.tokens - 1):
        tok, logits, cache = decode(params, tok, cache, jnp.int32(pos0 + step))
        outputs.append(tok)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    seqs = jnp.concatenate(outputs, axis=1)
    print(f"decode: {args.tokens} steps x {args.batch} seqs in {t_decode*1e3:.0f} ms "
          f"({args.batch*args.tokens/t_decode:.0f} tok/s)")
    print(f"first sequence: {seqs[0].tolist()}")


if __name__ == "__main__":
    main()
