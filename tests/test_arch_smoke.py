"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-grad step + prefill/decode on CPU; asserts shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke

pytestmark = pytest.mark.slow  # compiles every reduced architecture
from repro.models.transformer import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_cache,
    init_model,
)

B, S = 2, 32


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    s_text = S - cfg.frontend_tokens if cfg.family == "vlm" else S
    batch = {
        "tokens": jax.random.randint(k1, (B, s_text), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, s_text), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["frontend"] = jax.random.normal(k1, (B, cfg.frontend_tokens, cfg.frontend_dim))
        batch["labels"] = jax.random.randint(k2, (B, s_text), 0, cfg.vocab_size)
    elif cfg.frontend == "audio":
        batch["frontend"] = jax.random.normal(k1, (B, S, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = reduce_for_smoke(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, metrics = forward_train(p, cfg, batch, remat="none")
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_then_decode_smoke(arch):
    cfg = reduce_for_smoke(ARCHS[arch])
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    max_len = S + 8
    logits, cache = forward_prefill(
        params, cfg, batch["tokens"],
        frontend_embeds=batch.get("frontend"), max_len=max_len,
    )
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, axis=-1)[:, None]
    pos0 = batch["tokens"].shape[1] + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    for step in range(2):
        logits, cache = forward_decode(params, cfg, tok, cache, jnp.int32(pos0 + step))
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: decode step {step}"
        tok = jnp.argmax(logits, axis=-1)[:, None]


def test_decode_matches_prefill_dense():
    """Teacher-forced decode step must reproduce the prefill's next-token
    logits (cache correctness)."""
    cfg = reduce_for_smoke(ARCHS["qwen3-1.7b"])
    params = init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    # prefill over S tokens, then decode token S given cache
    logits_a, cache = forward_prefill(params, cfg, tokens, max_len=S + 4)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size)
    logits_b, _ = forward_decode(params, cfg, nxt, cache, jnp.int32(S))
    # cross-check: prefill over the extended sequence gives the same logits
    ext = jnp.concatenate([tokens, nxt], axis=1)
    logits_c, _ = forward_prefill(params, cfg, ext, max_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(logits_b), np.asarray(logits_c), rtol=2e-2, atol=2e-2
    )


def test_param_counts_match_published_class():
    """Analytic parameter counts should land in the right size class."""
    expect_range = {
        "granite-moe-3b-a800m": (2.5e9, 4.5e9),
        "qwen2-moe-a2.7b": (13e9, 16e9),     # 14.3B total (2.7B active)
        "seamless-m4t-medium": (0.7e9, 1.6e9),
        "internvl2-76b": (68e9, 84e9),       # LM backbone + projector
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "phi3-medium-14b": (12e9, 16e9),
        "qwen3-1.7b": (1.3e9, 2.3e9),
        "yi-9b": (8e9, 10e9),
        "zamba2-7b": (6e9, 9e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
    }
    for arch, (lo, hi) in expect_range.items():
        n = ARCHS[arch].param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
