"""Circuit-oracle invariants (the SPICE stand-in must behave like a circuit)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.device_models import CircuitParams, analog_dot_product, pixel_drive


@pytest.fixture(scope="module")
def params() -> CircuitParams:
    return CircuitParams()


def test_zero_input_gives_zero_output(params):
    I = jnp.zeros((4, 75))
    W = jnp.ones((4, 75)) * 0.5
    v = analog_dot_product(I, W, params)
    np.testing.assert_allclose(np.asarray(v), 0.0, atol=1e-7)
    # zero weights likewise (padded NVM slots must contribute nothing)
    v = analog_dot_product(jnp.ones((4, 75)), jnp.zeros((4, 75)), params)
    np.testing.assert_allclose(np.asarray(v), 0.0, atol=1e-7)


def test_output_bounded_by_supply(params):
    I = jnp.ones((1, 75))
    W = jnp.ones((1, 75))
    v = float(analog_dot_product(I, W, params)[0])
    assert 0.9 < v < params.v_sat  # full-scale drive saturates near (not at) v_sat


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 74), st.floats(0.1, 0.9), st.floats(0.1, 0.9))
def test_monotone_in_each_pixel(j, base_i, base_w):
    """dV/dI_j >= 0 and dV/dW_j >= 0: brighter pixel / higher conductance
    can only pull the bitline higher."""
    params = CircuitParams()
    I = jnp.full((75,), base_i)
    W = jnp.full((75,), base_w)

    def f_i(x):
        return analog_dot_product(I.at[j].set(x), W, params)

    def f_w(x):
        return analog_dot_product(I, W.at[j].set(x), params)

    gi = jax.grad(f_i)(jnp.float32(base_i))
    gw = jax.grad(f_w)(jnp.float32(base_w))
    assert gi >= 0 and gw >= 0


def test_coupling_is_weak_but_present(params):
    """Marginal contribution of one pixel shrinks as the bitline rises —
    the inter-pixel dependence the bucket model exists to capture."""
    I_lo = jnp.full((75,), 0.1).at[0].set(0.0)
    I_hi = jnp.full((75,), 0.9).at[0].set(0.0)
    W = jnp.full((75,), 0.8)

    def marginal(I_bg):
        v0 = analog_dot_product(I_bg, W, params)
        v1 = analog_dot_product(I_bg.at[0].set(1.0), W, params)
        return float(v1 - v0)

    m_lo, m_hi = marginal(I_lo), marginal(I_hi)
    assert m_hi < m_lo            # loading compresses the marginal
    assert m_hi > 0.1 * m_lo      # ... but never kills it (paper §4: own-(I,W)
    #                               dependence stays strong in every bucket)


def test_metal_line_effect_is_minor(params):
    """Fig. 7(c)/(f): 0-5 mm weight-die distance changes the output only
    slightly (the curvefit model stays valid across the whole range)."""
    rng = np.random.default_rng(0)
    I = jnp.asarray(rng.uniform(0, 1, (512, 75)), jnp.float32)
    W = jnp.asarray(rng.uniform(0, 1, (512, 75)), jnp.float32)
    v0 = analog_dot_product(I, W, params.replace(r_metal_mm=0.0))
    v5 = analog_dot_product(I, W, params.replace(r_metal_mm=5.0))
    rel = float(jnp.max(jnp.abs(v5 - v0))) / params.v_sat
    assert rel < 0.02


def test_fixed_point_converged(params):
    """Doubling the fixed-point iterations must not change the answer."""
    rng = np.random.default_rng(1)
    I = jnp.asarray(rng.uniform(0, 1, (256, 75)), jnp.float32)
    W = jnp.asarray(rng.uniform(0, 1, (256, 75)), jnp.float32)
    v8 = analog_dot_product(I, W, params)
    v16 = analog_dot_product(I, W, params.replace(fp_iters=16))
    np.testing.assert_allclose(np.asarray(v8), np.asarray(v16), atol=1e-6)


def test_pixel_drive_is_local(params):
    """pixel_drive is elementwise — no cross-pixel terms (coupling lives only
    in the bitline solve)."""
    rng = np.random.default_rng(2)
    I = jnp.asarray(rng.uniform(0, 1, (16,)), jnp.float32)
    W = jnp.asarray(rng.uniform(0, 1, (16,)), jnp.float32)
    g_batch = pixel_drive(I, W, params)
    g_single = jnp.stack([pixel_drive(I[i], W[i], params) for i in range(16)])
    np.testing.assert_allclose(np.asarray(g_batch), np.asarray(g_single), rtol=1e-6)


def test_oracle_is_differentiable(params):
    rng = np.random.default_rng(3)
    I = jnp.asarray(rng.uniform(0.1, 0.9, (75,)), jnp.float32)
    W = jnp.asarray(rng.uniform(0.1, 0.9, (75,)), jnp.float32)
    g = jax.grad(lambda w: jnp.sum(analog_dot_product(I, w, params)))(W)
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.max(g)) > 0
