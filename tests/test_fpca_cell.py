"""FPCA production cell: basis-form lowering path correctness + info math."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adc import ADCConfig
from repro.core.fpca_sim import WeightEncoding, encode_weights, extract_windows
from repro.core.mapping import FPCASpec
from repro.kernels.fpca_conv.ops import fpca_conv_basis_jnp, pad_to_lanes
from repro.kernels.fpca_conv.ref import fpca_conv_ref


def test_basis_jnp_matches_ref(bucket_model):
    """The dry-run lowering path (flat jnp basis form) == the oracle."""
    rng = np.random.default_rng(0)
    M, n_real, N, C = 192, 75, 128, 8
    patches = np.zeros((M, N), np.float32)
    patches[:, :n_real] = rng.uniform(0, 1, (M, n_real))
    w = np.zeros((N, C), np.float32)
    w[:n_real] = rng.uniform(0, 1, (n_real, C))
    w2 = np.roll(w, 1, axis=1)
    mask = np.zeros((N,), np.float32)
    mask[:n_real] = 1.0
    bn = rng.integers(0, 20, (C,)).astype(np.float32)
    adc = ADCConfig()
    got = fpca_conv_basis_jnp(
        jnp.asarray(patches), jnp.asarray(w), jnp.asarray(w2), bucket_model,
        adc, jnp.asarray(bn), mask=jnp.asarray(mask), n_real=n_real,
    )
    want = fpca_conv_ref(
        jnp.asarray(patches), jnp.asarray(w), jnp.asarray(w2), bucket_model,
        adc, jnp.asarray(bn), mask=jnp.asarray(mask),
    )
    assert np.abs(np.asarray(got) - np.asarray(want)).max() <= 1.0


@pytest.mark.slow
def test_fpca_cell_builds_on_host_mesh(bucket_model):
    from repro import compat
    from repro.launch.fpca_cell import FpcaShape, build_fpca_cell
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    shape = FpcaShape("tiny", 64, 2)
    with compat.set_mesh(mesh):
        jitted, args, info = build_fpca_cell(shape, mesh, bucket_model)
        compiled = jitted.lower(*args).compile()
    assert info.model_flops() > 0
    out_sds = jax.eval_shape(jitted, *args)
    assert out_sds.shape[-1] == info.spec.out_channels
    assert compat.cost_analysis_dict(compiled)["flops"] > 0
