"""SS-ADC model: up/down counting, BN fold, ReLU clamp, quantisation, STE."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.adc import ADCConfig, quantize_voltage, ste_round, updown_readout

CFG = ADCConfig(bits=8, v_ref=1.0)


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 0.997))  # half-LSB accuracy only holds below the
#                                saturation knee at (levels - 0.5) * lsb;
#                                above it the clamp to code 255 dominates
def test_quantisation_error_within_half_lsb(v):
    q = float(quantize_voltage(jnp.float32(v), CFG))
    assert abs(q * CFG.lsb - v) <= CFG.lsb / 2 + 1e-7


def test_updown_implements_relu():
    v_pos = jnp.asarray([0.1, 0.5, 0.2])
    v_neg = jnp.asarray([0.5, 0.1, 0.2])
    counts = updown_readout(v_pos, v_neg, CFG)
    assert float(counts[0]) == 0.0          # negative sum clamps to 0 (CDS ReLU)
    assert float(counts[1]) > 0.0
    assert float(counts[2]) == 0.0


def test_bn_offset_initialises_counter():
    v_pos = jnp.asarray([0.25])
    v_neg = jnp.asarray([0.25])
    assert float(updown_readout(v_pos, v_neg, CFG, bn_offset_counts=17.0)[0]) == 17.0
    # offset also rescues small negative sums (that is why it must be folded
    # *before* the clamp)
    v_neg2 = jnp.asarray([0.27])
    c = float(updown_readout(v_pos, v_neg2, CFG, bn_offset_counts=17.0)[0])
    assert 0.0 < c < 17.0


def test_saturation_at_full_scale():
    c = updown_readout(jnp.asarray([5.0]), jnp.asarray([0.0]), CFG)
    assert float(c[0]) == CFG.levels - 1


def test_ste_gradient_is_identity():
    g = jax.grad(lambda v: jnp.sum(ste_round(v / CFG.lsb)))(jnp.float32(0.4))
    np.testing.assert_allclose(float(g), 1.0 / CFG.lsb, rtol=1e-6)


def test_soft_readout_tracks_hard():
    rng = np.random.default_rng(0)
    vp = jnp.asarray(rng.uniform(0, 1, (256,)), jnp.float32)
    vn = jnp.asarray(rng.uniform(0, 1, (256,)), jnp.float32)
    hard = updown_readout(vp, vn, CFG, hard=True)
    soft = updown_readout(vp, vn, CFG, hard=False)
    assert float(jnp.max(jnp.abs(hard - soft))) <= 1.0  # within one count
