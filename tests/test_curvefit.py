"""Reproduces the paper's Fig. 8(b) claim: bucket-select curvefit error < 3%."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.curvefit import (
    BucketCurvefitModel,
    fit_bucket_model,
    predict_hard,
    predict_sigmoid,
)
from repro.core.device_models import analog_dot_product


def _err(pred, true, v_range=1.0):
    return np.abs(np.asarray(pred) - np.asarray(true)) / v_range


def test_error_below_3_percent(bucket_model, circuit_params, mixed_iw):
    """Paper Fig. 8(b): prediction error vs the circuit (SPICE stand-in) < 3%
    on random per-pixel (I, W) draws, across the full output range."""
    I, W = map(jnp.asarray, mixed_iw)
    v_true = analog_dot_product(I, W, circuit_params)
    for fn in (predict_hard, predict_sigmoid):
        err = _err(fn(bucket_model, I, W), v_true, circuit_params.v_sat)
        assert err.max() < 0.03, f"{fn.__name__}: max err {err.max():.4f}"
        assert err.mean() < 0.01


def test_all_buckets_exercised(circuit_params, mixed_iw):
    I, W = map(jnp.asarray, mixed_iw)
    v_true = np.asarray(analog_dot_product(I, W, circuit_params))
    occupancy = np.clip((v_true * 5).astype(int), 0, 4)
    assert set(np.unique(occupancy)) == {0, 1, 2, 3, 4}


def test_bucket_model_beats_generic_fit(bucket_model, circuit_params, mixed_iw):
    """The two-step method must out-predict the step-1 generic surface alone
    (the reason the paper introduces buckets)."""
    I, W = map(jnp.asarray, mixed_iw)
    v_true = analog_dot_product(I, W, circuit_params)
    err_bucket = _err(predict_hard(bucket_model, I, W), v_true).max()
    err_avg = _err(bucket_model.f_avg(I.mean(-1), W.mean(-1)), v_true).max()
    assert err_bucket < 0.6 * err_avg


def test_sigmoid_matches_hard_away_from_edges(bucket_model, circuit_params, mixed_iw):
    """Interior of a bucket: the sigmoid gates select exactly one bucket, so
    the differentiable equation equals the step-select one."""
    I, W = map(jnp.asarray, mixed_iw)
    v_est = bucket_model.f_avg(I.mean(-1), W.mean(-1))
    frac = (v_est / bucket_model.v_range * bucket_model.n_buckets) % 1.0
    interior = (frac > 0.2) & (frac < 0.8)
    h = np.asarray(predict_hard(bucket_model, I, W))[np.asarray(interior)]
    s = np.asarray(predict_sigmoid(bucket_model, I, W))[np.asarray(interior)]
    np.testing.assert_allclose(h, s, atol=2e-3)


def test_sigmoid_model_is_differentiable(bucket_model):
    rng = np.random.default_rng(0)
    I = jnp.asarray(rng.uniform(0, 1, (75,)), jnp.float32)
    W = jnp.asarray(rng.uniform(0, 1, (75,)), jnp.float32)
    g = jax.grad(lambda w: predict_sigmoid(bucket_model, I, w))(W)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.linalg.norm(g)) > 0


def test_serialisation_roundtrip(bucket_model, mixed_iw):
    I, W = map(jnp.asarray, mixed_iw)
    restored = BucketCurvefitModel.from_dict(bucket_model.to_dict())
    np.testing.assert_allclose(
        np.asarray(predict_sigmoid(bucket_model, I[:64], W[:64])),
        np.asarray(predict_sigmoid(restored, I[:64], W[:64])),
        rtol=1e-6,
    )


def test_fit_generalises_across_kernel_sizes(circuit_params):
    """A 3x3x3 (27-pixel) configuration refits cleanly — reconfigurability of
    the kernel size carries through the modeling pipeline."""
    model27 = fit_bucket_model(circuit_params, n_pixels=27, grid=33)
    rng = np.random.default_rng(7)
    I = jnp.asarray(rng.uniform(0, 1, (2048, 27)), jnp.float32)
    W = jnp.asarray(rng.uniform(0, 1, (2048, 27)), jnp.float32)
    v_true = analog_dot_product(I, W, circuit_params)
    err = _err(predict_sigmoid(model27, I, W), v_true)
    assert err.max() < 0.03


def test_estimator_ablation_meanfield_vs_mean_of_f(bucket_model, circuit_params, mixed_iw):
    """DESIGN.md §2 ablation: the step-1 estimate for heterogeneous windows.

    Both estimators (f_avg at window means vs mean of per-pixel f_avg) must
    select buckets accurately enough to keep the final prediction under the
    paper's 3% bound; we ship mean-field and record the alternative here.
    """
    I, W = map(jnp.asarray, mixed_iw)
    v_true = analog_dot_product(I, W, circuit_params)

    # shipped estimator: f_avg(mean I, mean W)
    est_mf = bucket_model.f_avg(I.mean(-1), W.mean(-1))
    # alternative: mean_j f_avg(I_j, W_j)
    est_mean = bucket_model.f_avg(I, W).mean(-1)

    idx_true = np.clip((np.asarray(v_true) * 5).astype(int), 0, 4)
    for name, est in (("mean_field", est_mf), ("mean_of_f", est_mean)):
        idx = np.clip((np.asarray(est) * 5).astype(int), 0, 4)
        agreement = (idx == idx_true).mean()
        assert agreement > 0.9, f"{name}: bucket selection agreement {agreement:.3f}"
    # mean-field must be at least as accurate as the alternative on RMSE
    rmse_mf = float(jnp.sqrt(jnp.mean((est_mf - v_true) ** 2)))
    rmse_mean = float(jnp.sqrt(jnp.mean((est_mean - v_true) ** 2)))
    assert rmse_mf < rmse_mean * 1.5  # same ballpark; we ship the cheaper one
