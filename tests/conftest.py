"""Shared fixtures. NOTE: device count must stay 1 here (smoke tests and
benches see the real CPU); only launch/dryrun.py forces 512 host devices."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.curvefit import BucketCurvefitModel, fit_bucket_model
from repro.core.device_models import CircuitParams


@pytest.fixture(scope="session")
def circuit_params() -> CircuitParams:
    return CircuitParams()


@pytest.fixture(scope="session")
def bucket_model(circuit_params: CircuitParams) -> BucketCurvefitModel:
    """One fitted 75-pixel bucket model shared across the whole test session."""
    return fit_bucket_model(circuit_params, n_pixels=75)


@pytest.fixture(scope="session")
def mixed_iw() -> tuple[np.ndarray, np.ndarray]:
    """Random (I, W) draws covering all five buckets (beta mixtures)."""
    rng = np.random.default_rng(42)
    parts_i, parts_w = [], []
    for a, b in [(1, 1), (5, 1), (1, 5), (8, 1), (12, 1)]:
        parts_i.append(rng.beta(a, b, (1500, 75)))
        parts_w.append(rng.beta(a, b, (1500, 75)))
    return (
        np.concatenate(parts_i).astype(np.float32),
        np.concatenate(parts_w).astype(np.float32),
    )
