"""Batched multi-spec frontend pipeline: backend parity + scheduler.

Parity contract: the fused production backends (Pallas kernel /
basis-expanded XLA form) must reproduce the dense reference simulation
(``fpca_forward`` with ``mode="bucket_sigmoid"``, hard ADC) count-for-count
across the reconfiguration grid — kernel x stride x binning x region-skip.
The output is integer SS-ADC counts, so parity is asserted exactly.

Scheduler contract: heterogeneous request mixes group by configuration, run
as one fused batch per group through a bounded LRU executable cache keyed by
compile signature, and results round-trip to the original request order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adc import ADCConfig
from repro.core.fpca_sim import fpca_forward
from repro.core.mapping import FPCASpec, output_dims
from repro.serving.fpca_pipeline import (
    FPCAPipeline,
    FrontendRequest,
    spec_signature,
)

H = W = 24  # eff grid stays >= the physical 5x5 kernel even at binning 2


def _spec(kernel: int, stride: int, binning: int) -> FPCASpec:
    return FPCASpec(
        image_h=H, image_w=W, out_channels=4, kernel=kernel, stride=stride,
        binning=binning,
    )


def _block_mask(spec: FPCASpec) -> np.ndarray:
    """Deterministic checkerboard keep/skip grid at the spec's block shape."""
    bh = -(-spec.eff_h // spec.skip_block)
    bw = -(-spec.eff_w // spec.skip_block)
    mask = (np.indices((bh, bw)).sum(axis=0) % 2).astype(bool)
    mask[0, 0] = True  # keep at least one block
    return mask


def _data(spec: FPCASpec, batch: int = 2, seed: int = 0):
    rng = np.random.default_rng(seed)
    images = jnp.asarray(
        rng.uniform(0, 1, (batch, H, W, spec.in_channels)), jnp.float32
    )
    k = spec.kernel
    kernel = jnp.asarray(
        rng.normal(size=(spec.out_channels, k, k, spec.in_channels)) * 0.2,
        jnp.float32,
    )
    bn = jnp.asarray(rng.integers(0, 10, (spec.out_channels,)), jnp.float32)
    return images, kernel, bn


PARITY_GRID = [
    (kernel, stride, binning)
    for kernel in (3, 5)
    for stride in (kernel, 2)
    for binning in (1, 2)
]


@pytest.mark.parametrize("kernel,stride,binning", PARITY_GRID)
@pytest.mark.parametrize("with_mask", [False, True])
def test_pallas_backend_matches_dense_reference(
    bucket_model, circuit_params, kernel, stride, binning, with_mask
):
    """Pallas-backed fpca_forward == dense reference, exact integer counts."""
    spec = _spec(kernel, stride, binning)
    images, kern, bn = _data(spec)
    block_mask = _block_mask(spec) if with_mask else None
    common = dict(
        circuit=circuit_params, model=bucket_model, bn_offset_counts=bn,
        mode="bucket_sigmoid", hard=True, block_mask=block_mask,
    )
    want = fpca_forward(images, kern, spec, **common)["counts"]
    got = fpca_forward(
        images, kern, spec, backend="pallas", interpret=True, **common
    )["counts"]
    h_o, w_o = output_dims(spec)
    assert got.shape == want.shape == (2, h_o, w_o, spec.out_channels)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("backend", ["reference", "pallas", "basis"])
def test_batched_equals_per_image_loop(bucket_model, circuit_params, backend):
    """The fused (B*h_o*w_o, N) batched path == a per-image loop, bit-for-bit."""
    spec = _spec(3, 2, 1)
    images, kern, bn = _data(spec, batch=3, seed=1)
    common = dict(
        circuit=circuit_params, model=bucket_model, bn_offset_counts=bn,
        mode="bucket_sigmoid", hard=True, backend=backend,
    )
    if backend == "pallas":
        common["interpret"] = True
    batched = fpca_forward(images, kern, spec, **common)["counts"]
    looped = np.stack(
        [
            np.asarray(fpca_forward(images[i], kern, spec, **common)["counts"])
            for i in range(images.shape[0])
        ]
    )
    np.testing.assert_array_equal(np.asarray(batched), looped)


def test_fused_backend_rejects_oracle_mode(bucket_model):
    spec = _spec(5, 5, 1)
    images, kern, _ = _data(spec)
    with pytest.raises(ValueError, match="bucket_sigmoid"):
        fpca_forward(images, kern, spec, model=bucket_model, mode="oracle",
                     backend="pallas")


# ---------------------------------------------------------------------------
# reconfiguration scheduler
# ---------------------------------------------------------------------------


def _pipeline(bucket_model, **kw) -> FPCAPipeline:
    kw.setdefault("backend", "basis")
    return FPCAPipeline(bucket_model, **kw)


def _register_grid(pipe: FPCAPipeline, seed: int = 0) -> dict[str, FPCASpec]:
    specs = {
        "dense": _spec(5, 5, 1),
        "overlap": _spec(3, 2, 1),
        "binned": _spec(5, 5, 2),
    }
    rng = np.random.default_rng(seed)
    for name, spec in specs.items():
        k = spec.kernel
        pipe.register(
            name, spec,
            rng.normal(size=(spec.out_channels, k, k, 3)).astype(np.float32) * 0.2,
        )
    return specs


def _requests(specs: dict[str, FPCASpec], order: list[str], seed: int = 7):
    rng = np.random.default_rng(seed)
    return [
        FrontendRequest(
            config=name,
            image=rng.uniform(0, 1, (H, W, 3)).astype(np.float32),
        )
        for name in order
    ]


def test_heterogeneous_mix_grouped_by_spec(bucket_model):
    """An interleaved mix runs as one fused batch per configuration."""
    pipe = _pipeline(bucket_model)
    specs = _register_grid(pipe)
    order = ["dense", "overlap", "dense", "binned", "overlap", "dense"]
    reqs = _requests(specs, order)
    groups = pipe.group_requests(reqs)
    assert groups == {"dense": [0, 2, 5], "overlap": [1, 4], "binned": [3]}
    pipe.submit(reqs)
    assert pipe.stats.batches == 3          # one fused call per spec group
    assert pipe.stats.requests == 6


def test_results_round_trip_to_request_order(bucket_model):
    """Each slot of the result list belongs to the request in that slot."""
    pipe = _pipeline(bucket_model)
    specs = _register_grid(pipe)
    order = ["overlap", "dense", "binned", "dense", "overlap"]
    reqs = _requests(specs, order)
    results = pipe.submit(reqs)
    for req, res in zip(reqs, results):
        h_o, w_o = output_dims(specs[req.config])
        assert res.shape == (h_o, w_o, 4)
        solo = pipe.submit([req])[0]        # singleton batch of the same frame
        np.testing.assert_array_equal(np.asarray(res), np.asarray(solo))


def test_pipeline_matches_fpca_forward(bucket_model, circuit_params):
    """Scheduler output == direct fused fpca_forward on the same frames."""
    pipe = _pipeline(bucket_model)
    specs = _register_grid(pipe)
    reqs = _requests(specs, ["overlap", "overlap", "dense"])
    results = pipe.submit(reqs)
    for req, res in zip(reqs, results):
        cfg = pipe._configs[req.config]
        want = fpca_forward(
            jnp.asarray(req.image), cfg.kernel, cfg.spec, model=bucket_model,
            bn_offset_counts=cfg.bn_offset, mode="bucket_sigmoid", hard=True,
            backend="basis",
        )["counts"]
        np.testing.assert_array_equal(np.asarray(res), np.asarray(want))


def test_executable_cache_hits_on_repeat_specs(bucket_model):
    pipe = _pipeline(bucket_model, cache_capacity=8)
    specs = _register_grid(pipe)
    reqs = _requests(specs, ["dense", "overlap", "binned"])
    pipe.submit(reqs)
    assert pipe.stats.cache_misses == 3 and pipe.stats.cache_hits == 0
    pipe.submit(reqs)                        # warm: every signature cached
    assert pipe.stats.cache_misses == 3 and pipe.stats.cache_hits == 3
    assert pipe.stats.evictions == 0


def test_executable_cache_is_bounded(bucket_model):
    pipe = _pipeline(bucket_model, cache_capacity=2)
    specs = _register_grid(pipe)             # 3 distinct signatures
    pipe.submit(_requests(specs, ["dense", "overlap", "binned"]))
    assert pipe.cache_size == 2              # never exceeds capacity
    assert pipe.stats.evictions == 1


def test_configs_sharing_signature_share_executable(bucket_model):
    """Reprogramming NVM weights must not recompile: two configs with the
    same (spec, c_o, adc, enc) hit one cached executable."""
    pipe = _pipeline(bucket_model)
    spec = _spec(5, 5, 1)
    rng = np.random.default_rng(3)
    kA = rng.normal(size=(4, 5, 5, 3)).astype(np.float32) * 0.2
    kB = rng.normal(size=(4, 5, 5, 3)).astype(np.float32) * 0.2
    pipe.register("progA", spec, kA)
    pipe.register("progB", spec, kB)
    assert spec_signature(spec, 4, pipe.adc, pipe.enc) == spec_signature(
        spec, 4, pipe.adc, pipe.enc
    )
    img = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    resA, resB = pipe.submit(
        [FrontendRequest("progA", img), FrontendRequest("progB", img)]
    )
    assert pipe.stats.cache_misses == 1 and pipe.stats.cache_hits == 1
    assert pipe.cache_size == 1
    # different weights really were applied
    assert not np.array_equal(np.asarray(resA), np.asarray(resB))


def test_pipeline_batch_padding_transparent(bucket_model):
    """Odd group sizes (padded to pow2 buckets) return only real frames."""
    pipe = _pipeline(bucket_model)
    specs = _register_grid(pipe)
    reqs = _requests(specs, ["dense"] * 5)   # padded to 8 internally
    results = pipe.submit(reqs)
    assert len(results) == 5
    solo = pipe.submit([reqs[3]])[0]
    np.testing.assert_array_equal(np.asarray(results[3]), np.asarray(solo))


def test_pipeline_region_skipping(bucket_model):
    pipe = _pipeline(bucket_model)
    specs = _register_grid(pipe)
    spec = specs["overlap"]
    mask = _block_mask(spec)
    req = _requests(specs, ["overlap"])[0]
    masked = pipe.submit(
        [FrontendRequest(req.config, req.image, block_mask=mask)]
    )[0]
    from repro.core.mapping import active_window_mask

    keep = active_window_mask(spec, mask)
    assert np.all(np.asarray(masked)[~keep] == 0)


def test_pipeline_data_parallel_mesh(bucket_model):
    """Batches shard over the host mesh's data axes (1-device smoke)."""
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    pipe = _pipeline(bucket_model, mesh=mesh)
    specs = _register_grid(pipe)
    reqs = _requests(specs, ["dense", "dense", "overlap"])
    results = pipe.submit(reqs)
    assert len(results) == 3
    no_mesh = _pipeline(bucket_model)
    _register_grid(no_mesh)
    plain = no_mesh.submit(reqs)
    for a, b in zip(results, plain):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plain_calibration_rejected_for_custom_circuit(bucket_model):
    """A calibration passed as a plain BucketCurvefitModel is implicitly a
    default-CircuitParams fit — serving a custom-circuit program from that
    pipeline must raise CalibrationKeyError, not silently pair the wrong
    physics (or quietly refit and ignore the supplied model)."""
    from repro.core.curvefit import fit_bucket_model
    from repro.core.device_models import CircuitParams
    from repro.fpca import FPCAProgram
    from repro.serving.fpca_pipeline import CalibrationKeyError

    pipe = _pipeline(bucket_model)
    spec = _spec(5, 5, 1)
    program = FPCAProgram(spec=spec, circuit=CircuitParams(drive_c=0.30))
    rng = np.random.default_rng(0)
    kernel = rng.normal(size=(spec.out_channels, 5, 5, 3)).astype(np.float32) * 0.2
    pipe.register("custom", program, kernel)
    with pytest.raises(CalibrationKeyError, match="plain"):
        pipe.serve([FrontendRequest("custom", np.zeros((H, W, 3), np.float32))])
    # keyed explicitly, the same circuit serves (fitted on demand)
    explicit = FPCAPipeline(
        {(program.circuit, spec.n_active_pixels): fit_bucket_model(
            program.circuit, n_pixels=spec.n_active_pixels)},
        backend="basis",
    )
    explicit.register("custom", program, kernel)
    out = explicit.serve(
        [FrontendRequest("custom", np.zeros((H, W, 3), np.float32))]
    )
    assert np.asarray(out[0]).shape == (*output_dims(spec), spec.out_channels)


def test_unknown_config_raises(bucket_model):
    pipe = _pipeline(bucket_model)
    with pytest.raises(KeyError):
        pipe.submit([FrontendRequest("nope", np.zeros((H, W, 3), np.float32))])


def test_mismatched_frame_geometry_raises(bucket_model):
    pipe = _pipeline(bucket_model)
    specs = _register_grid(pipe)
    with pytest.raises(ValueError, match="sensor geometry"):
        pipe.submit([FrontendRequest("dense", np.zeros((7, 7, 3), np.float32))])
