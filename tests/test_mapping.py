"""Schedule/cycle-model invariants, incl. a property test of the paper's Eq. 1."""

from __future__ import annotations

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mapping


def _spec(**kw) -> mapping.FPCASpec:
    defaults = dict(
        image_h=64, image_w=64, out_channels=8, kernel=5, stride=1, max_kernel=5
    )
    defaults.update(kw)
    return mapping.FPCASpec(**defaults)


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(2, 7),
    s=st.integers(1, 7),
    c_o=st.integers(1, 32),
    h=st.integers(16, 128),
    w=st.integers(16, 128),
)
def test_eq1_cycle_count(n, s, c_o, h, w):
    """N_C = 2 * h_o * c_o * lcm(S, n) / S  — against the explicit schedule."""
    if s > n or h < n or w < n:
        return
    spec = _spec(image_h=h, image_w=w, out_channels=c_o, kernel=n, stride=s, max_kernel=n)
    h_o = (h - n) // s + 1
    expected = 2 * h_o * c_o * math.lcm(s, n) // s
    assert mapping.n_cycles(spec) == expected
    assert sum(1 for _ in mapping.schedule(spec)) == expected


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 6), s=st.integers(1, 6))
def test_schedule_covers_every_window_once(n, s):
    """Per (channel, sign), the phase groups partition the output columns, and
    windows sharing a cycle occupy disjoint n-wide pixel-column groups."""
    if s > n:
        return
    spec = _spec(image_h=32, image_w=32, out_channels=2, kernel=n, stride=s, max_kernel=n)
    h_o, w_o = mapping.output_dims(spec)
    seen = {}
    for cyc in mapping.schedule(spec):
        key = (cyc.sign, cyc.channel, cyc.out_row)
        seen.setdefault(key, []).extend(cyc.window_cols.tolist())
        starts = np.sort(cyc.window_cols * s)
        if len(starts) > 1:
            assert (np.diff(starts) >= n).all(), "parallel windows overlap columns"
    for key, cols in seen.items():
        assert sorted(cols) == list(range(w_o)), f"row not fully covered: {key}"


def test_output_dims_use_physical_kernel():
    """Logical k < n still maps the full n x n footprint (paper §3.4.1), so the
    output grid is computed with n."""
    s_small = _spec(kernel=3, max_kernel=5)
    s_full = _spec(kernel=5, max_kernel=5)
    assert mapping.output_dims(s_small) == mapping.output_dims(s_full)


def test_colp_line_cycles_with_phase():
    spec = _spec(stride=1)
    lines = [c.colp_line for c in mapping.schedule(spec) if c.channel == 0 and c.out_row == 0]
    # stride 1, n = 5 -> 5 phases mapping kernel columns 0..4 (paper Fig. 5).
    assert sorted(set(lines)) == [0, 1, 2, 3, 4]


def test_stride_validation():
    with pytest.raises(ValueError):
        _spec(stride=6, max_kernel=5)
    with pytest.raises(ValueError):
        _spec(kernel=7, max_kernel=5)


def test_region_skipping_reduces_cycles():
    spec = _spec(image_h=64, image_w=64, out_channels=4, stride=5, skip_block=8)
    full = np.ones((8, 8), dtype=bool)
    half = full.copy()
    half[4:] = False
    none = np.zeros((8, 8), dtype=bool)
    c_full = mapping.n_cycles_with_skipping(spec, full)
    c_half = mapping.n_cycles_with_skipping(spec, half)
    c_none = mapping.n_cycles_with_skipping(spec, none)
    assert c_full == mapping.n_cycles(spec)
    assert c_none == 0
    assert c_none < c_half < c_full


def test_active_window_mask_boundary():
    """A window overlapping a kept block even partially must stay active
    (its RS/SW lines fire)."""
    spec = _spec(image_h=16, image_w=16, out_channels=1, stride=1, skip_block=8)
    mask = np.array([[True, False], [False, False]])
    active = mapping.active_window_mask(spec, mask)
    h_o, w_o = mapping.output_dims(spec)
    assert active.shape == (h_o, w_o)
    assert active[0, 0]          # fully inside the kept block
    assert active[0, 7]          # straddles the boundary -> still active
    assert not active[11, 11]    # fully inside skipped region


def test_binning_shrinks_output():
    s1 = _spec(image_h=64, image_w=64, binning=1)
    s4 = _spec(image_h=64, image_w=64, binning=4)
    h1, w1 = mapping.output_dims(s1)
    h4, w4 = mapping.output_dims(s4)
    assert h4 < h1 and w4 < w1
    assert mapping.n_cycles(s4) < mapping.n_cycles(s1)


def test_weights_per_column_formula():
    """§3.2: 2 * n^2 * 3 * c_o NVM devices per pixel column."""
    spec = _spec(out_channels=16)
    assert spec.weights_per_column == 2 * 25 * 3 * 16
