"""Property-test sweep for the adaptive streaming control plane.

Drawn via :mod:`_hypothesis_compat` (real hypothesis when installed, the
deterministic seeded-grid fallback otherwise), pinning the invariants the
streaming stack leans on:

* :func:`window_bucket` — power-of-two (or capped), monotone in the kept
  count, never smaller than the kept count, exact at the pow-2 boundaries
  ``±1`` (the flap-prone edges).
* :func:`block_delta_mask` / :class:`StreamSession` gating — output shape
  matches the periphery block grid, a keyframe tick keeps every block, and
  hysteresis never drops a block younger than ``hysteresis`` frames.
* :class:`StickyBucket` — always big enough for the tick's kept windows,
  shrinks only after ``patience`` consecutive under-full ticks, and
  ``patience=1`` reproduces the stateless bucket exactly.
* :class:`GateController` — threshold clamped to its configured range, the
  per-tick log-step bounded by ``max_step``, keyframe ticks never actuate.
"""

from __future__ import annotations

import math

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.mapping import FPCASpec, active_window_mask
from repro.kernels.fpca_conv.ops import StickyBucket, window_bucket
from repro.serving.control import GateController, GateControllerConfig
from repro.serving.streaming import DeltaGateConfig, StreamSession, block_delta_mask


def _spec(kernel: int = 5, stride: int = 5, binning: int = 1, hw: int = 24) -> FPCASpec:
    return FPCASpec(
        image_h=hw, image_w=hw, out_channels=4, kernel=kernel, stride=stride,
        binning=binning,
    )


# ---------------------------------------------------------------------------
# window_bucket invariants
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(n_keep=st.integers(0, 4096), m_total=st.integers(1, 4096))
def test_window_bucket_invariants(n_keep, m_total):
    n_keep = min(n_keep, m_total)           # masks never keep more than exists
    bucket = window_bucket(n_keep, m_total)
    # bounded: holds every kept window, never exceeds the grid
    assert max(n_keep, 1) <= bucket <= m_total
    # pow-2 unless capped at the grid size (the dense-fallback case)
    assert bucket == m_total or (bucket & (bucket - 1)) == 0
    # tight: no more than the next pow-2 of the kept count
    assert bucket <= 1 << (max(n_keep, 1) - 1).bit_length()
    # monotone in the kept count
    if n_keep < m_total:
        assert window_bucket(n_keep + 1, m_total) >= bucket


@settings(max_examples=30)
@given(p=st.integers(1, 11), m_shift=st.integers(1, 3))
def test_window_bucket_exact_at_pow2_boundaries(p, m_shift):
    pow2 = 1 << p
    m_total = pow2 << m_shift               # grid strictly above the boundary
    assert window_bucket(pow2, m_total) == pow2
    # pow2-1 rounds back up to pow2 — except 1, which is itself a bucket
    assert window_bucket(pow2 - 1, m_total) == (pow2 if pow2 > 2 else 1)
    assert window_bucket(pow2 + 1, m_total) == min(2 * pow2, m_total)


# ---------------------------------------------------------------------------
# block_delta_mask / StreamSession gate invariants
# ---------------------------------------------------------------------------


@settings(max_examples=12)
@given(
    kernel=st.integers(3, 5),
    stride=st.integers(2, 5),
    binning=st.sampled_from([1, 2]),
    threshold=st.floats(1e-3, 0.5),
    seed=st.integers(0, 2**16),
)
def test_block_delta_mask_shape_and_threshold_monotone(
    kernel, stride, binning, threshold, seed
):
    spec = _spec(kernel, stride, binning)
    rng = np.random.default_rng(seed)
    a = rng.uniform(0, 1, (spec.eff_h, spec.eff_w)).astype(np.float32)
    b = rng.uniform(0, 1, (spec.eff_h, spec.eff_w)).astype(np.float32)
    mask = block_delta_mask(a, b, spec, threshold)
    bh = math.ceil(spec.eff_h / spec.skip_block)
    bw = math.ceil(spec.eff_w / spec.skip_block)
    assert mask.shape == (bh, bw) and mask.dtype == bool
    # a stricter threshold can only drop blocks, never add them
    stricter = block_delta_mask(a, b, spec, threshold * 2.0)
    assert not np.any(stricter & ~mask)
    # identical frames never flag a change
    assert not block_delta_mask(a, a, spec, threshold).any()


@settings(max_examples=10)
@given(
    hysteresis=st.integers(0, 3),
    keyframe_interval=st.sampled_from([0, 3, 5]),
    threshold=st.floats(0.01, 0.2),
    seed=st.integers(0, 2**16),
)
def test_session_gate_keyframe_and_hysteresis_invariants(
    hysteresis, keyframe_interval, threshold, seed
):
    """Keyframes keep all blocks; a changed block survives >= hysteresis
    extra frames; every mask matches the block grid."""
    spec = _spec()
    gate = DeltaGateConfig(
        threshold=threshold, hysteresis=hysteresis,
        keyframe_interval=keyframe_interval,
    )
    session = StreamSession("s", "cam", spec, gate)
    rng = np.random.default_rng(seed)
    bh = math.ceil(spec.eff_h / spec.skip_block)
    bw = math.ceil(spec.eff_w / spec.skip_block)
    n_frames = 12
    frames, prev_eff = [], None
    changed_at: list[np.ndarray | None] = []
    for _ in range(n_frames):
        frame = rng.uniform(0, 1, (spec.image_h, spec.image_w, 3)).astype(np.float32)
        if rng.random() < 0.4 and frames:
            frame = frames[-1]              # occasionally a static tick
        frames.append(frame)
        eff = np.asarray(frame, np.float32).mean(axis=-1)
        changed_at.append(
            block_delta_mask(prev_eff, eff, spec, threshold)
            if prev_eff is not None else None
        )
        prev_eff = eff
    masks = [session.step(f) for f in frames]
    age = np.full((bh, bw), hysteresis + 1, np.int64)
    for t, mask in enumerate(masks):
        assert mask.shape == (bh, bw)
        if changed_at[t] is not None:
            age = np.where(changed_at[t], 0, age + 1)
        keyframe = t == 0 or (keyframe_interval > 0 and t % keyframe_interval == 0)
        if keyframe:
            assert mask.all()               # keyframe tick keeps every block
        else:
            # hysteresis never drops a block younger than `hysteresis`
            young = age <= hysteresis
            assert mask[young].all()
            # and never keeps one older (no phantom blocks)
            assert not mask[~young].any()


@settings(max_examples=8)
@given(binning=st.sampled_from([1, 2]), seed=st.integers(0, 2**16))
def test_gate_mask_feeds_active_window_mask(binning, seed):
    """The gate's block grid is exactly what active_window_mask consumes."""
    spec = _spec(binning=binning)
    session = StreamSession(
        "s", "cam", spec, DeltaGateConfig(threshold=0.05, hysteresis=1)
    )
    rng = np.random.default_rng(seed)
    frame = rng.uniform(0, 1, (spec.image_h, spec.image_w, 3)).astype(np.float32)
    mask = session.step(frame)
    window = active_window_mask(spec, mask)     # raises on a shape mismatch
    assert window.all()                         # first frame = keyframe


# ---------------------------------------------------------------------------
# StickyBucket invariants
# ---------------------------------------------------------------------------


@settings(max_examples=25)
@given(
    patience=st.integers(1, 6),
    m_total=st.sampled_from([64, 100, 256]),
    seed=st.integers(0, 2**16),
)
def test_sticky_bucket_invariants(patience, m_total, seed):
    rng = np.random.default_rng(seed)
    sticky = StickyBucket(patience)
    plain = StickyBucket(1)
    under_streak = 0
    prev_held = None
    for _ in range(40):
        n_keep = int(rng.integers(0, m_total + 1))
        raw = window_bucket(n_keep, m_total)
        served = sticky.bucket(n_keep, m_total)
        # correctness: the served bucket always holds this tick's windows
        assert served >= raw or served == m_total
        assert max(n_keep, 1) <= served <= m_total
        # shrink discipline: only after `patience` consecutive under-full ticks
        if prev_held is not None and served < prev_held:
            assert under_streak + 1 >= patience
        under_streak = under_streak + 1 if (prev_held is not None and raw < prev_held) else 0
        if prev_held is not None and served != prev_held and served == raw:
            under_streak = 0
        prev_held = served
        # patience=1 is the stateless bucket, bit for bit
        assert plain.bucket(n_keep, m_total) == raw
    # hysteresis can only reduce transitions relative to the flapping bucket
    assert sticky.switches <= plain.switches


def test_sticky_bucket_defers_then_shrinks():
    sticky = StickyBucket(patience=3)
    assert sticky.bucket(100, 400) == 128
    for i in range(2):                      # two under-full ticks: still held
        assert sticky.bucket(10, 400) == 128
    assert sticky.bucket(10, 400) == 16     # third consecutive: shrink
    assert sticky.switches == 1             # (the initial 128 is not a switch)
    assert sticky.shrinks_deferred == 2
    assert sticky.bucket(200, 400) == 256   # growth is always immediate
    assert sticky.switches == 2


def test_sticky_bucket_idle_ticks_advance_shrink_streak():
    """All-skipped ticks count as under-full: after a quiet period of
    >= patience ticks the first active tick shrinks immediately (no stale
    oversized bucket survives a lull)."""
    sticky = StickyBucket(patience=3)
    assert sticky.bucket(100, 400) == 128
    for _ in range(3):
        sticky.observe_idle()               # nothing served, no transition
    assert sticky.switches == 0
    assert sticky.bucket(5, 400) == 8       # wake tick: shrinks right away
    # idle on a fresh instance is a no-op (nothing held to shrink)
    fresh = StickyBucket(patience=2)
    fresh.observe_idle()
    assert fresh.bucket(100, 400) == 128


# ---------------------------------------------------------------------------
# GateController invariants
# ---------------------------------------------------------------------------


@settings(max_examples=12)
@given(
    target=st.floats(0.05, 0.6),
    thr0=st.floats(1e-4, 1.0),
    seed=st.integers(0, 2**16),
)
def test_controller_bounded_step_and_clamp(target, thr0, seed):
    spec = _spec()
    cfg = GateControllerConfig(target=target)
    ctl = GateController(cfg, spec, thr0)
    rng = np.random.default_rng(seed)
    bh = math.ceil(spec.eff_h / spec.skip_block)
    bw = math.ceil(spec.eff_w / spec.skip_block)
    prev = ctl.threshold
    for t in range(24):
        mask = rng.random((bh, bw)) < rng.random()   # arbitrary plant
        keyframe = t % 7 == 0
        thr = ctl.observe(mask, keyframe=keyframe)
        assert cfg.min_threshold <= thr <= cfg.max_threshold
        # bounded actuation in log space
        assert abs(math.log(thr) - math.log(prev)) <= cfg.max_step + 1e-12
        if keyframe:
            assert thr == prev              # held-out tick never actuates
            assert ctl.history[-1]["observed"] is None
        prev = thr
    assert len(ctl.history) == 24


@settings(max_examples=6)
@given(seed=st.integers(0, 2**16))
def test_controller_energy_observation_matches_report(seed):
    """The hoisted-baseline energy observation equals the full report."""
    from repro.core import analysis

    spec = _spec()
    ctl = GateController(
        GateControllerConfig(target=0.2, metric="energy"), spec, 0.02
    )
    rng = np.random.default_rng(seed)
    bh = math.ceil(spec.eff_h / spec.skip_block)
    bw = math.ceil(spec.eff_w / spec.skip_block)
    mask = rng.random((bh, bw)) < 0.5
    rep = analysis.streaming_frontend_report(spec, [mask])
    assert ctl._observation(mask) == rep["energy_vs_dense"]


def test_controller_saturated_scene_no_windup():
    """A scene pinned at 0 kept windows must not wind up: once blocks appear
    again the threshold recovers within a few bounded steps."""
    spec = _spec()
    cfg = GateControllerConfig(target=0.15)
    ctl = GateController(cfg, spec, 0.02)
    bh = math.ceil(spec.eff_h / spec.skip_block)
    bw = math.ceil(spec.eff_w / spec.skip_block)
    empty = np.zeros((bh, bw), bool)
    for _ in range(50):
        ctl.observe(empty)
    # threshold driven to (near) the floor, integrator leaked + clamped
    assert ctl.threshold <= 0.02
    assert abs(ctl._integral) <= cfg.windup
    full = np.ones((bh, bw), bool)
    before = ctl.threshold
    ctl.observe(full)
    # the very next correction is bounded — no wound-up slam
    assert abs(math.log(ctl.threshold) - math.log(before)) <= cfg.max_step + 1e-12
