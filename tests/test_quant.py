"""Quantised int8 serving path: calibrated digital-head lowering.

What is pinned here:

* **exact accumulation** — :func:`repro.models.quant.quant_bank_dot` is
  bit-exact ``int8 x int8 -> int32`` through f32 sgemm carriers, including
  reductions deeper than the 1024-term chunk bound;
* **bounded parity** — the ``precision="int8"`` lowering tracks the f32
  reference within pinned max-logit-divergence / top-1-agreement bounds
  across the serving grid: dense batched, delta-gated masked streaming,
  zero-kept ticks, and bucket-edge inputs;
* **zero-recompile reprogram** — rewriting NVM planes *and* head weights
  on an int8-compiled model never recompiles (scales ride traced);
* **single-sourced leaf numerics** — gradient compression re-imports the
  same symmetric int8 helpers (no second quantiser to drift);
* **export round-trip** — calibrated activation scales pack/unpack through
  the npz bundle representation for chain and graph heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import fpca
from repro.core.mapping import FPCASpec
from repro.models import quant

pytestmark = pytest.mark.quant

H = 20  # 4x4 window grid at kernel 5 / stride 5 — smallest honest workload


def _programs(head=None, **frontend_kw):
    spec = FPCASpec(image_h=H, image_w=H, out_channels=4, kernel=5, stride=5)
    prog = fpca.FPCAProgram(
        spec=spec, gate=fpca.DeltaGateConfig(threshold=0.02), **frontend_kw
    )
    head = head or (fpca.DenseSpec(16, activation="relu"), fpca.DenseSpec(3))
    mp = fpca.FPCAModelProgram(frontend=prog, head=head)
    return mp, mp.replace(precision="int8")


def _kernel(mp, seed=0, scale=0.2):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=mp.frontend.kernel_shape) * scale).astype(
        np.float32
    )


# ---------------------------------------------------------------------------
# exact int32 accumulation through the f32 carrier bank
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n", [(2, 64, 5), (1, 1024, 8), (3, 1500, 7), (2, 4096, 16)]
)
def test_bank_dot_is_exact_int32(m, k, n):
    """quant_bank_dot == int64 reference for K below, at, and past the
    chunk bound (incl. a non-multiple-of-1024 K that exercises padding)."""
    rng = np.random.default_rng(k)
    x_q = rng.integers(-127, 128, size=(m, k)).astype(np.float32)
    w_q = rng.integers(-127, 128, size=(k, n)).astype(np.int8)
    out = np.asarray(jax.jit(quant.quant_bank_dot)(x_q, jnp.asarray(w_q)))
    ref = x_q.astype(np.int64) @ w_q.astype(np.int64)
    assert out.dtype == np.int32
    assert (out == ref).all()


def test_compression_reimports_leaf_helpers():
    """training/compression quantises with THE shared leaf helpers — the
    symmetric int8 numerics have exactly one definition."""
    from repro.training import compression

    assert compression._quantize_leaf is quant.quantize_leaf_symmetric
    g = jnp.asarray(np.random.default_rng(0).normal(size=(9, 4)), jnp.float32)
    q, s = quant.quantize_leaf_symmetric(g)
    assert q.dtype == jnp.int8
    deq = quant.dequantize_leaf(q, s)
    # reconstruction error bounded by half a step
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-7


# ---------------------------------------------------------------------------
# program surface
# ---------------------------------------------------------------------------


def test_precision_validated_and_signature_stable():
    mp, mp_i8 = _programs()
    with pytest.raises(ValueError, match="unknown precision"):
        mp.replace(precision="fp4")
    # every pre-existing f32 signature stays byte-identical; int8 extends it
    assert mp.signature() == _programs()[0].signature()
    assert not any("precision" in str(e) for e in mp.signature())
    assert ("precision", "int8") in mp_i8.signature()
    assert mp_i8.signature() != mp.signature()


def test_bind_quant_error_paths():
    mp, mp_i8 = _programs()
    hp = mp.init_head(jax.random.PRNGKey(0))
    qp = quant.quantize_head_params(mp_i8, hp)
    bad = [dict(qp[0]), dict(qp[1])]
    del bad[0]["x_scale"]
    with pytest.raises(ValueError, match="needs keys"):
        quant.bind_quant_head_params(mp_i8, bad)
    bad = [dict(qp[0]), dict(qp[1])]
    bad[1]["w_q"] = bad[1]["w_q"][:-1]
    with pytest.raises(ValueError, match="do not match"):
        quant.bind_quant_head_params(mp_i8, bad)
    with pytest.raises(ValueError, match="stages"):
        quant.bind_quant_head_params(mp_i8, qp[:1])
    # the model program dispatches: raw f32 params quantise on the way in
    bound = mp_i8.bind_head_params(hp)
    assert quant.is_quantized_params(bound)
    assert bound[0]["w_q"].dtype == jnp.int8


def test_act_scale_pack_roundtrip_chain_and_graph():
    mp, mp_i8 = _programs(
        head=(
            fpca.ConvSpec(6, 3, 1, "SAME", activation="relu"),
            fpca.PoolSpec(2, 2, "avg"),
            fpca.DenseSpec(5),
        )
    )
    hp = mp.init_head(jax.random.PRNGKey(1))
    counts = jnp.asarray(
        np.random.default_rng(0).integers(0, 256, size=(2, 4, 4, 4)),
        jnp.float32,
    )
    scales = quant.calibrate_head_scales(mp, mp.bind_head_params(hp), counts)
    packed = quant.pack_act_scales(mp, scales)
    assert packed.dtype == np.float32 and packed.shape == (len(mp.head),)
    back = quant.unpack_act_scales(mp, packed)
    assert back[1] is None  # pool stage stays parameterless
    for b, s in zip(back, scales):
        if s is None:
            assert b is None
        else:
            assert b == pytest.approx(s, rel=1e-6)
    with pytest.raises(ValueError, match="activation scales"):
        quant.unpack_act_scales(mp, packed[:-1])

    spec = FPCASpec(image_h=H, image_w=H, out_channels=4, kernel=5, stride=5)
    g = fpca.build_model(
        {"arch": "fpca_resnet", "spec": spec, "n_classes": 3, "width": 4}
    )
    gp = g.init_head(jax.random.PRNGKey(2))
    gs = quant.calibrate_head_scales(g, g.bind_head_params(gp), counts)
    gb = quant.unpack_act_scales(g, quant.pack_act_scales(g, gs))
    assert gb == pytest.approx(gs, rel=1e-6)


# ---------------------------------------------------------------------------
# bounded parity across the serving grid
# ---------------------------------------------------------------------------

# pinned bounds for the calibrated tiny classifier below (seeded, so the
# numbers are deterministic on a given jax/XLA stack; bounds carry margin)
MAX_LOGIT_DIVERGENCE = 0.35
MIN_TOP1_AGREEMENT = 0.9


def _compiled_pair(calibrate_on=None):
    mp, mp_i8 = _programs()
    kernel = _kernel(mp)
    hp = mp.init_head(jax.random.PRNGKey(0))
    m_f32 = fpca.compile(mp, backend="basis", weights=kernel, head_params=hp)
    if calibrate_on is not None:
        fe = fpca.compile(mp.frontend, backend="basis", weights=kernel)
        hp_i8 = quant.quantize_head_params(
            mp_i8, hp, sample_counts=fe.run(calibrate_on)
        )
    else:
        hp_i8 = hp
    m_i8 = fpca.compile(
        mp_i8, backend="basis", weights=kernel, head_params=hp_i8
    )
    return mp, m_f32, m_i8


def test_parity_dense_batched():
    rng = np.random.default_rng(3)
    frames = rng.uniform(0, 1, (8, H, H, 3)).astype(np.float32)
    _, m_f32, m_i8 = _compiled_pair(calibrate_on=frames)
    par = quant.logit_parity(m_f32.run(frames), m_i8.run(frames))
    assert par["max_abs_divergence"] <= MAX_LOGIT_DIVERGENCE
    assert par["top1_agreement"] >= MIN_TOP1_AGREEMENT


def test_parity_bucket_edges():
    """Constant frames sweeping [0, 1] drive the normalised bitline voltage
    across every bucket edge — the worst case for the int8 transfer LUT."""
    levels = np.linspace(0.0, 1.0, 11, dtype=np.float32)
    frames = np.stack([np.full((H, H, 3), v) for v in levels])
    _, m_f32, m_i8 = _compiled_pair(calibrate_on=frames)
    par = quant.logit_parity(m_f32.run(frames), m_i8.run(frames))
    assert par["max_abs_divergence"] <= MAX_LOGIT_DIVERGENCE
    assert par["top1_agreement"] >= MIN_TOP1_AGREEMENT


def test_parity_masked_and_zero_kept_stream():
    """Per-tick parity through delta-gated streaming, including a repeated
    frame whose tick keeps zero windows (quiet-branch logits)."""
    rng = np.random.default_rng(5)
    frames = rng.uniform(0, 1, (6, H, H, 3)).astype(np.float32)
    # repeat a frame past the gate's hysteresis so one tick keeps nothing
    frames[2] = frames[1]
    frames[3] = frames[1]
    frames[4] = frames[1]
    _, m_f32, m_i8 = _compiled_pair(calibrate_on=frames)
    got_zero_kept = False
    for r32, r8 in zip(m_f32.stream(frames), m_i8.stream(frames)):
        assert r32.kept_windows == r8.kept_windows  # gate sees raw frames
        got_zero_kept |= r32.kept_windows == 0
        par = quant.logit_parity(r32.logits, r8.logits)
        assert par["max_abs_divergence"] <= MAX_LOGIT_DIVERGENCE
    assert got_zero_kept, "grid must include a zero-kept tick"


def test_int8_segment_matches_int8_stream_exactly():
    """The lax.scan segment path serves the SAME int8 numerics as the
    per-tick stream — bit-exact, zero-kept ticks included."""
    rng = np.random.default_rng(7)
    frames = rng.uniform(0, 1, (5, H, H, 3)).astype(np.float32)
    frames[2] = frames[1]
    _, _, m_i8 = _compiled_pair()
    per_tick = np.stack([np.asarray(r.logits) for r in m_i8.stream(frames)])
    seg = np.asarray(m_i8.run_segment(frames).logits)
    np.testing.assert_array_equal(per_tick, seg.reshape(per_tick.shape))


def test_reference_backend_serves_int8_head():
    """Backends without quant_transfer (reference) serve the f32 frontend
    under the int8 head — the head lowering is backend-independent."""
    mp, mp_i8 = _programs()
    kernel = _kernel(mp)
    hp = mp.init_head(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    frames = rng.uniform(0, 1, (2, H, H, 3)).astype(np.float32)
    m_ref = fpca.compile(
        mp_i8, backend="reference", weights=kernel, head_params=hp
    )
    m_basis = fpca.compile(
        mp_i8, backend="basis", weights=kernel, head_params=hp
    )
    # identical head quantisation; only the frontend transfer differs, and
    # that by at most 1 LSB on a sliver of counts
    par = quant.logit_parity(m_ref.run(frames), m_basis.run(frames))
    assert par["max_abs_divergence"] <= MAX_LOGIT_DIVERGENCE


def test_int8_lowering_matches_fake_quant_reference():
    """apply_head_int8 == the fake-quant f32 simulation (dequantised
    weights, requantised activations) — divergence from f32 is pure
    quantisation error, never a lowering bug."""
    mp, mp_i8 = _programs()
    hp = mp.init_head(jax.random.PRNGKey(0))
    counts = jnp.asarray(
        np.random.default_rng(0).integers(0, 256, size=(3, 4, 4, 4)),
        jnp.float32,
    )
    qp = quant.quantize_head_params(mp_i8, hp)
    got = np.asarray(quant.apply_head_int8(mp_i8, qp, counts))

    x = np.asarray(counts, np.float64).reshape(3, -1)
    for q, act in zip(qp, ("relu", None)):
        xs = float(q["x_scale"])
        x_q = np.clip(np.round(x / xs), -127, 127)
        acc = x_q @ np.asarray(q["w_q"], np.float64)
        x = acc * (xs * np.asarray(q["w_scale"], np.float64)) + np.asarray(
            q["b"], np.float64
        )
        if act == "relu":
            x = np.maximum(x, 0.0)
    np.testing.assert_allclose(got, x, atol=1e-4)


def test_graph_head_int8_lowering():
    """Zoo graph heads (residual adds, detect conv) lower stage-for-stage."""
    spec = FPCASpec(image_h=H, image_w=H, out_channels=4, kernel=5, stride=5)
    for cfg in (
        {"arch": "fpca_resnet", "spec": spec, "n_classes": 3, "width": 4},
        {"arch": "fpca_detect", "spec": spec, "n_classes": 2, "width": 4},
    ):
        g = fpca.build_model(cfg).replace(precision="int8")
        gp = g.init_head(jax.random.PRNGKey(3))
        counts = jnp.asarray(
            np.random.default_rng(1).integers(0, 256, size=(2, 4, 4, 4)),
            jnp.float32,
        )
        qp = quant.quantize_head_params(g, gp, sample_counts=counts)
        out_i8 = np.asarray(g.apply_head(qp, counts))
        out_f32 = np.asarray(
            g.replace(precision="f32").apply_head(
                g.replace(precision="f32").bind_head_params(gp), counts
            )
        )
        assert out_i8.shape == out_f32.shape
        scale = max(float(np.max(np.abs(out_f32))), 1.0)
        assert float(np.max(np.abs(out_i8 - out_f32))) <= 0.1 * scale, cfg


# ---------------------------------------------------------------------------
# reprogramming
# ---------------------------------------------------------------------------


def test_int8_reprogram_is_zero_recompile():
    """NVM planes, head weights AND freshly calibrated scales all ride
    traced: reprogramming an int8-compiled model never recompiles."""
    mp, mp_i8 = _programs()
    kernel = _kernel(mp)
    hp = mp.init_head(jax.random.PRNGKey(0))
    m = fpca.compile(mp_i8, backend="basis", weights=kernel, head_params=hp)
    rng = np.random.default_rng(13)
    frames = rng.uniform(0, 1, (2, H, H, 3)).astype(np.float32)
    before = np.asarray(m.run(frames))
    misses = m.cache_info().misses
    hp2 = mp.init_head(jax.random.PRNGKey(42))
    m.reprogram(kernel * 0.7, head_params=hp2)
    after = np.asarray(m.run(frames))
    assert m.cache_info().misses == misses, "reprogram recompiled"
    assert not np.array_equal(before, after), "reprogram was a no-op"
    # streaming off the reprogrammed handle also stays on the warm cache
    for _ in m.stream(frames):
        pass
    assert m.cache_info().misses > 0  # sanity: the cache is really in play
