"""Model-zoo subsystem: meta-arch registry, head graphs, detection heads
and neuromorphic event streams.

Contracts pinned here (the CI api-surface job runs this file as the
``-m zoo`` fast lane):

* **Registry** — ``register_arch`` / ``build_model`` round-trip, arch
  stamping for telemetry, message-asserted error paths (duplicate
  registration, unknown arch, missing ``arch`` key).
* **``fpca_cnn`` compatibility** — the zoo-built classifier is
  *byte-identical* to ``configs.fpca_cnn.make_model_program``: golden
  signature pin, bit-equal logits, and ZERO new compiles on a shared
  executable cache.
* **HeadGraph validation** — cycles, duplicate/reserved node names,
  undefined inputs, join-shape mismatches and bad outputs all fail at
  construction with node-named messages.
* **Residual / detection numerics** — compiled graph heads equal the
  dense-compose oracle (frontend counts -> ``apply_head``), per-tick AND
  skip-aware patched streaming, per-tick AND segment serving.
* **Shared-head fusion** — same-signature model configs of one launch are
  served by ONE vmapped head pass, bit-identical to the per-config path.
* **Event streams** — per-tick packets reconcile exactly with the gate's
  changed-block accounting; segment-reconstructed packets are identical to
  per-tick ones; ``fleet_report`` breaks workloads out per arch.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax

import repro.fpca as fpca
from repro.core import analysis
from repro.core.mapping import FPCASpec, active_window_mask, output_dims
from repro.fpca import zoo
from repro.models import heads
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.observe import assert_reconciled, fleet_report
from repro.serving.streaming import StreamServer

pytestmark = pytest.mark.zoo

H = W = 20


def _spec(c_o: int = 3) -> FPCASpec:
    return FPCASpec(image_h=H, image_w=W, out_channels=c_o, kernel=5, stride=5)


def _kernel(spec: FPCASpec, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    k = spec.kernel
    return (rng.normal(size=(spec.out_channels, k, k, spec.in_channels))
            * 0.2).astype(np.float32)


def _frames(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    f = rng.uniform(0, 1, (n, H, W, 3)).astype(np.float32)
    if n > 2:
        f[2] = f[1]          # one quiet tick exercises the zero-event path
    return f


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_round_trip():
    assert {"fpca_cnn", "fpca_resnet", "fpca_detect"} <= set(
        zoo.available_archs()
    )

    @zoo.register_arch("zoo_test_arch")
    def _build(cfg):
        return zoo._ARCHS["fpca_cnn"](cfg)

    try:
        model = zoo.build_model({"arch": "zoo_test_arch", "spec": _spec()})
        assert model.arch == "zoo_test_arch"     # stamped for telemetry
        assert "zoo_test_arch" in zoo.available_archs()
        # overwrite=True replaces; kwargs override cfg keys
        @zoo.register_arch("zoo_test_arch", overwrite=True)
        def _build2(cfg):
            return zoo._ARCHS["fpca_resnet"](cfg)

        model2 = zoo.build_model({"arch": "fpca_cnn"}, arch="zoo_test_arch",
                                 spec=_spec())
        assert model2.is_graph_head
    finally:
        zoo._ARCHS.pop("zoo_test_arch", None)


def test_registry_duplicate_rejected():
    with pytest.raises(ValueError, match=r"'fpca_cnn' already registered"):
        zoo.register_arch("fpca_cnn")(lambda cfg: None)


def test_registry_bad_names():
    with pytest.raises(ValueError, match="non-empty string"):
        zoo.register_arch("")
    with pytest.raises(KeyError, match=r"unknown architecture 'nope'"):
        zoo.build_model({"arch": "nope"})
    with pytest.raises(KeyError, match="needs an 'arch' key"):
        zoo.build_model({"spec": _spec()})


# ---------------------------------------------------------------------------
# fpca_cnn: byte-identical to the config module (golden pin, zero compiles)
# ---------------------------------------------------------------------------

GOLDEN_CNN_SIG = (
    "repro.fpca.model/1",
    "repro.fpca/1",
    ("spec", 20, 20, 3, 5, 5, 5, 3, 0, 1, 8),
    ("out_channels", 3),
    ("adc", 8, 1.0),
    ("enc", 16, 1.0),
    ("circuit", ("v_sat", 1.0), ("s0", 0.37), ("drive_a", 0.15),
     ("drive_b", -0.1), ("drive_c", 0.25), ("coupling", 0.15),
     ("kappa_r", 0.012), ("r_metal_mm", 0.0), ("fp_iters", 8.0)),
    ("head", ("dense", 64, "relu"), ("dense", 2, "")),
    ("input_scale", 1.0),
)


def test_fpca_cnn_signature_golden():
    """Exact pinned value: the zoo build keys the same executables as the
    config module — change only by bumping a version string deliberately."""
    model = zoo.build_model({"arch": "fpca_cnn", "spec": _spec()})
    assert model.signature() == GOLDEN_CNN_SIG
    assert model.arch == "fpca_cnn"


def test_fpca_cnn_matches_config_module(bucket_model):
    from repro.configs.fpca_cnn import make_model_program

    spec = _spec()
    legacy = make_model_program(spec)
    built = zoo.build_model({"arch": "fpca_cnn", "spec": spec})
    # the arch stamp is telemetry-only: signatures identical
    assert built.signature() == legacy.signature()

    kernel = _kernel(spec)
    hp = legacy.init_head(jax.random.PRNGKey(0))
    cache = fpca.ExecutableCache(capacity=8)
    m1 = fpca.compile(legacy, backend="basis", weights=kernel,
                      head_params=hp, model=bucket_model, cache=cache)
    images = _frames(2)
    out1 = np.asarray(m1.run(images))
    misses = cache.info().misses
    m2 = fpca.compile(built, backend="basis", weights=kernel,
                      head_params=hp, model=bucket_model, cache=cache)
    out2 = np.asarray(m2.run(images))
    # bit-identical logits, ZERO new compiles: the zoo build warm-hits every
    # executable the config-module build compiled
    np.testing.assert_array_equal(out1, out2)
    assert cache.info().misses == misses


# ---------------------------------------------------------------------------
# HeadGraph validation (message-asserted error paths)
# ---------------------------------------------------------------------------


def test_head_graph_cycle_rejected():
    with pytest.raises(ValueError, match="cycle"):
        heads.HeadGraph(
            nodes=(
                heads.Node("a", fpca.ConvSpec(4, 3, padding="SAME"), ("b",)),
                heads.Node("b", fpca.ConvSpec(4, 3, padding="SAME"), ("a",)),
                heads.Node("out", fpca.DenseSpec(2), ("b",)),
            ),
            output="out",
        )


def test_head_graph_duplicate_and_reserved_names():
    conv = fpca.ConvSpec(4, 3, padding="SAME")
    with pytest.raises(ValueError, match=r"duplicate node name 'a'"):
        heads.HeadGraph(
            nodes=(heads.Node("a", conv), heads.Node("a", conv, ("a",)),
                   heads.Node("out", fpca.DenseSpec(2), ("a",))),
            output="out",
        )
    with pytest.raises(ValueError, match="'input' is reserved"):
        heads.HeadGraph(
            nodes=(heads.Node("input", conv),
                   heads.Node("out", fpca.DenseSpec(2), ("input",))),
            output="out",
        )


def test_head_graph_undefined_input_and_output():
    conv = fpca.ConvSpec(4, 3, padding="SAME")
    with pytest.raises(ValueError, match=r"reads undefined input 'ghost'"):
        heads.HeadGraph(
            nodes=(heads.Node("a", conv, ("ghost",)),
                   heads.Node("out", fpca.DenseSpec(2), ("a",))),
            output="out",
        )
    with pytest.raises(ValueError, match=r"output 'missing' is not a node"):
        heads.HeadGraph(
            nodes=(heads.Node("out", fpca.DenseSpec(2)),),
            output="missing",
        )
    with pytest.raises(ValueError, match="DenseSpec .* or DetectSpec"):
        heads.HeadGraph(
            nodes=(heads.Node("a", conv),), output="a"
        )


def test_head_graph_join_shape_mismatch():
    # stem emits 4 channels, branch emits 6: residual add must refuse
    g = heads.HeadGraph(
        nodes=(
            heads.Node("stem", fpca.ConvSpec(4, 3, padding="SAME")),
            heads.Node("branch", fpca.ConvSpec(6, 3, padding="SAME"),
                       ("stem",)),
            heads.Node("join", heads.AddSpec(), ("stem", "branch")),
            heads.Node("out", fpca.DenseSpec(2), ("join",)),
        ),
        output="out",
    )
    with pytest.raises(
        ValueError, match=r"node 'join': residual add needs matching"
    ):
        g.shapes((4, 4, 3))
    with pytest.raises(ValueError, match="at least 2 inputs"):
        heads.Node("join", heads.AddSpec(), ("stem",))


def test_head_graph_param_binding_errors():
    model = zoo.build_model({"arch": "fpca_resnet", "spec": _spec()})
    params = model.init_head(jax.random.PRNGKey(0))
    bad = dict(params)
    bad.pop("logits")
    with pytest.raises(ValueError, match="do not match parameterized nodes"):
        model.bind_head_params(bad)
    bad = dict(params)
    bad["fc"] = {"w": np.zeros((3, 3), np.float32),
                 "b": np.zeros((3,), np.float32)}
    with pytest.raises(ValueError, match=r"head node 'fc'"):
        model.bind_head_params(bad)


def test_graph_head_shapes_and_flops():
    model = zoo.build_model({"arch": "fpca_resnet", "spec": _spec()})
    with pytest.raises(TypeError, match="chain heads"):
        model.head_shapes()
    shapes = model.head.shapes(model.frontend.out_shape)
    assert shapes["join"] == shapes["stem"]
    fl = analysis.head_flops(model)
    assert fl["macs"] > 0 and fl["params"] > 0
    assert any(row["layer"].startswith("join:") for row in fl["per_layer"])
    rep = analysis.head_report(model)
    assert rep["e_head"] > 0


# ---------------------------------------------------------------------------
# residual classifier: compiled == dense-compose oracle
# ---------------------------------------------------------------------------


def test_resnet_compiled_matches_oracle(bucket_model):
    spec = _spec()
    model = zoo.build_model({"arch": "fpca_resnet", "spec": spec,
                             "width": 4, "hidden": 8, "n_classes": 3})
    kernel = _kernel(spec)
    hp = model.init_head(jax.random.PRNGKey(1))
    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    fe = fpca.compile(model.frontend, backend="basis", weights=kernel,
                      model=bucket_model)
    images = _frames(2, seed=3)
    got = np.asarray(m.run(images))
    counts = np.asarray(fe.run(images))
    want = np.asarray(model.apply_head(hp, counts))
    np.testing.assert_array_equal(got, want)
    assert got.shape == (2, 3)


# ---------------------------------------------------------------------------
# detection: Detections struct, streaming, patched parity, segments
# ---------------------------------------------------------------------------


def _detect_setup(bucket_model, gate_threshold=0.05):
    spec = _spec()
    model = zoo.build_model({"arch": "fpca_detect", "spec": spec,
                             "width": 4, "n_classes": 3})
    kernel = _kernel(spec, seed=2)
    hp = model.init_head(jax.random.PRNGKey(2))
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("det", model, kernel, head_params=hp)
    server = StreamServer(
        pipe, fpca.DeltaGateConfig(threshold=gate_threshold, hysteresis=0,
                                   keyframe_interval=0),
    )
    return spec, model, kernel, hp, pipe, server


def test_detect_run_returns_detections(bucket_model):
    spec = _spec()
    model = zoo.build_model({"arch": "fpca_detect", "spec": spec,
                             "width": 4, "n_classes": 3})
    assert model.output_kind == "detections"
    assert model.detect_classes == 3
    m = fpca.compile(model, backend="basis", weights=_kernel(spec),
                     head_params=model.init_head(jax.random.PRNGKey(0)),
                     model=bucket_model)
    det = m.run(_frames(2))
    assert isinstance(det, heads.Detections)
    h_o, w_o = output_dims(spec)
    assert det.scores.shape == (2, h_o, w_o, 3)
    assert det.boxes.shape == (2, h_o, w_o, 4)
    assert det.class_map().shape == (2, h_o, w_o)
    top = heads.Detections(det.scores[0], det.boxes[0]).top_k(3)
    assert len(top) == 3 and {"cell", "class", "score", "box"} <= top[0].keys()


def test_detect_stream_patched_parity(bucket_model):
    """Skip-aware detection: every gated tick's per-cell map equals the
    dense-compose oracle (masked counts patched into the carried effective
    map, head applied) — the chain-head parity contract, now for graphs."""
    spec, model, kernel, hp, pipe, server = _detect_setup(bucket_model)
    server.add_stream("cam", "det")
    # localized motion: only the top-left quadrant moves after the keyframe,
    # so gated ticks keep a strict subset of the windows
    rng = np.random.default_rng(7)
    base = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    frames = np.stack([base] * 5)
    for t in range(1, 5):
        frames[t, :10, :10] = rng.uniform(0, 1, (10, 10, 3))
    fe = fpca.compile(model.frontend, backend="basis", weights=kernel,
                      model=bucket_model)
    results = list(server.serve("cam", frames))
    assert any(0 < r.kept_windows < r.total_windows for r in results)
    eff = np.zeros(model.frontend.out_shape, np.float32)
    for frame, r in zip(frames, results):
        assert r.detections is not None
        if r.block_mask is None or r.block_mask.all():
            counts = np.asarray(fe.run(frame))
            window = np.ones(counts.shape[:2], bool)
        else:
            window = active_window_mask(spec, r.block_mask)
            counts = np.asarray(fe.run(frame, block_mask=r.block_mask))
        eff = np.where(window[..., None], counts, eff)
        want = np.asarray(model.apply_head(hp, eff[None]))[0]
        np.testing.assert_array_equal(r.logits, want,
                                      err_msg=f"tick {r.frame_idx}")
        np.testing.assert_array_equal(
            np.asarray(r.detections.scores), want[..., :3]
        )
        np.testing.assert_array_equal(
            np.asarray(r.detections.boxes), want[..., 3:]
        )
        assert r.predicted_class is None      # per-cell map, not a logit row


def test_detect_segment_matches_per_tick(bucket_model):
    frames = _frames(6, seed=9)
    _, _, _, _, pipe_a, srv_a = _detect_setup(bucket_model)
    srv_a.add_stream("cam", "det")
    per_tick = list(srv_a.serve("cam", frames))
    _, _, _, _, pipe_b, srv_b = _detect_setup(bucket_model)
    srv_b.add_stream("cam", "det")
    seg = srv_b.run_segment("cam", frames)
    assert len(seg) == len(per_tick)
    for a, b in zip(per_tick, seg):
        np.testing.assert_array_equal(a.logits, b.logits,
                                      err_msg=f"tick {a.frame_idx}")
        assert b.detections is not None


def test_detect_serve_requests(bucket_model):
    """Pipeline serve(): detection configs resolve to Detections."""
    from repro.serving.fpca_pipeline import FrontendRequest

    _, model, _, _, pipe, _ = _detect_setup(bucket_model)
    frame = _frames(1)[0]
    out = pipe.serve([FrontendRequest("det", frame)])
    assert isinstance(out[0], heads.Detections)
    assert out[0].n_classes == 3


# ---------------------------------------------------------------------------
# shared-head fusion
# ---------------------------------------------------------------------------


def test_fused_shared_heads_bit_parity(bucket_model):
    spec = _spec()
    model = zoo.build_model({"arch": "fpca_resnet", "spec": spec,
                             "width": 4, "hidden": 8})
    kernel = _kernel(spec)
    hp_a = model.init_head(jax.random.PRNGKey(3))
    hp_b = model.init_head(jax.random.PRNGKey(4))
    frames = _frames(4, seed=11)

    def serve(fuse: bool):
        pipe = FPCAPipeline(bucket_model, backend="basis")
        pipe.register("a", model, kernel, head_params=hp_a)
        pipe.register("b", model, kernel, head_params=hp_b)
        srv = StreamServer(pipe, fpca.DeltaGateConfig(threshold=0.05),
                           fuse_shared_heads=fuse)
        srv.add_stream("s", ["a", "b"])
        return list(srv.serve("s", frames)), srv

    fused, srv_f = serve(True)
    plain, srv_p = serve(False)
    assert srv_f.stats.fused_head_calls == len(frames)
    assert srv_p.stats.fused_head_calls == 0
    for x, y in zip(fused, plain):
        assert (x.config, x.frame_idx) == (y.config, y.frame_idx)
        np.testing.assert_array_equal(x.logits, y.logits)


# ---------------------------------------------------------------------------
# event streams
# ---------------------------------------------------------------------------


def test_event_stream_reconciles(bucket_model):
    _, model, _, _, pipe, server = _detect_setup(bucket_model)
    server.add_stream("cam", "det", events=True)
    frames = _frames(5, seed=13)
    results = list(server.serve("cam", frames))
    tap = server.event_taps["cam"]
    # one packet per tick, aligned; first tick has no delta -> empty packet
    assert [r.events.frame_idx for r in results] == list(range(5))
    assert results[0].events.n_events == 0
    assert results[2].events.n_events == 0    # quiet tick (repeated frame)
    assert tap.stats.ticks == 5
    total = sum(r.events.n_events for r in results)
    assert total == tap.stats.events > 0
    assert tap.stats.events == tap.stats.events_pos + tap.stats.events_neg
    st = server.sessions["cam"]._primary
    assert st.changed_total == tap.stats.events
    assert_reconciled(pipe, server)
    # raster round-trips coords and polarity
    p = next(r.events for r in results if r.events.n_events)
    grid = p.raster()
    assert grid.shape == p.grid_shape
    assert int(np.abs(grid).sum()) == p.n_events


def test_event_segment_matches_per_tick(bucket_model):
    frames = _frames(6, seed=17)
    _, _, _, _, pipe_a, srv_a = _detect_setup(bucket_model)
    srv_a.add_stream("cam", "det", events=True)
    list(srv_a.serve("cam", frames))
    want = [(p.frame_idx, p.coords.tolist(), p.polarity.tolist())
            for p in srv_a.event_taps["cam"].packets]

    # mixed serving: 3 per-tick, then one compiled segment for the rest
    _, _, _, _, pipe_b, srv_b = _detect_setup(bucket_model)
    srv_b.add_stream("cam", "det", events=True)
    list(srv_b.serve("cam", frames[:3]))
    seg_results = srv_b.run_segment("cam", frames[3:])
    got = [(p.frame_idx, p.coords.tolist(), p.polarity.tolist())
           for p in srv_b.event_taps["cam"].packets]
    assert got == want
    assert [r.events.frame_idx for r in seg_results] == [3, 4, 5]
    assert_reconciled(pipe_b, srv_b)


def test_event_tap_requires_gated_shared_gate(bucket_model):
    _, model, kernel, hp, pipe, _ = _detect_setup(bucket_model)
    dense = StreamServer(pipe, gating=False)
    with pytest.raises(ValueError, match="gated stream"):
        dense.add_stream("cam", "det", events=True)
    assert "cam" not in dense.sessions       # no half-attached stream
    per_cfg = StreamServer(pipe)
    with pytest.raises(NotImplementedError, match="per-config"):
        per_cfg.add_stream(
            "cam", ["det"], events=True,
            gate={"det": fpca.DeltaGateConfig(threshold=0.05)},
        )
    assert "cam" not in per_cfg.sessions


def test_fleet_report_breaks_out_workloads(bucket_model):
    _, model, _, _, pipe, server = _detect_setup(bucket_model)
    server.add_stream("cam", "det", events=True)
    # workload rows aggregate the process-global registry: diff against a
    # pre-serve snapshot so other tests' instances cancel out
    before = fleet_report(server)["workloads"]

    def row(wl, arch, name):
        return wl.get(arch, {}).get(name, 0)

    list(server.serve("cam", _frames(3, seed=19)))
    rep = fleet_report(server)
    wl = rep["workloads"]
    runs = row(wl, "fpca_detect", "fpca_model_runs_total") - row(
        before, "fpca_detect", "fpca_model_runs_total")
    ticks = row(wl, "events", "fpca_events_ticks") - row(
        before, "events", "fpca_events_ticks")
    assert runs > 0
    assert ticks == 3
    assert rep["fleet"]["fused_head_calls"] == 0
