"""Property-based tests on model-substrate invariants (hypothesis)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCHS, reduce_for_smoke
from repro.models.attention import attend_blockwise, attend_full
from repro.models.layers import cross_entropy_loss, rms_norm, rope
from repro.models.moe import init_moe, moe
from repro.models.ssm import ssd_chunked
from repro.models.transformer import forward_train, init_model


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**30), st.integers(1, 4096))
def test_rope_preserves_norm(seed, position):
    """RoPE is a rotation: per-head vector norms are invariant."""
    x = jax.random.normal(jax.random.PRNGKey(seed % 1000), (1, 1, 2, 64))
    y = rope(x, jnp.asarray([position]))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 512), st.integers(0, 64))
def test_rope_is_relative(p1, delta):
    """<rope(q, p1), rope(k, p1+d)> depends only on d (the RoPE property)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))

    def score(p):
        qr = rope(q, jnp.asarray([p]))
        kr = rope(k, jnp.asarray([p + delta]))
        return float(jnp.sum(qr * kr))

    assert score(p1) == pytest.approx(score(p1 + 37), rel=1e-4, abs=1e-4)


def test_rms_norm_unit_rms():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 7.0 + 3.0
    y = rms_norm({"scale": jnp.ones((128,))}, x)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_cross_entropy_uniform_is_log_vocab():
    V = 173
    logits = jnp.zeros((4, 9, V))
    labels = jax.random.randint(jax.random.PRNGKey(0), (4, 9), 0, V)
    assert float(cross_entropy_loss(logits, labels)) == pytest.approx(np.log(V), rel=1e-5)


def test_cross_entropy_mask_excludes_tokens():
    V = 31
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, V))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, V)
    mask = jnp.zeros((2, 8)).at[:, :4].set(1.0)
    full = cross_entropy_loss(logits[:, :4], labels[:, :4])
    masked = cross_entropy_loss(logits, labels, mask)
    assert float(masked) == pytest.approx(float(full), rel=1e-5)


# ---------------------------------------------------------------------------
# attention causality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", [attend_full, attend_blockwise])
def test_attention_is_causal(impl):
    """Perturbing the future must not change past outputs."""
    B, S, H, KV, D = 1, 96, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    cut = 40
    out1 = impl(q, k, v, causal=True)
    k2 = k.at[:, cut:].add(3.0)
    v2 = v.at[:, cut:].add(-5.0)
    out2 = impl(q, k2, v2, causal=True)
    np.testing.assert_allclose(
        np.asarray(out1[:, :cut]), np.asarray(out2[:, :cut]), atol=1e-5
    )
    assert np.abs(np.asarray(out1[:, cut:] - out2[:, cut:])).max() > 1e-3


def test_ssd_is_causal():
    b, l, h, p, n = 1, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, 1, n))
    C = jax.random.normal(ks[4], (b, l, 1, n))
    y1, _ = ssd_chunked(x, dt, A, B, C, chunk=16)
    x2 = x.at[:, 40:].add(10.0)
    y2, _ = ssd_chunked(x2, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y1[:, :40]), np.asarray(y2[:, :40]), atol=1e-4)


def test_model_forward_is_causal():
    """End-to-end: future-token edits don't change past logits (dense)."""
    cfg = reduce_for_smoke(ARCHS["yi-9b"])
    params = init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, cfg.vocab_size)
    from repro.models.transformer import forward_prefill

    logits1, _ = forward_prefill(params, cfg, tokens[:, :16], max_len=24)
    logits2, _ = forward_prefill(params, cfg, tokens, max_len=24)
    del logits2  # full-seq last-position logits differ; check via mid slice
    # compare: prefix prefill's last logits == full forward at position 15
    # (recompute with a teacher-forced pass)
    from repro.models.layers import unembed
    # simpler: two prefills sharing the prefix must agree on last-prefix logits
    alt = tokens.at[:, 16:].set((tokens[:, 16:] + 7) % cfg.vocab_size)
    l1, _ = forward_prefill(params, cfg, tokens[:, :16], max_len=24)
    l2, _ = forward_prefill(params, cfg, alt[:, :16], max_len=24)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


class _MoECfg:
    d_model = 32
    n_experts = 8
    top_k = 2
    moe_d_ff = 16
    n_shared_experts = 0
    moe_renormalize = True
    family = "moe"


def test_moe_aux_losses_bounded():
    cfg = _MoECfg()
    params = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe(params, x, cfg)
    assert y.shape == x.shape
    assert float(aux["moe_lb_loss"]) >= 1.0 - 1e-6  # Cauchy-Schwarz lower bound
    assert 0.0 <= float(aux["moe_drop_frac"]) <= 1.0
    assert float(aux["moe_z_loss"]) >= 0.0


def test_moe_generous_capacity_drops_nothing():
    cfg = _MoECfg()
    params = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    _, aux = moe(params, x, cfg, capacity_factor=8.0)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_gradients_reach_router_and_experts():
    cfg = _MoECfg()
    params = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))

    def loss(p):
        y, aux = moe(p, x, cfg)
        return jnp.sum(y**2) + aux["moe_lb_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]["w"]).max()) > 0
    assert float(jnp.abs(g["experts"]["gate"]).max()) > 0


# ---------------------------------------------------------------------------
# training-step invariants
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(st.sampled_from([1, 2, 4]))
def test_grad_accumulation_invariance(n_micro):
    """Loss/grads must not depend on how the batch is microbatched."""
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import make_train_step
    from repro.training.optimizer import init_adamw

    cfg = reduce_for_smoke(ARCHS["qwen3-1.7b"])
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab_size),
    }
    step1 = make_train_step(cfg, AdamWConfig(), n_micro=1, remat="none")
    stepN = make_train_step(cfg, AdamWConfig(), n_micro=n_micro, remat="none")
    _, _, m1 = step1(params, opt, batch)
    _, _, mN = stepN(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(mN["loss"]), rel=1e-4)
    assert float(m1["grad_norm"]) == pytest.approx(float(mN["grad_norm"]), rel=1e-3)
