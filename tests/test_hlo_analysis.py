"""HLO analyzer: validated against XLA cost_analysis on unrolled programs
(where cost_analysis is trustworthy) and hand-computed collective traffic."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import cost_analysis_dict
from repro.launch.hlo_analysis import analyze_hlo

D = 128


def _compile(fn, *sds):
    return jax.jit(fn).lower(*sds).compile()


def test_scan_flops_match_unrolled_cost_analysis():
    w = jnp.ones((D, D), jnp.float32)
    L = 7

    def body(c, _):
        return c @ w, None

    def scanned(x):
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    def unrolled(x):
        for _ in range(L):
            x = x @ w
        return x

    sds = jax.ShapeDtypeStruct((D, D), jnp.float32)
    c_scan = _compile(scanned, sds)
    c_unroll = _compile(unrolled, sds)
    want = cost_analysis_dict(c_unroll)["flops"]
    got = analyze_hlo(c_scan.as_text(), world=1).flops
    assert got == pytest.approx(want, rel=0.01), (got, want)


def test_nested_scan_multipliers():
    w = jnp.ones((D, D), jnp.float32)

    def inner(c, _):
        return c @ w, None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=3)
        return y, None

    def fn(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    sds = jax.ShapeDtypeStruct((D, D), jnp.float32)
    c = _compile(fn, sds)
    got = analyze_hlo(c.as_text(), world=1).flops
    want = 15 * 2 * D**3  # 5 x 3 matmuls
    assert got == pytest.approx(want, rel=0.01)


def test_collective_bytes_in_scan(monkeypatch):
    """all-reduce inside a scan counts trip_count times with ring factor."""
    if jax.device_count() < 2:
        pytest.skip("needs forced multi-device run (covered in dryrun sweep)")


def test_vocab_matmul_and_batch_dot():
    def fn(x, w):
        return jnp.einsum("bsd,dv->bsv", x, w)

    sds_x = jax.ShapeDtypeStruct((2, 16, 32), jnp.float32)
    sds_w = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    c = _compile(fn, sds_x, sds_w)
    got = analyze_hlo(c.as_text(), world=1).flops
    assert got == pytest.approx(2 * 2 * 16 * 32 * 64, rel=0.01)


def test_bytes_proxy_scales_with_trip_count():
    w = jnp.ones((D, D), jnp.float32)

    def body(c, _):
        return c @ w, None

    def fn(x, n):
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    sds = jax.ShapeDtypeStruct((D, D), jnp.float32)
    b3 = analyze_hlo(_compile(lambda x: fn(x, 3), sds).as_text(), 1).bytes_proxy
    b9 = analyze_hlo(_compile(lambda x: fn(x, 9), sds).as_text(), 1).bytes_proxy
    assert 2.0 < b9 / b3 < 3.5  # ~3x, modulo entry-level constants


def test_no_unknown_trip_counts_in_typical_scan():
    w = jnp.ones((D, D), jnp.float32)

    def fn(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=4)
        return y

    sds = jax.ShapeDtypeStruct((D, D), jnp.float32)
    st = analyze_hlo(_compile(fn, sds).as_text(), 1)
    assert st.n_whiles == 1
    assert st.unknown_trip_whiles == 0
