"""The unified ``repro.fpca`` compile/execute API.

Contracts pinned here:

* **API surface** — ``repro.fpca.__all__`` resolves, and importing the new
  package (plus the serving layers rebased on it) raises no
  ``DeprecationWarning`` — deprecated paths must not leak back into library
  internals (enforced again as a CI lane with ``-W error::DeprecationWarning``).
* **compile → reprogram → run** — zero recompiles across an NVM weight
  rewrite, asserted via ``cache_info()``: the field-programmability headline
  as an executable test.
* **Backend registry** — built-ins registered, unknown names rejected with
  the available list, third-party backends registrable and servable.
* **Signature stability** — golden values for ``spec_signature`` and
  ``FPCAProgram.signature()``: a silent change here silently invalidates
  every warm executable cache in a fleet, so the exact tuples are pinned.
* **Deprecated aliases** — ``FrontendConfig`` / ``FPCAFrontendConfig`` and
  the ``submit`` / fused-``fpca_forward`` shims stay importable/callable and
  warn.
"""

from __future__ import annotations

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import repro.fpca as fpca
from repro.core.mapping import FPCASpec, active_window_mask, output_dims

H = W = 24


def _spec(kernel: int = 5, stride: int = 5, c_o: int = 4) -> FPCASpec:
    return FPCASpec(
        image_h=H, image_w=W, out_channels=c_o, kernel=kernel, stride=stride
    )


def _data(spec: FPCASpec, batch: int = 2, seed: int = 0):
    rng = np.random.default_rng(seed)
    images = rng.uniform(0, 1, (batch, H, W, spec.in_channels)).astype(np.float32)
    k = spec.kernel
    kernel = (
        rng.normal(size=(spec.out_channels, k, k, spec.in_channels)) * 0.2
    ).astype(np.float32)
    return images, kernel


def _dense_reference(bucket_model, spec, images, kernel):
    from repro.core.fpca_sim import fpca_forward

    return np.asarray(
        fpca_forward(
            images, kernel, spec, model=bucket_model, mode="bucket_sigmoid",
            hard=True,
        )["counts"]
    )


# ---------------------------------------------------------------------------
# API surface
# ---------------------------------------------------------------------------


def test_all_names_resolve():
    for name in fpca.__all__:
        assert getattr(fpca, name) is not None, name


def test_package_imports_deprecation_clean():
    """The new package and the serving layers rebased on it import without
    touching any deprecated path (the CI api-surface lane in one test)."""
    import os

    src = Path(__file__).resolve().parents[1] / "src"
    code = (
        "import repro.fpca as f; "
        "assert all(hasattr(f, n) for n in f.__all__); "
        "import repro.core, repro.serving.streaming, "
        "repro.serving.fpca_pipeline, repro.serving.control"
    )
    env = dict(os.environ, PYTHONPATH=str(src))
    subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning", "-c", code],
        check=True,
        env=env,
    )


# ---------------------------------------------------------------------------
# compile / run / reprogram
# ---------------------------------------------------------------------------


def test_compile_run_matches_dense_reference(bucket_model):
    spec = _spec()
    images, kernel = _data(spec)
    fe = fpca.compile(
        fpca.FPCAProgram(spec=spec), backend="basis", weights=kernel,
        model=bucket_model,
    )
    got = np.asarray(fe.run(images))
    np.testing.assert_array_equal(got, _dense_reference(bucket_model, spec, images, kernel))
    # single-frame call mirrors the input's batchedness
    one = np.asarray(fe.run(images[0]))
    np.testing.assert_array_equal(one, got[0])


def test_reprogram_performs_zero_recompiles(bucket_model):
    """compile() -> run -> reprogram -> run: the executable-cache miss count
    must not move across the NVM rewrite (the acceptance contract)."""
    spec = _spec()
    images, k1 = _data(spec, seed=1)
    _, k2 = _data(spec, seed=2)
    fe = fpca.compile(
        fpca.FPCAProgram(spec=spec), backend="basis", weights=k1,
        model=bucket_model,
    )
    out1 = np.asarray(fe.run(images))
    misses_before = fe.cache_info().misses
    assert misses_before == 1                     # exactly one compile
    fe.reprogram(k2)
    out2 = np.asarray(fe.run(images))
    info = fe.cache_info()
    assert info.misses == misses_before           # ZERO recompiles
    assert info.hits >= 1
    assert fe.stats.reprograms == 2               # compile(weights=) + reprogram
    assert not np.array_equal(out1, out2)         # new weights really serve
    np.testing.assert_array_equal(
        out2, _dense_reference(bucket_model, spec, images, k2)
    )


def test_run_requires_programmed_weights(bucket_model):
    fe = fpca.compile(
        fpca.FPCAProgram(spec=_spec()), backend="basis", model=bucket_model
    )
    with pytest.raises(RuntimeError, match="reprogram"):
        fe.run(np.zeros((1, H, W, 3), np.float32))


def test_reprogram_validates_kernel_shape(bucket_model):
    fe = fpca.compile(
        fpca.FPCAProgram(spec=_spec()), backend="basis", model=bucket_model
    )
    with pytest.raises(ValueError, match="kernel shape"):
        fe.reprogram(np.zeros((4, 3, 3, 3), np.float32))  # spec kernel is 5


def test_compiled_block_mask_parity(bucket_model):
    """Region skipping through the handle: kept windows bit-identical to
    dense, skipped windows exact zeros, fewer windows executed."""
    spec = _spec()
    images, kernel = _data(spec)
    fe = fpca.compile(
        fpca.FPCAProgram(spec=spec), backend="basis", weights=kernel,
        model=bucket_model,
    )
    bh = -(-spec.eff_h // spec.skip_block)
    bw = -(-spec.eff_w // spec.skip_block)
    mask = np.zeros((bh, bw), bool)
    mask[0, 0] = True
    got = np.asarray(fe.run(images, block_mask=mask))
    dense = _dense_reference(bucket_model, spec, images, kernel)
    keep = active_window_mask(spec, mask)
    np.testing.assert_array_equal(got[:, keep], dense[:, keep])
    assert np.all(got[:, ~keep] == 0)
    assert fe.stats.windows_executed < fe.stats.windows_total


def test_reference_backend_serves_same_counts(bucket_model):
    """Backends are interchangeable behind the handle: the dense reference
    executable serves bit-identical counts to the fused basis path."""
    spec = _spec()
    images, kernel = _data(spec)
    outs = {}
    for backend in ("basis", "reference"):
        fe = fpca.compile(
            fpca.FPCAProgram(spec=spec), backend=backend, weights=kernel,
            model=bucket_model,
        )
        outs[backend] = np.asarray(fe.run(images))
    np.testing.assert_array_equal(outs["basis"], outs["reference"])


def test_compiled_stream_dense_and_gated(bucket_model):
    spec = _spec()
    _, kernel = _data(spec)
    fe = fpca.compile(
        fpca.FPCAProgram(spec=spec), backend="basis", weights=kernel,
        model=bucket_model,
    )
    rng = np.random.default_rng(5)
    frames = [rng.uniform(0, 1, (H, W, 3)).astype(np.float32) for _ in range(4)]
    h_o, w_o = output_dims(spec)
    # dense stream == per-frame run()
    dense = list(fe.stream(frames))
    assert [r.frame_idx for r in dense] == list(range(4))
    for frame, r in zip(frames, dense):
        np.testing.assert_array_equal(
            r.counts, np.asarray(fe.run(frame))
        )
        assert r.kept_windows == h_o * w_o and r.block_mask is None
    # gated static stream: everything after the keyframe is skipped
    static = [frames[0]] * 4
    gated = list(
        fe.stream(
            static,
            gate=fpca.DeltaGateConfig(threshold=0.05, hysteresis=0,
                                      keyframe_interval=0),
        )
    )
    assert gated[0].kept_windows == h_o * w_o      # first frame = keyframe
    assert all(r.kept_windows == 0 for r in gated[1:])
    assert all(np.all(r.counts == 0) for r in gated[1:])


def test_program_gate_controller_drive_stream(bucket_model):
    """program.gate / program.controller are the stream() defaults."""
    from repro.data.pipeline import SyntheticMovingObject

    spec = _spec()
    _, kernel = _data(spec)
    program = fpca.FPCAProgram(
        spec=spec,
        gate=fpca.DeltaGateConfig(threshold=0.02, hysteresis=1,
                                  keyframe_interval=0),
        controller=fpca.GateControllerConfig(target=0.3),
    )
    fe = fpca.compile(program, backend="basis", weights=kernel,
                      model=bucket_model)
    cam = SyntheticMovingObject((H, W), seed=3, radius=4.0)
    results = list(fe.stream(cam.frame_at(t) for t in range(6)))
    assert len(results) == 6
    session = fe._stream_session
    assert session.controller is not None
    assert len(session.controller.history) == 6
    # the servo actually moved the threshold off the initial gate value
    assert session.gate.threshold != program.gate.threshold


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------


def test_builtin_backends_registered():
    names = fpca.available_backends()
    for name in ("reference", "pallas", "basis"):
        assert name in names
    assert fpca.get_backend("basis").fused
    assert fpca.get_backend("reference").differentiable


def test_unknown_backend_rejected_with_available_list():
    with pytest.raises(ValueError, match="unknown backend"):
        fpca.get_backend("verilator")
    from repro.core.fpca_sim import fpca_forward

    spec = _spec()
    images, kernel = _data(spec)
    with pytest.raises(ValueError, match="available"):
        fpca_forward(images, kernel, spec, backend="verilator")


def test_third_party_backend_registers_and_serves(bucket_model):
    """A registered third-party backend is a first-class compile() target."""
    basis = fpca.get_backend("basis")
    calls = {"n": 0}

    def make_executable(model, **kw):
        calls["n"] += 1
        return basis.make_executable(model, **kw)

    try:
        fpca.register_backend(
            "thirdparty-test", description="test double"
        )(make_executable)
        assert "thirdparty-test" in fpca.available_backends()
        with pytest.raises(ValueError, match="already registered"):
            fpca.register_backend("thirdparty-test")(make_executable)
        spec = _spec()
        images, kernel = _data(spec)
        fe = fpca.compile(
            fpca.FPCAProgram(spec=spec), backend="thirdparty-test",
            weights=kernel, model=bucket_model,
        )
        got = np.asarray(fe.run(images))
        np.testing.assert_array_equal(
            got, _dense_reference(bucket_model, spec, images, kernel)
        )
        assert calls["n"] == 1
    finally:
        from repro.fpca.backends import _REGISTRY

        _REGISTRY.pop("thirdparty-test", None)


def test_registered_programs_with_custom_adc_stay_distinct(bucket_model):
    """register() accepts a full FPCAProgram; two programs sharing a spec
    but differing in a compiled-in field (ADC bits) must NOT share an
    executable — and must serve their own epilogue constants."""
    from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest

    spec = _spec()
    images, kernel = _data(spec, batch=1)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("a8", fpca.FPCAProgram(spec=spec), kernel)
    pipe.register(
        "a3", fpca.FPCAProgram(spec=spec, adc=fpca.ADCConfig(bits=3)), kernel
    )
    res8, res3 = pipe.serve(
        [FrontendRequest("a8", images[0]), FrontendRequest("a3", images[0])]
    )
    assert pipe.cache_info().misses == 2          # distinct signatures
    assert np.asarray(res3).max() <= 7            # 3-bit saturation served
    assert not np.array_equal(np.asarray(res8), np.asarray(res3))


def test_pipeline_fits_model_against_program_circuit(bucket_model):
    """A registered custom-circuit program must serve counts calibrated for
    THAT circuit (parity with fpca.compile on the same program), not the
    pipeline's default calibration."""
    from repro.core.curvefit import fit_bucket_model
    from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest

    spec = _spec()
    images, kernel = _data(spec)
    circuit = fpca.CircuitParams(s0=0.5)
    custom_model = fit_bucket_model(circuit, n_pixels=spec.n_active_pixels)
    program = fpca.FPCAProgram(spec=spec, circuit=circuit)
    # inject both calibrations so the test fits nothing extra itself
    pipe = FPCAPipeline(
        {
            (fpca.CircuitParams(), 75): bucket_model,
            (circuit, 75): custom_model,
        },
        backend="basis",
    )
    pipe.register("custom", program, kernel)
    pipe.register("default", spec, kernel)
    res_custom, res_default = pipe.serve(
        [FrontendRequest("custom", images[0]), FrontendRequest("default", images[0])]
    )
    want = fpca.compile(
        program, backend="basis", weights=kernel, model=custom_model
    ).run(images[0])
    np.testing.assert_array_equal(np.asarray(res_custom), np.asarray(want))
    # the two calibrations genuinely differ on this input
    assert not np.array_equal(np.asarray(res_custom), np.asarray(res_default))


def test_fanout_rejects_incompatible_programs(bucket_model):
    """Channel-stacking configs whose programs differ beyond out_channels
    (here: ADC bits) must be rejected — one stacked launch serves ONE
    epilogue, so accepting them would silently mis-serve one config."""
    from repro.serving.fpca_pipeline import FPCAPipeline
    from repro.serving.streaming import StreamServer

    spec = _spec()
    _, kernel = _data(spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("a8", fpca.FPCAProgram(spec=spec), kernel)
    pipe.register(
        "a3", fpca.FPCAProgram(spec=spec, adc=fpca.ADCConfig(bits=3)), kernel
    )
    images = np.zeros((1, H, W, 3), np.float32)
    with pytest.raises(ValueError, match="compile signature"):
        pipe.run_config_batch(["a8", "a3"], images)
    server = StreamServer(pipe)
    with pytest.raises(ValueError, match="shared spec"):
        server.add_stream("s0", ("a8", "a3"))


def test_register_rejects_kernel_program_channel_mismatch(bucket_model):
    from repro.serving.fpca_pipeline import FPCAPipeline

    spec = _spec()
    _, kernel = _data(spec)                       # 4 output channels
    pipe = FPCAPipeline(bucket_model, backend="basis")
    with pytest.raises(ValueError, match="output channels"):
        pipe.register(
            "x", fpca.FPCAProgram(spec=spec, out_channels=8), kernel
        )


def test_non_fused_backend_not_servable_through_fpca_forward(bucket_model):
    """fpca_forward must refuse a registered non-fused third-party backend
    rather than silently serving the built-in reference simulation."""
    from repro.core.fpca_sim import fpca_forward
    from repro.fpca.backends import _REGISTRY

    spec = _spec()
    images, kernel = _data(spec)
    try:
        fpca.register_backend("cosim-test", fused=False)(
            lambda model, **kw: None
        )
        with pytest.raises(ValueError, match="not servable"):
            fpca_forward(images, kernel, spec, backend="cosim-test")
    finally:
        _REGISTRY.pop("cosim-test", None)


def test_pipeline_shares_one_cache_across_handles(bucket_model):
    """The pipeline's handles share a single bounded executable cache."""
    from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest

    pipe = FPCAPipeline(bucket_model, backend="basis", cache_capacity=2)
    rng = np.random.default_rng(0)
    for i, (k, s) in enumerate([(5, 5), (3, 2), (5, 1)]):
        spec = _spec(k, s)
        pipe.register(
            f"c{i}", spec,
            (rng.normal(size=(4, k, k, 3)) * 0.2).astype(np.float32),
        )
    img = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    pipe.serve([FrontendRequest(f"c{i}", img) for i in range(3)])
    info = pipe.cache_info()
    assert info.misses == 3 and info.currsize == 2 and info.evictions == 1
    assert pipe.cache_size == 2


# ---------------------------------------------------------------------------
# signature stability (golden)
# ---------------------------------------------------------------------------

GOLDEN_SPEC_SIG = (
    "repro.fpca/1",
    ("spec", 24, 24, 4, 3, 2, 5, 3, 0, 1, 8),
    ("out_channels", 4),
    ("adc", 8, 1.0),
    ("enc", 16, 1.0),
)

GOLDEN_PROGRAM_SIG = GOLDEN_SPEC_SIG + (
    ("circuit", ("v_sat", 1.0), ("s0", 0.37), ("drive_a", 0.15),
     ("drive_b", -0.1), ("drive_c", 0.25), ("coupling", 0.15),
     ("kappa_r", 0.012), ("r_metal_mm", 0.0), ("fp_iters", 8.0)),
)


def test_spec_signature_golden():
    """Exact pinned value: changing it silently invalidates every warm
    executable cache (and breaks cross-process cache keys) — bump the
    signature version string deliberately instead."""
    spec = FPCASpec(image_h=24, image_w=24, out_channels=4, kernel=3, stride=2)
    sig = fpca.spec_signature(spec, 4, fpca.ADCConfig(), fpca.WeightEncoding())
    assert sig == GOLDEN_SPEC_SIG


def test_program_signature_golden():
    spec = FPCASpec(image_h=24, image_w=24, out_channels=4, kernel=3, stride=2)
    assert fpca.FPCAProgram(spec=spec).signature() == GOLDEN_PROGRAM_SIG


def test_signature_excludes_runtime_state():
    """Gate / controller / weights are runtime state: programs differing only
    there share one signature (reprogramming never recompiles)."""
    spec = _spec()
    base = fpca.FPCAProgram(spec=spec)
    gated = fpca.FPCAProgram(
        spec=spec,
        gate=fpca.DeltaGateConfig(threshold=0.5),
        controller=fpca.GateControllerConfig(target=0.3),
    )
    assert base.signature() == gated.signature()
    # ...while anything compiled-in changes it
    assert base.signature() != fpca.FPCAProgram(
        spec=spec, adc=fpca.ADCConfig(bits=4)
    ).signature()
    assert base.signature() != fpca.FPCAProgram(
        spec=spec, out_channels=7
    ).signature()


def test_spec_signature_importable_from_old_home():
    """The serving-pipeline re-export stays the same function."""
    from repro.serving.fpca_pipeline import spec_signature as old

    assert old is fpca.spec_signature


# ---------------------------------------------------------------------------
# deprecated aliases & shims
# ---------------------------------------------------------------------------


def test_frontend_config_alias_importable_and_warns():
    with pytest.warns(DeprecationWarning, match="ProgrammedConfig"):
        from repro.serving.fpca_pipeline import FrontendConfig
    assert FrontendConfig is fpca.ProgrammedConfig


def test_fpca_frontend_config_alias_importable_and_warns():
    with pytest.warns(DeprecationWarning, match="FPCAProgram"):
        from repro.core.frontend import FPCAFrontendConfig
    assert FPCAFrontendConfig is fpca.FPCAProgram
    with pytest.warns(DeprecationWarning):
        from repro.core import FPCAFrontendConfig as from_core
    assert from_core is fpca.FPCAProgram
    # old keyword construction still works through the alias
    cfg = fpca.FPCAProgram(spec=_spec(), circuit=fpca.CircuitParams())
    assert cfg.adc == fpca.ADCConfig()


def test_submit_shim_warns_and_forwards(bucket_model):
    from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest

    spec = _spec()
    images, kernel = _data(spec, batch=1)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    req = FrontendRequest("cam", images[0])
    want = pipe.serve([req])[0]
    with pytest.warns(DeprecationWarning, match="serve"):
        got = pipe.submit([req])[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_fpca_forward_warns(bucket_model):
    from repro.core.fpca_sim import fpca_forward

    spec = _spec()
    images, kernel = _data(spec)
    with pytest.warns(DeprecationWarning, match="repro.fpca.compile"):
        fpca_forward(
            images, kernel, spec, model=bucket_model, mode="bucket_sigmoid",
            hard=True, backend="basis",
        )
