"""Fault-tolerance substrate: checkpoint atomicity + elastic resharding,
resumable deterministic data, gradient compression convergence."""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.data.pipeline import LMStreamConfig, PrefetchIterator, SyntheticLM, SyntheticVWW
from repro.models.transformer import init_model
from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.compression import compress_decompress, init_error_state
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _state():
    cfg = reduce_for_smoke(ARCHS["qwen3-1.7b"])
    params = init_model(jax.random.PRNGKey(0), cfg)
    return params, init_adamw(params)


def test_checkpoint_roundtrip(tmp_path):
    params, opt = _state()
    save_checkpoint(tmp_path, 7, (params, opt), extra={"cursor": 7})
    (p2, o2), extra = restore_checkpoint(tmp_path, (params, opt))
    assert extra["step"] == 7 and extra["cursor"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2.step) == int(opt.step)


def test_checkpoint_retention_and_latest(tmp_path):
    params, opt = _state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, (params, opt), keep=3)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4, 5]
    assert latest_step(tmp_path) == 5


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir (simulated crash) must not shadow a good ckpt."""
    params, opt = _state()
    save_checkpoint(tmp_path, 1, (params, opt))
    crash = tmp_path / "step_00000002.tmp"
    crash.mkdir()
    (crash / "garbage").write_text("boom")
    assert latest_step(tmp_path) == 1
    restore_checkpoint(tmp_path, (params, opt))  # must not raise


def test_checkpoint_detects_structure_mismatch(tmp_path):
    params, opt = _state()
    save_checkpoint(tmp_path, 1, params)
    other = init_model(jax.random.PRNGKey(0), reduce_for_smoke(ARCHS["yi-9b"]))
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, other)


def test_elastic_resharding(tmp_path):
    """Save unsharded, restore onto a 1x1 mesh sharding (the elastic path);
    values must be identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    params, _ = _state()
    save_checkpoint(tmp_path, 3, params)
    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda p: NamedSharding(mesh, P(*([None] * p.ndim))), params
    )
    restored, _ = restore_checkpoint(tmp_path, params, shardings=shardings)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert b.sharding.mesh.shape == {"data": 1, "model": 1}


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = LMStreamConfig(vocab_size=97, seq_len=32, global_batch=8, seed=3)
    s1, s2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1 = s1.batch_at(41)
    b2 = s2.batch_at(41)  # fresh object, same address -> same bytes
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_sharding_partitions_batch():
    cfg = LMStreamConfig(vocab_size=97, seq_len=16, global_batch=8, seed=0)
    s = SyntheticLM(cfg)
    shards = [s.batch_at(5, shard=i, n_shards=4)["tokens"] for i in range(4)]
    assert all(x.shape == (2, 16) for x in shards)
    flat = np.concatenate([x.ravel() for x in shards])
    assert len(np.unique(flat)) > 1  # shards differ
    a = s.batch_at(5, shard=0, n_shards=4)["tokens"]
    np.testing.assert_array_equal(a, shards[0])  # per-shard determinism


def test_data_is_learnable():
    """The affine-recurrence stream must be predictable from the previous
    token (else the end-to-end training example can't show loss decrease)."""
    cfg = LMStreamConfig(vocab_size=50, seq_len=64, global_batch=4, seed=1, noise=0.0)
    b = SyntheticLM(cfg).batch_at(0)
    t, l = b["tokens"], b["labels"]
    # next token is a fixed function of current: same current => same next
    pairs = {}
    for cur, nxt in zip(t.ravel(), l.ravel()):
        assert pairs.setdefault(int(cur), int(nxt)) == int(nxt)


def test_prefetch_and_stall_detection():
    calls = []

    def make(step):
        calls.append(step)
        return {"x": step}

    it = PrefetchIterator(make, start_step=10, timeout_s=5.0)
    s, b = next(it)
    assert s == 10 and b["x"] == 10
    s, b = next(it)
    assert s == 11
    it.close()

    slow = PrefetchIterator(lambda s: (__import__("time").sleep(10), s)[1], timeout_s=0.2)
    with pytest.raises(TimeoutError):
        next(slow)
    assert slow.stalls == 1
    slow.close()


def test_vww_is_shape_coded_not_brightness_coded():
    data = SyntheticVWW((48, 48))
    b = data.batch_at(0, 256)
    imgs, labels = b["images"], b["labels"]
    # class means differ structurally...
    mean_pos = imgs[labels == 1].mean(axis=0)
    mean_neg = imgs[labels == 0].mean(axis=0)
    assert np.abs(mean_pos - mean_neg).max() > 0.02
    # ...but a max-brightness threshold cannot separate (no intensity shortcut)
    bright = imgs.reshape(len(imgs), -1).max(axis=1)
    best_acc = 0.0
    for thr in np.linspace(bright.min(), bright.max(), 64):
        acc = max(
            ((bright > thr) == labels).mean(), ((bright <= thr) == labels).mean()
        )
        best_acc = max(best_acc, acc)
    assert best_acc < 0.75


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_compression_error_feedback_is_unbiased_over_time():
    """Error feedback: the *sum* of compressed grads tracks the sum of true
    grads (residual stays bounded), so optimisation converges."""
    rng = np.random.default_rng(0)
    g_sum = np.zeros((64,), np.float32)
    ghat_sum = np.zeros((64,), np.float32)
    err = {"w": jnp.zeros((64,), jnp.float32)}
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(0, 1, 64), jnp.float32)}
        ghat, err, _ = compress_decompress(g, err)
        g_sum += np.asarray(g["w"])
        ghat_sum += np.asarray(ghat["w"])
    resid = np.abs(g_sum - ghat_sum).max()
    assert resid < 0.1  # bounded by one quantisation step, not 50 of them


def test_compressed_training_converges():
    """Linear regression with int8+EF grads reaches the uncompressed loss."""
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(0, 1, (256, 16)), jnp.float32)
    w_true = jnp.asarray(rng.normal(0, 1, (16,)), jnp.float32)
    y = X @ w_true

    def loss_fn(params):
        return jnp.mean((X @ params["w"] - y) ** 2)

    grad_fn = jax.jit(jax.grad(loss_fn))
    cfg = AdamWConfig(lr=3e-2, weight_decay=0.0, warmup_steps=1, total_steps=400)

    def run(compressed: bool):
        params = {"w": jnp.zeros((16,), jnp.float32)}
        opt = init_adamw(params)
        err = init_error_state(params)
        for _ in range(400):
            g = grad_fn(params)
            if compressed:
                g, err, _ = compress_decompress(g, err)
            params, opt, _ = adamw_update(g, opt, params, cfg)
        return float(loss_fn(params))

    assert run(compressed=True) < 1e-3
