"""Committed benchmark artifacts stay strict JSON with the keys the
tooling (``perf_compare``, CI artifact consumers) depends on — and the
telemetry overhead guard holds on the committed numbers."""

from __future__ import annotations

import json
import math
from pathlib import Path

import pytest

pytestmark = pytest.mark.telemetry

REPO = Path(__file__).resolve().parents[1]

REQUIRED_KEYS = {
    "BENCH_frontend.json": ("workload", "frames_per_s"),
    "BENCH_stream.json": (
        "workload", "masked", "dense", "scan_segment", "sticky_buckets",
        "controller", "controller_energy", "sensor_model", "telemetry",
        "speedup_masked_vs_dense", "kept_window_frac",
    ),
    "BENCH_model.json": (
        "workload", "batched_dense", "stream_dense", "stream_masked",
        "scan_segment", "head", "sensor_model", "telemetry",
        "quantised_int8",
    ),
    "BENCH_fleet.json": (
        "workload", "devices", "weak_scaling", "arbitration",
        "idle_stream", "admission", "fleet_report",
    ),
}


def _assert_finite(obj, path=""):
    """No NaN/Infinity anywhere — strict RFC 8259 emitters map them to
    null, so any non-finite float in a committed artifact is a writer
    regression."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            _assert_finite(v, f"{path}.{k}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _assert_finite(v, f"{path}[{i}]")
    elif isinstance(obj, float):
        assert math.isfinite(obj), f"non-finite float at {path}"


@pytest.mark.parametrize("name", sorted(REQUIRED_KEYS))
def test_bench_artifact_schema(name):
    path = REPO / name
    if not path.exists():
        pytest.skip(f"{name} not generated in this checkout")
    text = path.read_text()
    # strict parse: the standard decoder accepts Infinity/NaN extensions,
    # so reject those tokens explicitly before decoding
    rec = json.loads(
        text,
        parse_constant=lambda tok: pytest.fail(
            f"{name} contains non-standard JSON token {tok!r}"
        ),
    )
    for key in REQUIRED_KEYS[name]:
        assert key in rec, f"{name} is missing required key {key!r}"
    _assert_finite(rec, name)


def test_model_bench_int8_lanes():
    """The quantised-int8 lanes of BENCH_model.json carry the full
    speedup/parity row set, strict-JSON finite throughout (the events
    lanes' ``None`` fps sentinel stays the one sanctioned non-number)."""
    path = REPO / "BENCH_model.json"
    if not path.exists():
        pytest.skip("BENCH_model.json not generated in this checkout")
    rec = json.loads(path.read_text())
    q = rec["quantised_int8"]
    for lane in ("batched", "stream_masked", "scan_segment"):
        assert "frames_per_s" in q[lane] and "speedup_vs_f32" in q[lane]
        assert q[lane]["frames_per_s"] > 0
        assert math.isfinite(q[lane]["speedup_vs_f32"])
    par = q["parity"]
    assert math.isfinite(par["max_abs_divergence"])
    assert 0.0 <= par["top1_agreement"] <= 1.0
    hm = q["head_model"]
    for key in ("t_head_f32", "t_head_int8", "e_head_f32", "e_head_int8",
                "int8_speedup", "int8_energy_ratio"):
        assert math.isfinite(hm[key]), f"head_model.{key} not finite"
    # the int8 datapath model must claim a cheaper head, not a dearer one
    assert hm["t_head_int8"] < hm["t_head_f32"]
    assert hm["e_head_int8"] < hm["e_head_f32"]
    # zero-work fps sentinel contract: any absent rate in the events lanes
    # is None, never 0/inf/nan
    for scene in rec["events"].values():
        fps = scene["events_per_s"]
        assert fps is None or (isinstance(fps, float) and fps > 0)


def test_stream_bench_telemetry_overhead_guard():
    """Acceptance gate: disabled-mode telemetry hooks cost <= 2% of the
    scan-segment stream lane (recorded by benchmarks/stream_bench.py)."""
    path = REPO / "BENCH_stream.json"
    if not path.exists():
        pytest.skip("BENCH_stream.json not generated in this checkout")
    tel = json.loads(path.read_text())["telemetry"]
    assert tel["disabled_overhead_frac"] <= 0.02
    assert tel["hook_crossings"] > 0 and tel["disabled_hook_cost_s"] >= 0
    # the fleet report embedded in the artifact reconciles with itself:
    # kept fraction is windows_kept / windows_total of the same cells
    fleet = tel["fleet_report"]["fleet"]
    assert fleet["kept_fraction"] == pytest.approx(
        fleet["windows_kept"] / max(fleet["windows_total"], 1)
    )


def test_telemetry_jsonl_artifacts_are_strict():
    """The bench-written JSONL logs (uploaded by CI) parse line by line."""
    found = list(REPO.glob("telemetry_*.jsonl"))
    if not found:
        pytest.skip("no telemetry JSONL artifacts in this checkout")
    for path in found:
        lines = path.read_text().strip().splitlines()
        assert lines, f"{path.name} is empty"
        events = [json.loads(line) for line in lines]
        assert events[0]["event"] == "session_start"
        assert events[-1]["event"] == "session_end"
        for ev in events:
            assert "ts" in ev and "event" in ev
            json.dumps(ev, allow_nan=False)
