"""Cell machinery on a host mesh: the same build/lower/compile path the
512-device dry-run uses, exercised at reduced scale in CI."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.configs import ARCHS, reduce_for_smoke
from repro.configs.base import ShapeSpec
from repro.launch.cells import CellPlan, build_cell
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import summarize_cell
from repro.launch.sharding import ShardingPolicy, param_shardings
from repro.models.transformer import init_model

# every test here lowers+compiles full cells — the slow half of tier-1
pytestmark = pytest.mark.slow


def _mesh():
    return make_host_mesh(1, 1)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-moe-3b-a800m", "mamba2-2.7b", "zamba2-7b"])
@pytest.mark.parametrize(
    "shape",
    [
        ShapeSpec("t", 64, 4, "train"),
        ShapeSpec("p", 64, 4, "prefill"),
        ShapeSpec("d", 64, 4, "decode"),
    ],
)
def test_cell_compiles_on_host_mesh(arch, shape):
    cfg = reduce_for_smoke(ARCHS[arch])
    mesh = _mesh()
    with compat.set_mesh(mesh):
        jitted, args = build_cell(cfg, shape, mesh, CellPlan(remat="none"))
        compiled = jitted.lower(*args).compile()
    rec = summarize_cell(compiled, cfg, shape, mesh.size)
    assert rec["flops_per_device"] > 0
    assert rec["terms"]["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert rec["collectives"]["unknown_trip_whiles"] == 0


def test_param_shardings_cover_every_leaf():
    """Every parameter leaf of every arch must match a sharding rule whose
    spec rank fits the leaf (catches new params w/o rules)."""
    mesh = _mesh()
    for arch, full_cfg in ARCHS.items():
        cfg = reduce_for_smoke(full_cfg)
        shapes = jax.eval_shape(
            lambda k, c=cfg: init_model(k, c), jax.ShapeDtypeStruct((2,), jnp.uint32)
        )
        shardings = param_shardings(shapes, mesh, ShardingPolicy())
        for (path, leaf), (_, sh) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(shardings)[0],
        ):
            assert len(sh.spec) <= leaf.ndim, (arch, path, leaf.shape, sh.spec)


def test_expert_parallel_policy_changes_expert_specs():
    mesh = _mesh()
    cfg = reduce_for_smoke(ARCHS["granite-moe-3b-a800m"])
    shapes = jax.eval_shape(
        lambda k: init_model(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    tp = param_shardings(shapes, mesh, ShardingPolicy(expert_parallel=False))
    ep = param_shardings(shapes, mesh, ShardingPolicy(expert_parallel=True))
    tp_spec = tp["blocks"]["moe"]["experts"]["gate"].spec
    ep_spec = ep["blocks"]["moe"]["experts"]["gate"].spec
    assert tp_spec != ep_spec
    assert "model" in str(ep_spec[1])  # expert axis sharded (after layer pad)
