"""End-to-end FPCA frontend behaviour: sim vs ideal convolution, region
skipping, trainable frontend, analysis-model claims (Fig. 9)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis, mapping
from repro.core.adc import ADCConfig
from repro.core.fpca_sim import WeightEncoding, calibrate_gain, encode_weights, extract_windows, fpca_forward
from repro.core.frontend import FPCAFrontend, FPCAFrontendConfig

SPEC = mapping.FPCASpec(image_h=24, image_w=24, out_channels=4, kernel=3, stride=2)


def _rand_kernel(key, spec=SPEC, scale=0.5):
    return (
        jax.random.normal(key, (spec.out_channels, spec.kernel, spec.kernel, spec.in_channels))
        * scale
        / spec.kernel
    )


def test_window_weight_layouts_agree():
    """extract_windows and encode_weights must flatten identically: a window
    dotted with the encoded weights == the ideal convolution."""
    key = jax.random.PRNGKey(0)
    img = jax.random.uniform(jax.random.PRNGKey(1), (24, 24, 3))
    kernel = _rand_kernel(key)
    enc = WeightEncoding(n_levels=1 << 16, w_scale=1.0)  # ~continuous levels
    w_pos, w_neg = encode_weights(kernel, SPEC, enc)
    I = extract_windows(img, SPEC)
    got = I @ (w_pos - w_neg).T * enc.w_scale
    # oracle: explicit conv with the same stride over the physical 5x5 window
    kpad = jnp.pad(kernel, ((0, 0), (0, 2), (0, 2), (0, 0)))
    want = jax.lax.conv_general_dilated(
        img[None].transpose(0, 3, 1, 2),
        kpad.transpose(0, 3, 1, 2),
        window_strides=(2, 2),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0].transpose(1, 2, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_fpca_tracks_ideal_conv(circuit_params, bucket_model):
    """Fig. 7(c)/(f): analog output is 'fairly linear' vs the ideal dot
    product — calibrated counts must correlate > 0.99 with the ideal conv."""
    img = jax.random.uniform(jax.random.PRNGKey(2), (24, 24, 3))
    kernel = _rand_kernel(jax.random.PRNGKey(3))
    enc, adc = WeightEncoding(), ADCConfig()
    out = fpca_forward(
        img, kernel, SPEC, circuit=circuit_params, adc=adc, enc=enc, mode="oracle"
    )
    gain, r2 = calibrate_gain(SPEC, circuit=circuit_params, adc=adc, enc=enc)
    assert r2 > 0.99  # the linearity claim itself
    w_pos, w_neg = encode_weights(kernel, SPEC, enc)
    I = extract_windows(img, SPEC)
    ideal_signed = I @ (w_pos - w_neg).T * enc.w_scale
    # analog path linearity (pre-ADC): Fig. 7(c)/(f) scatter
    analog = (out["v_pos"] - out["v_neg"]) * gain
    corr_analog = np.corrcoef(
        np.asarray(ideal_signed).ravel(), np.asarray(analog).ravel()
    )[0, 1]
    assert corr_analog > 0.99
    # full digital path adds +/-1-count ADC noise on top
    ideal = jnp.maximum(ideal_signed, 0.0)
    approx = out["counts"] * adc.lsb * gain
    corr = np.corrcoef(np.asarray(ideal).ravel(), np.asarray(approx).ravel())[0, 1]
    assert corr > 0.97


def test_bucket_modes_match_oracle(circuit_params, bucket_model):
    img = jax.random.uniform(jax.random.PRNGKey(4), (24, 24, 3))
    kernel = _rand_kernel(jax.random.PRNGKey(5))
    outs = {
        m: fpca_forward(
            img, kernel, SPEC, circuit=circuit_params, model=bucket_model, mode=m
        )
        for m in ("oracle", "bucket_hard", "bucket_sigmoid")
    }
    for m in ("bucket_hard", "bucket_sigmoid"):
        dv = np.abs(np.asarray(outs[m]["v_pos"] - outs["oracle"]["v_pos"]))
        assert dv.max() < 0.03 * circuit_params.v_sat  # paper's error bound


def test_region_skipping_zeroes_windows(circuit_params):
    spec = mapping.FPCASpec(
        image_h=16, image_w=16, out_channels=2, kernel=3, stride=1, skip_block=8
    )
    img = jax.random.uniform(jax.random.PRNGKey(6), (16, 16, 3))
    kernel = _rand_kernel(jax.random.PRNGKey(7), spec)
    mask = np.array([[True, False], [False, False]])
    out = fpca_forward(img, kernel, spec, circuit=circuit_params, block_mask=mask)
    active = mapping.active_window_mask(spec, mask)
    counts = np.asarray(out["counts"])
    assert (counts[~active] == 0).all()
    assert counts[active].sum() > 0


def test_frontend_trains_and_deploys(circuit_params, bucket_model):
    cfg = FPCAFrontendConfig(spec=SPEC, circuit=circuit_params)
    layer = FPCAFrontend(cfg, model=bucket_model)
    params = layer.init(jax.random.PRNGKey(8))
    imgs = jax.random.uniform(jax.random.PRNGKey(9), (2, 24, 24, 3))
    train_out = layer.apply(params, imgs, train=True)
    assert train_out.shape == (2, *layer.out_shape)
    assert bool(jnp.all(jnp.isfinite(train_out)))

    # gradients flow to kernel and bn_offset through quantisers + ADC
    def loss(p):
        return jnp.mean(layer.apply(p, imgs, train=True) ** 2)

    grads = jax.grad(loss)(params)
    assert float(jnp.linalg.norm(grads["kernel"])) > 0
    assert float(jnp.linalg.norm(grads["bn_offset"])) > 0

    # deployment path agrees with training path within a few counts
    eval_out = layer.apply(params, imgs, train=False)
    lsb_units = cfg.adc.lsb * layer.gain
    assert float(jnp.max(jnp.abs(eval_out - train_out))) < 12 * lsb_units


# ---------------------------------------------------------------------------
# Analysis models (Fig. 9 qualitative claims)
# ---------------------------------------------------------------------------


def _aspec(stride, c_o, binning=1):
    return mapping.FPCASpec(
        image_h=224, image_w=224, out_channels=c_o, kernel=5, stride=stride, binning=binning
    )


def test_energy_falls_with_stride_and_channels():
    e = {s: analysis.frontend_energy(_aspec(s, 8))["e_total"] for s in (1, 2, 5)}
    assert e[5] < e[2] < e[1]  # Fig. 9(a): larger stride -> fewer ops -> less energy
    e8 = analysis.frontend_energy(_aspec(5, 8))["e_total"]
    e32 = analysis.frontend_energy(_aspec(5, 32))["e_total"]
    assert e8 < e32  # fewer channels -> more savings


def test_co32_erases_energy_savings():
    """Paper: 'increasing the output channel count to 32 does not lead to
    energy savings' vs the conventional baseline."""
    base = analysis.conventional_cis(224, 224)["e_total"]
    e32_s1 = analysis.frontend_energy(_aspec(1, 32))["e_total"]
    e8_s5 = analysis.frontend_energy(_aspec(5, 8))["e_total"]
    assert e32_s1 > base      # no savings at c_o=32, stride 1
    assert e8_s5 < base       # clear savings at c_o=8, stride 5


def test_framerate_improves_with_stride_and_binning():
    f = {s: analysis.frontend_latency(_aspec(s, 8))["fps"] for s in (1, 5)}
    assert f[5] > f[1]
    f_bin = analysis.frontend_latency(_aspec(5, 8, binning=4))["fps"]
    assert f_bin > f[5]  # Fig. 9(b): binning buys frame rate


def test_fpca_framerate_below_conventional():
    """Paper: 'maximum frontend frame rate of the FPCA model is generally
    lower than that of conventional RGB CIS'."""
    conv = analysis.conventional_cis(224, 224)["fps"]
    fpca = analysis.frontend_latency(_aspec(1, 8))["fps"]
    assert fpca < conv


def test_bandwidth_reduction_grows_with_stride():
    br = {s: analysis.bandwidth_reduction(_aspec(s, 8)) for s in (1, 2, 5)}
    assert br[1] < br[2] < br[5]  # Fig. 9(c)
    assert analysis.bandwidth_reduction(_aspec(5, 32)) < br[5]  # more channels -> less BR


def test_energy_with_region_skipping():
    spec = _aspec(5, 8)
    mask = np.zeros((28, 28), dtype=bool)
    mask[:14] = True  # top half active
    e_full = analysis.frontend_energy(spec)["e_total"]
    e_skip = analysis.frontend_energy(spec, block_mask=mask)["e_total"]
    assert 0.3 * e_full < e_skip < 0.7 * e_full


def test_reshape_patch_path_matches_conv_path():
    """stride == kernel fast path (pure reshape) must equal the general
    conv_general_dilated_patches path."""
    spec_fast = mapping.FPCASpec(image_h=25, image_w=30, out_channels=2, kernel=5, stride=5)
    img = jax.random.uniform(jax.random.PRNGKey(11), (25, 30, 3))
    fast = extract_windows(img, spec_fast)
    # force the general path by using padding=0 stride=5 via conv directly
    patches = jax.lax.conv_general_dilated_patches(
        img[None].transpose(0, 3, 1, 2), filter_shape=(5, 5),
        window_strides=(5, 5), padding=((0, 0), (0, 0)),
    )
    general = jnp.transpose(patches[0], (1, 2, 0))
    np.testing.assert_allclose(np.asarray(fast), np.asarray(general), rtol=1e-6)
