"""Unified telemetry layer: registry cells, spans, JSONL, reconciliation.

The fast ``-m telemetry`` CI lane.  Everything here uses the tiny 24x24
spec so the whole module compiles a handful of small executables once
(module-scoped serving fixture) and the rest is pure host-side checks.
"""

from __future__ import annotations

import json
import types

import numpy as np
import pytest

from repro import fpca
from repro.core.mapping import FPCASpec
from repro.fpca import telemetry
from repro.fpca.cache import ExecutableCache
from repro.fpca.telemetry import MetricFamily, OVERFLOW_LABEL
from repro.serving.fpca_pipeline import FPCAPipeline, PipelineStats
from repro.serving.observe import (
    assert_reconciled,
    fleet_report,
    render_fleet_report,
)
from repro.serving.streaming import StreamServer

pytestmark = pytest.mark.telemetry

SPEC = FPCASpec(image_h=24, image_w=24, out_channels=4, kernel=3, stride=2)


@pytest.fixture(autouse=True)
def _no_leaked_session():
    yield
    telemetry.disable()


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One gated fleet served with telemetry on: per-tick ticks, then a
    compiled segment, then per-tick again (span nesting across modes)."""
    path = tmp_path_factory.mktemp("telemetry") / "events.jsonl"
    rng = np.random.default_rng(0)
    kernel = rng.normal(size=(4, 3, 3, 3)).astype(np.float32)
    pipe = FPCAPipeline(backend="basis")
    pipe.register("edges", SPEC, kernel)
    server = StreamServer(
        pipe,
        gate=fpca.DeltaGateConfig(threshold=0.05, keyframe_interval=6),
        controller=fpca.GateControllerConfig(target=0.5),
    )
    server.add_stream("cam0", "edges")
    frames = (rng.normal(size=(12, 24, 24, 3)) * 0.1).astype(np.float32)
    telemetry.enable(path, device_time_rate=2)
    list(server.serve("cam0", frames[:4]))
    list(server.serve_segments("cam0", frames[4:8], segment_length=4))
    list(server.serve("cam0", frames[8:]))
    telemetry.disable()
    return types.SimpleNamespace(
        pipe=pipe, server=server, path=path,
        events=telemetry.read_jsonl(path),
    )


# -- JSONL export ------------------------------------------------------------


def test_jsonl_strict_roundtrip(served):
    """Every line is strict RFC 8259 JSON with ts/event keys; the session
    frames the log."""
    raw = served.path.read_text().strip().splitlines()
    assert len(raw) == len(served.events) > 2
    for line, ev in zip(raw, served.events):
        assert json.loads(line) == ev          # parse == parsed
        json.dumps(ev, allow_nan=False)        # strictly re-serialisable
        assert "Infinity" not in line and "NaN" not in line
        assert "ts" in ev and "event" in ev
    assert served.events[0]["event"] == "session_start"
    assert served.events[-1]["event"] == "session_end"


def test_span_nesting_across_segments(served):
    """run_segment spans nest under serve_segment; tick spans are roots."""
    spans = [e for e in served.events if e["event"] == "span"]
    by_name = {}
    for s in spans:
        by_name.setdefault(s["span"], []).append(s)
    assert set(by_name) >= {"serve_tick", "serve_segment", "run_segment"}
    for s in by_name["run_segment"]:
        assert s["parent"] == "serve_segment"
        assert s["depth"] >= 1
    for s in by_name["serve_segment"] + by_name["serve_tick"]:
        assert s["parent"] is None
        assert s["dur_s"] >= 0


def test_device_time_sampling(served):
    """device_time_rate=2 blocked on every 2nd instrumented launch."""
    samples = [e for e in served.events if e["event"] == "device_time"]
    assert samples, "no device-time samples despite device_time_rate=2"
    for s in samples:
        assert s["dur_s"] >= 0
        assert s["backend"] == "basis"


# -- reconciliation / single-sourcing ----------------------------------------


def test_stats_surfaces_reconcile_exactly(served):
    assert_reconciled(served.pipe, served.server)


def test_fleet_report_matches_legacy_counters(served):
    rep = fleet_report(served.server)
    s = served.server.stats
    fleet = rep["fleet"]
    assert fleet["frames"] == s.frames == 12
    assert fleet["windows_total"] == s.windows_total
    assert fleet["windows_kept"] == s.windows_kept
    assert fleet["segments"] == s.segments == 1
    assert fleet["segment_ticks"] == s.segment_ticks == 4
    assert fleet["serve_seconds"] == s.serve_seconds > 0
    info = served.pipe.cache_info()
    assert fleet["cache"]["hits"] == info.hits
    assert fleet["cache"]["misses"] == info.misses
    json.dumps(rep, allow_nan=False)           # strict-JSON-able
    table = render_fleet_report(rep)
    assert "cam0" in table and "edges" in table


def test_no_double_counting(served):
    """The old bug: windows_executed mirrored into the pipeline AND the
    handle.  Parent-chained cells make the pipeline total exactly the sum
    of its handles' cells — no more, no less."""
    handles = list(served.pipe._handles.values())
    assert handles
    total = sum(h.stats.windows_executed for h in handles)
    assert served.pipe.stats.windows_executed == total
    total_skip = sum(h.stats.launches_skipped for h in handles)
    assert served.pipe.stats.launches_skipped == total_skip


def test_servo_telemetry_gauges(served):
    text = telemetry.registry().render()
    assert 'fpca_gate_threshold{controller="cam0/edges"}' in text
    ctl = served.server.sessions["cam0"].controller
    fam = telemetry.registry().gauge("fpca_gate_threshold")
    assert ctl.threshold == fam.labels(controller="cam0/edges").value


def test_fleet_allocation_gauges_sum_to_budget_and_reconcile():
    """The fleet arbiter's per-tenant rollups: allocation gauges sum to the
    global budget gauge, and admission rejections leave every stats surface
    exactly reconciled (a rejected stream must not touch serving counters)."""
    from repro.serving.fleet import (
        FleetAdmissionError,
        FleetConfig,
        FleetController,
    )

    rng = np.random.default_rng(1)
    kernel = rng.normal(size=(4, 3, 3, 3)).astype(np.float32)
    pipe = FPCAPipeline(backend="basis")
    pipe.register("edges", SPEC, kernel)
    server = StreamServer(
        pipe,
        gate=fpca.DeltaGateConfig(threshold=0.05, keyframe_interval=6),
        controller=fpca.GateControllerConfig(target=0.5),
    )
    fc = FleetController(server, FleetConfig(budget=0.6, floor=0.2))
    fc.add_stream("t0", "edges")
    fc.add_stream("t1", "edges", priority=2.0)
    fc.add_stream("t2", "edges")
    with pytest.raises(FleetAdmissionError):        # capacity = 3
        fc.add_stream("t3", "edges")
    reg = telemetry.registry()
    alloc = {
        labels["stream"]: value
        for name, _k, labels, value in reg.collect()
        if name == "fpca_fleet_allocation"
        and labels.get("stream") in ("t0", "t1", "t2")
    }
    budget = [v for n, _k, _l, v in reg.collect() if n == "fpca_fleet_budget"]
    assert sum(alloc.values()) == pytest.approx(budget[0]) == 0.6
    # the rendered export carries the same cells
    text = reg.render()
    assert 'fpca_fleet_allocation{stream="t1"}' in text
    assert "fpca_fleet_rejected_total" in text
    # rejected admission left serving telemetry untouched and reconciled
    assert len(server.sessions) == 3
    assert_reconciled(pipe, server)
    json.dumps(fc.arbitration_table(), allow_nan=False)


# -- StatsView semantics -----------------------------------------------------


def test_parent_chain_and_parent_map():
    parent = PipelineStats()
    child = fpca.FrontendStats(parent=parent)
    child.runs += 2
    child.windows_executed += 5
    child.reprograms += 1
    assert parent.batches == 2                 # _PARENT_MAP runs -> batches
    assert parent.windows_executed == 5
    assert child.snapshot()[0] == 2
    with pytest.raises(AttributeError):
        child.not_a_field
    with pytest.raises(AttributeError):
        child.not_a_field = 1
    d = child.as_dict()
    assert d["runs"] == 2 and d["reprograms"] == 1


def test_registry_export_tracks_views_live():
    view = fpca.FrontendStats()
    view.windows_total += 7
    inst = view._labels["instance"]
    rows = {
        (n, l.get("instance")): v
        for n, _k, l, v in telemetry.registry().collect()
    }
    assert rows[("fpca_frontend_windows_total", inst)] == 7


# -- registry ----------------------------------------------------------------


def test_label_cardinality_bounded():
    fam = MetricFamily("test_bounded_total", "counter", "", ("stream",),
                       max_label_sets=4)
    for i in range(10):
        fam.labels(stream=f"s{i}").add(1)
    # 4 interned + 1 shared overflow cell, never more
    assert len(fam._cells) == 5
    assert fam.overflowed == 6
    overflow = fam.labels(stream="anything_new")
    assert overflow is fam._cells[(OVERFLOW_LABEL,)]
    total = sum(c.value for c in fam._cells.values())
    assert total == 10                          # totals stay honest


def test_prometheus_render_shape():
    reg = telemetry.registry()
    reg.histogram("test_render_seconds", "help text", ("site",)).labels(
        site="x").observe(0.002)
    text = reg.render()
    assert "# TYPE test_render_seconds histogram" in text
    assert "# HELP test_render_seconds help text" in text
    assert 'test_render_seconds_bucket{site="x",le="+Inf"} 1' in text
    assert 'test_render_seconds_count{site="x"} 1' in text
    cnt = reg.counter("test_render_total")
    cnt.cell().add(3)
    assert "test_render_total 3" in reg.render()


def test_snapshot_is_strict_json():
    snap = telemetry.registry().snapshot()
    json.dumps(snap, allow_nan=False)


# -- disabled mode -----------------------------------------------------------


def test_disabled_mode_allocates_nothing():
    telemetry.disable()
    assert not telemetry.enabled()
    # the null span is ONE shared object: no per-call allocation at all
    s1, s2 = telemetry.span("serve_tick"), telemetry.span("compile")
    assert s1 is s2 is telemetry._NULL_SPAN
    fields = {"stream": "cam0"}
    assert telemetry.span("serve_tick", fields) is s1
    # events are dropped without touching any session state
    telemetry.event("servo_actuate", err=1.0)


def test_disabled_instrumented_launch_is_passthrough():
    calls = []

    def fn(x):
        calls.append(x)
        return x * 2

    telemetry.disable()
    wrapped = telemetry.instrument_launch(fn, site="test", backend="ref")
    fam = telemetry.registry().counter("fpca_launches_total")
    cell = fam.labels(site="test", backend="ref")
    before = cell.value
    assert wrapped(21) == 42
    assert cell.value == before                # nothing counted when off
    telemetry.enable(None)
    assert wrapped(1) == 2
    assert cell.value == before + 1            # counted when on
    telemetry.disable()
    assert wrapped.__wrapped__ is fn


# -- executable cache --------------------------------------------------------


def test_cache_eviction_ordering_and_verbose_info():
    cache = ExecutableCache(capacity=2)
    cache.get(("a",), lambda: "A")
    cache.get(("b",), lambda: "B")
    cache.get(("a",), lambda: "A")             # refresh a: b is now LRU
    cache.get(("c",), lambda: "C")             # evicts b
    cache.get(("d",), lambda: "D")             # evicts a
    info = cache.info(verbose=True)
    assert info.eviction_log == (("b",), ("a",))
    assert info.resident == (("c",), ("d",))   # LRU-first ordering
    assert info.by_key[("a",)] == (1, 1)       # 1 hit, 1 miss
    assert info.by_key[("b",)] == (0, 1)
    assert (info.hits, info.misses, info.evictions) == (1, 4, 2)
    # non-verbose stays the stable 5-tuple the API contract pins
    assert cache.info() == (1, 4, 2, 2, 2)


def test_eviction_log_is_bounded():
    cache = ExecutableCache(capacity=1)
    cache.eviction_log_cap  # class attr exists
    for i in range(cache.eviction_log_cap + 10):
        cache.get((i,), lambda: i)
    log = cache.info(verbose=True).eviction_log
    assert len(log) == cache.eviction_log_cap
    assert log[-1] == (cache.eviction_log_cap + 8,)   # newest retained
