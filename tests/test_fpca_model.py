"""Multi-layer model programs: analog frontend + digital CNN head behind
one ``fpca.compile()``.

Contracts pinned here:

* **Spec validation** — head layer chains are checked at construction
  (geometry, final-logits stage, activations).
* **Fused-jit parity** — ``compile(FPCAModelProgram).run()`` logits are
  bit-identical to composing a frontend handle with the reference
  ``apply_head``, for every registered backend, dense and masked (including
  zero-kept and bucket-edge ``n_keep``).
* **Zero-recompile reprogram** — NVM planes AND head parameters enter
  traced; rewriting either never recompiles (via ``cache_info()``).
* **Signature stability** — the model signature is a golden-pinned
  versioned primitive tuple extending the frontend's; head *specs* and
  ``input_scale`` are compiled in, head *parameters* are excluded.
* **Skip-aware streaming** — delta-gated ticks patch kept windows into the
  previous effective activation map, so every tick yields class logits (an
  all-skipped tick reproduces the previous logits exactly), on the handle's
  ``stream()`` and through ``FPCAPipeline`` / ``StreamServer``.
* **Accounting** — ``analysis.head_flops`` / ``model_streaming_report``
  report the digital head next to the executed-window stats.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax

import repro.fpca as fpca
from repro.core import analysis
from repro.core.mapping import FPCASpec, output_dims

H = W = 24


def _spec(kernel: int = 5, stride: int = 5, c_o: int = 4) -> FPCASpec:
    return FPCASpec(
        image_h=H, image_w=W, out_channels=c_o, kernel=kernel, stride=stride
    )


def _head() -> tuple:
    return (fpca.DenseSpec(8, activation="relu"), fpca.DenseSpec(3))


def _model(spec: FPCASpec | None = None, head: tuple | None = None,
           **kw) -> fpca.FPCAModelProgram:
    return fpca.FPCAModelProgram(
        frontend=fpca.FPCAProgram(spec=spec or _spec()),
        head=head or _head(),
        **kw,
    )


def _data(spec: FPCASpec, batch: int = 2, seed: int = 0):
    rng = np.random.default_rng(seed)
    images = rng.uniform(0, 1, (batch, H, W, spec.in_channels)).astype(np.float32)
    k = spec.kernel
    kernel = (
        rng.normal(size=(spec.out_channels, k, k, spec.in_channels)) * 0.2
    ).astype(np.float32)
    return images, kernel


def _mask_with_keep(b: int, h_o: int, w_o: int, n_keep: int) -> np.ndarray:
    """A (b, h_o, w_o) window mask keeping exactly ``n_keep`` windows."""
    flat = np.zeros(b * h_o * w_o, bool)
    flat[:n_keep] = True
    return flat.reshape(b, h_o, w_o)


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


def test_model_program_validates_head():
    fe = fpca.FPCAProgram(spec=_spec())
    with pytest.raises(ValueError, match="at least one layer"):
        fpca.FPCAModelProgram(frontend=fe, head=())
    with pytest.raises(ValueError, match="last head stage"):
        fpca.FPCAModelProgram(frontend=fe, head=(fpca.ActivationSpec("relu"),))
    # frontend output is (4, 4, c): a 5x5 VALID conv cannot fit
    with pytest.raises(ValueError, match="conv kernel"):
        fpca.FPCAModelProgram(
            frontend=fe, head=(fpca.ConvSpec(4, 5), fpca.DenseSpec(2))
        )
    with pytest.raises(ValueError, match="pool size"):
        fpca.FPCAModelProgram(
            frontend=fe, head=(fpca.PoolSpec(8), fpca.DenseSpec(2))
        )
    with pytest.raises(ValueError, match="unknown activation"):
        fpca.DenseSpec(4, activation="softmax3")
    with pytest.raises(ValueError, match="input_scale"):
        fpca.FPCAModelProgram(frontend=fe, head=_head(), input_scale=0.0)
    # conv/pool after a dense (flat) input cannot chain
    with pytest.raises(ValueError, match="spatial"):
        fpca.FPCAModelProgram(
            frontend=fe,
            head=(fpca.DenseSpec(8), fpca.ConvSpec(2, 1), fpca.DenseSpec(2)),
        )


def test_model_head_shapes_chain():
    model = _model(head=(
        fpca.ConvSpec(6, 3, activation="relu"),
        fpca.PoolSpec(2),
        fpca.DenseSpec(5, activation="relu"),
        fpca.DenseSpec(2),
    ))
    assert model.head_shapes() == [(4, 4, 4), (2, 2, 6), (1, 1, 6), (5,), (2,)]
    assert model.n_classes == 2


def test_init_head_matches_apply(bucket_model):
    model = _model(head=(
        fpca.ConvSpec(6, 3, activation="relu"),
        fpca.PoolSpec(2, kind="avg"),
        fpca.ActivationSpec("tanh"),
        fpca.DenseSpec(2),
    ))
    params = model.init_head(jax.random.PRNGKey(0))
    assert len(params) == len(model.head)
    assert params[1] == {} and params[2] == {}          # parameterless stages
    counts = np.random.default_rng(0).uniform(
        0, 255, (3, *model.frontend.out_shape)
    ).astype(np.float32)
    logits = np.asarray(model.apply_head(params, counts))
    assert logits.shape == (3, 2)
    assert np.all(np.isfinite(logits))


# ---------------------------------------------------------------------------
# signature stability (golden)
# ---------------------------------------------------------------------------

GOLDEN_FRONTEND_SIG = (
    "repro.fpca/1",
    ("spec", 24, 24, 4, 3, 2, 5, 3, 0, 1, 8),
    ("out_channels", 4),
    ("adc", 8, 1.0),
    ("enc", 16, 1.0),
    ("circuit", ("v_sat", 1.0), ("s0", 0.37), ("drive_a", 0.15),
     ("drive_b", -0.1), ("drive_c", 0.25), ("coupling", 0.15),
     ("kappa_r", 0.012), ("r_metal_mm", 0.0), ("fp_iters", 8.0)),
)

GOLDEN_MODEL_SIG = (
    ("repro.fpca.model/1",)
    + GOLDEN_FRONTEND_SIG
    + (
        ("head", ("dense", 8, "relu"), ("dense", 3, "")),
        ("input_scale", 1.0),
    )
)


def test_model_signature_golden():
    """Exact pinned value: the model signature is the executable-cache key
    contract — change it only by bumping the version string deliberately."""
    spec = FPCASpec(image_h=24, image_w=24, out_channels=4, kernel=3, stride=2)
    model = fpca.FPCAModelProgram(
        frontend=fpca.FPCAProgram(spec=spec), head=_head()
    )
    assert model.signature() == GOLDEN_MODEL_SIG
    # and it extends the frontend's signature verbatim
    assert model.frontend.signature() == GOLDEN_FRONTEND_SIG
    assert model.signature()[1 : 1 + len(GOLDEN_FRONTEND_SIG)] == GOLDEN_FRONTEND_SIG


def test_model_signature_static_vs_runtime():
    base = _model()
    # head parameters / gates are runtime state: same signature
    gated = fpca.FPCAModelProgram(
        frontend=fpca.FPCAProgram(
            spec=_spec(), gate=fpca.DeltaGateConfig(threshold=0.5)
        ),
        head=_head(),
    )
    assert base.signature() == gated.signature()
    # anything compiled-in changes it: head specs, input_scale, frontend adc
    assert base.signature() != _model(
        head=(fpca.DenseSpec(8, activation="relu"), fpca.DenseSpec(4))
    ).signature()
    assert base.signature() != _model(input_scale=0.5).signature()
    assert base.signature() != fpca.FPCAModelProgram(
        frontend=fpca.FPCAProgram(spec=_spec(), adc=fpca.ADCConfig(bits=4)),
        head=_head(),
    ).signature()


# ---------------------------------------------------------------------------
# fused-jit parity (the acceptance contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "basis", "pallas"])
def test_model_logits_match_frontend_plus_head(bucket_model, backend):
    """Fused frontend+head logits are bit-identical to composing the
    frontend handle with the reference head apply — dense and masked,
    including zero-kept and bucket-edge ``n_keep`` values."""
    model = _model(input_scale=0.125)
    images, kernel = _data(model.spec)
    head_params = model.init_head(jax.random.PRNGKey(1))
    interpret = True if backend == "pallas" else None
    cache = fpca.ExecutableCache(32)
    m = fpca.compile(model, backend=backend, weights=kernel,
                     head_params=head_params, model=bucket_model,
                     cache=cache, interpret=interpret)
    fe = fpca.compile(model.frontend, backend=backend, weights=kernel,
                      model=bucket_model, cache=cache, interpret=interpret)
    h_o, w_o = output_dims(model.spec)
    b = images.shape[0]
    m_total = b * h_o * w_o

    got = np.asarray(m.run(images))
    want = np.asarray(model.apply_head(head_params, fe.run(images)))
    assert got.shape == (b, model.n_classes)
    np.testing.assert_array_equal(got, want)

    # masked parity across the bucket edges (n_keep = 0, 1, pow2 +/- 1, M)
    for n_keep in (0, 1, 7, 8, 9, m_total):
        keep = _mask_with_keep(b, h_o, w_o, n_keep)
        got = np.asarray(m.run(images, window_keep=keep))
        want = np.asarray(
            model.apply_head(head_params, fe.run(images, window_keep=keep))
        )
        np.testing.assert_array_equal(got, want, err_msg=f"n_keep={n_keep}")


def test_model_single_frame_mirrors_batchedness(bucket_model):
    model = _model()
    images, kernel = _data(model.spec)
    head_params = model.init_head(jax.random.PRNGKey(0))
    m = fpca.compile(model, backend="basis", weights=kernel,
                     head_params=head_params, model=bucket_model)
    batched = np.asarray(m.run(images))
    one = np.asarray(m.run(images[0]))
    assert one.shape == (model.n_classes,)
    np.testing.assert_array_equal(one, batched[0])


def test_model_zero_kept_short_circuits_frontend(bucket_model):
    """An all-skipped batch launches no frontend kernel but still serves the
    head on the exact-zero activation map — a class decision, not zeros."""
    model = _model()
    images, kernel = _data(model.spec)
    head_params = model.init_head(jax.random.PRNGKey(0))
    m = fpca.compile(model, backend="basis", weights=kernel,
                     head_params=head_params, model=bucket_model)
    h_o, w_o = output_dims(model.spec)
    keep = np.zeros((2, h_o, w_o), bool)
    runs_before = m.stats.runs
    got = np.asarray(m.run(images, window_keep=keep))
    assert m.stats.launches_skipped == 1
    assert m.stats.runs == runs_before            # no frontend launch
    zeros = np.zeros((2, *model.frontend.out_shape), np.float32)
    np.testing.assert_array_equal(
        got, np.asarray(model.apply_head(head_params, zeros))
    )


def test_model_reprogram_zero_recompiles(bucket_model):
    """Rewriting NVM planes AND/OR head parameters never recompiles."""
    model = _model()
    images, k1 = _data(model.spec, seed=1)
    _, k2 = _data(model.spec, seed=2)
    hp1 = model.init_head(jax.random.PRNGKey(1))
    hp2 = model.init_head(jax.random.PRNGKey(2))
    m = fpca.compile(model, backend="basis", weights=k1, head_params=hp1,
                     model=bucket_model)
    out1 = np.asarray(m.run(images))
    misses = m.cache_info().misses
    assert misses == 1                            # exactly one fused compile
    m.reprogram(k2)                               # NVM rewrite
    out2 = np.asarray(m.run(images))
    m.reprogram(head_params=hp2)                  # head rewrite
    out3 = np.asarray(m.run(images))
    info = m.cache_info()
    assert info.misses == misses                  # ZERO recompiles
    assert info.hits >= 2
    assert not np.array_equal(out1, out2)
    assert not np.array_equal(out2, out3)
    # head params really serve: parity against the reference apply
    fe = fpca.compile(model.frontend, backend="basis", weights=k2,
                      model=bucket_model)
    np.testing.assert_array_equal(
        out3, np.asarray(model.apply_head(hp2, fe.run(images)))
    )


def test_model_requires_programmed_parameters(bucket_model):
    model = _model()
    images, kernel = _data(model.spec)
    m = fpca.compile(model, backend="basis", model=bucket_model)
    with pytest.raises(RuntimeError, match="reprogram"):
        m.run(images)
    m.reprogram(kernel)
    with pytest.raises(RuntimeError, match="head"):
        m.run(images)
    with pytest.raises(ValueError, match="stages"):
        m.reprogram(head_params=[{}])
    with pytest.raises(ValueError, match="head_params"):
        fpca.compile(fpca.FPCAProgram(spec=_spec()), backend="basis",
                     model=bucket_model, head_params=[{}])


# ---------------------------------------------------------------------------
# skip-aware streaming
# ---------------------------------------------------------------------------


def test_model_stream_skip_aware_logits(bucket_model):
    """A static gated stream: everything after the keyframe is skipped, yet
    every tick yields the keyframe's logits (the effective activation map
    carries forward); counts stay exact zeros for skipped ticks."""
    model = _model()
    rng = np.random.default_rng(5)
    frame = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    _, kernel = _data(model.spec)
    hp = model.init_head(jax.random.PRNGKey(0))
    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    gate = fpca.DeltaGateConfig(threshold=0.05, hysteresis=0,
                                keyframe_interval=0)
    results = list(m.stream([frame] * 4, gate=gate))
    h_o, w_o = output_dims(model.spec)
    assert results[0].kept_windows == h_o * w_o
    dense_logits = np.asarray(m.run(frame))
    np.testing.assert_array_equal(results[0].logits, dense_logits)
    for r in results[1:]:
        assert r.kept_windows == 0
        assert np.all(r.counts == 0)              # frontend skipped
        np.testing.assert_array_equal(r.logits, dense_logits)  # head patched


def test_model_stream_patches_effective_activations(bucket_model):
    """Moving scene: per-tick logits equal a manual effective-map
    simulation (patch kept windows into the previous map, apply the head)."""
    from repro.data.pipeline import SyntheticMovingObject

    model = _model()
    _, kernel = _data(model.spec)
    hp = model.init_head(jax.random.PRNGKey(0))
    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    fe = fpca.compile(model.frontend, backend="basis", weights=kernel,
                      model=bucket_model)
    cam = SyntheticMovingObject((H, W), seed=3)
    frames = [cam.frame_at(t) for t in range(6)]
    gate = fpca.DeltaGateConfig(threshold=0.02, hysteresis=1,
                                keyframe_interval=4)
    results = list(m.stream(frames, gate=gate))
    assert any(0 < r.kept_windows < r.total_windows for r in results)

    from repro.core.mapping import active_window_mask

    eff = np.zeros(model.frontend.out_shape, np.float32)
    for frame, r in zip(frames, results):
        if r.block_mask is None or r.block_mask.all():
            counts = np.asarray(fe.run(frame))
            window = np.ones(counts.shape[:2], bool)
        else:
            window = active_window_mask(model.spec, r.block_mask)
            counts = np.asarray(fe.run(frame, block_mask=r.block_mask))
        eff = np.where(window[..., None], counts, eff)
        want = np.asarray(model.apply_head(hp, eff[None]))[0]
        np.testing.assert_array_equal(r.logits, want,
                                      err_msg=f"tick {r.frame_idx}")


def test_model_streams_are_iterator_independent(bucket_model):
    """Two concurrent stream() iterators from ONE handle must not share the
    effective activation map: interleaved iteration matches sequential."""
    from repro.data.pipeline import SyntheticMovingObject

    model = _model()
    _, kernel = _data(model.spec)
    hp = model.init_head(jax.random.PRNGKey(0))
    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    gate = fpca.DeltaGateConfig(threshold=0.02, hysteresis=1,
                                keyframe_interval=4)
    frames_a = [SyntheticMovingObject((H, W), seed=1).frame_at(t)
                for t in range(5)]
    frames_b = [SyntheticMovingObject((H, W), seed=2).frame_at(t)
                for t in range(5)]
    want_a = [r.logits for r in m.stream(frames_a, gate=gate)]
    want_b = [r.logits for r in m.stream(frames_b, gate=gate)]
    it_a = m.stream(frames_a, gate=gate, depth=1)
    it_b = m.stream(frames_b, gate=gate, depth=1)
    got_a, got_b = [], []
    for a, b in zip(it_a, it_b):          # interleaved ticks
        got_a.append(a.logits)
        got_b.append(b.logits)
    for want, got in ((want_a, got_a), (want_b, got_b)):
        for w_l, g_l in zip(want, got):
            np.testing.assert_array_equal(g_l, w_l)


def test_model_reprogram_bn_offset_alone(bucket_model):
    """A bn_offset-only rewrite must serve (and still never recompile)."""
    model = _model()
    images, kernel = _data(model.spec)
    hp = model.init_head(jax.random.PRNGKey(0))
    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    out1 = np.asarray(m.run(images))
    misses = m.cache_info().misses
    m.reprogram(bn_offset=np.full((model.out_channels,), 50.0, np.float32))
    out2 = np.asarray(m.run(images))
    assert m.cache_info().misses == misses
    assert not np.array_equal(out1, out2)
    fe = fpca.compile(model.frontend, backend="basis", weights=kernel,
                      bn_offset=np.full((model.out_channels,), 50.0, np.float32),
                      model=bucket_model)
    np.testing.assert_array_equal(
        out2, np.asarray(model.apply_head(hp, fe.run(images)))
    )
    with pytest.raises(ValueError, match="reprogram needs"):
        m.reprogram()


# ---------------------------------------------------------------------------
# pipeline + stream server wiring
# ---------------------------------------------------------------------------


def test_pipeline_serves_model_config(bucket_model):
    from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest

    model = _model()
    images, kernel = _data(model.spec)
    hp = model.init_head(jax.random.PRNGKey(0))
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cls", model, kernel, head_params=hp)
    pipe.register("fe", model.spec, kernel)
    res = pipe.serve(
        [FrontendRequest("cls", images[0]), FrontendRequest("fe", images[0]),
         FrontendRequest("cls", images[1])]
    )
    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    want = np.asarray(m.run(images))
    np.testing.assert_array_equal(np.asarray(res[0]), want[0])
    np.testing.assert_array_equal(np.asarray(res[2]), want[1])
    assert np.asarray(res[1]).shape == model.frontend.out_shape


def test_pipeline_register_model_validation(bucket_model):
    from repro.serving.fpca_pipeline import FPCAPipeline

    model = _model()
    _, kernel = _data(model.spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    with pytest.raises(ValueError, match="head_params"):
        pipe.register("cls", model, kernel)
    with pytest.raises(ValueError, match="head_params"):
        pipe.register("fe", model.spec, kernel,
                      head_params=model.init_head(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="output channels"):
        pipe.register("cls", model, kernel[:2],
                      head_params=model.init_head(jax.random.PRNGKey(0)))
    # a stage-count mismatch fails AT registration, not on the first serve
    with pytest.raises(ValueError, match="stages"):
        pipe.register("cls", model, kernel,
                      head_params=model.init_head(jax.random.PRNGKey(0))[:1])


def test_cross_config_stacking_with_model_config(bucket_model):
    """A model config and a frontend config sharing a compile signature
    merge into ONE channel-stacked launch; the model's head then runs on its
    slice — logits bit-identical to serving it alone."""
    from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest

    model = _model()
    images, kernel = _data(model.spec)
    hp = model.init_head(jax.random.PRNGKey(0))
    pipe = FPCAPipeline(bucket_model, backend="basis",
                        cross_config_batching=True)
    pipe.register("cls", model, kernel, head_params=hp)
    pipe.register("fe", model.spec, kernel * 0.5)
    res = pipe.serve(
        [FrontendRequest("cls", images[0]), FrontendRequest("fe", images[0])]
    )
    assert pipe.stats.merged_groups == 1
    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    np.testing.assert_array_equal(
        np.asarray(res[0]), np.asarray(m.run(images[0]))
    )


def test_stream_server_yields_model_logits(bucket_model):
    """StreamServer ticks on a model config carry per-tick class logits,
    tick-for-tick bit-identical to the handle's solo stream()."""
    from repro.data.pipeline import SyntheticMovingObject
    from repro.serving.fpca_pipeline import FPCAPipeline
    from repro.serving.streaming import StreamServer

    model = _model()
    _, kernel = _data(model.spec)
    hp = model.init_head(jax.random.PRNGKey(0))
    gate = fpca.DeltaGateConfig(threshold=0.02, hysteresis=1,
                                keyframe_interval=4)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cls", model, kernel, head_params=hp)
    pipe.register("fe", model.spec, kernel)
    server = StreamServer(pipe, gate)
    server.add_stream("cam", "cls")
    server.add_stream("plain", "fe")
    cam = SyntheticMovingObject((H, W), seed=3)
    frames = [cam.frame_at(t) for t in range(6)]
    got = [
        r
        for results in server.run(
            {"cam": f, "plain": f} for f in frames
        )
        for r in results
    ]
    model_results = [r for r in got if r.config == "cls"]
    plain_results = [r for r in got if r.config == "fe"]
    assert all(r.logits is not None for r in model_results)
    assert all(r.logits is None for r in plain_results)

    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    solo = list(m.stream(frames, gate=gate))
    for a, b in zip(model_results, solo):
        assert a.frame_idx == b.frame_idx
        assert a.kept_windows == b.kept_windows
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.logits, b.logits)
        assert a.predicted_class == b.predicted_class


def test_stream_server_dense_model_logits(bucket_model):
    """Gating off: every tick's logits equal the fused dense run."""
    from repro.serving.fpca_pipeline import FPCAPipeline
    from repro.serving.streaming import StreamServer

    model = _model()
    images, kernel = _data(model.spec)
    hp = model.init_head(jax.random.PRNGKey(0))
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cls", model, kernel, head_params=hp)
    server = StreamServer(pipe, gating=False)
    server.add_stream("cam", "cls")
    m = fpca.compile(model, backend="basis", weights=kernel, head_params=hp,
                     model=bucket_model)
    for r in server.serve("cam", list(images)):
        np.testing.assert_array_equal(
            r.logits, np.asarray(m.run(images[r.frame_idx]))
        )


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def test_head_flops_exact_counts():
    model = _model(head=(
        fpca.ConvSpec(6, 3, activation="relu"),   # (4,4,4) -> (2,2,6)
        fpca.DenseSpec(5, activation="relu"),     # 24 -> 5
        fpca.DenseSpec(2),                        # 5 -> 2
    ))
    fl = analysis.head_flops(model)
    conv_macs = 2 * 2 * 6 * (3 * 3 * 4)
    assert fl["per_layer"][0]["macs"] == conv_macs
    assert fl["per_layer"][1]["macs"] == 24 * 5
    assert fl["per_layer"][2]["macs"] == 5 * 2
    assert fl["macs"] == conv_macs + 24 * 5 + 5 * 2
    assert fl["flops"] == 2 * fl["macs"]
    assert fl["params"] == 6 * (3 * 3 * 4 + 1) + 5 * (24 + 1) + 2 * (5 + 1)


def test_head_flops_invariant_to_activation_spelling():
    """A fused activation and a standalone ActivationSpec stage are the same
    computation — they must report the same energy/latency."""
    fused = _model(head=(fpca.DenseSpec(8, activation="relu"),
                         fpca.DenseSpec(2)))
    spelled = _model(head=(fpca.DenseSpec(8), fpca.ActivationSpec("relu"),
                           fpca.DenseSpec(2)))
    a, b = analysis.head_report(fused), analysis.head_report(spelled)
    assert a["macs"] == b["macs"] and a["params"] == b["params"]
    assert a["elem_ops"] == b["elem_ops"] == 8
    assert a["e_head"] == b["e_head"] and a["t_head"] == b["t_head"]


def test_bind_head_params_validates_shapes():
    """Wrong-shaped head weights fail at the bind call site with a clear
    error, never inside a jitted trace."""
    model = _model()
    good = model.init_head(jax.random.PRNGKey(0))
    bad = [dict(good[0]), dict(good[1])]
    bad[0]["w"] = np.asarray(bad[0]["w"]).T          # transposed dense weight
    with pytest.raises(ValueError, match="parameter shapes"):
        model.bind_head_params(bad)
    missing = [{"w": good[0]["w"]}, good[1]]         # bias dropped
    with pytest.raises(ValueError, match="parameter shapes"):
        model.bind_head_params(missing)
    assert len(model.bind_head_params(good)) == 2


def test_model_streaming_report_extends_frontend_stats():
    model = _model()
    bh = -(-model.spec.eff_h // model.spec.skip_block)
    bw = -(-model.spec.eff_w // model.spec.skip_block)
    masks = [None, np.zeros((bh, bw), bool), np.ones((bh, bw), bool)]
    rep = analysis.model_streaming_report(model, masks)
    base = analysis.streaming_frontend_report(model.spec, masks)
    for key, val in base.items():
        assert rep[key] == val                    # frontend stats unchanged
    assert rep["head_macs_per_frame"] == analysis.head_flops(model)["macs"]
    assert rep["t_head_total"] > 0 and rep["e_head_total"] > 0
    assert rep["e_model_total"] > rep["e_total"]
    # the head runs dense every frame, so the whole-model ratio is closer to
    # dense than the frontend-only ratio
    assert rep["model_energy_vs_dense"] >= rep["energy_vs_dense"]
