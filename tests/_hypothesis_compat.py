"""Property-test shim: real hypothesis when installed, a deterministic
fixed-seed fallback otherwise.

The tier-1 suite must *collect* everywhere, including minimal CI images
without hypothesis.  Test modules import ``given`` / ``settings`` / ``st``
from here instead of from ``hypothesis``:

    from _hypothesis_compat import given, settings, st

With hypothesis installed this module is a pure re-export (full shrinking,
example database, etc.).  Without it, ``@given`` degrades to running the test
body over a fixed seeded grid of ``max_examples`` draws — no shrinking, but
deterministic and honouring the declared strategy ranges, so the property is
still exercised on every platform.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 20
    _SEED = 0x5EED

    class _Strategy:
        """Minimal strategy: an rng draw plus its range boundary values."""

        def __init__(self, draw, boundaries):
            self._draw = draw
            self.boundaries = boundaries   # [low edge, high edge]

        def example_from(self, rng: np.random.Generator):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                [min_value, max_value],
            )

        @staticmethod
        def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                [min_value, max_value],
            )

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            seq = list(elements)
            return _Strategy(
                lambda rng: seq[int(rng.integers(0, len(seq)))],
                [seq[0], seq[-1]],
            )

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.integers(0, 2)), [False, True])

    st = _Strategies()

    def settings(*, max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        """Records ``max_examples`` on the (already ``given``-wrapped) test."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
        """Run the test over a deterministic seeded grid of examples.

        The first two examples pin every strategy jointly to its low / high
        range edge; uniform draws fill the remaining budget — a cheap
        stand-in for hypothesis' edge-case bias.
        """

        def deco(fn):
            sig = inspect.signature(fn)
            params = [p for p in sig.parameters.values() if p.name not in kw_strategies]
            n_pos = len(arg_strategies)
            # positional strategies fill the RIGHTMOST remaining params
            # (hypothesis semantics); whatever is left comes from fixtures.
            fixture_params = params[: len(params) - n_pos] if n_pos else params
            pos_names = [p.name for p in params[len(params) - n_pos :]] if n_pos else []

            @functools.wraps(fn)
            def wrapper(*fixture_args, **fixture_kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = np.random.default_rng(_SEED)
                for i in range(n):
                    if i < 2:
                        # examples 0/1: every strategy at its low/high edge —
                        # uniform draws alone would (almost) never land there
                        drawn = {k: s.boundaries[i] for k, s in kw_strategies.items()}
                        drawn.update(
                            (name, s.boundaries[i])
                            for name, s in zip(pos_names, arg_strategies)
                        )
                    else:
                        drawn = {k: s.example_from(rng) for k, s in kw_strategies.items()}
                        drawn.update(
                            (name, s.example_from(rng))
                            for name, s in zip(pos_names, arg_strategies)
                        )
                    fn(*fixture_args, **fixture_kwargs, **drawn)

            # hide drawn params from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(parameters=fixture_params)
            return wrapper

        return deco
