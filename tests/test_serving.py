"""Serving-path correctness: SWA ring buffer, long decode consistency,
enc-dec caches, continuous batching invariants."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.models.transformer import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_cache,
    init_model,
)


def _greedy_roll(params, cfg, tokens, n_steps, max_len):
    """Prefill then teacher-forced decode of ground-truth continuation."""
    logits, cache = forward_prefill(params, cfg, tokens[:, :-n_steps], max_len=max_len)
    outs = [logits]
    pos0 = tokens.shape[1] - n_steps
    for i in range(n_steps):
        logits, cache = forward_decode(
            params, cfg, tokens[:, pos0 + i : pos0 + i + 1], cache, jnp.int32(pos0 + i)
        )
        outs.append(logits)
    return outs


def test_swa_ring_buffer_matches_full_recompute():
    """Decoding past the sliding window with the ring-buffer cache must match
    a from-scratch prefill at every step (the ring is pure optimisation)."""
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["h2o-danube-1.8b"]), window=16)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, total = 2, 48  # 3x the window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, total), 0, cfg.vocab_size)

    # ring-buffer path: prefill 20, decode the rest step by step
    prefill_len = 20
    logits, cache = forward_prefill(params, cfg, tokens[:, :prefill_len], max_len=total)
    assert cache["layers"]["k"].shape[2] == cfg.window  # ring allocated at window
    ring_logits = []
    for i in range(prefill_len, total):
        logits, cache = forward_decode(
            params, cfg, tokens[:, i : i + 1], cache, jnp.int32(i)
        )
        ring_logits.append(np.asarray(logits))

    # reference: full prefill at each length
    for idx, end in enumerate(range(prefill_len + 1, total + 1)):
        ref, _ = forward_prefill(params, cfg, tokens[:, :end], max_len=total)
        np.testing.assert_allclose(
            ring_logits[idx], np.asarray(ref), rtol=3e-2, atol=3e-2
        ), f"step {idx}"


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-7b"])
def test_ssm_decode_matches_prefill(arch):
    """SSM/hybrid O(1)-state decode must agree with chunked prefill."""
    cfg = reduce_for_smoke(ARCHS[arch])
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S, n_dec = 2, 24, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    outs = _greedy_roll(params, cfg, tokens, n_dec, max_len=S + 2)
    for i, logit in enumerate(outs[1:]):
        end = S - n_dec + i + 1
        ref, _ = forward_prefill(params, cfg, tokens[:, :end], max_len=S + 2)
        np.testing.assert_allclose(
            np.asarray(logit), np.asarray(ref), rtol=4e-2, atol=4e-2
        ), f"decode step {i}"


def test_encdec_decode_consistency():
    cfg = reduce_for_smoke(ARCHS["seamless-m4t-medium"])
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.frontend_dim))
    l_a, cache = forward_prefill(
        params, cfg, tokens[:, : S - 1], frontend_embeds=frames, max_len=S + 2
    )
    l_b, _ = forward_decode(params, cfg, tokens[:, S - 1 :], cache, jnp.int32(S - 1))
    ref, _ = forward_prefill(params, cfg, tokens, frontend_embeds=frames, max_len=S + 2)
    np.testing.assert_allclose(np.asarray(l_b), np.asarray(ref), rtol=3e-2, atol=3e-2)


def test_moe_decode_matches_prefill():
    cfg = reduce_for_smoke(ARCHS["qwen2-moe-a2.7b"])
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 20
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    l_a, cache = forward_prefill(params, cfg, tokens[:, : S - 1], max_len=S + 2)
    l_b, _ = forward_decode(params, cfg, tokens[:, S - 1 :], cache, jnp.int32(S - 1))
    ref, _ = forward_prefill(params, cfg, tokens, max_len=S + 2)
    # MoE decode routes a tiny token batch -> capacity differences possible;
    # still must match within loose numeric bounds for identical routing
    np.testing.assert_allclose(np.asarray(l_b), np.asarray(ref), rtol=6e-2, atol=6e-2)
