"""Fleet budget arbitration + multi-device sharding (the ``-m fleet`` lane).

Contracts pinned here:

* **Water-filling split** — allocations sum to the budget, respect the
  per-stream ``[floor, ceiling]`` clamp, and order like the weights
  (``priority * activity``); ceiling-capped excess re-spreads.
* **Arbitration dynamics** — a busy scene's allocation rises at a static
  scene's expense while the fleet total stays pinned to the budget, and
  every re-solved share lands in that stream's PI servo as its new target
  (bumpless: EMA/integrator state carries over).
* **Admission control** — at most ``budget // floor`` streams; over
  capacity the fleet rejects (default) or queues FIFO, and rejections
  leave all telemetry surfaces reconciled.
* **Multi-device parity** — serving the fleet with the fused batch sharded
  over a host mesh's data axes is bit-identical to unsharded serving, with
  gate/arbitration state host-local.  The CI lane re-runs this module under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; locally it adapts
  to however many devices exist.
"""

from __future__ import annotations

import json

import jax
import numpy as np
import pytest

from repro.core.mapping import FPCASpec
from repro.data.pipeline import SyntheticMovingObject
from repro.fpca import telemetry
from repro.launch.mesh import make_host_mesh
from repro.serving.fleet import FleetAdmissionError, FleetConfig, FleetController
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.observe import (
    assert_reconciled,
    fleet_report,
    render_fleet_report,
)
from repro.serving.streaming import (
    DeltaGateConfig,
    GateControllerConfig,
    StreamServer,
)
from repro.serving.fleet import _waterfill

pytestmark = pytest.mark.fleet

H = W = 24
SPEC = FPCASpec(image_h=H, image_w=W, out_channels=4, kernel=5, stride=5)
GATE = DeltaGateConfig(threshold=0.05, hysteresis=1, keyframe_interval=8)


def _kernel(seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    k = SPEC.kernel
    return (rng.normal(size=(SPEC.out_channels, k, k, 3)) * 0.2).astype(
        np.float32
    )


def _pipeline(mesh=None) -> FPCAPipeline:
    pipe = FPCAPipeline(backend="basis", mesh=mesh)
    pipe.register("cam", SPEC, _kernel())
    return pipe


def _fleet(config: FleetConfig, mesh=None, target: float = 0.5):
    pipe = _pipeline(mesh)
    server = StreamServer(
        pipe, gate=GATE, controller=GateControllerConfig(target=target)
    )
    return pipe, server, FleetController(server, config)


def _busy(seed: int = 3) -> SyntheticMovingObject:
    return SyntheticMovingObject((H, W), seed=seed, radius=4.0)


def _static_frame(seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(0, 1, (H, W, 3)).astype(np.float32)


# ---------------------------------------------------------------------------
# water-filling split (pure math)
# ---------------------------------------------------------------------------


def test_waterfill_sums_to_budget_within_bounds():
    weights = {"a": 1.0, "b": 2.0, "c": 4.0}
    alloc = _waterfill(weights, 0.6, 0.05, 0.4)
    assert sum(alloc.values()) == pytest.approx(0.6)
    for v in alloc.values():
        assert 0.05 <= v <= 0.4 + 1e-12
    # allocations order like the weights
    assert alloc["a"] < alloc["b"] < alloc["c"]


def test_waterfill_ceiling_respreads_excess():
    # one dominant stream would claim ~0.55 of 0.6 unclamped; the ceiling
    # caps it and the clawed-back excess re-spreads over the rest
    alloc = _waterfill({"hog": 100.0, "a": 1.0, "b": 1.0}, 0.6, 0.02, 0.3)
    assert alloc["hog"] == pytest.approx(0.3)
    assert alloc["a"] == pytest.approx(alloc["b"])
    assert sum(alloc.values()) == pytest.approx(0.6)


def test_waterfill_floor_only_when_budget_tight():
    # budget == n * floor: everyone sits exactly at the floor
    alloc = _waterfill({"a": 5.0, "b": 1.0}, 0.2, 0.1, 0.9)
    assert alloc == {"a": pytest.approx(0.1), "b": pytest.approx(0.1)}


def test_fleet_config_validation():
    with pytest.raises(ValueError):
        FleetConfig(budget=0.0)
    with pytest.raises(ValueError):
        FleetConfig(floor=0.5, ceiling=0.4)
    with pytest.raises(ValueError):
        FleetConfig(budget=0.1, floor=0.2)
    with pytest.raises(ValueError):
        FleetConfig(admission="defer")
    with pytest.raises(ValueError):
        FleetConfig(rebalance_ticks=0)


# ---------------------------------------------------------------------------
# arbitration dynamics
# ---------------------------------------------------------------------------


def test_busy_stream_wins_budget_from_static_stream():
    """The starved-vs-greedy contract: a moving scene's activity EMA rises,
    so arbitration shifts budget to it; the static stream decays toward the
    floor; the fleet total stays pinned to the budget."""
    cfg = FleetConfig(budget=0.6, floor=0.1, ceiling=0.9, rebalance_ticks=4)
    pipe, server, fc = _fleet(cfg)
    fc.add_stream("busy", "cam")
    fc.add_stream("static", "cam")
    # right after admission both weigh in at full activity -> equal split
    assert fc._members["busy"].allocation == pytest.approx(0.3)
    assert fc._members["static"].allocation == pytest.approx(0.3)
    cam, still = _busy(), _static_frame()
    for _ in fc.run({"busy": cam.frame_at(t), "static": still}
                    for t in range(24)):
        pass
    m_busy, m_static = fc._members["busy"], fc._members["static"]
    assert m_busy.activity > m_static.activity
    assert m_busy.allocation > m_static.allocation
    assert m_busy.allocation + m_static.allocation == pytest.approx(
        cfg.budget
    )
    # each share was pushed into that stream's servo as its new target
    for m in (m_busy, m_static):
        assert m.session.controller.config.target == pytest.approx(
            m.allocation
        )
    assert fc.rebalances >= 24 // cfg.rebalance_ticks


def test_retarget_is_bumpless():
    """A rebalance re-points the servo without resetting its state."""
    _, server, fc = _fleet(FleetConfig(budget=0.6, floor=0.1))
    fc.add_stream("s0", "cam")
    cam = _busy(seed=5)
    list(fc.serve("s0", (cam.frame_at(t) for t in range(6))))
    ctl = server.sessions["s0"].controller
    ema, hist, thr = ctl.ema, len(ctl.history), ctl.threshold
    assert hist == 6 and ema is not None
    ctl.retarget(0.123)
    assert ctl.config.target == 0.123
    assert ctl.ema == ema and len(ctl.history) == hist
    assert ctl.threshold == thr        # actuation waits for an observation
    ctl.retarget(0.123)                # no-op on an unchanged target
    assert ctl.config.target == 0.123
    with pytest.raises(ValueError):
        ctl.retarget(0.0)              # GateControllerConfig re-validates


def test_segment_serving_rebalances_every_boundary():
    cfg = FleetConfig(budget=0.6, floor=0.1, rebalance_ticks=1000)
    _, server, fc = _fleet(cfg)
    fc.add_stream("s0", "cam")
    before = fc.rebalances
    cam = _busy(seed=6)
    frames = np.stack([cam.frame_at(t) for t in range(12)])
    got = list(fc.serve_segments("s0", frames, segment_length=4))
    assert len(got) == 12
    # one re-solve per boundary (the only point a traced threshold moves),
    # regardless of the per-tick cadence
    assert fc.rebalances - before == 3
    assert fc._members["s0"].ticks_observed == 12


def test_fleet_segment_serving_matches_plain_server():
    """Arbitration wraps serving without perturbing a single-stream trace:
    with one admitted stream the allocation is budget-clamped once at
    admission, after which results must match a plain server given the same
    initial target."""
    cfg = FleetConfig(budget=0.4, floor=0.1, ceiling=0.4)
    _, _, fc = _fleet(cfg)
    fc.add_stream("s0", "cam")
    cam = _busy(seed=9)
    frames = np.stack([cam.frame_at(t) for t in range(8)])
    got = list(fc.serve_segments("s0", frames, segment_length=4))
    ref_srv = StreamServer(
        _pipeline(), gate=GATE,
        controller=GateControllerConfig(target=0.4),
    )
    ref_srv.add_stream("s0", "cam")
    ref = list(ref_srv.serve_segments("s0", frames, segment_length=4))
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        assert a.frame_idx == b.frame_idx
        assert a.kept_windows == b.kept_windows
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.block_mask, b.block_mask)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_rejects_over_capacity_and_reconciles():
    cfg = FleetConfig(budget=0.6, floor=0.2)     # capacity 3
    pipe, server, fc = _fleet(cfg)
    assert fc.capacity == 3
    for i in range(3):
        assert fc.add_stream(f"s{i}", "cam") is not None
    with pytest.raises(FleetAdmissionError):
        fc.add_stream("s3", "cam")
    with pytest.raises(FleetAdmissionError):
        fc.add_stream("s4", "cam")
    assert fc.rejections == 2
    assert len(server.sessions) == 3             # rejected streams left no trace
    # rejected admissions must not skew any stats surface
    assert_reconciled(pipe, server)


def test_admission_queue_fifo():
    cfg = FleetConfig(budget=0.6, floor=0.2, admission="queue")
    _, server, fc = _fleet(cfg)
    for i in range(3):
        fc.add_stream(f"s{i}", "cam")
    assert fc.add_stream("s3", "cam", priority=2.0) is None
    assert fc.add_stream("s4", "cam") is None
    assert fc.queued == ("s3", "s4")
    assert fc.rejections == 2
    admitted = fc.remove_stream("s1")
    assert [s.stream_id for s in admitted] == ["s3"]   # FIFO
    assert fc.queued == ("s4",)
    assert "s3" in server.sessions and "s1" not in server.sessions
    assert fc._members["s3"].priority == 2.0           # kwargs survived the queue
    # freeing two slots admits the rest
    admitted = fc.remove_stream("s2")
    assert [s.stream_id for s in admitted] == ["s4"]
    assert fc.queued == ()


def test_duplicate_and_invalid_admissions():
    _, server, fc = _fleet(FleetConfig(budget=0.6, floor=0.1))
    fc.add_stream("s0", "cam")
    with pytest.raises(ValueError, match="already admitted"):
        fc.add_stream("s0", "cam")
    with pytest.raises(ValueError, match="priority"):
        fc.add_stream("s1", "cam", priority=0.0)
    with pytest.raises(KeyError):
        fc.remove_stream("ghost")
    # a fleet stream without a servo has no actuator: rejected AND rolled back
    srv_plain = StreamServer(_pipeline(), gate=GATE)   # no controller default
    fc2 = FleetController(srv_plain, FleetConfig(budget=0.6, floor=0.1))
    with pytest.raises(ValueError, match="GateController"):
        fc2.add_stream("s0", "cam")
    assert "s0" not in srv_plain.sessions


# ---------------------------------------------------------------------------
# telemetry rollups + reporting
# ---------------------------------------------------------------------------


def test_allocation_gauges_sum_to_budget():
    cfg = FleetConfig(budget=0.6, floor=0.1)
    _, _, fc = _fleet(cfg)
    fc.add_stream("g0", "cam")
    fc.add_stream("g1", "cam", priority=3.0)
    reg = telemetry.registry()
    rows = {
        labels["stream"]: value
        for name, _k, labels, value in reg.collect()
        if name == "fpca_fleet_allocation" and labels.get("stream") in
        ("g0", "g1")
    }
    assert sum(rows.values()) == pytest.approx(cfg.budget)
    assert rows["g1"] > rows["g0"]               # priority weighs in pre-serving
    budget = [v for n, _k, _l, v in reg.collect() if n == "fpca_fleet_budget"]
    assert budget == [pytest.approx(cfg.budget)]


def test_idle_stream_round_trips_strict_json():
    """An admitted-but-never-served stream (0 executed windows) flows
    through the arbitration table and fleet report with None sentinels —
    never Infinity (the strict-JSON writer would refuse it)."""
    pipe, server, fc = _fleet(FleetConfig(budget=0.6, floor=0.1))
    fc.add_stream("idle", "cam")
    cam = _busy(seed=8)
    fc.add_stream("live", "cam")
    list(fc.serve("live", (cam.frame_at(t) for t in range(4))))
    table = fc.arbitration_table()
    rows = {r["stream"]: r for r in table["streams"]}
    assert rows["idle"]["activity"] is None      # never observed
    assert rows["idle"]["ticks_observed"] == 0
    assert rows["live"]["activity"] is not None
    report = fleet_report(server, fleet=fc)
    text = json.dumps(report, allow_nan=False)   # strict RFC 8259
    assert "Infinity" not in text and "NaN" not in text
    assert report["arbitration"]["admitted"] == 2
    rendered = render_fleet_report(report)
    assert "arbitration: budget 0.6" in rendered
    assert "idle: prio 1" in rendered


def test_removed_stream_zeroes_its_gauges():
    _, _, fc = _fleet(FleetConfig(budget=0.6, floor=0.1))
    fc.add_stream("r0", "cam")
    fc.add_stream("r1", "cam")
    fc.remove_stream("r0")
    rows = {
        labels["stream"]: value
        for name, _k, labels, value in telemetry.registry().collect()
        if name == "fpca_fleet_allocation" and labels.get("stream") in
        ("r0", "r1")
    }
    assert rows["r0"] == 0.0
    assert rows["r1"] == pytest.approx(0.6)      # sole survivor takes it all


# ---------------------------------------------------------------------------
# multi-device sharding (8 emulated devices in the CI lane, adapts locally)
# ---------------------------------------------------------------------------


def test_sharded_fleet_serving_matches_unsharded():
    """The fused union-masked fleet batch shards over the mesh data axes
    bit-identically, with gate + arbitration state host-local.  Under the CI
    lane's XLA_FLAGS this runs with data=8; locally with whatever exists."""
    ndev = jax.device_count()
    mesh = make_host_mesh(data=ndev)
    cfg = FleetConfig(budget=0.6, floor=0.1, rebalance_ticks=4)
    cams = {f"cam{i}": _busy(seed=10 + i) for i in range(3)}

    def _serve(mesh_arg):
        pipe, server, fc = _fleet(cfg, mesh=mesh_arg)
        for sid in cams:
            fc.add_stream(sid, "cam")
        out = [
            r
            for results in fc.run(
                {sid: cam.frame_at(t) for sid, cam in cams.items()}
                for t in range(10)
            )
            for r in results
        ]
        return pipe, server, fc, out

    pipe_m, server_m, fc_m, got = _serve(mesh)
    _, _, fc_p, ref = _serve(None)
    assert len(got) == len(ref) == 30
    for a, b in zip(got, ref):
        assert (a.stream_id, a.frame_idx) == (b.stream_id, b.frame_idx)
        assert a.kept_windows == b.kept_windows
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.block_mask, b.block_mask)
    # arbitration solved identically on both sides
    for sid in cams:
        assert fc_m._members[sid].allocation == pytest.approx(
            fc_p._members[sid].allocation
        )
    # the compiled handles really shard over every (virtual) device...
    handles = list(pipe_m._handles.values())
    assert handles and all(h.data_parallelism == ndev for h in handles)
    # ...while gate state stays host-local per stream
    for session in server_m.sessions.values():
        assert isinstance(session._prev, np.ndarray)
    assert_reconciled(pipe_m, server_m)


def test_data_parallelism_property_unsharded():
    pipe, server, fc = _fleet(FleetConfig(budget=0.6, floor=0.1))
    fc.add_stream("s0", "cam")
    cam = _busy(seed=11)
    list(fc.serve("s0", (cam.frame_at(t) for t in range(2))))
    handles = list(pipe._handles.values())
    assert handles and all(h.data_parallelism == 1 for h in handles)
