"""flash_attention + ssd Pallas kernels vs oracles (interpret mode)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas, flash_attention_ref
from repro.kernels.ssd import ssd_chunked_pallas, ssd_intra_chunk_pallas, ssd_intra_chunk_ref
from repro.models.ssm import ssd_chunked


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def _qkv(b, sq, sk, h, kv, d, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, sq, h, d), dtype)
    k = jax.random.normal(k2, (b, sk, kv, d), dtype)
    v = jax.random.normal(k3, (b, sk, kv, d), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "b,s,h,kv,d,bq,bk,causal,window",
    [
        (1, 256, 4, 4, 64, 128, 128, True, None),    # MHA causal, exact tiles
        (2, 200, 8, 2, 32, 64, 64, True, None),      # GQA, ragged seq
        (1, 256, 4, 1, 64, 128, 64, False, None),    # MQA, bidirectional
        (1, 300, 4, 2, 128, 128, 128, True, 64),     # sliding window
    ],
)
def test_flash_matches_ref(b, s, h, kv, d, bq, bk, causal, window):
    q, k, v = _qkv(b, s, s, h, kv, d)
    got = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=bq, block_k=bk, interpret=True
    )
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)])
def test_flash_dtype_sweep(dtype, tol):
    q, k, v = _qkv(1, 192, 192, 4, 2, 64, dtype=dtype, seed=1)
    got = flash_attention_pallas(q, k, v, block_q=64, block_k=64, interpret=True)
    want = flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )


def test_flash_cross_attention_shapes():
    """Sq != Sk (cross attention / prefix reuse)."""
    q, _, _ = _qkv(2, 64, 64, 4, 2, 32, seed=2)
    k2 = jax.random.normal(jax.random.PRNGKey(4), (2, 160, 2, 32))
    v2 = jax.random.normal(jax.random.PRNGKey(5), (2, 160, 2, 32))
    got = flash_attention_pallas(q, k2, v2, causal=False, block_q=64, block_k=64, interpret=True)
    want = flash_attention_ref(q, k2, v2, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------


def _ssd_inputs(b, l, h, p, g, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, g, n))
    C = jax.random.normal(ks[4], (b, l, g, n))
    return x, dt, A, B, C


@pytest.mark.parametrize(
    "b,l,h,p,n,chunk",
    [
        (1, 128, 4, 64, 64, 64),     # two chunks
        (2, 96, 2, 32, 16, 32),      # three chunks, small dims
        (1, 64, 8, 64, 128, 64),     # single chunk, wide state
    ],
)
def test_ssd_pallas_matches_model(b, l, h, p, n, chunk):
    x, dt, A, B, C = _ssd_inputs(b, l, h, p, 1, n)
    y_ref, s_ref = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y_got, s_got = ssd_chunked_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref), rtol=2e-3, atol=2e-3)


def test_ssd_intra_kernel_vs_oracle():
    b, nc, q, h, p, n = 2, 3, 32, 4, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    xbar = jax.random.normal(ks[0], (b, nc, q, h, p))
    Bh = jax.random.normal(ks[1], (b, nc, q, h, n))
    Ch = jax.random.normal(ks[2], (b, nc, q, h, n))
    cum = -jnp.cumsum(jax.nn.softplus(jax.random.normal(ks[3], (b, nc, q, h))), axis=2)
    y_ref, s_ref, _ = ssd_intra_chunk_ref(xbar, Bh, Ch, cum)
    y_got, s_got = ssd_intra_chunk_pallas(xbar, Bh, Ch, cum, interpret=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
    # kernel returns (N, P)-major states
    np.testing.assert_allclose(
        np.asarray(s_got.transpose(0, 1, 2, 4, 3)), np.asarray(s_ref), rtol=1e-4, atol=1e-4
    )


def test_ssd_initial_state_carries():
    """Chaining two halves through initial_state == one full pass (the
    invariant serving relies on)."""
    x, dt, A, B, C = _ssd_inputs(1, 64, 2, 16, 1, 8, seed=9)
    y_full, s_full = ssd_chunked(x, dt, A, B, C, chunk=16)
    y1, s1 = ssd_chunked_pallas(
        x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], chunk=16, interpret=True
    )
    y2, s2 = ssd_chunked_pallas(
        x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:],
        chunk=16, initial_state=s1, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 32:]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# flash attention backward kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,s,h,kv,d,bq,bk,causal,window",
    [
        (1, 192, 4, 4, 32, 64, 64, True, None),    # MHA causal
        (2, 160, 4, 2, 32, 64, 64, True, None),    # GQA (group sum path)
        (1, 128, 4, 1, 64, 64, 64, False, None),   # MQA bidirectional
        (1, 200, 2, 2, 32, 64, 64, True, 48),      # sliding window, ragged
    ],
)
def test_flash_bwd_kernel_matches_autodiff(b, s, h, kv, d, bq, bk, causal, window):
    from repro.kernels.flash_attention.bwd_kernel import flash_attention_bwd_pallas
    from repro.models.attention import _flash_fwd_impl, _grouped

    q, k, v = _qkv(b, s, s, h, kv, d, seed=3)
    g = jax.random.normal(jax.random.PRNGKey(9), (b, s, h, d))

    # reference grads through the (already validated) full-attention path
    def loss(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, causal=causal, window=window) * g)

    dq_ref, dk_ref, dv_ref = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    out, lse = _flash_fwd_impl(q, k, v, causal, window, bk)
    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, out, lse, g, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dq_ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk_ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_ref), rtol=2e-3, atol=2e-3)
