"""Streaming subsystem: in-kernel region skipping, delta gate, serving loop.

Contracts pinned here:

* **Compute-real masking** — the window-compacted fused path (both the
  Pallas kernel in interpret mode and the XLA basis lowering) returns counts
  bit-identical to the dense reference on kept windows and exact zeros on
  skipped windows, across the reconfiguration grid (full sweep marked slow,
  a smoke subset in the fast lane).
* **Delta gate** — keyframes keep everything, static scenes go quiet,
  changed blocks stay live for exactly ``hysteresis`` extra frames.
* **Serving loop** — the double-buffered server yields results strictly in
  frame order regardless of depth, and multi-stream fan-in (one device batch
  for many cameras) matches looped single-stream serving bit-for-bit.
* **Cross-config batching** — configs sharing a compile signature merge into
  one channel-stacked call with unchanged per-request results.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import analysis
from repro.core.fpca_sim import fpca_forward
from repro.core.mapping import FPCASpec, active_window_mask, output_dims
from repro.data.pipeline import SyntheticMovingObject
from repro.kernels.fpca_conv.ops import fpca_conv, window_bucket
from repro.serving.fpca_pipeline import FPCAPipeline, FrontendRequest
from repro.serving.saliency import saliency_mask
from repro.serving.streaming import (
    DeltaGateConfig,
    GateControllerConfig,
    StreamServer,
    StreamSession,
    block_delta_mask,
)

H = W = 24


def _spec(kernel: int = 5, stride: int = 5, binning: int = 1) -> FPCASpec:
    return FPCASpec(
        image_h=H, image_w=W, out_channels=4, kernel=kernel, stride=stride,
        binning=binning,
    )


def _sparse_block_mask(spec: FPCASpec) -> np.ndarray:
    """Keep only the top-left block — actually exercises the gather path."""
    bh = -(-spec.eff_h // spec.skip_block)
    bw = -(-spec.eff_w // spec.skip_block)
    mask = np.zeros((bh, bw), bool)
    mask[0, 0] = True
    return mask


def _data(spec: FPCASpec, batch: int = 2, seed: int = 0):
    rng = np.random.default_rng(seed)
    images = rng.uniform(0, 1, (batch, H, W, spec.in_channels)).astype(np.float32)
    k = spec.kernel
    kernel = (rng.normal(size=(spec.out_channels, k, k, spec.in_channels)) * 0.2
              ).astype(np.float32)
    return images, kernel


def _assert_masked_parity(bucket_model, spec, backend, block_mask):
    images, kernel = _data(spec)
    common = dict(model=bucket_model, mode="bucket_sigmoid", hard=True)
    dense = np.asarray(
        fpca_forward(images, kernel, spec, **common)["counts"]
    )
    kw = {"interpret": True} if backend == "pallas" else {}
    got = np.asarray(
        fpca_forward(
            images, kernel, spec, backend=backend, block_mask=block_mask,
            **kw, **common,
        )["counts"]
    )
    keep = active_window_mask(spec, block_mask)
    np.testing.assert_array_equal(got[:, keep], dense[:, keep])
    assert np.all(got[:, ~keep] == 0)


# ---------------------------------------------------------------------------
# in-kernel region skipping: masked vs dense, bit-exact on kept windows
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["basis", "pallas"])
def test_masked_parity_smoke(bucket_model, backend):
    """Fast-lane streaming smoke: sparse mask through the compacted path."""
    spec = _spec(5, 5, 1)
    _assert_masked_parity(bucket_model, spec, backend, _sparse_block_mask(spec))


PARITY_GRID = [
    (kernel, stride, binning)
    for kernel in (3, 5)
    for stride in (kernel, 2)
    for binning in (1, 2)
]


@pytest.mark.slow
@pytest.mark.parametrize("kernel,stride,binning", PARITY_GRID)
@pytest.mark.parametrize("backend", ["basis", "pallas"])
def test_masked_parity_full_grid(bucket_model, kernel, stride, binning, backend):
    """Full reconfiguration grid x both fused backends (streaming sweep)."""
    spec = _spec(kernel, stride, binning)
    _assert_masked_parity(bucket_model, spec, backend, _sparse_block_mask(spec))


def test_window_bucket_bounded_pow2():
    assert window_bucket(1, 400) == 1
    assert window_bucket(3, 400) == 4
    assert window_bucket(129, 400) == 256
    assert window_bucket(300, 400) == 400   # capped -> dense fallback
    assert window_bucket(0, 400) == 1       # empty mask still a valid bucket


def test_pipeline_masked_request_skips_compute(bucket_model):
    """The scheduler executes only the kept-window bucket, not the grid."""
    spec = _spec()
    _, kernel = _data(spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    h_o, w_o = output_dims(spec)
    mask = _sparse_block_mask(spec)
    img = _data(spec, batch=1)[0][0]
    out = pipe.submit([FrontendRequest("cam", img, block_mask=mask)])[0]
    keep = active_window_mask(spec, mask)
    dense = pipe.submit([FrontendRequest("cam", img)])[0]
    np.testing.assert_array_equal(np.asarray(out)[keep], np.asarray(dense)[keep])
    assert np.all(np.asarray(out)[~keep] == 0)
    # 2 batches: the masked one ran a pow2 bucket < full grid, the dense one
    # the whole grid
    assert pipe.stats.windows_executed < pipe.stats.windows_total
    assert pipe.stats.windows_executed < h_o * w_o + window_bucket(
        int(keep.sum()), h_o * w_o
    ) + 1


# ---------------------------------------------------------------------------
# temporal delta gate
# ---------------------------------------------------------------------------


def _flat_frames(spec, n, value=0.5):
    return [np.full((H, W, 3), value, np.float32) for _ in range(n)]


def test_block_delta_mask_localises_change():
    spec = _spec()
    a = np.full((spec.eff_h, spec.eff_w), 0.5, np.float32)
    b = a.copy()
    b[:8, 8:16] += 0.2                      # bump exactly block (0, 1)
    mask = block_delta_mask(a, b, spec, threshold=0.05)
    want = np.zeros_like(mask)
    want[0, 1] = True
    np.testing.assert_array_equal(mask, want)


def test_delta_gate_keyframe_and_hysteresis():
    spec = _spec()
    gate = DeltaGateConfig(threshold=0.05, hysteresis=1, keyframe_interval=6)
    from repro.serving.streaming import StreamSession

    session = StreamSession("s", "cam", spec, gate)
    frames = _flat_frames(spec, 10)
    # frame 2 changes one block, everything else is static
    frames[2] = frames[2].copy()
    frames[2][:8, :8] += 0.3
    masks = [session.step(f) for f in frames]
    assert masks[0].all()                   # first frame = keyframe
    assert not masks[1].any()               # static scene goes quiet
    assert masks[2][0, 0] and masks[2].sum() == 1       # change detected
    assert masks[3][0, 0] and masks[3].sum() == 1       # hysteresis frame 1
    # frame 4: change was 2 frames ago (> hysteresis) AND the bumped frame
    # reverting also registers as a change at frame 3 -> block lives one
    # extra pair, then dies
    assert masks[4][0, 0] and masks[4].sum() == 1       # revert delta + hyst
    assert not masks[5].any()
    assert masks[6].all()                   # keyframe refresh at interval 6
    assert not masks[7].any()               # ...and quiet again right after


def test_delta_gate_disabled_session_is_dense():
    from repro.serving.streaming import StreamSession

    session = StreamSession("s", "cam", _spec(), None)
    assert session.step(np.zeros((H, W, 3), np.float32)) is None


# ---------------------------------------------------------------------------
# double-buffered serving loop
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def stream_pipe(bucket_model):
    """One pipeline (and executable cache) shared by all serving-loop tests."""
    spec = _spec()
    _, kernel = _data(spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    return pipe


def _make_server(pipe, n_streams=1, **server_kw):
    server = StreamServer(
        pipe, DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=8),
        **server_kw,
    )
    for i in range(n_streams):
        server.add_stream(f"s{i}", "cam")
    return server


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_double_buffer_results_in_frame_order(stream_pipe, depth):
    """Results come back tick-ordered for any in-flight depth, and the depth
    never changes the numbers."""
    server = _make_server(stream_pipe, depth=depth)
    stream = SyntheticMovingObject((H, W), seed=3, radius=4.0)
    results = list(server.serve("s0", stream.frames(7)))
    assert [r.frame_idx for r in results] == list(range(7))
    ref_server = _make_server(stream_pipe, depth=1)
    ref = list(ref_server.serve("s0", stream.frames(7)))
    for a, b in zip(results, ref):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_multi_stream_fan_in_matches_looped_single_stream(stream_pipe):
    """Two cameras in one device batch == each camera served alone."""
    server = _make_server(stream_pipe, n_streams=2, depth=2)
    cams = {
        "s0": SyntheticMovingObject((H, W), seed=4, radius=4.0),
        "s1": SyntheticMovingObject((H, W), seed=5, radius=4.0),
    }
    ticks = [{sid: cam.frame_at(t) for sid, cam in cams.items()} for t in range(5)]
    fanned = [r for results in server.run(ticks) for r in results]
    for sid, cam in cams.items():
        solo_server = _make_server(stream_pipe, depth=2)
        solo = list(solo_server.serve("s0", cam.frames(5)))
        mine = [r for r in fanned if r.stream_id == sid]
        assert [r.frame_idx for r in mine] == list(range(5))
        for a, b in zip(mine, solo):
            np.testing.assert_array_equal(a.counts, b.counts)
            np.testing.assert_array_equal(a.block_mask, b.block_mask)


def test_stream_server_gated_faster_windows_than_dense(stream_pipe):
    """The gate's executed-window count actually drops below dense."""
    server = _make_server(stream_pipe, depth=2)
    stream = SyntheticMovingObject((H, W), seed=6, radius=4.0)
    list(server.serve("s0", stream.frames(6)))
    assert server.stats.windows_kept < server.stats.windows_total
    assert stream_pipe.stats.windows_executed < stream_pipe.stats.windows_total
    rep = server.sessions["s0"].energy_report()
    assert rep["frames"] == 6
    assert 0 < rep["kept_window_frac"] < 1
    assert rep["energy_vs_dense"] < 1 and rep["latency_vs_dense"] <= 1


def test_stream_server_unknown_stream_or_config():
    from repro.core.curvefit import BucketCurvefitModel  # noqa: F401  (import path smoke)

    pipe = FPCAPipeline(backend="basis")
    server = StreamServer(pipe)
    with pytest.raises(KeyError):
        server.add_stream("s0", "nope")


# ---------------------------------------------------------------------------
# cross-config channel batching
# ---------------------------------------------------------------------------


def test_cross_config_batching_merges_and_matches(bucket_model):
    spec = _spec()
    rng = np.random.default_rng(11)
    kA = (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32)
    kB = (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32)
    img0 = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    img1 = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    reqs = [
        FrontendRequest("A", img0),
        FrontendRequest("B", img1),
        FrontendRequest("A", img1, block_mask=_sparse_block_mask(spec)),
    ]

    plain = FPCAPipeline(bucket_model, backend="basis")
    plain.register("A", spec, kA)
    plain.register("B", spec, kB)
    want = plain.submit(reqs)
    assert plain.stats.batches == 2 and plain.stats.merged_groups == 0

    merged = FPCAPipeline(bucket_model, backend="basis", cross_config_batching=True)
    merged.register("A", spec, kA)
    merged.register("B", spec, kB)
    got = merged.submit(reqs)
    assert merged.stats.batches == 1 and merged.stats.merged_groups == 1
    for a, b in zip(got, want):
        assert a.shape == (4, 4, 4)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cross_config_batching_leaves_distinct_specs_alone(bucket_model):
    specA, specB = _spec(5, 5, 1), _spec(3, 2, 1)
    rng = np.random.default_rng(12)
    pipe = FPCAPipeline(bucket_model, backend="basis", cross_config_batching=True)
    pipe.register("A", specA, (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32))
    pipe.register("B", specB, (rng.normal(size=(4, 3, 3, 3)) * 0.2).astype(np.float32))
    img = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    res = pipe.submit([FrontendRequest("A", img), FrontendRequest("B", img)])
    assert pipe.stats.batches == 2 and pipe.stats.merged_groups == 0
    assert res[0].shape == (4, 4, 4)
    h_o, w_o = output_dims(specB)
    assert res[1].shape == (h_o, w_o, 4)


# ---------------------------------------------------------------------------
# saliency (library home of the former example helper)
# ---------------------------------------------------------------------------


def test_saliency_mask_shape_and_fraction():
    spec = _spec()
    rng = np.random.default_rng(13)
    img = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    mask = saliency_mask(img, spec, keep_frac=0.4)
    bh = -(-spec.eff_h // spec.skip_block)
    bw = -(-spec.eff_w // spec.skip_block)
    assert mask.shape == (bh, bw) and mask.dtype == bool
    assert 1 <= mask.sum() <= mask.size


def test_saliency_mask_binned_grid():
    spec = _spec(5, 5, binning=2)
    rng = np.random.default_rng(14)
    img = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    mask = saliency_mask(img, spec, keep_frac=0.5)
    bh = -(-spec.eff_h // spec.skip_block)
    bw = -(-spec.eff_w // spec.skip_block)
    assert mask.shape == (bh, bw)


# ---------------------------------------------------------------------------
# bucket-edge bitwise parity: the flap-prone kept counts the grid never pins
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["basis", "pallas"])
def test_masked_parity_at_bucket_edges(bucket_model, backend):
    """Bit-exact masked-vs-dense at n_keep = 0, 1, pow2-1, pow2, pow2+1, M.

    These kept counts sit exactly on the bucket boundaries (window_bucket
    transitions), where an off-by-one in the gather/row-validity logic would
    truncate a kept window or leak a padding row — the PR-2 parity grid only
    ever exercised one sparse mask far from the edges.
    """
    spec = _spec(5, 5, 1)
    images, kernel = _data(spec, batch=2)
    h_o, w_o = output_dims(spec)
    M = images.shape[0] * h_o * w_o
    dense = np.asarray(
        fpca_forward(
            images, kernel, spec, model=bucket_model, mode="bucket_sigmoid",
            hard=True,
        )["counts"]
    )
    kw = {"interpret": True} if backend == "pallas" else {}
    pow2 = 8
    rng = np.random.default_rng(21)
    scatter = rng.permutation(M)
    for n_keep in (0, 1, pow2 - 1, pow2, pow2 + 1, M):
        flat = np.zeros(M, bool)
        flat[scatter[:n_keep]] = True
        wm = flat.reshape(images.shape[0], h_o, w_o)
        got = np.asarray(
            fpca_conv(
                images, kernel, bucket_model, spec=spec, impl=backend,
                window_mask=wm, **kw,
            )
        )
        np.testing.assert_array_equal(got[wm], dense[wm], err_msg=f"n_keep={n_keep}")
        assert np.all(got[~wm] == 0), f"n_keep={n_keep}"


# ---------------------------------------------------------------------------
# zero-kept ticks: short-circuit, and the accounting stays division-safe
# ---------------------------------------------------------------------------


def test_masked_call_with_full_bucket_stays_trace_safe(bucket_model):
    """With an explicit full-size m_bucket the mask is never materialised on
    host, so the masked entry point still jits over traced masks (the
    zero-keep short-circuit must not regress this)."""
    import jax

    spec = _spec()
    images, kernel = _data(spec, batch=1)
    h_o, w_o = output_dims(spec)
    M = h_o * w_o

    @jax.jit
    def run(imgs, mask):
        return fpca_conv(
            imgs, kernel, bucket_model, spec=spec, impl="basis",
            window_mask=mask, m_bucket=M,
        )

    keep = np.zeros((1, h_o, w_o), bool)
    keep[0, 0, 0] = True
    out = np.asarray(run(images, keep))          # traces without concretising
    dense = np.asarray(
        fpca_forward(
            images, kernel, spec, model=bucket_model, mode="bucket_sigmoid",
            hard=True,
        )["counts"]
    )
    np.testing.assert_array_equal(out[keep], dense[keep])
    assert np.all(out[~keep] == 0)


@pytest.mark.parametrize("backend", ["basis", "pallas"])
def test_compacted_kernel_handles_zero_valid_rows(bucket_model, backend):
    """The in-kernel gather/row-validity path at zero valid rows.

    An eager all-false mask short-circuits on host before any launch, so
    this is only reachable through a pre-built bucketed executable (the
    serving cache's entry point, whose masks enter traced) — the kernel then
    runs a bucket whose every row is padding and the epilogue must still
    produce exact zeros."""
    import jax.numpy as jnp

    from repro.kernels.fpca_conv.ops import make_fpca_conv_executable

    spec = _spec()
    images, kernel = _data(spec, batch=1)
    h_o, w_o = output_dims(spec)
    kw = {"interpret": True} if backend == "pallas" else {}
    run_exe = make_fpca_conv_executable(
        bucket_model, spec=spec, impl=backend, m_bucket=8, **kw  # 8 < M
    )
    bn = jnp.zeros((spec.out_channels,), jnp.float32)

    def run(imgs, mask):
        return run_exe(jnp.asarray(imgs), jnp.asarray(kernel), bn, jnp.asarray(mask))

    out = np.asarray(run(images, np.zeros((1, h_o, w_o), bool)))
    assert out.shape == (1, h_o, w_o, spec.out_channels)
    assert np.all(out == 0)
    # ...and with valid rows present, the same jitted bucket stays bit-exact
    keep = np.zeros((1, h_o, w_o), bool)
    keep.flat[[0, 3, 7]] = True
    got = np.asarray(run(images, keep))
    dense = np.asarray(
        fpca_forward(
            images, kernel, spec, model=bucket_model, mode="bucket_sigmoid",
            hard=True,
        )["counts"]
    )
    np.testing.assert_array_equal(got[keep], dense[keep])
    assert np.all(got[~keep] == 0)


def test_zero_kept_tick_short_circuits_without_launch(bucket_model):
    spec = _spec()
    _, kernel = _data(spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    h_o, w_o = output_dims(spec)
    img = _data(spec, batch=1)[0]
    before = (pipe.stats.batches, pipe.stats.windows_executed)
    out = pipe.run_config_batch("cam", img, np.zeros((1, h_o, w_o), bool))
    assert out.shape == (1, h_o, w_o, spec.out_channels)
    assert np.all(np.asarray(out) == 0)
    # no fused call was dispatched and no window was executed
    assert pipe.stats.batches == before[0]
    assert pipe.stats.windows_executed == before[1]
    assert pipe.stats.launches_skipped == 1


def test_zero_kept_accounting_no_division_by_zero():
    spec = _spec()
    bh = -(-spec.eff_h // spec.skip_block)
    bw = -(-spec.eff_w // spec.skip_block)
    empty = np.zeros((bh, bw), bool)
    lat = analysis.frontend_latency(spec, block_mask=empty)
    assert lat["n_cycles"] == 0 and lat["t_total"] == 0
    # zero work executed -> fps is the None sentinel (never Infinity: the
    # strict-JSON artifact writer rejects non-finite floats)
    assert lat["fps"] is None
    rep = analysis.streaming_frontend_report(spec, [empty, empty])
    assert rep["executed_windows"] == 0 and rep["executed_cycles"] == 0
    assert rep["kept_window_frac"] == 0 and rep["energy_vs_dense"] == 0
    assert rep["fps_effective"] is None
    json.dumps(rep, allow_nan=False)   # idle stream round-trips strict JSON
    # ...and through the session-level report
    session = StreamSession("s", "cam", spec, DeltaGateConfig())
    session.block_masks.extend([empty, empty])
    srep = session.energy_report()
    assert srep["executed_windows"] == 0
    assert srep["fps_effective"] is None


def test_all_skipped_stream_ticks_skip_launches(bucket_model):
    """A static scene (no keyframes) produces all-skipped ticks end to end."""
    spec = _spec()
    _, kernel = _data(spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    server = StreamServer(
        pipe, DeltaGateConfig(threshold=0.05, hysteresis=0, keyframe_interval=0)
    )
    server.add_stream("s0", "cam")
    frame = np.full((H, W, 3), 0.5, np.float32)
    results = list(server.serve("s0", [frame] * 4))
    assert [r.kept_windows for r in results[1:]] == [0, 0, 0]
    assert all(np.all(r.counts == 0) for r in results[1:])
    assert server.stats.launches_skipped == 3


def test_serve_seconds_brackets_hand_timed_wall_clock(bucket_model):
    """``serve_seconds`` accumulates exactly the dispatch+finalize halves of
    each tick, so it is positive and never exceeds an enclosing hand-timed
    bracket; ``fps_wall`` derives from it (and is the None sentinel on a
    server that has never served)."""
    import time

    from repro.serving.observe import fleet_report

    spec = _spec()
    _, kernel = _data(spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    server = StreamServer(pipe, DeltaGateConfig(threshold=0.05))
    server.add_stream("s0", "cam")
    assert server.stats.serve_seconds == 0
    assert fleet_report(server)["fleet"]["fps_wall"] is None
    frames = _data(spec, batch=6, seed=2)[0]
    t0 = time.perf_counter()
    results = list(server.serve("s0", frames))
    elapsed = time.perf_counter() - t0
    assert len(results) == 6
    assert 0 < server.stats.serve_seconds <= elapsed
    rep = fleet_report(server)["fleet"]
    assert rep["fps_wall"] == pytest.approx(6 / server.stats.serve_seconds)


def test_serve_seconds_billed_when_serving_raises(bucket_model):
    """The billing is single-exit (try/finally): a tick that raises
    mid-dispatch still accounts the wall time already spent, so fps_wall
    stays honest across failures."""
    spec = _spec()
    _, kernel = _data(spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    server = StreamServer(pipe, DeltaGateConfig(threshold=0.05))
    server.add_stream("s0", "cam")
    good = _data(spec, batch=1, seed=3)[0][0]
    bad = np.zeros((7, 7, 3), np.float32)          # wrong sensor geometry
    with pytest.raises((ValueError, TypeError)):
        list(server.serve("s0", [good, bad]))
    assert server.stats.serve_seconds > 0
    # ...and segment mode bills through the same contract
    before = server.stats.serve_seconds
    with pytest.raises((ValueError, TypeError)):
        server.run_segment("s0", np.zeros((2, 7, 7, 3), np.float32))
    assert server.stats.serve_seconds > before


# ---------------------------------------------------------------------------
# sticky bucket hysteresis through the serving stack
# ---------------------------------------------------------------------------


def test_sticky_buckets_cut_switches_with_identical_outputs(bucket_model):
    """Keyframe-driven bucket flaps: patience rides them out, counts match."""
    spec = _spec()
    _, kernel = _data(spec)
    gate = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=4)
    stream = SyntheticMovingObject((H, W), seed=8, radius=4.0)

    def serve(patience):
        pipe = FPCAPipeline(bucket_model, backend="basis", bucket_patience=patience)
        pipe.register("cam", spec, kernel)
        server = StreamServer(pipe, gate)
        server.add_stream("s0", "cam")
        results = list(server.serve("s0", stream.frames(12)))
        return results, server

    flap, flap_server = serve(1)
    sticky, sticky_server = serve(8)
    # identical gate decisions, bit-identical activations
    for a, b in zip(flap, sticky):
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.block_mask, b.block_mask)
    # keyframes force the dense bucket every 4 ticks: the stateless pipeline
    # flaps down after each, the sticky one holds
    assert flap_server.stats.bucket_switches > 0
    assert sticky_server.stats.bucket_switches < flap_server.stats.bucket_switches
    assert sticky_server.stats.bucket_shrinks_deferred > 0


# ---------------------------------------------------------------------------
# keep-fraction servo: convergence on the synthetic stream (§ acceptance)
# ---------------------------------------------------------------------------


def test_controller_converges_to_keep_budget():
    """The servo lands the kept fraction within ±20% of a 0.15 budget inside
    32 ticks of a SyntheticMovingObject stream (no kernels needed: the servo
    runs on the gate masks alone)."""
    spec = FPCASpec(image_h=64, image_w=64, out_channels=4, kernel=5, stride=5)
    from repro.serving.control import GateController

    gate = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=0)
    ctl = GateController(GateControllerConfig(target=0.15), spec, gate.threshold)
    session = StreamSession("s", "cam", spec, gate, controller=ctl)
    stream = SyntheticMovingObject((64, 64), seed=2, radius=7.0)
    for t in range(40):
        session.step(stream.frame_at(t))
    converged = ctl.converged_tick(rel_tol=0.2)
    assert converged is not None and converged <= 32
    assert 0.12 <= ctl.ema <= 0.18
    # the servoed threshold is what the session now gates with
    assert session.gate.threshold == ctl.threshold


def test_controller_server_wiring_per_stream(bucket_model):
    """Each stream servos independently; thresholds actually move."""
    spec = _spec()
    _, kernel = _data(spec)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    server = StreamServer(
        pipe,
        DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=0),
        controller=GateControllerConfig(target=0.3),
    )
    server.add_stream("s0", "cam")
    server.add_stream("s1", "cam")
    cams = {
        "s0": SyntheticMovingObject((H, W), seed=4, radius=4.0),
        "s1": SyntheticMovingObject((H, W), seed=9, radius=6.0),
    }
    ticks = [{sid: cam.frame_at(t) for sid, cam in cams.items()} for t in range(8)]
    for _ in server.run(ticks):
        pass
    c0 = server.sessions["s0"].controller
    c1 = server.sessions["s1"].controller
    assert c0 is not None and c1 is not None and c0 is not c1
    assert len(c0.history) == 8 and len(c1.history) == 8
    # different scenes -> different servoed thresholds
    assert server.sessions["s0"].gate.threshold != server.sessions["s1"].gate.threshold


# ---------------------------------------------------------------------------
# multi-config streams: one camera fanned to several programmed configs
# ---------------------------------------------------------------------------


def test_multi_config_stream_matches_single_config_serving(bucket_model):
    """One channel-stacked call per tick == each config served alone."""
    spec = _spec()
    rng = np.random.default_rng(31)
    kA = (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32)
    kB = (rng.normal(size=(6, 5, 5, 3)) * 0.2).astype(np.float32)
    gate = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=4)
    stream = SyntheticMovingObject((H, W), seed=12, radius=4.0)
    # ONE pipeline (and executable cache) serves all three runs: parity does
    # not depend on cache state, and sharing keeps the fast lane cheap
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("A", spec, kA)
    pipe.register("B", spec, kB)

    def serve(configs):
        server = StreamServer(pipe, gate)
        server.add_stream("s0", configs)
        return [
            r
            for results in server.run({"s0": stream.frame_at(t)} for t in range(5))
            for r in results
        ]

    b0, f0 = pipe.stats.batches, pipe.stats.fanout_batches
    fanned = serve(("A", "B"))
    # one result per (tick, config), served by ONE stacked call per tick
    assert pipe.stats.fanout_batches - f0 == 5
    assert pipe.stats.batches - b0 == 5         # not 10: the fan-out is fused
    soloA = serve("A")
    soloB = serve("B")
    assert [r.config for r in fanned] == ["A", "B"] * 5
    for got, want in zip([r for r in fanned if r.config == "A"], soloA):
        assert got.counts.shape == (4, 4, 4)
        np.testing.assert_array_equal(got.counts, want.counts)
        np.testing.assert_array_equal(got.block_mask, want.block_mask)
    for got, want in zip([r for r in fanned if r.config == "B"], soloB):
        assert got.counts.shape == (4, 4, 6)
        np.testing.assert_array_equal(got.counts, want.counts)


def test_per_config_gates_match_solo_serving(bucket_model):
    """add_stream(sid, ("A", "B"), gate={...}) gives each config its own
    gate state; every (stream, config) result is bit-identical to serving
    that config alone with that gate — even though the fused call executes
    only the union mask."""
    spec = _spec()
    rng = np.random.default_rng(41)
    kA = (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32)
    kB = (rng.normal(size=(6, 5, 5, 3)) * 0.2).astype(np.float32)
    gateA = DeltaGateConfig(threshold=0.01, hysteresis=1, keyframe_interval=4)
    gateB = DeltaGateConfig(threshold=0.08, hysteresis=0, keyframe_interval=0)
    stream = SyntheticMovingObject((H, W), seed=13, radius=4.0)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("A", spec, kA)
    pipe.register("B", spec, kB)

    def serve(configs, gate):
        server = StreamServer(pipe)
        server.add_stream("s0", configs, gate=gate)
        return [
            r
            for results in server.run({"s0": stream.frame_at(t)} for t in range(6))
            for r in results
        ]

    fanned = serve(("A", "B"), {"A": gateA, "B": gateB})
    soloA = serve("A", gateA)
    soloB = serve("B", gateB)
    assert [r.config for r in fanned] == ["A", "B"] * 6
    for got, want in zip([r for r in fanned if r.config == "A"], soloA):
        assert got.kept_windows == want.kept_windows
        np.testing.assert_array_equal(got.block_mask, want.block_mask)
        np.testing.assert_array_equal(got.counts, want.counts)
    for got, want in zip([r for r in fanned if r.config == "B"], soloB):
        assert got.kept_windows == want.kept_windows
        np.testing.assert_array_equal(got.block_mask, want.block_mask)
        np.testing.assert_array_equal(got.counts, want.counts)
    # the tighter gate A and the looser gate B really made different calls
    keptA = [r.kept_windows for r in fanned if r.config == "A"]
    keptB = [r.kept_windows for r in fanned if r.config == "B"]
    assert keptA != keptB


def test_per_config_controllers_servo_independently(bucket_model):
    """One GateController per config of one camera: different budgets lead
    to different servoed thresholds within a single stream."""
    from repro.serving.streaming import GateControllerConfig as GCC

    spec = _spec()
    rng = np.random.default_rng(42)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("A", spec, (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32))
    pipe.register("B", spec, (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32))
    gate = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=0)
    server = StreamServer(pipe)
    session = server.add_stream(
        "s0", ("A", "B"),
        gate={"A": gate, "B": gate},
        controller={"A": GCC(target=0.1), "B": GCC(target=0.5)},
    )
    cam = SyntheticMovingObject((H, W), seed=14, radius=5.0)
    for _ in server.run({"s0": cam.frame_at(t)} for t in range(8)):
        pass
    ctlA = session.state_for("A").controller
    ctlB = session.state_for("B").controller
    assert ctlA is not None and ctlB is not None and ctlA is not ctlB
    assert len(ctlA.history) == 8 and len(ctlB.history) == 8
    assert session.state_for("A").gate.threshold != session.state_for("B").gate.threshold
    # per-config energy accounting sees per-config histories
    repA = session.energy_report(config="A")
    repB = session.energy_report(config="B")
    assert repA["frames"] == repB["frames"] == 8
    assert repA["kept_window_frac"] != repB["kept_window_frac"]


def test_per_stream_gate_none_gives_dense_baseline(stream_pipe):
    """add_stream(gate=None) on a gated server disables gating for that
    stream only (omitting the argument inherits the server default)."""
    server = _make_server(stream_pipe, n_streams=1, depth=1)
    server.add_stream("dense", "cam", gate=None)
    stream = SyntheticMovingObject((H, W), seed=7, radius=4.0)
    ticks = [
        {"s0": stream.frame_at(t), "dense": stream.frame_at(t)}
        for t in range(4)
    ]
    results = [r for rs in server.run(ticks) for r in rs]
    dense = [r for r in results if r.stream_id == "dense"]
    gated = [r for r in results if r.stream_id == "s0"]
    h_o, w_o = output_dims(server.sessions["s0"].spec)
    assert all(r.block_mask is None and r.kept_windows == h_o * w_o for r in dense)
    assert any(r.kept_windows < h_o * w_o for r in gated[1:])


def test_per_config_gate_mapping_must_cover_all_configs(bucket_model):
    spec = _spec()
    rng = np.random.default_rng(43)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("A", spec, (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32))
    pipe.register("B", spec, (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32))
    server = StreamServer(pipe)
    with pytest.raises(KeyError, match="missing config"):
        server.add_stream("s0", ("A", "B"), gate={"A": DeltaGateConfig()})


def test_multi_config_stream_requires_shared_spec(bucket_model):
    rng = np.random.default_rng(32)
    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("A", _spec(5, 5, 1), (rng.normal(size=(4, 5, 5, 3)) * 0.2).astype(np.float32))
    pipe.register("B", _spec(3, 2, 1), (rng.normal(size=(4, 3, 3, 3)) * 0.2).astype(np.float32))
    server = StreamServer(pipe)
    with pytest.raises(ValueError, match="shared spec"):
        server.add_stream("s0", ("A", "B"))


# ---------------------------------------------------------------------------
# CompiledFrontend.stream() vs StreamServer: the single-camera loop serves
# the exact same ticks as solo server serving
# ---------------------------------------------------------------------------


def test_compiled_stream_matches_server_solo(bucket_model):
    """Tick-for-tick bit-identical parity between the handle's single-camera
    ``stream()`` loop and ``StreamServer`` solo serving of the same frames
    through the same gate (counts, masks, kept counts, frame order)."""
    import repro.fpca as fpca

    spec = _spec()
    _, kernel = _data(spec)
    gate = DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=4)
    cam = SyntheticMovingObject((H, W), seed=9)
    frames = [cam.frame_at(t) for t in range(8)]

    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    server = StreamServer(pipe, gate, depth=2)
    server.add_stream("s0", "cam")
    via_server = list(server.serve("s0", frames))

    fe = fpca.compile(
        fpca.FPCAProgram(spec=spec), backend="basis", weights=kernel,
        model=bucket_model,
    )
    via_handle = list(fe.stream(frames, gate=gate, depth=2))

    assert len(via_server) == len(via_handle) == len(frames)
    kept_some = False
    for s, h in zip(via_server, via_handle):
        assert s.frame_idx == h.frame_idx
        assert s.kept_windows == h.kept_windows
        assert s.total_windows == h.total_windows
        np.testing.assert_array_equal(s.block_mask, h.block_mask)
        np.testing.assert_array_equal(s.counts, h.counts)
        kept_some |= 0 < s.kept_windows < s.total_windows
    assert kept_some                        # the gate actually gated


def test_compiled_stream_matches_server_solo_dense(bucket_model):
    """Same parity with gating disabled (dense baseline both ways)."""
    import repro.fpca as fpca

    spec = _spec()
    _, kernel = _data(spec)
    rng = np.random.default_rng(11)
    frames = [rng.uniform(0, 1, (H, W, 3)).astype(np.float32) for _ in range(4)]

    pipe = FPCAPipeline(bucket_model, backend="basis")
    pipe.register("cam", spec, kernel)
    server = StreamServer(pipe, gating=False)
    server.add_stream("s0", "cam")
    via_server = list(server.serve("s0", frames))

    fe = fpca.compile(
        fpca.FPCAProgram(spec=spec), backend="basis", weights=kernel,
        model=bucket_model,
    )
    via_handle = list(fe.stream(frames, gate=None))
    for s, h in zip(via_server, via_handle):
        assert s.block_mask is None and h.block_mask is None
        assert s.kept_windows == h.kept_windows == s.total_windows
        np.testing.assert_array_equal(s.counts, h.counts)
