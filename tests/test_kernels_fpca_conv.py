"""fpca_conv Pallas kernel vs pure-jnp oracle: shape/dtype/block sweeps.

The kernel runs in ``interpret=True`` on CPU (Pallas executes the kernel body
in Python); the oracle is built on the independently-tested core modules.
The pipeline output is integer ADC counts, so "allclose" means: identical up
to 1 count at rounding boundaries (summation-order effects), bit-identical
almost everywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adc import ADCConfig
from repro.core.curvefit import fit_bucket_model
from repro.core.fpca_sim import WeightEncoding, encode_weights, extract_windows, fpca_forward
from repro.core.mapping import FPCASpec
from repro.kernels.fpca_conv.kernel import fpca_conv_pallas
from repro.kernels.fpca_conv.ops import fpca_conv, pad_to_lanes
from repro.kernels.fpca_conv.ref import fpca_conv_ref


def _data(m, c, n_real=75, n_pad=128, seed=0):
    rng = np.random.default_rng(seed)
    patches = np.zeros((m, n_pad), np.float32)
    patches[:, :n_real] = rng.uniform(0, 1, (m, n_real))
    w = np.zeros((n_pad, c), np.float32)
    w[:n_real] = rng.uniform(0, 1, (n_real, c))
    mask = np.zeros((n_pad,), np.float32)
    mask[:n_real] = 1.0
    bn = rng.integers(0, 30, (c,)).astype(np.float32)
    return map(jnp.asarray, (patches, w, np.roll(w, 1, axis=1), mask, bn))


def _compare(model, adc, m, c, block_m, block_c, seed=0, n_real=75):
    patches, w_pos, w_neg, mask, bn = _data(m, c, n_real=n_real, seed=seed)
    got = fpca_conv_pallas(
        patches, w_pos, w_neg, model, adc, bn, mask=mask,
        n_real=n_real, block_m=block_m, block_c=block_c, interpret=True,
    )
    want = fpca_conv_ref(patches, w_pos, w_neg, model, adc, bn, mask=mask)
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape == (m, c)
    diff = np.abs(got - want)
    assert diff.max() <= 1.0, f"max count diff {diff.max()}"
    assert (diff > 0).mean() < 0.05, f"too many rounding flips: {(diff > 0).mean():.3f}"


@pytest.mark.parametrize(
    "m,c,block_m,block_c",
    [
        (64, 8, 64, 128),      # tiny
        (256, 128, 128, 128),  # exact tiles
        (300, 130, 256, 128),  # ragged M and C (padding path)
        (1, 1, 64, 128),       # degenerate
        (128, 16, 32, 64),     # small blocks, multi-program grid
    ],
)
def test_kernel_matches_ref_8bit(bucket_model, m, c, block_m, block_c):
    _compare(bucket_model, ADCConfig(bits=8), m, c, block_m, block_c)


def test_kernel_matches_ref_high_resolution_adc(bucket_model):
    """16-bit ADC: lsb = 15 uV, so a <=1-count agreement pins the analog
    voltages of kernel and oracle to ~1e-5 V — a tight numeric validation."""
    _compare(bucket_model, ADCConfig(bits=16), 128, 32, 64, 128)


def test_kernel_small_pixel_count(circuit_params):
    """27-pixel (3x3x3) configuration — different mask/n_real path."""
    model27 = fit_bucket_model(circuit_params, n_pixels=27, grid=33)
    _compare(model27, ADCConfig(bits=8), 96, 8, 64, 128, n_real=27)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(bucket_model, dtype):
    """Patches arriving in bf16 (sensor pipeline) still validate — the kernel
    upcasts to f32 internally."""
    patches, w_pos, w_neg, mask, bn = _data(128, 8)
    got = fpca_conv_pallas(
        patches.astype(dtype), w_pos, w_neg, bucket_model, ADCConfig(), bn,
        mask=mask, n_real=75, block_m=64, block_c=128, interpret=True,
    )
    want = fpca_conv_ref(patches, w_pos, w_neg, bucket_model, ADCConfig(), bn, mask=mask)
    tol = 1.0 if dtype == jnp.float32 else 3.0  # bf16 input quantisation
    assert np.abs(np.asarray(got) - np.asarray(want)).max() <= tol


def test_ops_wrapper_end_to_end(bucket_model, circuit_params):
    """images -> fpca_conv (Pallas) == fpca_forward (core functional sim,
    bucket_sigmoid mode) on the same weights."""
    spec = FPCASpec(image_h=24, image_w=24, out_channels=6, kernel=3, stride=2)
    key = jax.random.PRNGKey(0)
    images = jax.random.uniform(jax.random.PRNGKey(1), (2, 24, 24, 3))
    kernel = jax.random.normal(key, (6, 3, 3, 3)) * 0.2
    adc, enc = ADCConfig(), WeightEncoding()
    got = fpca_conv(
        images, kernel, bucket_model, spec=spec, adc=adc, enc=enc,
        block_m=64, block_c=128, interpret=True,
    )
    want = jax.vmap(
        lambda im: fpca_forward(
            im, kernel, spec, circuit=circuit_params, model=bucket_model,
            adc=adc, enc=enc, mode="bucket_sigmoid", hard=True,
        )["counts"]
    )(images)
    assert got.shape == want.shape == (2, 10, 10, 6)
    diff = np.abs(np.asarray(got) - np.asarray(want))
    assert diff.max() <= 1.0


def test_pad_to_lanes():
    x = jnp.ones((5, 75))
    padded, mask = pad_to_lanes(x, axis=1)
    assert padded.shape == (5, 128)
    assert float(mask.sum()) == 75
    np.testing.assert_array_equal(np.asarray(padded[:, 75:]), 0.0)
