"""Differential parity harness for device-compiled streaming segments.

``CompiledFrontend.run_segment`` rolls K streaming ticks — delta gate,
hysteresis ages, keyframe cadence, kept-window compaction, skip-aware head —
into ONE ``jax.lax.scan`` launch.  That moves five host-side state machines
onto the device, so the contract pinned here is strict: the scan segment must
be **bit-identical, tick for tick**, to the existing per-tick Python loop,
across backends (reference / basis / interpret-pallas), dense and gated,
through zero-kept ticks, keyframe boundaries, compacted-bucket edges, early
exit, mid-stream ``reprogram()``, and host↔device mode interleaving.

Lanes:

* ``@pytest.mark.segment`` — the CI api-surface fast lane: tiny spec, K=4.
* ``@pytest.mark.slow``    — the full K=48 grid across all three backends,
  bucket edges, early exit, and the property sweeps.

Property tests (via ``_hypothesis_compat``) check the scan carry state
machine (block keep grid, keyframe flags, block ages, frame index, previous
logits) against ``StreamSession``'s host-side transitions for arbitrary
frame sequences and gate configs — the gate knobs enter the scan traced, so
the whole sweep shares one compiled executable.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

import repro.fpca as fpca
from _hypothesis_compat import given, settings, st
from repro.core import gating
from repro.core.mapping import FPCASpec, output_dims
from repro.fpca.cache import ExecutableCache
from repro.fpca.executable import CompiledFrontend, CompiledModel
from repro.serving.fpca_pipeline import FPCAPipeline
from repro.serving.streaming import StreamServer, StreamSession

H = W = 24
C_O = 3
GATE = fpca.DeltaGateConfig(threshold=0.02, hysteresis=1, keyframe_interval=4)
BACKENDS = ("reference", "basis", "pallas")   # pallas runs interpret=True


def _spec() -> FPCASpec:
    return FPCASpec(image_h=H, image_w=W, out_channels=C_O, kernel=5, stride=5)


def _kernel(seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(C_O, 5, 5, 3)) * 0.2).astype(np.float32)


def _frames(k: int, seed: int = 0, static: tuple[int, ...] = ()) -> np.ndarray:
    """A random scene; indices in ``static`` repeat their predecessor frame
    (zero block delta — the all-skipped regime)."""
    rng = np.random.default_rng(seed)
    frames = rng.uniform(0, 1, size=(k, H, W, 3)).astype(np.float32)
    for i in static:
        frames[i] = frames[i - 1]
    return frames


def _scene(k: int, seed: int = 0) -> np.ndarray:
    """Moving-blob scene with static stretches and a busy stretch — covers
    zero-kept ticks, partial keeps, and keyframe-interval crossings."""
    rng = np.random.default_rng(seed)
    frames = np.empty((k, H, W, 3), np.float32)
    base = rng.uniform(0, 1, size=(H, W, 3)).astype(np.float32)
    for t in range(k):
        f = base.copy()
        if t % 7 < 4:                     # moving blob 4 of every 7 ticks
            c = (t * 3) % (H - 6)
            f[c : c + 6, c : c + 6] += 0.5
        frames[t] = np.clip(f, 0, 1)
    # two fully-static stretches (frame repeated verbatim)
    for i in range(5, min(8, k)):
        frames[i] = frames[4]
    for i in range(k - 3, k):
        if i > 0:
            frames[i] = frames[k - 4]
    return frames


_HANDLES: dict[tuple, CompiledFrontend] = {}


def _fe(bucket_model, backend: str, gate=GATE) -> CompiledFrontend:
    key = (backend, gate)
    fe = _HANDLES.get(key)
    if fe is None:
        fe = fpca.compile(
            fpca.FPCAProgram(spec=_spec(), gate=gate),
            backend=backend, weights=_kernel(), model=bucket_model,
            interpret=True,
        )
        _HANDLES[key] = fe
    return fe


def _model_handle(bucket_model, backend: str = "basis") -> CompiledModel:
    key = (backend, "model")
    md = _HANDLES.get(key)
    if md is None:
        mp = fpca.FPCAModelProgram(
            frontend=fpca.FPCAProgram(spec=_spec(), gate=GATE),
            head=(fpca.DenseSpec(8, activation="relu"), fpca.DenseSpec(3)),
        )
        md = fpca.compile(
            mp, backend=backend, weights=_kernel(), model=bucket_model,
            head_params=mp.init_head(jax.random.PRNGKey(0)), interpret=True,
        )
        _HANDLES[key] = md
    return md  # type: ignore[return-value]


def _assert_segment_matches_stream(fe, frames, seg, gate=GATE) -> None:
    """Tick-for-tick bit-identity of one segment against the per-tick loop."""
    results = list(fe.stream(frames, gate=gate, controller=None))
    assert seg.ticks == len(results) == frames.shape[0]
    for t, r in enumerate(results):
        np.testing.assert_array_equal(
            np.asarray(seg.counts)[t], r.counts, err_msg=f"counts tick {t}"
        )
        assert int(seg.kept_windows[t]) == r.kept_windows, f"kept tick {t}"
        if gate is not None:
            np.testing.assert_array_equal(
                seg.block_masks[t], r.block_mask, err_msg=f"mask tick {t}"
            )
        if r.logits is not None:
            np.testing.assert_array_equal(
                np.asarray(seg.logits)[t], r.logits, err_msg=f"logits tick {t}"
            )


# ---------------------------------------------------------------------------
# fast lane (CI api-surface job: -m segment)
# ---------------------------------------------------------------------------


@pytest.mark.segment
@pytest.mark.parametrize("backend", ["reference", "basis"])
def test_segment_parity_fast(bucket_model, backend):
    """K=4 scan segment, gated, bit-identical to the per-tick loop."""
    fe = _fe(bucket_model, backend)
    frames = _frames(4, static=(2,))
    seg = fe.run_segment(frames, length=4)
    _assert_segment_matches_stream(fe, frames, seg)
    assert seg.gated and seg.length == 4 and seg.first_frame_idx == 0
    assert bool(seg.keyframes[0])           # first tick keyframes
    assert int(seg.state.frame_idx) == 4


@pytest.mark.segment
def test_segment_dense_fast(bucket_model):
    fe = _fe(bucket_model, "basis")
    frames = _frames(4)
    seg = fe.run_segment(frames, gate=None)
    _assert_segment_matches_stream(fe, frames, seg, gate=None)
    assert not seg.gated
    assert (seg.kept_windows == output_dims(_spec())[0] ** 2).all()


@pytest.mark.segment
def test_segment_chaining_fast(bucket_model):
    """Two chained K=2 segments == one K=4 segment, bit for bit."""
    fe = _fe(bucket_model, "basis")
    frames = _frames(4, static=(2,))
    whole = fe.run_segment(frames)
    s1 = fe.run_segment(frames[:2])
    s2 = fe.run_segment(frames[2:], state=s1.state)
    assert s2.first_frame_idx == 2
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s1.counts), np.asarray(s2.counts)]),
        np.asarray(whole.counts),
    )
    np.testing.assert_array_equal(
        np.concatenate([s1.kept_windows, s2.kept_windows]), whole.kept_windows
    )


@pytest.mark.segment
def test_segment_model_fast(bucket_model):
    """Model segment: in-scan skip-aware head, logits every tick."""
    md = _model_handle(bucket_model)
    frames = _frames(4, static=(2, 3))
    seg = md.run_segment(frames)
    assert seg.logits is not None and np.asarray(seg.logits).shape == (4, 3)
    _assert_segment_matches_stream(md, frames, seg)
    # the all-skipped tick reproduced the carried previous logits exactly
    zero_ticks = np.flatnonzero(seg.kept_windows == 0)
    assert zero_ticks.size >= 1
    for t in zero_ticks:
        np.testing.assert_array_equal(
            np.asarray(seg.logits)[t], np.asarray(seg.logits)[t - 1]
        )


# ---------------------------------------------------------------------------
# full grid (slow lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_parity_k48(bucket_model, backend):
    """The acceptance contract: K=48, gated, bit-identical per tick on every
    backend, through keyframe boundaries and zero-kept stretches."""
    fe = _fe(bucket_model, backend)
    frames = _scene(48)
    seg = fe.run_segment(frames, length=48)
    _assert_segment_matches_stream(fe, frames, seg)
    assert (seg.kept_windows == 0).any()        # the scene went quiet
    assert seg.keyframes[: 48 : GATE.keyframe_interval].all()


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_parity_dense_k48(bucket_model, backend):
    fe = _fe(bucket_model, backend)
    frames = _scene(48, seed=1)
    seg = fe.run_segment(frames, gate=None)
    _assert_segment_matches_stream(fe, frames, seg, gate=None)


@pytest.mark.slow
@pytest.mark.parametrize("m_bucket", [1, 2, 3, 15, 16])
def test_segment_bucket_edges(bucket_model, m_bucket):
    """Compacted-bucket edges (1, 2, pow2±1, M): any static bucket serves
    bit-identically — overflowing ticks fall back to the masked-dense branch
    inside the scan."""
    fe = _fe(bucket_model, "basis")
    frames = _scene(16, seed=2)
    ref = fe.run_segment(frames)                 # masked-dense (bucket M)
    seg = fe.run_segment(frames, m_bucket=m_bucket)
    np.testing.assert_array_equal(np.asarray(seg.counts), np.asarray(ref.counts))
    np.testing.assert_array_equal(seg.kept_windows, ref.kept_windows)
    # rows accounting reflects the bucket: kept<=bucket ticks bill the
    # bucket, overflows bill M, zero-kept ticks bill nothing
    M = output_dims(_spec())[0] ** 2
    kept = seg.kept_windows
    expect = np.where(kept == 0, 0, np.where(kept > m_bucket, M, m_bucket))
    np.testing.assert_array_equal(seg.rows_executed, expect)


@pytest.mark.slow
def test_segment_kept_extremes(bucket_model):
    """n_keep = 0 and n_keep = M inside one gated segment.

    The threshold is tiny-but-positive, not 0.0: XLA may rematerialise the
    effective frame into the carry store and the delta reduction with
    different fusions (a ~1e-8 wobble), so exactly-repeated frames compare
    "changed" against a zero threshold — identically on host and device,
    which is the parity contract, but not the extreme this test wants."""
    gate = fpca.DeltaGateConfig(threshold=1e-6, hysteresis=0,
                                keyframe_interval=0)
    fe = _fe(bucket_model, "basis", gate=gate)
    frames = _frames(6, seed=3, static=(2, 3))
    seg = fe.run_segment(frames)
    M = output_dims(_spec())[0] ** 2
    # any real change keeps everything; repeated frames keep nothing
    assert set(int(v) for v in np.unique(seg.kept_windows)) == {0, M}
    _assert_segment_matches_stream(fe, frames, seg, gate=gate)


@pytest.mark.slow
def test_segment_reprogram_between_segments(bucket_model):
    """reprogram() between segments: zero recompiles, and the chained output
    equals a per-tick host loop that switches kernels at the same tick."""
    fe = fpca.compile(
        fpca.FPCAProgram(spec=_spec(), gate=GATE), backend="basis",
        weights=_kernel(0), model=bucket_model, interpret=True,
    )
    frames = _scene(12, seed=4)
    k2 = _kernel(7)
    s1 = fe.run_segment(frames[:6])
    misses = fe.cache_info().misses
    fe.reprogram(k2)
    s2 = fe.run_segment(frames[6:], state=s1.state)
    assert fe.cache_info().misses == misses      # ZERO recompiles

    # host oracle: per-tick loop, same kernel switch at tick 6
    host = fpca.compile(
        fpca.FPCAProgram(spec=_spec(), gate=GATE), backend="basis",
        weights=_kernel(0), model=bucket_model, interpret=True,
    )
    it = host.stream(frames, depth=1)
    expect = [next(it).counts for _ in range(6)]
    host.reprogram(k2)
    expect += [r.counts for r in it]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s1.counts), np.asarray(s2.counts)]),
        np.stack(expect),
    )


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_early_exit(bucket_model, backend):
    """while_loop variant: a quiescent scene stops the segment early; the
    served prefix is bit-identical, and resuming serves the rest exactly."""
    gate = fpca.DeltaGateConfig(threshold=0.02, hysteresis=0,
                                keyframe_interval=0)
    fe = _fe(bucket_model, backend, gate=gate)
    frames = _frames(10, seed=5)
    frames[4:] = frames[3]                       # scene freezes at tick 4
    ref = fe.run_segment(frames, gate=gate)      # uninterrupted scan
    seg = fe.run_segment(frames, gate=gate, early_exit=2)
    assert seg.ticks < 10
    assert (seg.kept_windows[seg.ticks - 2 : seg.ticks] == 0).all()
    np.testing.assert_array_equal(
        np.asarray(seg.counts)[: seg.ticks],
        np.asarray(ref.counts)[: seg.ticks],
    )
    # resume with the remaining frames: the continuation is bit-identical
    rest = fe.run_segment(frames[seg.ticks :], state=seg.state, gate=gate)
    np.testing.assert_array_equal(
        np.asarray(rest.counts), np.asarray(ref.counts)[seg.ticks :]
    )
    np.testing.assert_array_equal(
        rest.kept_windows, ref.kept_windows[seg.ticks :]
    )


@pytest.mark.slow
def test_segment_length_and_shape_validation(bucket_model):
    fe = _fe(bucket_model, "basis")
    frames = _frames(4)
    with pytest.raises(ValueError, match="length"):
        fe.run_segment(frames, length=8)
    with pytest.raises(ValueError, match="frame stack"):
        fe.run_segment(frames[0])
    with pytest.raises(ValueError, match="early_exit"):
        fe.run_segment(frames, gate=None, early_exit=2)
    with pytest.raises(ValueError, match="patience"):
        fe.run_segment(frames, early_exit=0)


# ---------------------------------------------------------------------------
# property tests: scan carry vs StreamSession host transitions
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(
    threshold=st.floats(0.001, 0.2),
    hysteresis=st.integers(0, 3),
    keyframe_interval=st.integers(0, 5),
    seed=st.integers(0, 2**16),
)
def test_scan_gate_matches_session_transitions(
    bucket_model, threshold, hysteresis, keyframe_interval, seed
):
    """The scan's gate state machine (keep grid, keyframes, ages, frame
    index) matches StreamSession.step for arbitrary frame sequences and gate
    configs.  Gate knobs enter the scan traced, so the whole sweep shares
    ONE compiled executable."""
    gate = fpca.DeltaGateConfig(
        threshold=threshold, hysteresis=hysteresis,
        keyframe_interval=keyframe_interval,
    )
    fe = _fe(bucket_model, "reference")          # gate=GATE handle; gate
    frames = _frames(6, seed=seed, static=(2, 4, 5))
    seg = fe.run_segment(frames, gate=gate)      # passed per call (traced)
    session = StreamSession("s", "cfg", _spec(), gate)
    for t in range(6):
        keep = session.step(frames[t])
        st_ = session._primary
        np.testing.assert_array_equal(
            seg.block_masks[t], keep, err_msg=f"keep grid tick {t}"
        )
        assert bool(seg.keyframes[t]) == st_.last_keyframe, f"keyframe {t}"
        assert int(seg.kept_windows[t]) == int(st_.last_window_mask.sum())
    np.testing.assert_array_equal(
        np.asarray(seg.state.age, np.int64), session._primary.age
    )
    assert int(seg.state.frame_idx) == session.frame_idx
    np.testing.assert_array_equal(
        np.asarray(seg.state.prev_eff), session._prev
    )


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), hysteresis=st.integers(0, 2))
def test_scan_model_carry_matches_host_logits(bucket_model, seed, hysteresis):
    """Previous-logits carry: model segments reproduce the host skip-aware
    head trajectory (quiet ticks replay carried logits) bit-exactly."""
    gate = fpca.DeltaGateConfig(
        threshold=0.02, hysteresis=hysteresis, keyframe_interval=3
    )
    md = _model_handle(bucket_model)
    frames = _frames(6, seed=seed, static=(3, 4))
    seg = md.run_segment(frames, gate=gate)
    host = [
        np.asarray(r.logits)
        for r in md.stream(frames, gate=gate, controller=None)
    ]
    np.testing.assert_array_equal(np.asarray(seg.logits), np.stack(host))


# ---------------------------------------------------------------------------
# ExecutableCache coexistence (regression: no cross-eviction thrash)
# ---------------------------------------------------------------------------


def test_cache_holds_segment_and_batch_executables(bucket_model):
    """Segment, frontend, and model executables for ONE program coexist in a
    shared cache without evicting each other; reprogram() after run_segment
    still compiles nothing."""
    cache = ExecutableCache(16)
    md = fpca.compile(
        fpca.FPCAModelProgram(
            frontend=fpca.FPCAProgram(spec=_spec(), gate=GATE),
            head=(fpca.DenseSpec(8, activation="relu"), fpca.DenseSpec(3)),
        ),
        backend="basis", weights=_kernel(), model=bucket_model,
        head_params=None, interpret=True, cache=cache,
    )
    mp = md.model_program
    md.reprogram(head_params=mp.init_head(jax.random.PRNGKey(0)))
    frames = _frames(4, static=(2,))
    images = frames[:2]

    md.run(images)                               # batched model executable
    md.run_segment(frames)                       # segment executable
    md.run_frontend_weighted(                    # frontend-only executable
        md.kernel, md.bn_offset, images
    )
    info_warm = md.cache_info()
    assert info_warm.evictions == 0

    # a second pass over all three paths hits the warm cache only
    md.run(images)
    md.run_segment(frames)
    md.run_frontend_weighted(md.kernel, md.bn_offset, images)
    info = md.cache_info()
    assert info.misses == info_warm.misses       # no cross-eviction thrash
    assert info.evictions == 0

    # reprogram after run_segment: still zero recompiles on EVERY path
    md.reprogram(_kernel(9))
    md.run(images)
    md.run_segment(frames)
    assert md.cache_info().misses == info_warm.misses


# ---------------------------------------------------------------------------
# segment-aware stats and serving-layer integration
# ---------------------------------------------------------------------------


def _pipeline(bucket_model) -> FPCAPipeline:
    pipe = FPCAPipeline(bucket_model, backend="basis", interpret=True)
    pipe.register("cam", fpca.FPCAProgram(spec=_spec(), gate=GATE), _kernel())
    return pipe


def test_stats_are_segment_aware(bucket_model):
    """K ticks from one launch must report like K per-tick launches:
    launches_skipped counts in-scan zero-kept ticks, windows accounting
    covers every tick, and segments/segment_ticks record the rollup."""
    frames = _frames(6, static=(2, 3, 4))
    srv_tick = StreamServer(_pipeline(bucket_model), GATE)
    srv_tick.add_stream("cam0", "cam")
    list(srv_tick.serve("cam0", frames))

    srv_seg = StreamServer(_pipeline(bucket_model), GATE)
    srv_seg.add_stream("cam0", "cam")
    srv_seg.run_segment("cam0", frames)

    a, b = srv_seg.stats, srv_tick.stats
    assert a.ticks == b.ticks == 6
    assert a.frames == b.frames
    assert a.windows_total == b.windows_total
    assert a.windows_kept == b.windows_kept
    assert a.launches_skipped == b.launches_skipped > 0
    assert a.segments == 1 and a.segment_ticks == 6
    assert b.segments == 0 and b.segment_ticks == 0
    ps = srv_seg.pipeline.stats
    assert ps.segments == 1 and ps.segment_ticks == 6
    assert ps.launches_skipped == a.launches_skipped


def test_session_energy_report_covers_segment_ticks(bucket_model):
    """streaming_frontend_report stays honest: the session's retained mask
    history after a segment equals the per-tick history."""
    frames = _frames(6, static=(2, 3))
    srv_seg = StreamServer(_pipeline(bucket_model), GATE)
    srv_seg.add_stream("cam0", "cam")
    srv_seg.run_segment("cam0", frames)
    srv_tick = StreamServer(_pipeline(bucket_model), GATE)
    srv_tick.add_stream("cam0", "cam")
    list(srv_tick.serve("cam0", frames))
    rep_seg = srv_seg.sessions["cam0"].energy_report()
    rep_tick = srv_tick.sessions["cam0"].energy_report()
    assert rep_seg == rep_tick


def test_server_segment_mode_matches_per_tick(bucket_model):
    frames = _frames(8, static=(2, 3, 6))
    srv_tick = StreamServer(_pipeline(bucket_model), GATE)
    srv_tick.add_stream("cam0", "cam")
    ref = list(srv_tick.serve("cam0", frames))
    srv_seg = StreamServer(_pipeline(bucket_model), GATE)
    srv_seg.add_stream("cam0", "cam")
    got = list(srv_seg.serve_segments("cam0", frames, segment_length=4))
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        assert a.frame_idx == b.frame_idx
        assert a.kept_windows == b.kept_windows
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.block_mask, b.block_mask)


def test_server_interleaves_tick_and_segment_modes(bucket_model):
    """tick -> segment -> tick on ONE stream stays bit-identical to pure
    per-tick serving (absorb_segment rebuilds the host mirror)."""
    frames = _frames(9, static=(2, 5))
    srv_ref = StreamServer(_pipeline(bucket_model), GATE)
    srv_ref.add_stream("cam0", "cam")
    ref = list(srv_ref.serve("cam0", frames))
    srv = StreamServer(_pipeline(bucket_model), GATE)
    srv.add_stream("cam0", "cam")
    got = list(srv.serve("cam0", frames[:3]))
    got += srv.run_segment("cam0", frames[3:6])
    got += list(srv.serve("cam0", frames[6:]))
    assert [r.frame_idx for r in got] == [r.frame_idx for r in ref]
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a.counts, b.counts)
        assert a.kept_windows == b.kept_windows


def test_boundary_servo_steps_once_per_segment(bucket_model):
    """The threshold is constant inside a segment (traced gate args) and the
    servo applies one bounded actuation at the boundary; history still
    records every in-segment tick."""
    ctl = fpca.GateControllerConfig(target=0.3)
    srv = StreamServer(_pipeline(bucket_model), GATE, controller=ctl)
    session = srv.add_stream("cam0", "cam")
    thr0 = session.gate.threshold
    srv.run_segment("cam0", _frames(6, seed=11))
    c = session.controller
    assert c is not None and len(c.history) == 6
    in_segment = {h["threshold"] for h in c.history}
    assert in_segment == {thr0}                  # constant inside the segment
    assert session.gate.threshold != thr0        # one boundary actuation
    # the actuation is bounded exactly like a single per-tick step
    import math
    assert abs(math.log(session.gate.threshold) - math.log(thr0)) <= (
        ctl.max_step + 1e-12
    )


def test_boundary_servo_zero_tick_segment_is_a_no_op():
    """A zero-tick segment (early-exit fired before serving anything) made
    no observation, so the boundary servo must neither fold the stale EMA
    nor spend an actuation — the threshold stays bit-exactly where the last
    real observation left it."""
    from repro.serving.control import GateController

    spec = _spec()
    ctl = GateController(
        fpca.GateControllerConfig(target=0.3), spec, GATE.threshold
    )
    # seed real state: one observed segment moves the threshold
    bh = -(-spec.eff_h // spec.skip_block)
    bw = -(-spec.eff_w // spec.skip_block)
    masks = np.ones((3, bh, bw), bool)
    thr1 = ctl.observe_segment(masks, keyframes=[True, False, False])
    ema1, hist1, tick1 = ctl.ema, len(ctl.history), ctl._tick
    assert thr1 != GATE.threshold
    # the zero-tick boundary: identical threshold, EMA, history, tick count
    thr2 = ctl.observe_segment(np.zeros((0, bh, bw), bool))
    assert thr2 == thr1 == ctl.threshold
    assert ctl.ema == ema1
    assert len(ctl.history) == hist1 and ctl._tick == tick1


def test_segment_bucket_suggestion_threads_to_next_segment(bucket_model):
    """The finished segment sizes the next one's compacted row bucket
    (pow2 of the max informative kept count); serving with it stays
    bit-identical."""
    fe = _fe(bucket_model, "basis")
    frames = _scene(12, seed=6)
    s1 = fe.run_segment(frames[:6])
    assert s1.state.suggested_bucket is not None
    assert s1.state.suggested_bucket >= 1
    ref = fe.run_segment(frames[6:], state=dataclasses.replace(
        s1.state, suggested_bucket=None))
    s2 = fe.run_segment(frames[6:], state=s1.state)   # uses the suggestion
    np.testing.assert_array_equal(
        np.asarray(s2.counts), np.asarray(ref.counts)
    )


def test_frontend_stats_count_segments(bucket_model):
    fe = fpca.compile(
        fpca.FPCAProgram(spec=_spec(), gate=GATE), backend="basis",
        weights=_kernel(), model=bucket_model, interpret=True,
    )
    frames = _frames(5, static=(2, 3))
    seg = fe.run_segment(frames)
    M = output_dims(_spec())[0] ** 2
    assert fe.stats.segments == 1
    assert fe.stats.segment_ticks == 5
    assert fe.stats.windows_total == 5 * M
    assert fe.stats.windows_executed == int(seg.rows_executed.sum())
    assert fe.stats.launches_skipped == int((seg.kept_windows == 0).sum()) > 0


# ---------------------------------------------------------------------------
# shared gate numerics (the bit-parity foundation)
# ---------------------------------------------------------------------------


def test_host_gate_kernels_are_single_source():
    """The host loop's gate numerics ARE the scan's (one jnp implementation;
    the fused host step kernel returns the same bits as the split calls)."""
    spec = _spec()
    kernels = gating.host_gate_kernels(spec)
    rng = np.random.default_rng(0)
    a = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    b = rng.uniform(0, 1, (H, W, 3)).astype(np.float32)
    ea = np.asarray(kernels.eff(a))
    eb, delta_fused = kernels.step(ea, b)
    np.testing.assert_array_equal(np.asarray(eb), np.asarray(kernels.eff(b)))
    # the fused step is deterministic (same bits every call) — this is what
    # the parity contract rests on; against the *split* kernels XLA may fuse
    # the reductions differently, so only closeness is promised there
    eb2, delta2 = kernels.step(ea, b)
    np.testing.assert_array_equal(np.asarray(delta_fused), np.asarray(delta2))
    np.testing.assert_array_equal(np.asarray(eb), np.asarray(eb2))
    np.testing.assert_allclose(
        np.asarray(delta_fused),
        np.asarray(kernels.delta(ea, np.asarray(eb))),
        rtol=0, atol=1e-6,
    )


def test_host_gate_step_batch_matches_solo_bitwise():
    """The vmapped fleet kernel gates every stream of a group in ONE
    dispatch; per row it must return the same float32 bits as the solo
    fused step — a 1-ulp drift would flip keep/skip decisions and break
    the parity contract for batched fleet serving."""
    for spec in (_spec(), FPCASpec(image_h=H, image_w=18, out_channels=C_O,
                                   kernel=3, stride=3, binning=2)):
        kernels = gating.host_gate_kernels(spec)
        rng = np.random.default_rng(1)
        n = 5
        prevs = rng.uniform(
            0, 1, (n, spec.eff_h, spec.eff_w)
        ).astype(np.float32)
        frames = rng.uniform(
            0, 1, (n, spec.image_h, spec.image_w, 3)
        ).astype(np.float32)
        curs, deltas = kernels.step_batch(prevs, frames)
        for i in range(n):
            cur_i, delta_i = kernels.step(prevs[i], frames[i])
            np.testing.assert_array_equal(
                np.asarray(curs)[i], np.asarray(cur_i)
            )
            np.testing.assert_array_equal(
                np.asarray(deltas)[i], np.asarray(delta_i)
            )
