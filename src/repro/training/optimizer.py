"""AdamW with sharded state, global-norm clipping, and LR schedules.

No optax dependency — the update is a pure pytree transform whose moment
states inherit the parameters' shardings (FSDP'd optimizer state = ZeRO).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_adamw", "adamw_update", "make_lr_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array                 # i32 scalar
    mu: Any                         # first moments (f32, param-sharded)
    nu: Any                         # second moments


def init_adamw(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def make_lr_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup + cosine decay to ``min_lr_ratio * lr``."""

    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        prog = jnp.clip(
            (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)

    return schedule


def adamw_update(
    grads: Any, state: AdamWState, params: Any, cfg: AdamWConfig
) -> tuple[Any, AdamWState, dict[str, jax.Array]]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = make_lr_schedule(cfg)(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
