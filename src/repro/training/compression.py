"""Int8 gradient compression with error feedback (distributed-optimization
option for bandwidth-constrained interconnects, e.g. the cross-pod axis).

Scheme (1-bit-Adam family, int8 variant):

    q_t     = quantize(g_t + e_{t-1})          # per-leaf symmetric int8
    e_t     = (g_t + e_{t-1}) - dequant(q_t)   # residual kept locally
    g_used  = all-reduce(dequant(q_t))         # 4x less wire than f32

Error feedback keeps the *accumulated* quantisation error bounded, so SGD /
Adam converge at the uncompressed rate (tested on a toy problem in
tests/test_fault_tolerance.py).  ``sync_grads_compressed`` implements the
cross-device mean with ``shard_map`` + ``psum`` over the data axes so the
wire format really is int8-sized payloads; on a single device it degrades to
quantize/dequantize (the semantics the test pins down).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# the symmetric int8 leaf numerics are single-sourced with the quantised
# head lowering (repro.models.quant) — gradient compression and int8
# serving must agree on the same quantise/dequantise semantics
from repro.models.quant import dequantize_leaf, quantize_leaf_symmetric

__all__ = ["init_error_state", "compress_decompress", "sync_grads_compressed"]

_quantize_leaf = quantize_leaf_symmetric


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(
    grads: Any, error: Any
) -> tuple[Any, Any, dict[str, jax.Array]]:
    """Error-feedback int8 round trip; returns (g_hat, new_error, metrics)."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(x)
        deq = dequantize_leaf(q, scale)
        return deq, x - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g_hat = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    err_norm = jnp.sqrt(sum(jnp.sum(o[1] ** 2) for o in outs))
    return g_hat, new_e, {"compression_error_norm": err_norm}


def sync_grads_compressed(grads: Any, error: Any, mesh, axes: tuple[str, ...]):
    """Compressed gradient mean over ``axes`` (shard_map + psum).

    The int8 payload crosses the wire; the mean happens in f32 after
    dequantisation (psum of int8 payloads would overflow).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    g_hat, new_e, metrics = compress_decompress(grads, error)

    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if n == 1:
        return g_hat, new_e, metrics

    def mean_fn(g):
        return jax.tree.map(lambda x: jax.lax.psum(x, axes) / n, g)

    spec = jax.tree.map(lambda _: P(), g_hat)
    synced = shard_map(mean_fn, mesh=mesh, in_specs=(spec,), out_specs=spec)(g_hat)
    return synced, new_e, metrics
