"""Checkpoint/restore with elastic resharding.

Layout per checkpoint::

    <dir>/step_000123/
        manifest.json     # step, data cursor, rng, tree structure, dtypes
        arrays/<idx>.npy  # one file per leaf (globally assembled view)

* **Atomicity** — written to ``step_N.tmp`` and renamed; a crash mid-save
  never corrupts the latest checkpoint (rename is atomic on POSIX).
* **Elastic resharding** — arrays are stored as *global* logical arrays;
  ``restore`` places each leaf onto ANY target mesh/sharding via
  ``jax.make_array_from_callback`` reading just the slice each device needs
  (np.load with mmap), so a 16x16 checkpoint restores onto 2x16x16, 4x4, or
  a single host unchanged.  On a multi-host cluster the same code path runs
  per host with a shared filesystem; per-shard layouts are a straightforward
  extension recorded in the manifest schema (``layout`` field).
* **Retention** — ``keep`` newest checkpoints are retained.
"""

from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _flatten_with_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(
    directory: str | Path,
    step: int,
    state: Any,
    *,
    extra: dict | None = None,
    keep: int = 3,
) -> Path:
    """Write ``state`` (any pytree of arrays) atomically; returns final path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)

    leaves, treedef = _flatten_with_paths(state)
    meta = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / "arrays" / f"{i}.npy", arr)
        meta.append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest = {
        "step": step,
        "layout": "global-v1",
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "leaves": meta,
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)

    kept = sorted(directory.glob("step_*"))
    for old in kept[:-keep]:
        if old.is_dir() and not old.name.endswith(".tmp"):
            shutil.rmtree(old)
    return final


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    steps = sorted(
        int(p.name.split("_")[1])
        for p in directory.glob("step_*")
        if p.is_dir() and not p.name.endswith(".tmp")
    )
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str | Path,
    like: Any,
    *,
    step: int | None = None,
    shardings: Any | None = None,
) -> tuple[Any, dict]:
    """Restore onto the structure of ``like`` (a pytree of arrays or SDS).

    ``shardings``: optional pytree of NamedShardings for the TARGET mesh —
    this is the elastic-resharding path: each device materialises only its
    slice of the stored global array.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())

    leaves_like, treedef = _flatten_with_paths(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, target tree has "
            f"{len(leaves_like)} — architecture mismatch"
        )
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves_like)
    )

    out = []
    for i, (ref, shard) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(path / "arrays" / f"{i}.npy", mmap_mode="r")
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: stored {arr.shape} != target {ref.shape}")
        dtype = ref.dtype
        if shard is None:
            out.append(jax.numpy.asarray(np.asarray(arr), dtype=dtype))
        else:
            out.append(
                jax.make_array_from_callback(
                    tuple(arr.shape),
                    shard,
                    lambda idx, a=arr, d=dtype: np.asarray(a[idx], dtype=d),
                )
            )
    return treedef.unflatten(out), manifest["extra"] | {"step": manifest["step"]}
