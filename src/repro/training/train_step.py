"""The production train step: microbatched gradient accumulation + AdamW.

``make_train_step(cfg, ...)`` returns a pure function
``(params, opt_state, batch, rng) -> (params, opt_state, metrics)`` suitable
for ``jax.jit`` with sharded inputs.  Gradient accumulation runs as a
``lax.scan`` over microbatches (f32 accumulators, param-sharded), which is
what bounds activation memory at the assigned global batch sizes
(DESIGN.md §5); the optimizer update happens once per step on the averaged
gradients.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import forward_train
from repro.training.optimizer import AdamWConfig, adamw_update

__all__ = ["make_train_step", "pick_microbatches"]


def pick_microbatches(
    cfg: ModelConfig, per_device_batch: int, seq_len: int, budget_bytes: float = 4e9
) -> int:
    """Number of accumulation steps so saved per-layer activations fit a
    ~4 GB budget per device (residual-stream carries dominate under remat)."""
    bytes_per_seq_layer = seq_len * cfg.d_model * 2  # bf16 residual carry
    depth = max(cfg.n_layers, 1)
    per_seq = bytes_per_seq_layer * depth
    if cfg.family in ("ssm", "hybrid"):
        per_seq *= cfg.ssm_expand  # inner-width carries
    micro_bs = max(1, int(budget_bytes // max(per_seq, 1)))
    micro_bs = min(micro_bs, per_device_batch)
    # round UP so the budget is respected, then up again to a divisor
    n_micro = -(-per_device_batch // micro_bs)
    while per_device_batch % n_micro:
        n_micro += 1
    return n_micro


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    n_micro: int = 1,
    remat: str = "full",
):
    """Build the jittable train step (grad-accumulation over ``n_micro``)."""

    def loss_fn(params, batch):
        return forward_train(params, cfg, batch, remat=remat)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        def reshape_micro(x):
            b = x.shape[0]
            if b % n_micro:
                raise ValueError(
                    f"global batch {b} not divisible by n_micro={n_micro}"
                )
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        micro = jax.tree.map(reshape_micro, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            g_acc, loss_acc, metr_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            metr_acc = {k: metr_acc[k] + metrics[k] for k in metr_acc}
            return (g_acc, loss_acc + loss, metr_acc), None

        metrics0 = {
            "ce_loss": jnp.float32(0),
            "moe_lb_loss": jnp.float32(0),
            "moe_z_loss": jnp.float32(0),
            "moe_drop_frac": jnp.float32(0),
        }
        if cfg.family == "encdec":
            metrics0 = {"ce_loss": jnp.float32(0)}
        (grads, loss, metrics), _ = jax.lax.scan(body, (zeros, jnp.float32(0), metrics0), micro)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        loss = loss / n_micro
        metrics = {k: v / n_micro for k, v in metrics.items()}

        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
