"""Bounded LRU of jitted executables, keyed by compile signature.

One cache instance can back many :class:`repro.fpca.CompiledFrontend`
handles (that is how :class:`repro.serving.FPCAPipeline` bounds the *total*
number of live executables across every registered configuration): entries
are fresh jitted closures whose compiled programs are owned by the closure,
so LRU eviction genuinely frees them.

Counters are introspectable via :meth:`ExecutableCache.info` — the
``functools.lru_cache``-style :class:`CacheInfo` that
``CompiledFrontend.cache_info()`` surfaces, and the mechanism the
reprogram-without-recompile contract is asserted against (``misses`` must
not move across a ``reprogram()``).  ``info(verbose=True)`` adds the
telemetry-grade breakdown: per-signature hit/miss counts for every key the
cache has ever seen, plus a bounded, ordered eviction history — enough to
see exactly *which* executable thrashed when a fleet overflows capacity.
"""

from __future__ import annotations

import collections
from typing import Callable, NamedTuple

__all__ = ["CacheInfo", "CacheInfoVerbose", "ExecutableCache"]


class CacheInfo(NamedTuple):
    hits: int
    misses: int
    evictions: int
    currsize: int
    maxsize: int


class CacheInfoVerbose(NamedTuple):
    hits: int
    misses: int
    evictions: int
    currsize: int
    maxsize: int
    # per-signature (hits, misses) for every key ever requested, insertion
    # ordered; keys currently resident appear in `resident` in LRU order
    # (least recently used first).
    by_key: dict
    resident: tuple
    # least-recent-first record of evicted keys, bounded by eviction_log_cap.
    eviction_log: tuple


class ExecutableCache:
    """Bounded LRU: ``get(key, build)`` returns the cached executable or
    builds, inserts and (on overflow) evicts the least recently used."""

    #: retain at most this many eviction-history entries (oldest dropped).
    eviction_log_cap = 64

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._entries: collections.OrderedDict[tuple, Callable] = (
            collections.OrderedDict()
        )
        # key -> [hits, misses]; insertion ordered, never evicted (bounded
        # in practice by the signature space a process compiles).
        self._by_key: dict[tuple, list[int]] = {}
        self._eviction_log: collections.deque[tuple] = collections.deque(
            maxlen=self.eviction_log_cap
        )

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def get(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        per = self._by_key.setdefault(key, [0, 0])
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            per[0] += 1
            return self._entries[key]
        self.misses += 1
        per[1] += 1
        fn = build()
        self._entries[key] = fn
        if len(self._entries) > self.capacity:
            evicted, _ = self._entries.popitem(last=False)
            self.evictions += 1
            self._eviction_log.append(evicted)
        return fn

    def info(self, verbose: bool = False) -> CacheInfo | CacheInfoVerbose:
        if not verbose:
            return CacheInfo(
                hits=self.hits,
                misses=self.misses,
                evictions=self.evictions,
                currsize=len(self._entries),
                maxsize=self.capacity,
            )
        return CacheInfoVerbose(
            hits=self.hits,
            misses=self.misses,
            evictions=self.evictions,
            currsize=len(self._entries),
            maxsize=self.capacity,
            by_key={k: (h, m) for k, (h, m) in self._by_key.items()},
            resident=tuple(self._entries.keys()),
            eviction_log=tuple(self._eviction_log),
        )

    def counters(self) -> tuple[int, int, int]:
        """(hits, misses, evictions) snapshot — for delta-based mirroring."""
        return (self.hits, self.misses, self.evictions)
