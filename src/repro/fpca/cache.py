"""Bounded LRU of jitted executables, keyed by compile signature.

One cache instance can back many :class:`repro.fpca.CompiledFrontend`
handles (that is how :class:`repro.serving.FPCAPipeline` bounds the *total*
number of live executables across every registered configuration): entries
are fresh jitted closures whose compiled programs are owned by the closure,
so LRU eviction genuinely frees them.

Counters are introspectable via :meth:`ExecutableCache.info` — the
``functools.lru_cache``-style :class:`CacheInfo` that
``CompiledFrontend.cache_info()`` surfaces, and the mechanism the
reprogram-without-recompile contract is asserted against (``misses`` must
not move across a ``reprogram()``).
"""

from __future__ import annotations

import collections
from typing import Callable, NamedTuple

__all__ = ["CacheInfo", "ExecutableCache"]


class CacheInfo(NamedTuple):
    hits: int
    misses: int
    evictions: int
    currsize: int
    maxsize: int


class ExecutableCache:
    """Bounded LRU: ``get(key, build)`` returns the cached executable or
    builds, inserts and (on overflow) evicts the least recently used."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._entries: collections.OrderedDict[tuple, Callable] = (
            collections.OrderedDict()
        )

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def get(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            return self._entries[key]
        self.misses += 1
        fn = build()
        self._entries[key] = fn
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return fn

    def info(self) -> CacheInfo:
        return CacheInfo(
            hits=self.hits,
            misses=self.misses,
            evictions=self.evictions,
            currsize=len(self._entries),
            maxsize=self.capacity,
        )

    def counters(self) -> tuple[int, int, int]:
        """(hits, misses, evictions) snapshot — for delta-based mirroring."""
        return (self.hits, self.misses, self.evictions)
