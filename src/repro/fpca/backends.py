"""Pluggable execution backends for the FPCA frontend.

Replaces the string-literal dispatch that used to live inside
:func:`repro.core.fpca_sim.fpca_forward` with a registry: each
:class:`Backend` names one way of evaluating a programmed array and carries
the two entry points the rest of the stack needs —

* ``conv``            — one-shot batched forward (what ``fpca_forward``
  dispatches fused backends through);
* ``make_executable`` — a factory returning a *fresh* jitted
  ``(images, kernel, bn_offset[, window_mask]) -> counts`` closure whose
  compiled programs die with it.  This is what
  :class:`repro.fpca.CompiledFrontend` holds in its bounded LRU cache, so a
  serving host genuinely bounds live executables by dropping references.

Built-ins (registered at import):

* ``"reference"`` — the dense jnp simulation (every mode, the only
  differentiable path; the parity oracle).  Its executables serve the same
  calibrated bucket-sigmoid + hard-ADC semantics as the fused backends, so
  backends are interchangeable behind one :class:`CompiledFrontend`.
* ``"pallas"``    — the fused TPU kernel (``interpret=True`` off-TPU;
  validation only there).
* ``"basis"``     — the identical basis-expanded matmul-bank math lowered
  through XLA — the fast deployment path on non-TPU hosts.

Third parties register with the decorator::

    @register_backend("mysim", description="in-house RTL cosim")
    def _mysim_executable(model, *, spec, adc, enc, interpret=None,
                          m_bucket=None):
        ...return a (images, kernel, bn_offset[, window_mask]) callable...
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import gating
from repro.core.adc import ADCConfig, updown_readout
from repro.core.curvefit import BucketCurvefitModel
from repro.core.fpca_sim import WeightEncoding, _analog_read, encode_weights, extract_windows
from repro.core.mapping import FPCASpec, output_dims

__all__ = [
    "Backend",
    "register_backend",
    "get_backend",
    "available_backends",
    "default_backend_name",
]


@dataclasses.dataclass(frozen=True)
class Backend:
    """One registered execution backend.

    ``fused`` marks backends that serve the calibrated bucket-sigmoid model
    with hard ADC rounding through a single fused call (deployment-mode
    serving of the sensor model); non-fused backends run the dense
    simulation and may be differentiable.
    """

    name: str
    make_executable: Callable
    conv: Callable | None = None
    fused: bool = True
    differentiable: bool = False
    # whether executables differ per region-skip row bucket (m_bucket).
    # Fused kernels compile one program per bucket size; backends that
    # evaluate densely and mask post-hoc (the reference oracle) serve every
    # bucket with one executable, so caches can collapse the key.
    bucket_sensitive: bool = True
    # whether make_executable accepts transfer="int8" — the quantised
    # bucket-transfer LUT of precision="int8" model programs.  Backends
    # without it keep serving the f32 frontend under int8 models (the
    # reference backend stays the f32-frontend oracle the parity harness
    # bounds against); only flag backends whose factory takes the kwarg.
    quant_transfer: bool = False
    description: str = ""

    def instrumented(self, fn: Callable, *, site: str) -> Callable:
        """Wrap a jitted closure with the opt-in device-profile hooks
        (:func:`repro.fpca.telemetry.instrument_launch`): launch counting,
        ``jax.profiler.TraceAnnotation`` tagging and rate-limited
        ``block_until_ready`` device-time sampling, all labeled
        ``{site, backend}``.  :class:`repro.fpca.CompiledFrontend` routes
        every cache-built executable through this, so third-party backends
        registered via :func:`register_backend` are covered uniformly.
        Disabled-mode cost is one ``is None`` check per call."""
        from repro.fpca.telemetry import instrument_launch

        return instrument_launch(fn, site=site, backend=self.name)

    def make_model_executable(
        self,
        model_program,                      # repro.fpca.FPCAModelProgram
        bucket_model: "BucketCurvefitModel",
        *,
        interpret: bool | None = None,
        m_bucket: int | None = None,
    ) -> Callable:
        """A fresh jitted **whole-model** executable: frontend + digital head
        in ONE jit.

        The frontend stage is this backend's :attr:`make_executable` closure
        (inlined into the trace — still the registry-dispatched kernel math);
        the head is :meth:`repro.fpca.FPCAModelProgram.apply_head` lowered as
        plain jnp ops, so the fused outputs are bit-identical to composing a
        frontend handle with the reference head apply.  Signature:
        ``(images, kernel, bn_offset, head_params) -> head outputs`` — class
        logits for chain heads, any ``head_out_shape`` for zoo head graphs
        (e.g. per-cell detection maps) — with a trailing ``window_mask``
        argument when ``m_bucket`` is set (the region-skip compacted path;
        skipped windows enter the head as exact zeros).  Head parameters
        enter traced, so reprogramming them — like NVM weights — never
        recompiles.

        A ``precision="int8"`` model program selects the quantised head
        lowering through ``apply_head`` (same dispatch, traced quant
        pytree); on :attr:`quant_transfer` backends the frontend stage also
        serves the int8 bucket-transfer LUT.
        """
        kw = {}
        if self.quant_transfer and model_program.precision == "int8":
            kw["transfer"] = "int8"
        frontend = self.make_executable(
            bucket_model,
            spec=model_program.frontend.spec,
            adc=model_program.frontend.adc,
            enc=model_program.frontend.enc,
            interpret=interpret,
            m_bucket=m_bucket,
            **kw,
        )
        head = model_program.apply_head

        if m_bucket is None:

            @jax.jit
            def run(images, kernel, bn_offset, head_params):
                return head(head_params, frontend(images, kernel, bn_offset))

        else:

            @jax.jit
            def run(images, kernel, bn_offset, head_params, window_mask):
                return head(
                    head_params, frontend(images, kernel, bn_offset, window_mask)
                )

        return run

    def make_segment_executable(
        self,
        bucket_model: "BucketCurvefitModel",
        *,
        spec: FPCASpec,
        adc: ADCConfig | None = None,
        enc: WeightEncoding | None = None,
        interpret: bool | None = None,
        length: int,
        gated: bool = True,
        m_bucket: int | None = None,
        model_program=None,                 # repro.fpca.FPCAModelProgram
        early_exit: int | None = None,
        donate: bool = False,
    ) -> Callable:
        """A fresh jitted **segment** executable: ``length`` streaming ticks
        rolled into ONE device program (``jax.lax.scan``), the delta gate /
        hysteresis / keyframe state machine living in the carry.

        Per tick the body steps the gate (:mod:`repro.core.gating` — the
        same jnp numerics the host loop evaluates, so keep/skip decisions
        compare identical bits), derives the per-window keep grid and routes
        the frame through this backend's :attr:`make_executable` closures:

        * zero kept windows  -> exact zeros, no kernel math at all;
        * ``n_keep > m_bucket`` (keyframes, busy scenes) -> the masked dense
          variant (post-hoc zero mask — the existing dense-fallback path);
        * otherwise          -> the ``m_bucket``-compacted variant (static
          ``jnp.nonzero`` gather; the servo picks the bucket *between*
          segments so it stays trace-friendly inside the scan).

        With ``model_program`` the digital head is fused in: each tick
        patches kept windows into the carried effective activation map and
        runs the head on the patched map (an all-skipped tick reproduces
        the carried previous logits bit-exactly).  With ``early_exit=p`` the
        scan becomes a ``lax.while_loop`` that stops after ``p`` consecutive
        all-skipped ticks (quiescent scene) and reports ``ticks`` executed.

        Signature of the returned closure (gate knobs and all parameters
        enter traced — reprogramming and boundary servo steps never
        recompile)::

            run(frames, kernel, bn_offset[, head_params][, gate_args], carry)
              -> (outs, new_carry)

        where ``gate_args = (threshold f32, hysteresis i32, interval i32)``
        is present iff ``gated``; ``carry`` is the flat gate-state tuple
        (plus ``(eff, logits)`` for models) and ``outs`` maps ``counts``,
        ``block_keep``, ``kept``, ``keyframe``, ``ticks`` (and ``logits``).
        The head slot of the carry is shape-generic: chain heads carry
        ``(n_classes,)`` logits, zoo head graphs whatever
        ``FPCAModelProgram.head_out_shape`` says (per-cell detection maps
        included) — the per-tick ``outs["logits"]`` stacks ``K`` of them.
        ``donate=True`` donates the carry buffers (previous frame / ages /
        previous logits) to the next segment — skip on CPU, where jax does
        not implement donation.
        """
        adc = adc or ADCConfig()
        enc = enc or WeightEncoding()
        K = int(length)
        if K < 1:
            raise ValueError("segment length must be >= 1")
        h_o, w_o = output_dims(spec)
        M = h_o * w_o
        bh, bw = gating.block_grid(spec)
        head = model_program.apply_head if model_program is not None else None
        if early_exit is not None and not gated:
            raise ValueError("early_exit requires a gated segment")

        common = dict(
            spec=spec, adc=adc, enc=enc, interpret=interpret
        )
        if (
            self.quant_transfer
            and model_program is not None
            and model_program.precision == "int8"
        ):
            # int8 model segments serve the quantised bucket transfer in
            # every in-scan frontend branch, matching the fused model jit
            common["transfer"] = "int8"
        if not gated:
            mb = None
            fe_dense = self.make_executable(bucket_model, m_bucket=None, **common)
            fe_masked = fe_compact = None
        else:
            mb = M if m_bucket is None else max(1, min(int(m_bucket), M))
            fe_dense = None
            fe_masked = self.make_executable(bucket_model, m_bucket=M, **common)
            fe_compact = (
                self.make_executable(bucket_model, m_bucket=mb, **common)
                if mb < M and self.bucket_sensitive
                else None
            )

        def tick(kernel, bn_offset, head_params, gate_args, carry, frame):
            gate_carry = gating.GateCarry(*carry[:4])
            if gated:
                thr, hyst, ki = gate_args
                cur = gating.effective_frame(frame, spec)
                gate_carry, keep, keyframe = gating.gate_tick(
                    spec, gate_carry, cur, thr, hyst, ki
                )
                window = gating.window_mask_from_blocks(keep, spec)
                n_keep = jnp.sum(window).astype(jnp.int32)
            else:
                keep = jnp.ones((bh, bw), bool)
                keyframe = jnp.zeros((), bool)
                n_keep = jnp.asarray(M, jnp.int32)
                window = None
                gate_carry = gating.GateCarry(
                    gate_carry.has_prev,
                    gate_carry.prev_eff,
                    gate_carry.age,
                    gate_carry.frame_idx + 1,
                )
            c_o = kernel.shape[0]

            def compute(_):
                if not gated:
                    return fe_dense(frame[None], kernel, bn_offset)
                if fe_compact is None:
                    return fe_masked(frame[None], kernel, bn_offset, window[None])
                return jax.lax.cond(
                    n_keep > mb,
                    lambda __: fe_masked(
                        frame[None], kernel, bn_offset, window[None]
                    ),
                    lambda __: fe_compact(
                        frame[None], kernel, bn_offset, window[None]
                    ),
                    None,
                )

            if gated:
                # the zero-kept branch reproduces the host loop's
                # launch short-circuit: exact zeros, no kernel math
                counts = jax.lax.cond(
                    n_keep == 0,
                    lambda _: jnp.zeros((1, h_o, w_o, c_o), jnp.float32),
                    compute,
                    None,
                )[0]
            else:
                counts = compute(None)[0]
            outs = {
                "counts": counts,
                "block_keep": keep,
                "kept": n_keep,
                "keyframe": keyframe,
            }
            if head is None:
                return tuple(gate_carry), outs
            eff_prev, logits_prev = carry[4], carry[5]
            if gated:

                def quiet_head(_):
                    return eff_prev, logits_prev

                def live_head(_):
                    eff = jnp.where(window[..., None], counts, eff_prev)
                    return eff, head(head_params, eff[None])[0]

                eff, logits = jax.lax.cond(
                    n_keep == 0, quiet_head, live_head, None
                )
            else:
                eff = counts
                logits = head(head_params, eff[None])[0]
            outs["logits"] = logits
            return tuple(gate_carry) + (eff, logits), outs

        def scan_run(frames, kernel, bn_offset, head_params, gate_args, carry):
            def body(c, frame):
                return tick(kernel, bn_offset, head_params, gate_args, c, frame)

            carry, outs = jax.lax.scan(body, carry, frames)
            outs["ticks"] = jnp.asarray(K, jnp.int32)
            return outs, carry

        def while_run(frames, kernel, bn_offset, head_params, gate_args, carry):
            patience = int(early_exit)
            c_o = kernel.shape[0]
            outs0 = {
                "counts": jnp.zeros((K, h_o, w_o, c_o), jnp.float32),
                "block_keep": jnp.zeros((K, bh, bw), bool),
                "kept": jnp.zeros((K,), jnp.int32),
                "keyframe": jnp.zeros((K,), bool),
            }
            if head is not None:
                outs0["logits"] = jnp.zeros(
                    (K,) + tuple(carry[5].shape), jnp.float32
                )

            def cond_fn(state):
                t, quiet, _, __ = state
                return jnp.logical_and(t < K, quiet < patience)

            def body_fn(state):
                t, quiet, c, outs = state
                frame = jax.lax.dynamic_index_in_dim(
                    frames, t, axis=0, keepdims=False
                )
                c, o = tick(kernel, bn_offset, head_params, gate_args, c, frame)
                outs = {k: outs[k].at[t].set(o[k]) for k in outs}
                quiet = jnp.where(o["kept"] == 0, quiet + 1, 0)
                return t + 1, quiet, c, outs

            t, _, carry, outs = jax.lax.while_loop(
                cond_fn,
                body_fn,
                (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32), carry, outs0),
            )
            outs["ticks"] = t
            return outs, carry

        inner = while_run if early_exit is not None else scan_run

        if gated and head is not None:

            def run(frames, kernel, bn_offset, head_params, gate_args, carry):
                return inner(frames, kernel, bn_offset, head_params, gate_args, carry)

            donate_idx = 5
        elif gated:

            def run(frames, kernel, bn_offset, gate_args, carry):
                return inner(frames, kernel, bn_offset, None, gate_args, carry)

            donate_idx = 4
        elif head is not None:

            def run(frames, kernel, bn_offset, head_params, carry):
                return inner(frames, kernel, bn_offset, head_params, None, carry)

            donate_idx = 4
        else:

            def run(frames, kernel, bn_offset, carry):
                return inner(frames, kernel, bn_offset, None, None, carry)

            donate_idx = 3
        if donate:
            return jax.jit(run, donate_argnums=(donate_idx,))
        return jax.jit(run)


_REGISTRY: dict[str, Backend] = {}


def register_backend(
    name: str,
    *,
    conv: Callable | None = None,
    fused: bool = True,
    differentiable: bool = False,
    bucket_sensitive: bool = True,
    quant_transfer: bool = False,
    description: str = "",
    overwrite: bool = False,
) -> Callable[[Callable], Callable]:
    """Decorator registering an executable factory as backend ``name``.

    The decorated callable must have the signature
    ``factory(model, *, spec, adc, enc, interpret=None, m_bucket=None)`` and
    return a jitted ``(images, kernel, bn_offset) -> counts`` closure —
    ``(images, kernel, bn_offset, window_mask)`` when ``m_bucket`` is set
    (the region-skip compacted serving path).  With
    ``quant_transfer=True`` the factory must additionally accept
    ``transfer="f32" | "int8"`` (the quantised bucket-transfer lowering of
    ``precision="int8"`` model programs); the kwarg is never passed to
    backends registered without it.
    """

    def deco(make_executable: Callable) -> Callable:
        if name in _REGISTRY and not overwrite:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = Backend(
            name=name,
            make_executable=make_executable,
            conv=conv,
            fused=fused,
            differentiable=differentiable,
            bucket_sensitive=bucket_sensitive,
            quant_transfer=quant_transfer,
            description=description,
        )
        return make_executable

    return deco


def get_backend(name: str | Backend) -> Backend:
    """Resolve a backend by name (raises ``ValueError`` listing the options)."""
    if isinstance(name, Backend):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def default_backend_name() -> str:
    """Platform auto-select: the Pallas kernel on TPU, the XLA basis form
    elsewhere (interpret-mode Pallas is validation-only, far too slow to
    serve)."""
    return "pallas" if jax.default_backend() == "tpu" else "basis"


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------


def _fused_conv(impl: str) -> Callable:
    def conv(
        images: jax.Array,
        kernel: jax.Array,
        model: BucketCurvefitModel,
        *,
        spec: FPCASpec,
        adc: ADCConfig,
        enc: WeightEncoding,
        bn_offset: jax.Array,
        interpret: bool | None = None,
        window_mask=None,
    ) -> jax.Array:
        from repro.kernels.fpca_conv.ops import fpca_conv

        return fpca_conv(
            images, kernel, model, spec=spec, adc=adc, enc=enc,
            bn_offset=bn_offset, impl=impl, interpret=interpret,
            window_mask=window_mask,
        )

    return conv


def _fused_factory(impl: str) -> Callable:
    def make_executable(
        model: BucketCurvefitModel,
        *,
        spec: FPCASpec,
        adc: ADCConfig | None = None,
        enc: WeightEncoding | None = None,
        interpret: bool | None = None,
        m_bucket: int | None = None,
        transfer: str = "f32",
    ) -> Callable:
        from repro.kernels.fpca_conv.ops import make_fpca_conv_executable

        return make_fpca_conv_executable(
            model, spec=spec, adc=adc, enc=enc, impl=impl,
            interpret=interpret, m_bucket=m_bucket, transfer=transfer,
        )

    return make_executable


register_backend(
    "pallas",
    conv=_fused_conv("pallas"),
    description="fused TPU Pallas kernel (interpret-mode off-TPU: validation only)",
)(_fused_factory("pallas"))

register_backend(
    "basis",
    conv=_fused_conv("basis"),
    quant_transfer=True,
    description="basis-expanded matmul-bank math lowered through XLA "
    "(fast serving path on non-TPU hosts)",
)(_fused_factory("basis"))


@register_backend(
    "reference",
    fused=False,
    differentiable=True,
    bucket_sensitive=False,   # dense eval + post-hoc mask: one jit serves all buckets
    description="dense jnp simulation (parity oracle; the only "
    "differentiable path)",
)
def _reference_executable(
    model: BucketCurvefitModel,
    *,
    spec: FPCASpec,
    adc: ADCConfig | None = None,
    enc: WeightEncoding | None = None,
    interpret: bool | None = None,
    m_bucket: int | None = None,
) -> Callable:
    """Dense-reference executable serving the same deployment semantics as
    the fused kernels (calibrated bucket-sigmoid model, hard ADC).

    The masked variant evaluates every window and zeroes skipped slots
    post-hoc — the bit-exact oracle the compacted fused paths are pinned
    against; no compute is saved (use a fused backend to serve).
    """
    del interpret  # dense jnp path: nothing to interpret
    adc = adc or ADCConfig()
    enc = enc or WeightEncoding()

    def _counts(images: jax.Array, kernel: jax.Array, bn_offset: jax.Array) -> jax.Array:
        w_pos, w_neg = encode_weights(kernel, spec, enc, hard=True)
        I = extract_windows(images, spec)
        n_active = spec.n_active_pixels
        v_pos = _analog_read(I, w_pos, "bucket_sigmoid", None, model, n_active)
        v_neg = _analog_read(I, w_neg, "bucket_sigmoid", None, model, n_active)
        return updown_readout(v_pos, v_neg, adc, bn_offset, hard=True)

    if m_bucket is None:

        @jax.jit
        def run(images, kernel, bn_offset):
            return _counts(images, kernel, bn_offset)

    else:

        @jax.jit
        def run(images, kernel, bn_offset, window_mask):
            counts = _counts(images, kernel, bn_offset)
            keep = jnp.reshape(window_mask, counts.shape[:-1])
            return counts * keep[..., None].astype(counts.dtype)

    return run
