"""Declarative FPCA program spec — the single source of truth for "what is
programmed into the array".

The paper's headline is *field-programmability*: one pixel array is
dynamically reprogrammed (weights, kernel / channel / stride geometry)
without refabrication.  :class:`FPCAProgram` is that statement as a single
validated dataclass: everything that is **static to a compiled executable**
(sensor geometry, circuit constants, ADC precision, NVM weight encoding)
plus the optional streaming-control plane (delta gate, threshold servo)
composed into one spec with a stable :meth:`~FPCAProgram.signature`.

The split the API enforces:

* the **program** (this module) pins the compiled artifact — two programs
  with equal signatures share one executable;
* the **weights** (NVM conductance planes) enter traced — reprogramming them
  (:meth:`repro.fpca.CompiledFrontend.reprogram`) never recompiles.  That is
  the paper's field-programmability as an API contract, and it is why
  ``kernel`` / ``bn_offset`` are *not* program fields: they live in
  :class:`ProgrammedConfig` (a program bound to weights).

Signatures are **versioned primitive tuples** (ints / floats / strs only, no
dataclass instances), so they are stable across refactors of the config
classes themselves — a golden test pins them, because silently changing a
signature silently invalidates every warm executable cache in a fleet.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.adc import ADCConfig
from repro.core.device_models import CircuitParams
from repro.core.fpca_sim import WeightEncoding
from repro.core.mapping import FPCASpec, output_dims

__all__ = [
    "DeltaGateConfig",
    "GateControllerConfig",
    "FPCAProgram",
    "ProgrammedConfig",
    "spec_signature",
]

# Bump when the *meaning* of a signature field changes; appending new fields
# keeps old-version tuples distinct by construction.
_SIG_VERSION = "repro.fpca/1"


@dataclasses.dataclass(frozen=True)
class DeltaGateConfig:
    """Temporal delta gate knobs (per stream, or per config of a stream)."""

    threshold: float = 0.02      # mean |Δ| per block that counts as "changed"
    hysteresis: int = 1          # frames a block stays live after its change
    keyframe_interval: int = 30  # full-frame refresh period (0 = never)


@dataclasses.dataclass(frozen=True)
class GateControllerConfig:
    """Closed-loop gate-threshold servo knobs (per stream).

    ``target`` is the budget: the kept-window fraction (``metric="keep"``)
    or the executed-energy fraction of a dense readout (``metric="energy"``)
    the stream should settle at.  The servo error is measured *relative to
    the target* — ``(ema - target) / target``, clipped to
    ``[err_low, err_high]`` — so a 5% budget and a 50% budget servo with the
    same gains, and a saturated scene (observation pinned at 0 or 1) applies
    a bounded, steady corrective step instead of a runaway one.

    Gains are in nats of log-threshold per unit of *relative* error;
    ``max_step`` bounds the per-tick actuation.  The integrator **leaks**
    (``leak`` per tick) and is clamped to ``±windup``, and it only
    accumulates while the actuator is unsaturated — three layers of
    anti-windup, because the gate's block statistics give the plant a hard
    cliff (a threshold above every block delta keeps nothing) that a plain
    PI loop winds up against.
    """

    target: float = 0.15
    metric: str = "keep"            # "keep" | "energy"
    ema_alpha: float = 0.4          # EMA weight of the newest observation
    kp: float = 0.35                # proportional gain  [nats / unit rel-error]
    ki: float = 0.03                # integral gain      [nats / unit rel-error]
    max_step: float = 0.4           # |Δ ln threshold| bound per tick [nats]
    leak: float = 0.85              # integrator decay per tick
    windup: float = 2.0             # |integrator| clamp [rel-error ticks]
    err_low: float = -1.0           # rel-error clip (0 kept = exactly -1)
    err_high: float = 3.0
    deadband: float = 0.0           # |rel error| below which the servo holds
    min_threshold: float = 1e-4
    max_threshold: float = 1.0
    history_len: int = 512          # ticks of trajectory retained (no leak)

    def __post_init__(self) -> None:
        if not 0.0 < self.target <= 1.0:
            raise ValueError("target must be in (0, 1]")
        if self.metric not in ("keep", "energy"):
            raise ValueError(f"unknown metric {self.metric!r}")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError("ema_alpha must be in (0, 1]")
        if self.max_step <= 0.0:
            raise ValueError("max_step must be > 0")
        if not 0.0 <= self.leak <= 1.0:
            raise ValueError("leak must be in [0, 1]")
        if self.err_low >= self.err_high:
            raise ValueError("need err_low < err_high")
        if not 0.0 < self.min_threshold <= self.max_threshold:
            raise ValueError("need 0 < min_threshold <= max_threshold")
        if self.history_len < 1:
            raise ValueError("history_len must be >= 1")


def spec_signature(
    spec: FPCASpec, out_channels: int, adc: ADCConfig, enc: WeightEncoding
) -> tuple:
    """Hashable compiled-kernel signature, as a versioned primitive tuple.

    Everything that is *static* to a jitted executable: the spec pins patch
    geometry, ``out_channels`` the weight-plane width, adc/enc the epilogue
    constants.  Weights and BN offsets enter traced, so reprogramming the
    NVM planes does NOT change the signature (no recompile — the point of
    field-programmability).

    The tuple contains only primitives (never the dataclass instances), so
    adding a method or reordering fields on :class:`FPCASpec` /
    :class:`ADCConfig` / :class:`WeightEncoding` cannot silently change it;
    ``tests/test_fpca_api.py`` pins golden values.
    """
    return (
        _SIG_VERSION,
        ("spec", int(spec.image_h), int(spec.image_w), int(spec.out_channels),
         int(spec.kernel), int(spec.stride), int(spec.max_kernel),
         int(spec.in_channels), int(spec.padding), int(spec.binning),
         int(spec.skip_block)),
        ("out_channels", int(out_channels)),
        ("adc", int(adc.bits), float(adc.v_ref)),
        ("enc", int(enc.n_levels), float(enc.w_scale)),
    )


@dataclasses.dataclass(frozen=True)
class FPCAProgram:
    """One validated FPCA array program: the canonical configuration object.

    Composes everything the repo previously scattered across
    ``FPCAFrontendConfig`` (core) and the pipeline/server keyword soup:

    * ``spec``        — sensor + convolution geometry (:class:`FPCASpec`);
    * ``circuit``     — analog circuit constants the bucket model is fitted
      against;
    * ``adc`` / ``enc`` — SS-ADC precision and NVM weight encoding (the
      fused-kernel epilogue constants);
    * ``out_channels`` — programmed weight-plane width; defaults to
      ``spec.out_channels`` but may differ (e.g. a channel-stacked
      multi-config executable);
    * ``gate`` / ``controller`` — optional streaming control plane (temporal
      delta gate and its closed-loop threshold servo).  These are *runtime*
      knobs: they are deliberately **excluded** from :meth:`signature`, so
      retuning a gate never invalidates a compiled executable.

    Weights are not here: a program is the refabrication-free part of the
    paper's story, weights are the cheap NVM rewrite
    (:meth:`repro.fpca.CompiledFrontend.reprogram`).
    """

    spec: FPCASpec
    circuit: CircuitParams = CircuitParams()
    adc: ADCConfig = ADCConfig()
    enc: WeightEncoding = WeightEncoding()
    out_channels: int | None = None
    gate: DeltaGateConfig | None = None
    controller: GateControllerConfig | None = None

    def __post_init__(self) -> None:
        if self.out_channels is None:
            object.__setattr__(self, "out_channels", self.spec.out_channels)
        if int(self.out_channels) < 1:
            raise ValueError("out_channels must be >= 1")
        if self.controller is not None and not isinstance(
            self.controller, GateControllerConfig
        ):
            raise TypeError("controller must be a GateControllerConfig")
        if self.gate is not None and not isinstance(self.gate, DeltaGateConfig):
            raise TypeError("gate must be a DeltaGateConfig")

    # -- derived geometry ----------------------------------------------------
    @property
    def out_shape(self) -> tuple[int, int, int]:
        h_o, w_o = output_dims(self.spec)
        return (h_o, w_o, int(self.out_channels))

    @property
    def kernel_shape(self) -> tuple[int, int, int, int]:
        """Shape of the float kernel this program accepts: (c_o, k, k, c_i)."""
        s = self.spec
        return (int(self.out_channels), s.kernel, s.kernel, s.in_channels)

    # -- identity ------------------------------------------------------------
    def signature(self) -> tuple:
        """Stable compile signature of this program (primitive tuple).

        Extends :func:`spec_signature` with the circuit constants (they are
        baked into the compiled executable through the fitted bucket model).
        ``gate`` / ``controller`` / weights are runtime state and excluded —
        reprogramming any of them must never recompile.  Cached on first
        call: serving layers key handle lookups on it per tick.
        """
        sig = self.__dict__.get("_signature")
        if sig is None:
            circuit = tuple(
                (f.name, float(getattr(self.circuit, f.name)))
                for f in dataclasses.fields(self.circuit)
            )
            sig = spec_signature(
                self.spec, int(self.out_channels), self.adc, self.enc
            ) + (("circuit",) + circuit,)
            object.__setattr__(self, "_signature", sig)
        return sig

    def fanout_signature(self) -> tuple:
        """Compile signature with the channel width normalised out.

        Two programs may fan out into one channel-stacked fused call (their
        NVM planes concatenated, one launch) iff these match: the stacked
        executable serves a single adc/enc/circuit epilogue, so anything
        beyond ``out_channels`` differing would silently mis-serve one of
        them.
        """
        return self.replace(out_channels=1).signature()

    def replace(self, **kw: Any) -> "FPCAProgram":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ProgrammedConfig:
    """A program bound to NVM weights — one named, field-programmed state.

    What a physical FPCA holds at any instant: the compiled-artifact spec
    (:class:`FPCAProgram`) plus the conductance planes currently written to
    the weight die.  Registered into :class:`repro.serving.FPCAPipeline`
    under ``name``; the deprecated ``FrontendConfig`` alias forwards here.
    """

    name: str
    program: FPCAProgram
    kernel: jax.Array               # (c_o, k, k, c_i) float weights
    bn_offset: jax.Array            # (c_o,) counts

    @property
    def spec(self) -> FPCASpec:
        return self.program.spec

    @property
    def out_channels(self) -> int:
        return int(self.program.out_channels)

    @property
    def out_shape(self) -> tuple[int, int, int]:
        return self.program.out_shape
