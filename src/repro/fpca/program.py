"""Declarative FPCA program spec — the single source of truth for "what is
programmed into the array".

The paper's headline is *field-programmability*: one pixel array is
dynamically reprogrammed (weights, kernel / channel / stride geometry)
without refabrication.  :class:`FPCAProgram` is that statement as a single
validated dataclass: everything that is **static to a compiled executable**
(sensor geometry, circuit constants, ADC precision, NVM weight encoding)
plus the optional streaming-control plane (delta gate, threshold servo)
composed into one spec with a stable :meth:`~FPCAProgram.signature`.

The split the API enforces:

* the **program** (this module) pins the compiled artifact — two programs
  with equal signatures share one executable;
* the **weights** (NVM conductance planes) enter traced — reprogramming them
  (:meth:`repro.fpca.CompiledFrontend.reprogram`) never recompiles.  That is
  the paper's field-programmability as an API contract, and it is why
  ``kernel`` / ``bn_offset`` are *not* program fields: they live in
  :class:`ProgrammedConfig` (a program bound to weights).

Signatures are **versioned primitive tuples** (ints / floats / strs only, no
dataclass instances), so they are stable across refactors of the config
classes themselves — a golden test pins them, because silently changing a
signature silently invalidates every warm executable cache in a fleet.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.adc import ADCConfig
from repro.core.device_models import CircuitParams
from repro.core.fpca_sim import WeightEncoding
from repro.core.mapping import FPCASpec, output_dims

__all__ = [
    "DeltaGateConfig",
    "GateControllerConfig",
    "FPCAProgram",
    "ProgrammedConfig",
    "spec_signature",
    # multi-layer model programs (frontend + digital CNN head)
    "ConvSpec",
    "PoolSpec",
    "DenseSpec",
    "ActivationSpec",
    "FPCAModelProgram",
    "ProgrammedModel",
]

# Bump when the *meaning* of a signature field changes; appending new fields
# keeps old-version tuples distinct by construction.
_SIG_VERSION = "repro.fpca/1"
_MODEL_SIG_VERSION = "repro.fpca.model/1"


@dataclasses.dataclass(frozen=True)
class DeltaGateConfig:
    """Temporal delta gate knobs (per stream, or per config of a stream)."""

    threshold: float = 0.02      # mean |Δ| per block that counts as "changed"
    hysteresis: int = 1          # frames a block stays live after its change
    keyframe_interval: int = 30  # full-frame refresh period (0 = never)


@dataclasses.dataclass(frozen=True)
class GateControllerConfig:
    """Closed-loop gate-threshold servo knobs (per stream).

    ``target`` is the budget: the kept-window fraction (``metric="keep"``)
    or the executed-energy fraction of a dense readout (``metric="energy"``)
    the stream should settle at.  The servo error is measured *relative to
    the target* — ``(ema - target) / target``, clipped to
    ``[err_low, err_high]`` — so a 5% budget and a 50% budget servo with the
    same gains, and a saturated scene (observation pinned at 0 or 1) applies
    a bounded, steady corrective step instead of a runaway one.

    Gains are in nats of log-threshold per unit of *relative* error;
    ``max_step`` bounds the per-tick actuation.  The integrator **leaks**
    (``leak`` per tick) and is clamped to ``±windup``, and it only
    accumulates while the actuator is unsaturated — three layers of
    anti-windup, because the gate's block statistics give the plant a hard
    cliff (a threshold above every block delta keeps nothing) that a plain
    PI loop winds up against.
    """

    target: float = 0.15
    metric: str = "keep"            # "keep" | "energy"
    ema_alpha: float = 0.4          # EMA weight of the newest observation
    kp: float = 0.35                # proportional gain  [nats / unit rel-error]
    ki: float = 0.03                # integral gain      [nats / unit rel-error]
    max_step: float = 0.4           # |Δ ln threshold| bound per tick [nats]
    leak: float = 0.85              # integrator decay per tick
    windup: float = 2.0             # |integrator| clamp [rel-error ticks]
    err_low: float = -1.0           # rel-error clip (0 kept = exactly -1)
    err_high: float = 3.0
    deadband: float = 0.0           # |rel error| below which the servo holds
    min_threshold: float = 1e-4
    max_threshold: float = 1.0
    history_len: int = 512          # ticks of trajectory retained (no leak)

    def __post_init__(self) -> None:
        if not 0.0 < self.target <= 1.0:
            raise ValueError("target must be in (0, 1]")
        if self.metric not in ("keep", "energy"):
            raise ValueError(f"unknown metric {self.metric!r}")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError("ema_alpha must be in (0, 1]")
        if self.max_step <= 0.0:
            raise ValueError("max_step must be > 0")
        if not 0.0 <= self.leak <= 1.0:
            raise ValueError("leak must be in [0, 1]")
        if self.err_low >= self.err_high:
            raise ValueError("need err_low < err_high")
        if not 0.0 < self.min_threshold <= self.max_threshold:
            raise ValueError("need 0 < min_threshold <= max_threshold")
        if self.history_len < 1:
            raise ValueError("history_len must be >= 1")


def spec_signature(
    spec: FPCASpec, out_channels: int, adc: ADCConfig, enc: WeightEncoding
) -> tuple:
    """Hashable compiled-kernel signature, as a versioned primitive tuple.

    Everything that is *static* to a jitted executable: the spec pins patch
    geometry, ``out_channels`` the weight-plane width, adc/enc the epilogue
    constants.  Weights and BN offsets enter traced, so reprogramming the
    NVM planes does NOT change the signature (no recompile — the point of
    field-programmability).

    The tuple contains only primitives (never the dataclass instances), so
    adding a method or reordering fields on :class:`FPCASpec` /
    :class:`ADCConfig` / :class:`WeightEncoding` cannot silently change it;
    ``tests/test_fpca_api.py`` pins golden values.
    """
    return (
        _SIG_VERSION,
        ("spec", int(spec.image_h), int(spec.image_w), int(spec.out_channels),
         int(spec.kernel), int(spec.stride), int(spec.max_kernel),
         int(spec.in_channels), int(spec.padding), int(spec.binning),
         int(spec.skip_block)),
        ("out_channels", int(out_channels)),
        ("adc", int(adc.bits), float(adc.v_ref)),
        ("enc", int(enc.n_levels), float(enc.w_scale)),
    )


@dataclasses.dataclass(frozen=True)
class FPCAProgram:
    """One validated FPCA array program: the canonical configuration object.

    Composes everything the repo previously scattered across
    ``FPCAFrontendConfig`` (core) and the pipeline/server keyword soup:

    * ``spec``        — sensor + convolution geometry (:class:`FPCASpec`);
    * ``circuit``     — analog circuit constants the bucket model is fitted
      against;
    * ``adc`` / ``enc`` — SS-ADC precision and NVM weight encoding (the
      fused-kernel epilogue constants);
    * ``out_channels`` — programmed weight-plane width; defaults to
      ``spec.out_channels`` but may differ (e.g. a channel-stacked
      multi-config executable);
    * ``gate`` / ``controller`` — optional streaming control plane (temporal
      delta gate and its closed-loop threshold servo).  These are *runtime*
      knobs: they are deliberately **excluded** from :meth:`signature`, so
      retuning a gate never invalidates a compiled executable.

    Weights are not here: a program is the refabrication-free part of the
    paper's story, weights are the cheap NVM rewrite
    (:meth:`repro.fpca.CompiledFrontend.reprogram`).
    """

    spec: FPCASpec
    circuit: CircuitParams = CircuitParams()
    adc: ADCConfig = ADCConfig()
    enc: WeightEncoding = WeightEncoding()
    out_channels: int | None = None
    gate: DeltaGateConfig | None = None
    controller: GateControllerConfig | None = None

    def __post_init__(self) -> None:
        if self.out_channels is None:
            object.__setattr__(self, "out_channels", self.spec.out_channels)
        if int(self.out_channels) < 1:
            raise ValueError("out_channels must be >= 1")
        if self.controller is not None and not isinstance(
            self.controller, GateControllerConfig
        ):
            raise TypeError("controller must be a GateControllerConfig")
        if self.gate is not None and not isinstance(self.gate, DeltaGateConfig):
            raise TypeError("gate must be a DeltaGateConfig")

    # -- derived geometry ----------------------------------------------------
    @property
    def out_shape(self) -> tuple[int, int, int]:
        h_o, w_o = output_dims(self.spec)
        return (h_o, w_o, int(self.out_channels))

    @property
    def kernel_shape(self) -> tuple[int, int, int, int]:
        """Shape of the float kernel this program accepts: (c_o, k, k, c_i)."""
        s = self.spec
        return (int(self.out_channels), s.kernel, s.kernel, s.in_channels)

    # -- identity ------------------------------------------------------------
    def signature(self) -> tuple:
        """Stable compile signature of this program (primitive tuple).

        Extends :func:`spec_signature` with the circuit constants (they are
        baked into the compiled executable through the fitted bucket model).
        ``gate`` / ``controller`` / weights are runtime state and excluded —
        reprogramming any of them must never recompile.  Cached on first
        call: serving layers key handle lookups on it per tick.
        """
        sig = self.__dict__.get("_signature")
        if sig is None:
            circuit = tuple(
                (f.name, float(getattr(self.circuit, f.name)))
                for f in dataclasses.fields(self.circuit)
            )
            sig = spec_signature(
                self.spec, int(self.out_channels), self.adc, self.enc
            ) + (("circuit",) + circuit,)
            object.__setattr__(self, "_signature", sig)
        return sig

    def fanout_signature(self) -> tuple:
        """Compile signature with the channel width normalised out.

        Two programs may fan out into one channel-stacked fused call (their
        NVM planes concatenated, one launch) iff these match: the stacked
        executable serves a single adc/enc/circuit epilogue, so anything
        beyond ``out_channels`` differing would silently mis-serve one of
        them.
        """
        return self.replace(out_channels=1).signature()

    def replace(self, **kw: Any) -> "FPCAProgram":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ProgrammedConfig:
    """A program bound to NVM weights — one named, field-programmed state.

    What a physical FPCA holds at any instant: the compiled-artifact spec
    (:class:`FPCAProgram`) plus the conductance planes currently written to
    the weight die.  Registered into :class:`repro.serving.FPCAPipeline`
    under ``name``; the deprecated ``FrontendConfig`` alias forwards here.
    """

    name: str
    program: FPCAProgram
    kernel: jax.Array               # (c_o, k, k, c_i) float weights
    bn_offset: jax.Array            # (c_o,) counts

    @property
    def spec(self) -> FPCASpec:
        return self.program.spec

    @property
    def out_channels(self) -> int:
        return int(self.program.out_channels)

    @property
    def out_shape(self) -> tuple[int, int, int]:
        return self.program.out_shape


# ---------------------------------------------------------------------------
# Multi-layer model programs: analog frontend + digital CNN head
# ---------------------------------------------------------------------------
#
# The paper's workload is never the frontend alone — it is a CNN whose FIRST
# layer is the FPCA array (§1/§5, VWW-class classification).  A model program
# promotes the spec from one layer to that whole network: the FPCAProgram
# frontend stage plus a validated sequence of digital stages, compiled behind
# the same `fpca.compile()` with the same split — layer *specs* are static to
# the executable (they extend the signature), trained *parameters* enter
# traced (reprogramming them never recompiles).

_ACTIVATIONS = ("relu", "gelu", "silu", "tanh")


def _check_activation(act: str | None) -> None:
    if act is not None and act not in _ACTIVATIONS:
        raise ValueError(
            f"unknown activation {act!r}; available: {_ACTIVATIONS}"
        )


def _apply_activation(act: str | None, x):
    import jax.nn
    import jax.numpy as jnp

    if act is None:
        return x
    return {
        "relu": jax.nn.relu,
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "tanh": jnp.tanh,
    }[act](x)


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One digital convolution stage of a model head (NHWC, biased)."""

    out_channels: int
    kernel: int
    stride: int = 1
    padding: str = "VALID"          # "VALID" | "SAME"
    activation: str | None = "relu"

    def __post_init__(self) -> None:
        if self.out_channels < 1 or self.kernel < 1 or self.stride < 1:
            raise ValueError("conv out_channels/kernel/stride must be >= 1")
        if self.padding not in ("VALID", "SAME"):
            raise ValueError(f"padding must be VALID or SAME, got {self.padding!r}")
        _check_activation(self.activation)

    def _sig(self) -> tuple:
        return ("conv", int(self.out_channels), int(self.kernel),
                int(self.stride), self.padding, self.activation or "")


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """Spatial pooling stage (``kind``: "max" | "avg")."""

    size: int
    stride: int | None = None       # None = size (non-overlapping)
    kind: str = "max"

    def __post_init__(self) -> None:
        if self.size < 1 or (self.stride is not None and self.stride < 1):
            raise ValueError("pool size/stride must be >= 1")
        if self.kind not in ("max", "avg"):
            raise ValueError(f"pool kind must be max or avg, got {self.kind!r}")

    def _sig(self) -> tuple:
        s = self.size if self.stride is None else self.stride
        return ("pool", self.kind, int(self.size), int(s))


@dataclasses.dataclass(frozen=True)
class DenseSpec:
    """Fully-connected stage (flattens a spatial input); the final stage of
    every head is a DenseSpec — its ``features`` are the class logits."""

    features: int
    activation: str | None = None

    def __post_init__(self) -> None:
        if self.features < 1:
            raise ValueError("dense features must be >= 1")
        _check_activation(self.activation)

    def _sig(self) -> tuple:
        return ("dense", int(self.features), self.activation or "")


@dataclasses.dataclass(frozen=True)
class ActivationSpec:
    """A bare nonlinearity stage (for heads that separate it from conv/dense)."""

    fn: str = "relu"

    def __post_init__(self) -> None:
        _check_activation(self.fn)

    def _sig(self) -> tuple:
        return ("act", self.fn)


LayerSpec = ConvSpec | PoolSpec | DenseSpec | ActivationSpec


@dataclasses.dataclass(frozen=True)
class FPCAModelProgram:
    """One validated multi-layer model: FPCA frontend + digital CNN head.

    * ``frontend``     — the analog first layer (:class:`FPCAProgram`);
    * ``head``         — the digital stages applied to the frontend's SS-ADC
      counts, in order (conv / pool / dense / activation specs).  The last
      stage must be a :class:`DenseSpec` — its features are the class logits;
    * ``input_scale``  — counts -> activation-unit scale applied before the
      head (a trained network exports its digital gain calibration here,
      ``adc.lsb * gain``); compiled into the executable, hence in the
      signature.

    The program/weights split is the frontend's, extended: layer specs are
    static to the compiled executable (signature), trained parameters (NVM
    planes AND head weights) enter traced — reprogramming either never
    recompiles (:meth:`repro.fpca.CompiledModel.reprogram`).

    ``head`` may alternatively be a :class:`repro.models.heads.HeadGraph`
    (residual / multi-branch / detection topologies from the model zoo,
    :mod:`repro.fpca.zoo`); graph heads extend the signature under a
    distinct ``"head_graph"`` tag, so every chain-head signature stays
    byte-identical.  ``arch`` is the registered zoo name this program was
    built under (``None`` for hand-rolled programs) — a telemetry label
    only, deliberately **excluded** from :meth:`signature`.

    ``precision`` selects the digital-head lowering: ``"f32"`` (the
    bit-exact reference) or ``"int8"`` — per-channel symmetric int8
    weights, calibrated int8 activations and int32 accumulation
    (:mod:`repro.models.quant`), parity-bounded against f32.  It is a
    *compile* option (in the signature: the two lowerings are distinct
    executables), but the quantised parameters — scales included — enter
    traced, so :meth:`repro.fpca.CompiledModel.reprogram` stays
    zero-recompile either way.
    """

    frontend: FPCAProgram
    head: Any
    input_scale: float = 1.0
    arch: str | None = None
    precision: str = "f32"

    def __post_init__(self) -> None:
        if not isinstance(self.frontend, FPCAProgram):
            raise TypeError("frontend must be an FPCAProgram")
        if self.precision not in ("f32", "int8"):
            raise ValueError(
                f"unknown precision {self.precision!r}; available: "
                f"('f32', 'int8')"
            )
        from repro.models.heads import HeadGraph

        if isinstance(self.head, HeadGraph):
            if not float(self.input_scale) > 0.0:
                raise ValueError("input_scale must be > 0")
            # validates node geometry against the frontend's output shape
            self.head.shapes(self.frontend.out_shape)
            return
        object.__setattr__(self, "head", tuple(self.head))
        if not self.head:
            raise ValueError("model head needs at least one layer spec")
        for layer in self.head:
            if not isinstance(layer, (ConvSpec, PoolSpec, DenseSpec, ActivationSpec)):
                raise TypeError(f"unknown head layer spec {layer!r}")
        if not isinstance(self.head[-1], DenseSpec):
            raise ValueError(
                "the last head stage must be a DenseSpec (the class logits)"
            )
        if not float(self.input_scale) > 0.0:
            raise ValueError("input_scale must be > 0")
        self.head_shapes()   # validates the layer geometry chains

    # -- derived geometry ----------------------------------------------------
    @property
    def is_graph_head(self) -> bool:
        from repro.models.heads import HeadGraph

        return isinstance(self.head, HeadGraph)

    def head_shapes(self) -> list[tuple[int, ...]]:
        """Output shape after each head stage (index 0 = frontend output)."""
        if self.is_graph_head:
            raise TypeError(
                "head_shapes() is for chain heads; a HeadGraph head exposes "
                "per-node shapes via "
                "model.head.shapes(model.frontend.out_shape)"
            )
        shapes: list[tuple[int, ...]] = [self.frontend.out_shape]
        for i, layer in enumerate(self.head):
            cur = shapes[-1]
            if isinstance(layer, ConvSpec):
                if len(cur) != 3:
                    raise ValueError(
                        f"head[{i}]: conv needs a spatial (h, w, c) input, "
                        f"got shape {cur}"
                    )
                h, w, _ = cur
                if layer.padding == "SAME":
                    h_o = -(-h // layer.stride)
                    w_o = -(-w // layer.stride)
                else:
                    if layer.kernel > h or layer.kernel > w:
                        raise ValueError(
                            f"head[{i}]: conv kernel {layer.kernel} exceeds "
                            f"input {h}x{w}"
                        )
                    h_o = (h - layer.kernel) // layer.stride + 1
                    w_o = (w - layer.kernel) // layer.stride + 1
                shapes.append((h_o, w_o, layer.out_channels))
            elif isinstance(layer, PoolSpec):
                if len(cur) != 3:
                    raise ValueError(
                        f"head[{i}]: pool needs a spatial (h, w, c) input, "
                        f"got shape {cur}"
                    )
                h, w, c = cur
                if layer.size > h or layer.size > w:
                    raise ValueError(
                        f"head[{i}]: pool size {layer.size} exceeds input "
                        f"{h}x{w}"
                    )
                s = layer.size if layer.stride is None else layer.stride
                shapes.append(((h - layer.size) // s + 1,
                               (w - layer.size) // s + 1, c))
            elif isinstance(layer, DenseSpec):
                shapes.append((layer.features,))
            else:                       # ActivationSpec: shape-preserving
                shapes.append(cur)
        return shapes

    @property
    def n_classes(self) -> int:
        if self.is_graph_head:
            return int(self.head.n_classes)
        return int(self.head[-1].features)

    @property
    def head_out_shape(self) -> tuple[int, ...]:
        """Per-example output shape of the head: ``(n_classes,)`` for chain
        classifiers, the graph output shape (e.g. ``(gh, gw, C + 4)`` for a
        detection head) otherwise."""
        if self.is_graph_head:
            return tuple(self.head.out_shape(self.frontend.out_shape))
        return (self.n_classes,)

    @property
    def output_kind(self) -> str:
        """``"logits"`` (classifier) or ``"detections"`` (per-cell maps)."""
        return self.head.output_kind if self.is_graph_head else "logits"

    @property
    def detect_classes(self) -> int | None:
        """Class count of a detection head (``None`` for classifiers) — the
        split point :class:`repro.models.heads.Detections` needs."""
        return self.n_classes if self.output_kind == "detections" else None

    @property
    def spec(self) -> FPCASpec:
        return self.frontend.spec

    @property
    def out_channels(self) -> int:
        return int(self.frontend.out_channels)

    # -- parameters ----------------------------------------------------------
    def init_head(self, key: jax.Array) -> list[dict]:
        """Fresh head parameters: one dict per stage (``{}`` for
        parameterless pool/activation stages) — the pytree
        :meth:`apply_head` consumes and :class:`ProgrammedModel` binds.
        Graph heads return a dict keyed by node name instead."""
        if self.is_graph_head:
            return self.head.init(key, self.frontend.out_shape)
        from repro.models.layers import init_conv2d, init_linear

        params: list[dict] = []
        shapes = self.head_shapes()
        keys = jax.random.split(key, len(self.head))
        for i, layer in enumerate(self.head):
            cur = shapes[i]
            if isinstance(layer, ConvSpec):
                params.append(
                    init_conv2d(keys[i], cur[-1], layer.out_channels, layer.kernel)
                )
            elif isinstance(layer, DenseSpec):
                d_in = 1
                for d in cur:
                    d_in *= int(d)
                params.append(init_linear(keys[i], d_in, layer.features))
            else:
                params.append({})
        return params

    def bind_head_params(self, params: Any) -> Any:
        """Validate + coerce a head parameter pytree for serving — the
        single binding path used by
        :meth:`repro.fpca.CompiledModel.reprogram` and
        :meth:`repro.serving.FPCAPipeline.register`, so a stage-count or
        weight-shape mismatch fails at the call site, not inside a jitted
        trace.

        ``precision="f32"`` binds one f32 dict per stage.  With
        ``precision="int8"`` an already-quantised pytree (``w_q`` leaves,
        e.g. calibrated at export time) is validated and bound as-is; a
        plain f32 pytree is quantised on the spot with the data-free
        full-scale calibration (:func:`repro.models.quant.
        quantize_head_params` — pass explicit ``act_scales`` there for a
        data-calibrated bundle)."""
        if self.precision == "int8":
            from repro.models import quant

            if quant.is_quantized_params(params):
                return quant.bind_quant_head_params(self, params)
            return quant.quantize_head_params(self, params)
        return self._bind_f32(params)

    def _bind_f32(self, params: Any) -> Any:
        """The f32 binding path (also the pre-quantisation validator)."""
        if self.is_graph_head:
            return self.head.bind(params, self.frontend.out_shape)
        import jax.numpy as jnp

        bound = [
            jax.tree_util.tree_map(lambda a: jnp.asarray(a, jnp.float32), dict(p))
            for p in params
        ]
        if len(bound) != len(self.head):
            raise ValueError(
                f"head has {len(self.head)} stages but got {len(bound)} "
                f"parameter entries"
            )
        shapes = self.head_shapes()
        for i, (layer, p) in enumerate(zip(self.head, bound)):
            cur = shapes[i]
            if isinstance(layer, ConvSpec):
                want = {"w": (layer.out_channels, layer.kernel, layer.kernel,
                              cur[-1]),
                        "b": (layer.out_channels,)}
            elif isinstance(layer, DenseSpec):
                d_in = 1
                for d in cur:
                    d_in *= int(d)
                want = {"w": (d_in, layer.features), "b": (layer.features,)}
            else:
                want = {}
            got = {k: tuple(v.shape) for k, v in p.items()}
            if got != want:
                raise ValueError(
                    f"head[{i}] ({type(layer).__name__}): parameter shapes "
                    f"{got} do not match expected {want}"
                )
        return bound

    def apply_head(self, params, counts):
        """The reference head: SS-ADC counts ``(b, h_o, w_o, c_o)`` ->
        logits ``(b, n_classes)``, pure jnp ops (:mod:`repro.models.layers`).

        This function IS the numerics contract: the fused executable
        (:meth:`repro.fpca.CompiledModel.run`) traces exactly these ops after
        the frontend, so its logits are bit-identical to composing a
        frontend handle with this apply.

        With ``precision="int8"`` the contract is instead the quantised
        lowering (:func:`repro.models.quant.apply_head_int8`): same dispatch
        site, so every executable — fused model jit, head jit, patched
        streaming head, in-scan segment head — serves the int8 path.
        """
        import jax.numpy as jnp

        from repro.models.layers import avg_pool2d, conv2d, linear, max_pool2d

        if self.precision == "int8":
            from repro.models.quant import apply_head_int8

            return apply_head_int8(self, params, counts)
        if self.is_graph_head:
            x = jnp.asarray(counts, jnp.float32) * jnp.float32(self.input_scale)
            return self.head.apply(params, x)
        if len(params) != len(self.head):
            raise ValueError(
                f"head has {len(self.head)} stages but got {len(params)} "
                f"parameter entries"
            )
        x = jnp.asarray(counts, jnp.float32) * jnp.float32(self.input_scale)
        for layer, p in zip(self.head, params):
            if isinstance(layer, ConvSpec):
                x = _apply_activation(
                    layer.activation, conv2d(p, x, layer.stride, layer.padding)
                )
            elif isinstance(layer, PoolSpec):
                pool = max_pool2d if layer.kind == "max" else avg_pool2d
                x = pool(x, layer.size, layer.stride)
            elif isinstance(layer, DenseSpec):
                if x.ndim > 2:
                    x = x.reshape(x.shape[0], -1)
                x = _apply_activation(layer.activation, linear(p, x))
            else:
                x = _apply_activation(layer.fn, x)
        return x

    # -- identity ------------------------------------------------------------
    def signature(self) -> tuple:
        """Stable model compile signature: a versioned primitive tuple
        extending the frontend's (golden-pinned in
        ``tests/test_fpca_model.py``).  Head *specs* and ``input_scale`` are
        compiled in; head *parameters* (like NVM weights) are runtime state
        and excluded — reprogramming them never recompiles."""
        sig = self.__dict__.get("_signature")
        if sig is None:
            if self.is_graph_head:
                head_sig = ("head_graph",) + self.head._sig_entries()
            else:
                head_sig = ("head",) + tuple(
                    layer._sig() for layer in self.head
                )
            sig = (
                (_MODEL_SIG_VERSION,)
                + self.frontend.signature()
                + (head_sig, ("input_scale", float(self.input_scale)))
            )
            if self.precision != "f32":
                # appended only off the f32 default, so every pre-existing
                # f32 signature stays byte-identical (golden-pinned)
                sig = sig + (("precision", self.precision),)
            object.__setattr__(self, "_signature", sig)
        return sig

    def replace(self, **kw: Any) -> "FPCAModelProgram":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ProgrammedModel:
    """A model program bound to its trained parameters — NVM planes for the
    analog frontend plus the head weight pytree, the way
    :class:`ProgrammedConfig` binds a frontend program to NVM weights.

    Registered into :class:`repro.serving.FPCAPipeline` under ``name``;
    ``program`` exposes the *frontend* program so every spec-bucketing /
    channel-stacking path treats a model config exactly like a frontend one.
    """

    name: str
    model: FPCAModelProgram
    kernel: jax.Array               # (c_o, k, k, c_i) float NVM weights
    bn_offset: jax.Array            # (c_o,) counts
    head_params: Any                # pytree matching model.init_head()

    @property
    def program(self) -> FPCAProgram:
        return self.model.frontend

    @property
    def spec(self) -> FPCASpec:
        return self.model.frontend.spec

    @property
    def out_channels(self) -> int:
        return int(self.model.frontend.out_channels)

    @property
    def out_shape(self) -> tuple[int, int, int]:
        return self.model.frontend.out_shape
