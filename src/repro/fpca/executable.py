"""``compile()`` and :class:`CompiledFrontend` — the explicit executable
handle of the unified FPCA API.

The paper's programming model, as an API contract::

    program = FPCAProgram(spec=FPCASpec(...))      # what to fabricate-free
    fe = fpca.compile(program, backend="basis")    # compile the array once
    fe.reprogram(kernel)                           # cheap NVM rewrite
    counts = fe.run(batch)                         # fused serving call
    fe.reprogram(other_kernel)                     # STILL zero recompiles
    for result in fe.stream(frames):               # delta-gated streaming
        ...

``compile()`` fits (or accepts) the calibrated bucket model, resolves the
backend from the registry and returns a handle that owns everything that
used to be implicit module / scheduler state: the bounded LRU of jitted
executables (introspectable via :meth:`CompiledFrontend.cache_info`), the
sticky region-skip row buckets, batch padding + mesh sharding, and the
executed-window accounting (:attr:`CompiledFrontend.stats`).

Reprogramming is guaranteed recompile-free because weights enter every
executable *traced* while the cache key is the program's
:meth:`~repro.fpca.FPCAProgram.signature` (which excludes weights by
construction) — asserted by the API test suite via ``cache_info()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.curvefit import BucketCurvefitModel, fit_bucket_model
from repro.core.mapping import FPCASpec, active_window_mask, output_dims
from repro.fpca.backends import Backend, default_backend_name, get_backend
from repro.fpca.cache import CacheInfo, ExecutableCache
from repro.fpca.program import FPCAProgram
from repro.kernels.fpca_conv.ops import StickyBucket
from repro.launch.mesh import data_axes, data_extent

__all__ = ["FrontendStats", "CompiledFrontend", "compile"]

_USE_PROGRAM = object()   # stream() sentinel: "inherit from program"


@dataclasses.dataclass
class FrontendStats:
    """Per-handle serving counters (all monotonic)."""

    runs: int = 0                   # fused executable invocations
    reprograms: int = 0             # NVM weight rewrites
    windows_total: int = 0          # windows submitted (incl. batch padding)
    windows_executed: int = 0       # windows that actually reached the kernel
    launches_skipped: int = 0       # all-skipped batches short-circuited
    bucket_switches: int = 0        # served bucket-size transitions
    bucket_shrinks_deferred: int = 0  # flap events sticky hysteresis absorbed

    def snapshot(self) -> tuple[int, ...]:
        return dataclasses.astuple(self)


def _round_up_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class CompiledFrontend:
    """An explicitly-held FPCA executable: one program, one backend, weights
    swappable without recompiling.

    Construct via :func:`compile`.  The handle is the unit every serving
    layer now composes over: :class:`repro.serving.FPCAPipeline` keeps one
    per distinct compile signature (sharing one :class:`ExecutableCache`),
    and :meth:`stream` gives single-camera continuous vision without any
    scheduler at all.
    """

    def __init__(
        self,
        program: FPCAProgram,
        *,
        backend: Backend,
        model: BucketCurvefitModel,
        mesh: jax.sharding.Mesh | None = None,
        cache: ExecutableCache | None = None,
        cache_capacity: int = 8,
        bucket_patience: int = 1,
        interpret: bool | None = None,
    ):
        if bucket_patience < 1:
            raise ValueError("bucket_patience must be >= 1")
        self.program = program
        self.backend = backend
        self.model = model
        self.mesh = mesh
        self.interpret = interpret
        self.bucket_patience = bucket_patience
        self._cache = cache if cache is not None else ExecutableCache(cache_capacity)
        self._sig = program.signature()
        self._sticky: dict[int, StickyBucket] = {}   # keyed by padded window count
        self._kernel: jax.Array | None = None
        self._bn: jax.Array | None = None
        self.stats = FrontendStats()

    # -- introspection -------------------------------------------------------
    @property
    def spec(self) -> FPCASpec:
        return self.program.spec

    @property
    def out_channels(self) -> int:
        return int(self.program.out_channels)

    @property
    def out_shape(self) -> tuple[int, int, int]:
        return self.program.out_shape

    @property
    def kernel(self) -> jax.Array | None:
        """Currently programmed NVM weights (None until :meth:`reprogram`)."""
        return self._kernel

    @property
    def bn_offset(self) -> jax.Array | None:
        return self._bn

    def signature(self) -> tuple:
        return self._sig

    def cache_info(self) -> CacheInfo:
        """LRU executable-cache counters (``hits/misses/evictions/currsize``).

        ``misses`` counts compiles: it must not move across
        :meth:`reprogram` — the field-programmability contract."""
        return self._cache.info()

    def reset_bucket_state(self) -> None:
        """Forget sticky row-bucket state (counters in ``stats`` remain)."""
        self._sticky.clear()

    # -- programming ---------------------------------------------------------
    def reprogram(
        self, kernel: Any, bn_offset: Any | None = None
    ) -> "CompiledFrontend":
        """Rewrite the NVM weight planes (and BN offsets) in place.

        Guaranteed not to recompile: weights enter every executable traced,
        and the cache key is the program signature, which excludes them by
        construction.  Returns ``self`` so ``compile(...).reprogram(k)``
        chains.
        """
        kernel = jnp.asarray(kernel, jnp.float32)
        want = self.program.kernel_shape
        if tuple(kernel.shape) != want:
            raise ValueError(
                f"kernel shape {tuple(kernel.shape)} does not match program "
                f"kernel shape {want}"
            )
        if bn_offset is None:
            bn_offset = (
                self._bn
                if self._bn is not None
                else jnp.zeros((self.out_channels,), jnp.float32)
            )
        bn_offset = jnp.asarray(bn_offset, jnp.float32)
        if bn_offset.shape != (self.out_channels,):
            raise ValueError(
                f"bn_offset shape {tuple(bn_offset.shape)} != "
                f"({self.out_channels},)"
            )
        self._kernel = kernel
        self._bn = bn_offset
        self.stats.reprograms += 1
        return self

    # -- execution -----------------------------------------------------------
    def run(
        self,
        images: Any,
        *,
        block_mask: np.ndarray | None = None,
        window_keep: np.ndarray | None = None,
    ) -> jax.Array:
        """Serve one frame ``(H, W, c_i)`` or batch ``(B, H, W, c_i)``.

        ``block_mask`` is the §3.4.5 per-block keep grid (one grid applied
        to every frame, or a leading batch axis of grids); ``window_keep``
        is the already-derived per-window ``(B, h_o, w_o)`` boolean mask —
        pass at most one.  Skipped windows never execute on fused backends
        and come back as exact zeros.  Dispatch is non-blocking (jax async);
        the squeezed result mirrors the input's batchedness.
        """
        if self._kernel is None:
            raise RuntimeError(
                "no weights programmed: call reprogram(kernel) first "
                "(or pass weights= to compile())"
            )
        images = jnp.asarray(images, jnp.float32)
        squeeze = images.ndim == 3
        if squeeze:
            images = images[None]
        if block_mask is not None:
            if window_keep is not None:
                raise ValueError("pass block_mask or window_keep, not both")
            block_mask = np.asarray(block_mask)
            if block_mask.ndim == 2:
                keep = active_window_mask(self.spec, block_mask)
                window_keep = np.broadcast_to(
                    keep, (images.shape[0],) + keep.shape
                )
            else:
                window_keep = np.stack(
                    [active_window_mask(self.spec, m) for m in block_mask]
                )
        counts = self.run_weighted(self._kernel, self._bn, images, window_keep)
        return counts[0] if squeeze else counts

    def run_weighted(
        self,
        kernel: jax.Array,
        bn_offset: jax.Array,
        images: jax.Array,
        window_keep: np.ndarray | None = None,
    ) -> jax.Array:
        """One fused executable call with explicit weights — the core
        dispatch every serving layer routes to.

        ``images`` is a ``(b, H, W, c_i)`` batch; ``window_keep`` an optional
        per-window ``(b, h_o, w_o)`` boolean keep grid.  The batch is padded
        to its pow-2 bucket (mesh-aligned), padding frames are masked out
        *in-kernel* whenever a keep grid is present, and the call is
        dispatched asynchronously — the returned array is unrealised, so
        callers can overlap host prep with device compute and block later.

        The weights are per-call state (this is what lets
        :class:`repro.serving.FPCAPipeline` serve many programmed
        configurations — including channel-stacked fan-outs — through
        signature-shared handles); :meth:`run` binds the handle's own
        programmed weights.
        """
        spec = self.spec
        images = jnp.asarray(images, jnp.float32)
        want = (spec.image_h, spec.image_w, spec.in_channels)
        if images.ndim != 4 or images.shape[1:] != want:
            raise ValueError(
                f"expected (b, {want[0]}, {want[1]}, {want[2]}) batch, "
                f"got {images.shape}"
            )
        c_o = int(kernel.shape[0])
        if c_o != self.out_channels:
            raise ValueError(
                f"kernel has {c_o} output channels; this handle is compiled "
                f"for {self.out_channels}"
            )
        b = images.shape[0]
        h_o, w_o = output_dims(spec)
        if window_keep is not None and window_keep.shape != (b, h_o, w_o):
            raise ValueError(
                f"window_keep shape {window_keep.shape} != {(b, h_o, w_o)}"
            )
        padded = self._padded_batch(b)
        if padded > b:
            images = jnp.pad(images, ((0, padded - b), (0, 0), (0, 0), (0, 0)))
            if window_keep is not None:
                window_keep = np.concatenate(
                    [window_keep, np.zeros((padded - b, h_o, w_o), bool)]
                )
        m_total = padded * h_o * w_o
        self.stats.windows_total += m_total
        if window_keep is None:
            images = self._shard_batch(images)
            self.stats.runs += 1
            run = self._executable(None)
            self.stats.windows_executed += m_total
            return run(images, kernel, bn_offset)[:b]
        n_keep = int(np.count_nonzero(window_keep))
        if n_keep == 0:
            # all-skipped tick: the result is exact zeros by contract, so no
            # kernel launches at all (0 executed windows in the stats); the
            # sticky bucket still counts the tick as under-full so a stale
            # large bucket shrinks on the first active tick after the lull
            self.stats.launches_skipped += 1
            sticky = self._sticky.get(m_total)
            if sticky is not None:
                sticky.observe_idle()
            return jnp.zeros((b, h_o, w_o, c_o), jnp.float32)
        images = self._shard_batch(images)
        self.stats.runs += 1
        m_bucket = self._bucket_for(n_keep, m_total)
        run = self._executable(m_bucket)
        self.stats.windows_executed += m_bucket
        return run(images, kernel, bn_offset, jnp.asarray(window_keep))[:b]

    def stream(
        self,
        frames: Iterable[Any],
        *,
        gate: Any = _USE_PROGRAM,
        controller: Any = _USE_PROGRAM,
        depth: int = 2,
        stream_id: str = "stream0",
    ) -> Iterator[Any]:
        """Serve a continuous frame stream through this handle.

        The single-camera counterpart of
        :class:`repro.serving.StreamServer`: each frame steps a temporal
        delta gate (defaults to ``program.gate``; pass an explicit
        ``gate=None`` for a dense readout even on a gated program),
        optionally servoed by a closed-loop threshold controller (defaults
        to ``program.controller``; explicit ``None`` disables), and the
        resulting keep mask is compacted in-kernel.  Up to ``depth`` ticks
        stay in flight (dispatch is non-blocking), results yield strictly in
        frame order as :class:`repro.serving.streaming.StreamFrameResult`.
        """
        import collections as _collections

        from repro.serving.control import GateController
        from repro.serving.streaming import StreamFrameResult, StreamSession

        if depth < 1:
            raise ValueError("depth must be >= 1")
        gate = self.program.gate if gate is _USE_PROGRAM else gate
        cconf = (
            self.program.controller
            if controller is _USE_PROGRAM
            else controller
        )
        ctl = (
            GateController(cconf, self.spec, gate.threshold)
            if (cconf is not None and gate is not None)
            else None
        )
        session = StreamSession(stream_id, "__compiled__", self.spec, gate,
                                controller=ctl)
        self._stream_session = session   # introspectable (controller history)
        h_o, w_o = output_dims(self.spec)

        def _finalize(entry: dict) -> StreamFrameResult:
            return StreamFrameResult(
                stream_id=stream_id,
                frame_idx=entry["frame_idx"],
                counts=np.asarray(entry["counts"])[0],   # blocks until ready
                block_mask=entry["block_mask"],
                kept_windows=entry["kept"],
                total_windows=h_o * w_o,
                config="__compiled__",
            )

        inflight: _collections.deque[dict] = _collections.deque()
        for frame in frames:
            frame = np.asarray(frame, np.float32)
            frame_idx = session.frame_idx
            block = session.step(frame)
            window = session.last_window_mask if gate is not None else None
            kept = int(window.sum()) if window is not None else h_o * w_o
            counts = self.run_weighted(
                self._require_weights(), self._bn, jnp.asarray(frame)[None],
                None if window is None else window[None],
            )
            inflight.append(
                {"frame_idx": frame_idx, "counts": counts,
                 "block_mask": block, "kept": kept}
            )
            while len(inflight) > depth:
                yield _finalize(inflight.popleft())
        while inflight:
            yield _finalize(inflight.popleft())

    # -- internals -----------------------------------------------------------
    def _require_weights(self) -> jax.Array:
        if self._kernel is None:
            raise RuntimeError(
                "no weights programmed: call reprogram(kernel) first"
            )
        return self._kernel

    def _padded_batch(self, b: int) -> int:
        padded = _round_up_pow2(b)
        if self.mesh is not None:
            n_data = data_extent(self.mesh)
            padded = -(-padded // n_data) * n_data
        return padded

    def _shard_batch(self, images: jax.Array) -> jax.Array:
        if self.mesh is None:
            return images
        P = jax.sharding.PartitionSpec
        sharding = jax.sharding.NamedSharding(
            self.mesh, P(data_axes(self.mesh), *([None] * (images.ndim - 1)))
        )
        return jax.device_put(images, sharding)

    def _executable(self, m_bucket: int | None) -> Callable:
        # bucket-insensitive backends (dense eval + post-hoc mask) serve
        # every bucket size with one executable: collapse the key so sticky
        # bucket transitions don't churn the shared LRU with identical jits
        if m_bucket is not None and not self.backend.bucket_sensitive:
            m_bucket = -1
        key = self._sig + (self.backend.name, m_bucket)

        def build() -> Callable:
            # a FRESH jitted closure per signature: its compiled programs are
            # owned by the closure, so LRU eviction genuinely frees the
            # executable (a shared module-level jit cache would keep them
            # alive).
            return self.backend.make_executable(
                self.model,
                spec=self.spec,
                adc=self.program.adc,
                enc=self.program.enc,
                interpret=self.interpret,
                m_bucket=m_bucket,
            )

        return self._cache.get(key, build)

    def _bucket_for(self, n_keep: int, m_total: int) -> int:
        """Sticky row bucket for one (handle, window-count) batch shape.

        With ``bucket_patience=1`` this is exactly
        :func:`repro.kernels.fpca_conv.ops.window_bucket`, but bucket
        transitions are still counted — ``stats.bucket_switches`` is the
        flap count a hysteresis-free server pays.
        """
        sticky = self._sticky.get(m_total)
        if sticky is None:
            sticky = self._sticky[m_total] = StickyBucket(self.bucket_patience)
        before = (sticky.switches, sticky.shrinks_deferred)
        m_bucket = sticky.bucket(n_keep, m_total)
        self.stats.bucket_switches += sticky.switches - before[0]
        self.stats.bucket_shrinks_deferred += sticky.shrinks_deferred - before[1]
        return m_bucket


def compile(  # noqa: A001  (torch.compile-style public name)
    program: FPCAProgram | FPCASpec,
    *,
    backend: str | Backend | None = None,
    mesh: jax.sharding.Mesh | None = None,
    weights: Any | None = None,
    bn_offset: Any | None = None,
    model: BucketCurvefitModel | None = None,
    cache: ExecutableCache | None = None,
    cache_capacity: int = 8,
    bucket_patience: int = 1,
    interpret: bool | None = None,
) -> CompiledFrontend:
    """Compile an :class:`FPCAProgram` into a held executable handle.

    Args:
      program: the validated program spec (a bare :class:`FPCASpec` is
        wrapped in a default program for convenience).
      backend: registered backend name (see
        :func:`repro.fpca.available_backends`) or a :class:`Backend`
        instance; ``None`` auto-selects by platform (Pallas on TPU, the XLA
        basis form elsewhere).
      mesh: optional ``jax.sharding.Mesh`` — batches shard over its data
        axes and batch padding rounds up to the data-axis extent.
      weights / bn_offset: optionally program the NVM planes immediately
        (equivalent to calling :meth:`CompiledFrontend.reprogram`).
      model: fitted :class:`BucketCurvefitModel`; fitted on demand from
        ``program.circuit`` when omitted (a one-off ~seconds calibration, as
        a deployment would run once).
      cache: share a bounded :class:`ExecutableCache` across handles (the
        pipeline does this to bound total live executables); a private cache
        of ``cache_capacity`` otherwise.
      bucket_patience: sticky-bucket hysteresis for region-skip row buckets
        (``1`` = stateless).
      interpret: forwarded to Pallas (default: interpret off-TPU).
    """
    if isinstance(program, FPCASpec):
        program = FPCAProgram(spec=program)
    if not isinstance(program, FPCAProgram):
        raise TypeError(f"expected FPCAProgram or FPCASpec, got {type(program)}")
    be = get_backend(backend if backend is not None else default_backend_name())
    if model is None:
        model = fit_bucket_model(
            program.circuit, n_pixels=program.spec.n_active_pixels
        )
    handle = CompiledFrontend(
        program,
        backend=be,
        model=model,
        mesh=mesh,
        cache=cache,
        cache_capacity=cache_capacity,
        bucket_patience=bucket_patience,
        interpret=interpret,
    )
    if weights is not None:
        handle.reprogram(weights, bn_offset)
    return handle
