"""``compile()`` and :class:`CompiledFrontend` — the explicit executable
handle of the unified FPCA API.

The paper's programming model, as an API contract::

    program = FPCAProgram(spec=FPCASpec(...))      # what to fabricate-free
    fe = fpca.compile(program, backend="basis")    # compile the array once
    fe.reprogram(kernel)                           # cheap NVM rewrite
    counts = fe.run(batch)                         # fused serving call
    fe.reprogram(other_kernel)                     # STILL zero recompiles
    for result in fe.stream(frames):               # delta-gated streaming
        ...

``compile()`` fits (or accepts) the calibrated bucket model, resolves the
backend from the registry and returns a handle that owns everything that
used to be implicit module / scheduler state: the bounded LRU of jitted
executables (introspectable via :meth:`CompiledFrontend.cache_info`), the
sticky region-skip row buckets, batch padding + mesh sharding, and the
executed-window accounting (:attr:`CompiledFrontend.stats`).

Reprogramming is guaranteed recompile-free because weights enter every
executable *traced* while the cache key is the program's
:meth:`~repro.fpca.FPCAProgram.signature` (which excludes weights by
construction) — asserted by the API test suite via ``cache_info()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gating
from repro.core.curvefit import BucketCurvefitModel, fit_bucket_model
from repro.core.mapping import FPCASpec, active_window_mask, output_dims
from repro.fpca import telemetry
from repro.fpca.backends import Backend, default_backend_name, get_backend
from repro.fpca.cache import CacheInfo, CacheInfoVerbose, ExecutableCache
from repro.fpca.program import FPCAProgram
from repro.kernels.fpca_conv.ops import StickyBucket, segment_bucket
from repro.launch.mesh import data_axes, data_extent

__all__ = [
    "FrontendStats",
    "SegmentState",
    "SegmentResult",
    "CompiledFrontend",
    "CompiledModel",
    "compile",
]

_USE_PROGRAM = object()   # stream() sentinel: "inherit from program"

# Model-side workload accounting, broken out per zoo architecture (the
# ``arch`` stamp on FPCAModelProgram; "custom" for hand-rolled programs).
# These are fleet-global labeled families — fleet_report()'s "workloads"
# table and the Prometheus render split classifier vs detection vs event
# traffic from them without any per-handle plumbing.
_C_MODEL_RUNS = telemetry.registry().counter(
    "fpca_model_runs_total",
    "model-side executable dispatches (fused, patched or segment)",
    ("arch",), max_label_sets=64,
)
_C_MODEL_FRAMES = telemetry.registry().counter(
    "fpca_model_frames_total",
    "frames/ticks served by model-side dispatches",
    ("arch",), max_label_sets=64,
)


class FrontendStats(telemetry.StatsView):
    """Per-handle serving counters (all monotonic) — thin views over
    :mod:`repro.fpca.telemetry` registry cells.

    Fields (in ``snapshot()`` order):

    * ``runs``              — fused executable invocations
    * ``reprograms``        — NVM weight rewrites
    * ``windows_total``     — windows submitted (incl. batch padding)
    * ``windows_executed``  — windows that actually reached the kernel
    * ``launches_skipped``  — all-skipped ticks that launched no kernel
      (per-tick short-circuits AND in-scan zero-kept ticks of compiled
      segments)
    * ``bucket_switches``   — served bucket-size transitions
    * ``bucket_shrinks_deferred`` — flap events sticky hysteresis absorbed
    * ``segments``          — device-compiled segment launches
    * ``segment_ticks``     — ticks served from inside those launches

    When the handle is owned by a :class:`repro.serving.FPCAPipeline` the
    cells are parent-chained into the pipeline's ``PipelineStats`` (same
    field names), so every increment lands in exactly one place and the
    fleet totals can never drift from the per-handle counters.
    """

    _PREFIX = "fpca_frontend"
    # fleet wiring: a handle run is one pipeline batch; reprograms stay
    # per-handle (no pipeline-level counterpart)
    _PARENT_MAP = {"runs": "batches", "reprograms": None}
    _FIELDS = (
        "runs",
        "reprograms",
        "windows_total",
        "windows_executed",
        "launches_skipped",
        "bucket_switches",
        "bucket_shrinks_deferred",
        "segments",
        "segment_ticks",
    )


@dataclasses.dataclass
class SegmentState:
    """Carry threaded between :meth:`CompiledFrontend.run_segment` calls.

    The first four fields are the device-resident delta-gate state
    (:class:`repro.core.gating.GateCarry`); model segments add the effective
    activation map and previous logits.  ``suggested_bucket`` is a host-side
    hint — the compacted-row bucket the finished segment's kept counts size
    for the next one (:func:`repro.kernels.fpca_conv.ops.segment_bucket`).
    Treat instances as opaque: thread the ``state`` of one
    :class:`SegmentResult` into the next call.  When the segment ran with
    buffer donation, the *previous* state's arrays are dead after the call.
    """

    has_prev: Any
    prev_eff: Any
    age: Any
    frame_idx: Any
    eff: Any | None = None           # model segments: effective activation map
    logits: Any | None = None        # model segments: previous logits
    suggested_bucket: int | None = None

    def carry(self, model: bool) -> tuple:
        c = (
            jnp.asarray(self.has_prev, bool),
            jnp.asarray(self.prev_eff, jnp.float32),
            jnp.asarray(self.age, jnp.int32),
            jnp.asarray(self.frame_idx, jnp.int32),
        )
        if model:
            if self.eff is None or self.logits is None:
                raise ValueError(
                    "model segment needs a state carrying (eff, logits) — "
                    "thread the state a CompiledModel.run_segment returned"
                )
            c += (
                jnp.asarray(self.eff, jnp.float32),
                jnp.asarray(self.logits, jnp.float32),
            )
        return c


@dataclasses.dataclass
class SegmentResult:
    """Outputs of one device-compiled streaming segment.

    Per-tick arrays span the full compiled ``length`` K; with early exit
    only the first ``ticks`` entries are meaningful (``counts`` rows past
    ``ticks`` are zeros, ``kept_windows`` zeros, masks False).  ``counts``
    (and ``logits``) stay unrealised device arrays so callers can overlap
    the next segment's host work; the small per-tick bookkeeping arrays are
    realised eagerly for stats and the boundary servo.
    """

    counts: Any                      # (K, h_o, w_o, c_o) device array
    block_masks: np.ndarray          # (K, bh, bw) bool
    kept_windows: np.ndarray         # (K,) int
    keyframes: np.ndarray            # (K,) bool
    rows_executed: np.ndarray        # (K,) int — compacted rows per tick
    ticks: int                       # ticks actually executed (== K, or fewer
    #                                  when early_exit stopped on a quiet scene)
    length: int                      # compiled segment length K
    first_frame_idx: int             # stream frame index of tick 0
    gated: bool
    state: SegmentState
    logits: Any | None = None        # model segments: (K,) + head_out_shape
    detect_classes: int | None = None  # detection segments: class count

    def detections(self) -> list:
        """Per-tick :class:`repro.models.heads.Detections` of a detection
        segment (first ``ticks`` entries; raises for classifier segments)."""
        if self.detect_classes is None:
            raise ValueError(
                "not a detection segment: this model's head emits logits"
            )
        from repro.models.heads import Detections

        raw = np.asarray(self.logits)[: self.ticks]
        return [Detections.from_raw(r, self.detect_classes) for r in raw]


def _round_up_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class CompiledFrontend:
    """An explicitly-held FPCA executable: one program, one backend, weights
    swappable without recompiling.

    Construct via :func:`compile`.  The handle is the unit every serving
    layer now composes over: :class:`repro.serving.FPCAPipeline` keeps one
    per distinct compile signature (sharing one :class:`ExecutableCache`),
    and :meth:`stream` gives single-camera continuous vision without any
    scheduler at all.
    """

    def __init__(
        self,
        program: FPCAProgram,
        *,
        backend: Backend,
        model: BucketCurvefitModel,
        mesh: jax.sharding.Mesh | None = None,
        cache: ExecutableCache | None = None,
        cache_capacity: int = 8,
        bucket_patience: int = 1,
        interpret: bool | None = None,
        stats_parent: telemetry.StatsView | None = None,
    ):
        if bucket_patience < 1:
            raise ValueError("bucket_patience must be >= 1")
        self.program = program
        self.backend = backend
        self.model = model
        self.mesh = mesh
        self.interpret = interpret
        self.bucket_patience = bucket_patience
        self._cache = cache if cache is not None else ExecutableCache(cache_capacity)
        self._sig = program.signature()
        self._sticky: dict[int, StickyBucket] = {}   # keyed by padded window count
        self._kernel: jax.Array | None = None
        self._bn: jax.Array | None = None
        # parent-chained when a pipeline owns the handle: shared-name fields
        # (windows_executed, launches_skipped, ...) single-source into the
        # pipeline's PipelineStats cells
        self.stats = FrontendStats(parent=stats_parent)

    # -- introspection -------------------------------------------------------
    @property
    def spec(self) -> FPCASpec:
        return self.program.spec

    @property
    def out_channels(self) -> int:
        return int(self.program.out_channels)

    @property
    def out_shape(self) -> tuple[int, int, int]:
        return self.program.out_shape

    @property
    def kernel(self) -> jax.Array | None:
        """Currently programmed NVM weights (None until :meth:`reprogram`)."""
        return self._kernel

    @property
    def bn_offset(self) -> jax.Array | None:
        return self._bn

    def signature(self) -> tuple:
        return self._sig

    def cache_info(self, verbose: bool = False) -> CacheInfo | CacheInfoVerbose:
        """LRU executable-cache counters (``hits/misses/evictions/currsize``).

        ``misses`` counts compiles: it must not move across
        :meth:`reprogram` — the field-programmability contract.
        ``verbose=True`` adds the per-signature hit/miss breakdown, the
        resident keys in LRU order, and the bounded eviction history."""
        return self._cache.info(verbose=verbose)

    def reset_bucket_state(self) -> None:
        """Forget sticky row-bucket state (counters in ``stats`` remain)."""
        self._sticky.clear()

    # -- programming ---------------------------------------------------------
    def reprogram(
        self, kernel: Any, bn_offset: Any | None = None
    ) -> "CompiledFrontend":
        """Rewrite the NVM weight planes (and BN offsets) in place.

        Guaranteed not to recompile: weights enter every executable traced,
        and the cache key is the program signature, which excludes them by
        construction.  Returns ``self`` so ``compile(...).reprogram(k)``
        chains.
        """
        kernel = jnp.asarray(kernel, jnp.float32)
        want = self.program.kernel_shape
        if tuple(kernel.shape) != want:
            raise ValueError(
                f"kernel shape {tuple(kernel.shape)} does not match program "
                f"kernel shape {want}"
            )
        if bn_offset is None:
            bn_offset = (
                self._bn
                if self._bn is not None
                else jnp.zeros((self.out_channels,), jnp.float32)
            )
        bn_offset = jnp.asarray(bn_offset, jnp.float32)
        if bn_offset.shape != (self.out_channels,):
            raise ValueError(
                f"bn_offset shape {tuple(bn_offset.shape)} != "
                f"({self.out_channels},)"
            )
        with telemetry.span("reprogram"):
            self._kernel = kernel
            self._bn = bn_offset
            self.stats.reprograms += 1
        return self

    # -- execution -----------------------------------------------------------
    def run(
        self,
        images: Any,
        *,
        block_mask: np.ndarray | None = None,
        window_keep: np.ndarray | None = None,
    ) -> jax.Array:
        """Serve one frame ``(H, W, c_i)`` or batch ``(B, H, W, c_i)``.

        ``block_mask`` is the §3.4.5 per-block keep grid (one grid applied
        to every frame, or a leading batch axis of grids); ``window_keep``
        is the already-derived per-window ``(B, h_o, w_o)`` boolean mask —
        pass at most one.  Skipped windows never execute on fused backends
        and come back as exact zeros.  Dispatch is non-blocking (jax async);
        the squeezed result mirrors the input's batchedness.
        """
        if self._kernel is None:
            raise RuntimeError(
                "no weights programmed: call reprogram(kernel) first "
                "(or pass weights= to compile())"
            )
        images = jnp.asarray(images, jnp.float32)
        squeeze = images.ndim == 3
        if squeeze:
            images = images[None]
        if block_mask is not None:
            if window_keep is not None:
                raise ValueError("pass block_mask or window_keep, not both")
            block_mask = np.asarray(block_mask)
            if block_mask.ndim == 2:
                keep = active_window_mask(self.spec, block_mask)
                window_keep = np.broadcast_to(
                    keep, (images.shape[0],) + keep.shape
                )
            else:
                window_keep = np.stack(
                    [active_window_mask(self.spec, m) for m in block_mask]
                )
        with telemetry.span("run"):
            counts = self.run_weighted(
                self._kernel, self._bn, images, window_keep
            )
        return counts[0] if squeeze else counts

    def run_weighted(
        self,
        kernel: jax.Array,
        bn_offset: jax.Array,
        images: jax.Array,
        window_keep: np.ndarray | None = None,
    ) -> jax.Array:
        """One fused executable call with explicit weights — the core
        dispatch every serving layer routes to.

        ``images`` is a ``(b, H, W, c_i)`` batch; ``window_keep`` an optional
        per-window ``(b, h_o, w_o)`` boolean keep grid.  The batch is padded
        to its pow-2 bucket (mesh-aligned), padding frames are masked out
        *in-kernel* whenever a keep grid is present, and the call is
        dispatched asynchronously — the returned array is unrealised, so
        callers can overlap host prep with device compute and block later.

        The weights are per-call state (this is what lets
        :class:`repro.serving.FPCAPipeline` serve many programmed
        configurations — including channel-stacked fan-outs — through
        signature-shared handles); :meth:`run` binds the handle's own
        programmed weights.
        """
        return self._dispatch_weighted(kernel, bn_offset, images, window_keep)

    def _dispatch_weighted(
        self,
        kernel: jax.Array,
        bn_offset: jax.Array,
        images: jax.Array,
        window_keep: np.ndarray | None = None,
        *,
        executable_for: Callable | None = None,
        extra: tuple = (),
        empty: Callable | None = None,
    ) -> jax.Array:
        """Shared padding / sharding / bucketing / accounting engine behind
        every weighted call.

        Hooks let :class:`CompiledModel` reuse the whole machinery with a
        fused frontend+head executable: ``executable_for(m_bucket)`` builds
        (or fetches) the jitted closure, ``extra`` is appended as traced call
        arguments (head parameters) before the window mask, and
        ``empty(b, h_o, w_o, c_o)`` produces the all-skipped short-circuit
        result (exact-zero counts for the frontend; head-on-zeros logits for
        a model).
        """
        executable_for = executable_for or self._executable
        spec = self.spec
        images = jnp.asarray(images, jnp.float32)
        want = (spec.image_h, spec.image_w, spec.in_channels)
        if images.ndim != 4 or images.shape[1:] != want:
            raise ValueError(
                f"expected (b, {want[0]}, {want[1]}, {want[2]}) batch, "
                f"got {images.shape}"
            )
        c_o = int(kernel.shape[0])
        if c_o != self.out_channels:
            raise ValueError(
                f"kernel has {c_o} output channels; this handle is compiled "
                f"for {self.out_channels}"
            )
        b = images.shape[0]
        h_o, w_o = output_dims(spec)
        if window_keep is not None and window_keep.shape != (b, h_o, w_o):
            raise ValueError(
                f"window_keep shape {window_keep.shape} != {(b, h_o, w_o)}"
            )
        padded = self._padded_batch(b)
        if padded > b:
            images = jnp.pad(images, ((0, padded - b), (0, 0), (0, 0), (0, 0)))
            if window_keep is not None:
                window_keep = np.concatenate(
                    [window_keep, np.zeros((padded - b, h_o, w_o), bool)]
                )
        m_total = padded * h_o * w_o
        self.stats.windows_total += m_total
        if window_keep is None:
            images = self._shard_batch(images)
            self.stats.runs += 1
            run = executable_for(None)
            self.stats.windows_executed += m_total
            return run(images, kernel, bn_offset, *extra)[:b]
        n_keep = int(np.count_nonzero(window_keep))
        if n_keep == 0:
            # all-skipped tick: the frontend result is exact zeros by
            # contract, so no kernel launches at all (0 executed windows in
            # the stats); the sticky bucket still counts the tick as
            # under-full so a stale large bucket shrinks on the first active
            # tick after the lull
            self.stats.launches_skipped += 1
            sticky = self._sticky.get(m_total)
            if sticky is not None:
                sticky.observe_idle()
            if empty is not None:
                return empty(b, h_o, w_o, c_o)
            return jnp.zeros((b, h_o, w_o, c_o), jnp.float32)
        images = self._shard_batch(images)
        self.stats.runs += 1
        m_bucket = self._bucket_for(n_keep, m_total)
        run = executable_for(m_bucket)
        self.stats.windows_executed += m_bucket
        return run(images, kernel, bn_offset, *extra, jnp.asarray(window_keep))[:b]

    def stream(
        self,
        frames: Iterable[Any],
        *,
        gate: Any = _USE_PROGRAM,
        controller: Any = _USE_PROGRAM,
        depth: int = 2,
        stream_id: str = "stream0",
    ) -> Iterator[Any]:
        """Serve a continuous frame stream through this handle.

        The single-camera counterpart of
        :class:`repro.serving.StreamServer`: each frame steps a temporal
        delta gate (defaults to ``program.gate``; pass an explicit
        ``gate=None`` for a dense readout even on a gated program),
        optionally servoed by a closed-loop threshold controller (defaults
        to ``program.controller``; explicit ``None`` disables), and the
        resulting keep mask is compacted in-kernel.  Up to ``depth`` ticks
        stay in flight (dispatch is non-blocking), results yield strictly in
        frame order as :class:`repro.serving.streaming.StreamFrameResult`.
        """
        import collections as _collections

        from repro.serving.control import GateController
        from repro.serving.streaming import StreamFrameResult, StreamSession

        if depth < 1:
            raise ValueError("depth must be >= 1")
        gate = self.program.gate if gate is _USE_PROGRAM else gate
        cconf = (
            self.program.controller
            if controller is _USE_PROGRAM
            else controller
        )
        ctl = (
            GateController(cconf, self.spec, gate.threshold, name=stream_id)
            if (cconf is not None and gate is not None)
            else None
        )
        session = StreamSession(stream_id, "__compiled__", self.spec, gate,
                                controller=ctl)
        self._stream_session = session   # introspectable (controller history)
        h_o, w_o = output_dims(self.spec)

        def _finalize(entry: dict) -> StreamFrameResult:
            return StreamFrameResult(
                stream_id=stream_id,
                frame_idx=entry["frame_idx"],
                counts=np.asarray(entry["counts"])[0],   # blocks until ready
                block_mask=entry["block_mask"],
                kept_windows=entry["kept"],
                total_windows=h_o * w_o,
                config="__compiled__",
                **self._stream_extra_results(entry),
            )

        inflight: _collections.deque[dict] = _collections.deque()
        state: dict = {}   # per-ITERATOR stream state (e.g. the model's
        #                    effective activation map) — two concurrent
        #                    stream() iterators must never share it
        span_fields = {"stream": stream_id}  # prebuilt: no per-tick churn
        for frame in frames:
            with telemetry.span("serve_tick", span_fields):
                frame = np.asarray(frame, np.float32)
                frame_idx = session.frame_idx
                block = session.step(frame)
                window = (
                    session.last_window_mask if gate is not None else None
                )
                kept = int(window.sum()) if window is not None else h_o * w_o
                entry = {
                    "frame_idx": frame_idx, "block_mask": block, "kept": kept
                }
                entry.update(self._stream_launch(frame, window, state))
            inflight.append(entry)
            while len(inflight) > depth:
                yield _finalize(inflight.popleft())
        while inflight:
            yield _finalize(inflight.popleft())

    def _stream_launch(
        self, frame: np.ndarray, window: np.ndarray | None, state: dict
    ) -> dict:
        """Dispatch one stream tick (non-blocking); returns entry fields.

        ``state`` is private to one ``stream()`` iterator.
        :class:`CompiledModel` overrides this to patch kept-window
        activations into the iterator's effective activation map and launch
        the digital head on top.
        """
        counts = self.run_weighted(
            self._require_weights(), self._bn, jnp.asarray(frame)[None],
            None if window is None else window[None],
        )
        return {"counts": counts}

    def _stream_extra_results(self, entry: dict) -> dict:
        """Extra ``StreamFrameResult`` fields realised from a tick entry."""
        return {}

    # -- device-compiled segments --------------------------------------------
    def run_segment(
        self,
        frames: Any,
        *,
        length: int | None = None,
        state: SegmentState | None = None,
        gate: Any = _USE_PROGRAM,
        m_bucket: int | None = None,
        early_exit: int | None = None,
        donate: bool | None = None,
    ) -> SegmentResult:
        """Serve ``K`` streaming ticks as ONE device-compiled program.

        The whole per-tick loop of :meth:`stream` — delta gate, hysteresis
        ages, keyframe cadence, kept-window compaction, zero-kept
        short-circuit — runs inside a single ``jax.lax.scan`` launch, so
        tick latency is kernel-bound instead of dispatch-bound.  Outputs are
        bit-identical, tick for tick, to the per-tick Python loop (the
        differential harness in ``tests/test_segment_parity.py`` pins this
        across backends).

        Args:
          frames: ``(K, H, W, c_i)`` stack; ``K`` is static per compiled
            executable, so serve a stream in fixed-length chunks.
          length: optional assertion that ``K`` matches the planned segment
            length (chunking bugs fail loudly instead of recompiling).
          state: the previous segment's :attr:`SegmentResult.state`; ``None``
            starts a fresh stream (first tick keyframes, like the host loop).
          gate: ``DeltaGateConfig`` for this segment (default: the
            program's; explicit ``None`` = dense readout).  The threshold
            enters traced — a boundary servo retunes it for the next segment
            without recompiling.
          m_bucket: static compacted-row bucket for non-keyframe ticks
            (keyframes and busier ticks take the masked-dense branch).
            Default: the state's ``suggested_bucket`` from the previous
            segment, dense for the first.
          early_exit: stop after this many consecutive all-skipped ticks
            (``lax.while_loop`` variant); ``result.ticks`` reports how far
            the segment got — feed the remaining frames to the next call.
          donate: donate the carry buffers (previous frame / ages / previous
            logits) to the device call; default on for non-CPU backends.
        """
        return self.run_segment_weighted(
            self._require_weights(), self._bn, frames,
            length=length, state=state, gate=gate, m_bucket=m_bucket,
            early_exit=early_exit, donate=donate,
        )

    def run_segment_weighted(
        self,
        kernel: jax.Array,
        bn_offset: jax.Array,
        frames: Any,
        *,
        length: int | None = None,
        state: SegmentState | None = None,
        gate: Any = _USE_PROGRAM,
        m_bucket: int | None = None,
        early_exit: int | None = None,
        donate: bool | None = None,
    ) -> SegmentResult:
        """:meth:`run_segment` with explicit weights (the serving-layer
        entry point — weights enter traced, so reprogramming between
        segments never recompiles)."""
        return self._dispatch_segment(
            kernel, bn_offset, frames, length=length, state=state, gate=gate,
            m_bucket=m_bucket, early_exit=early_exit, donate=donate,
            head_params=None,
        )

    def _dispatch_segment(self, *args: Any, **kwargs: Any) -> SegmentResult:
        if not telemetry.enabled():
            return self._dispatch_segment_inner(*args, **kwargs)
        with telemetry.span("run_segment",
                            {"model": kwargs.get("head_params") is not None}):
            return self._dispatch_segment_inner(*args, **kwargs)

    def _dispatch_segment_inner(
        self,
        kernel: jax.Array,
        bn_offset: jax.Array,
        frames: Any,
        *,
        length: int | None,
        state: SegmentState | None,
        gate: Any,
        m_bucket: int | None,
        early_exit: int | None,
        donate: bool | None,
        head_params: Any | None,
    ) -> SegmentResult:
        spec = self.spec
        frames = jnp.asarray(frames, jnp.float32)
        want = (spec.image_h, spec.image_w, spec.in_channels)
        if frames.ndim != 4 or frames.shape[1:] != want:
            raise ValueError(
                f"expected (K, {want[0]}, {want[1]}, {want[2]}) frame stack, "
                f"got {frames.shape}"
            )
        K = int(frames.shape[0])
        if K < 1:
            raise ValueError("need at least one frame")
        if length is not None and int(length) != K:
            raise ValueError(
                f"length={length} does not match the {K}-frame stack"
            )
        c_o = int(kernel.shape[0])
        if c_o != self.out_channels:
            raise ValueError(
                f"kernel has {c_o} output channels; this handle is compiled "
                f"for {self.out_channels}"
            )
        gate = self.program.gate if gate is _USE_PROGRAM else gate
        gated = gate is not None
        h_o, w_o = output_dims(spec)
        M = h_o * w_o
        bh, bw = gating.block_grid(spec)
        is_model = head_params is not None
        if gated:
            if m_bucket is None:
                m_bucket = (
                    state.suggested_bucket
                    if state is not None and state.suggested_bucket
                    else M
                )
            m_bucket = max(1, min(int(m_bucket), M))
        else:
            m_bucket = None
        if early_exit is not None:
            early_exit = int(early_exit)
            if early_exit < 1:
                raise ValueError("early_exit patience must be >= 1")
            if not gated:
                raise ValueError("early_exit requires a gated segment")
        if donate is None:
            donate = jax.default_backend() != "cpu"
        run = self._segment_executable(
            K, m_bucket, gated, early_exit, bool(donate), model=is_model
        )
        if state is None:
            state = self._fresh_segment_state(
                gate.hysteresis if gated else 0, is_model
            )
        first_idx = int(state.frame_idx)
        args: list = [frames, kernel, bn_offset]
        if is_model:
            args.append(head_params)
        if gated:
            args.append((
                jnp.asarray(gate.threshold, jnp.float32),
                jnp.asarray(gate.hysteresis, jnp.int32),
                jnp.asarray(gate.keyframe_interval, jnp.int32),
            ))
        args.append(state.carry(is_model))
        outs, new_carry = run(*args)
        # the per-tick bookkeeping is realised eagerly (it feeds stats and
        # the boundary servo); counts/logits stay lazy for overlap
        ticks = int(outs["ticks"])
        if gated:
            kept = np.asarray(outs["kept"], np.int64)
            keyframes = np.asarray(outs["keyframe"], bool)
            block_masks = np.asarray(outs["block_keep"], bool)
            rows = np.where(kept == 0, 0, np.where(kept > m_bucket, M, m_bucket))
            rows[ticks:] = 0
            suggested = segment_bucket(kept[:ticks], M, keyframes[:ticks])
        else:
            kept = np.full(K, M, np.int64)
            keyframes = np.zeros(K, bool)
            block_masks = np.ones((K, bh, bw), bool)
            rows = np.full(K, M, np.int64)
            suggested = None
        new_state = SegmentState(*new_carry[:4])
        if is_model:
            new_state.eff, new_state.logits = new_carry[4], new_carry[5]
        new_state.suggested_bucket = suggested
        detect_classes = (
            self.model_program.detect_classes if is_model else None
        )
        self.stats.runs += 1
        self.stats.segments += 1
        self.stats.segment_ticks += ticks
        self.stats.windows_total += ticks * M
        self.stats.windows_executed += int(rows[:ticks].sum())
        if gated:
            self.stats.launches_skipped += int((kept[:ticks] == 0).sum())
        return SegmentResult(
            counts=outs["counts"],
            block_masks=block_masks,
            kept_windows=kept,
            keyframes=keyframes,
            rows_executed=rows,
            ticks=ticks,
            length=K,
            first_frame_idx=first_idx,
            gated=gated,
            state=new_state,
            logits=outs.get("logits"),
            detect_classes=detect_classes,
        )

    def _fresh_segment_state(
        self, hysteresis: int, is_model: bool
    ) -> SegmentState:
        st = SegmentState(*gating.init_gate_carry(self.spec, hysteresis))
        if is_model:
            h_o, w_o = output_dims(self.spec)
            st.eff = jnp.zeros((h_o, w_o, self.out_channels), jnp.float32)
            # head_out_shape generalises (n_classes,) to detection maps
            st.logits = jnp.zeros(
                self.model_program.head_out_shape, jnp.float32
            )
        return st

    def _segment_executable(
        self,
        K: int,
        m_bucket: int | None,
        gated: bool,
        early_exit: int | None,
        donate: bool,
        *,
        model: bool = False,
    ) -> Callable:
        mb_key = m_bucket
        if mb_key is not None and not self.backend.bucket_sensitive:
            mb_key = -1
        key = self.signature() + (
            self.backend.name, "segment", K, mb_key, gated, early_exit,
            donate, model,
        )

        def build() -> Callable:
            return self.backend.instrumented(
                self.backend.make_segment_executable(
                    self.model,
                    spec=self.spec,
                    adc=self.program.adc,
                    enc=self.program.enc,
                    interpret=self.interpret,
                    length=K,
                    gated=gated,
                    m_bucket=m_bucket,
                    model_program=self.model_program if model else None,
                    early_exit=early_exit,
                    donate=donate,
                ),
                site="segment",
            )

        return self._cache.get(key, build)

    @property
    def data_parallelism(self) -> int:
        """Devices the fused batch shards over (1 = unsharded single device).

        The batch-carrying extent of the compiled mesh — what
        :meth:`_padded_batch` rounds the launch up to and what the fleet
        weak-scaling bench sweeps (`benchmarks/fleet_bench.py`).  Gate state
        never shards: it stays host-local per stream.
        """
        return 1 if self.mesh is None else data_extent(self.mesh)

    # -- internals -----------------------------------------------------------
    def _require_weights(self) -> jax.Array:
        if self._kernel is None:
            raise RuntimeError(
                "no weights programmed: call reprogram(kernel) first"
            )
        return self._kernel

    def _padded_batch(self, b: int) -> int:
        padded = _round_up_pow2(b)
        if self.mesh is not None:
            n_data = data_extent(self.mesh)
            padded = -(-padded // n_data) * n_data
        return padded

    def _shard_batch(self, images: jax.Array) -> jax.Array:
        if self.mesh is None:
            return images
        P = jax.sharding.PartitionSpec
        sharding = jax.sharding.NamedSharding(
            self.mesh, P(data_axes(self.mesh), *([None] * (images.ndim - 1)))
        )
        return jax.device_put(images, sharding)

    def _frontend_transfer(self) -> str:
        """Bucket-transfer lowering the frontend executables serve: "int8"
        for a precision="int8" model program on a quant_transfer backend
        (so streaming counts match the fused model jit's frontend stage),
        "f32" everywhere else — frontend-only handles included."""
        mp = getattr(self, "model_program", None)
        if (
            mp is not None
            and mp.precision == "int8"
            and self.backend.quant_transfer
        ):
            return "int8"
        return "f32"

    def _executable(self, m_bucket: int | None) -> Callable:
        # bucket-insensitive backends (dense eval + post-hoc mask) serve
        # every bucket size with one executable: collapse the key so sticky
        # bucket transitions don't churn the shared LRU with identical jits
        if m_bucket is not None and not self.backend.bucket_sensitive:
            m_bucket = -1
        transfer = self._frontend_transfer()
        key = self._sig + (self.backend.name, m_bucket, transfer)

        def build() -> Callable:
            # a FRESH jitted closure per signature: its compiled programs are
            # owned by the closure, so LRU eviction genuinely frees the
            # executable (a shared module-level jit cache would keep them
            # alive).
            kw = {"transfer": transfer} if transfer != "f32" else {}
            return self.backend.instrumented(
                self.backend.make_executable(
                    self.model,
                    spec=self.spec,
                    adc=self.program.adc,
                    enc=self.program.enc,
                    interpret=self.interpret,
                    m_bucket=m_bucket,
                    **kw,
                ),
                site="frontend",
            )

        return self._cache.get(key, build)

    def _bucket_for(self, n_keep: int, m_total: int) -> int:
        """Sticky row bucket for one (handle, window-count) batch shape.

        With ``bucket_patience=1`` this is exactly
        :func:`repro.kernels.fpca_conv.ops.window_bucket`, but bucket
        transitions are still counted — ``stats.bucket_switches`` is the
        flap count a hysteresis-free server pays.
        """
        sticky = self._sticky.get(m_total)
        if sticky is None:
            sticky = self._sticky[m_total] = StickyBucket(self.bucket_patience)
        before = (sticky.switches, sticky.shrinks_deferred)
        m_bucket = sticky.bucket(n_keep, m_total)
        self.stats.bucket_switches += sticky.switches - before[0]
        self.stats.bucket_shrinks_deferred += sticky.shrinks_deferred - before[1]
        return m_bucket


class CompiledModel(CompiledFrontend):
    """An explicitly-held multi-layer model executable: analog frontend +
    digital CNN head behind one handle.

    Construct via :func:`compile` on an
    :class:`repro.fpca.FPCAModelProgram`.  Everything the frontend handle
    owns is reused — the shared bounded executable LRU, sticky region-skip
    buckets, batch padding + mesh sharding, executed-window stats — but:

    * :meth:`run` returns class **logits**: the head is fused into the same
      jit as the frontend (one dispatch per batch), bit-identical to
      composing a frontend handle with
      :meth:`~repro.fpca.FPCAModelProgram.apply_head`;
    * :meth:`reprogram` rewrites NVM planes AND/OR head parameters — both
      enter every executable traced, so neither ever recompiles;
    * :meth:`stream` is **skip-aware**: each delta-gated tick patches the
      kept-window activations into the previous *effective activation map*
      and runs the head on the patched map, so a stream of mostly-skipped
      ticks still yields a class decision per tick (an all-skipped tick
      reproduces the previous logits exactly).
    """

    def __init__(
        self,
        model_program: "FPCAModelProgram",
        *,
        head_params: Any | None = None,
        **kw: Any,
    ):
        from repro.fpca.program import FPCAModelProgram

        if not isinstance(model_program, FPCAModelProgram):
            raise TypeError(
                f"expected FPCAModelProgram, got {type(model_program)}"
            )
        super().__init__(model_program.frontend, **kw)
        self.model_program = model_program
        self._model_sig = model_program.signature()
        self._head_params: Any | None = None
        # arch-labeled workload cells (zoo stamp; "custom" off-registry)
        self.arch = model_program.arch or "custom"
        self._m_runs = _C_MODEL_RUNS.labels(arch=self.arch)
        self._m_frames = _C_MODEL_FRAMES.labels(arch=self.arch)
        if head_params is not None:
            self.reprogram(head_params=head_params)

    # -- introspection -------------------------------------------------------
    @property
    def n_classes(self) -> int:
        return self.model_program.n_classes

    @property
    def head_out_shape(self) -> tuple[int, ...]:
        return self.model_program.head_out_shape

    @property
    def output_kind(self) -> str:
        return self.model_program.output_kind

    @property
    def detect_classes(self) -> int | None:
        return self.model_program.detect_classes

    @property
    def head_params(self) -> Any | None:
        """Currently programmed head parameters (None until programmed)."""
        return self._head_params

    def signature(self) -> tuple:
        """The MODEL signature (extends the frontend's; golden-pinned)."""
        return self._model_sig

    def frontend_signature(self) -> tuple:
        return self._sig

    # -- programming ---------------------------------------------------------
    def reprogram(
        self,
        kernel: Any | None = None,
        bn_offset: Any | None = None,
        *,
        head_params: Any | None = None,
    ) -> "CompiledModel":
        """Rewrite NVM weight planes, BN offsets and/or the head pytree.

        Any side may be updated alone (a ``bn_offset``-only rewrite reuses
        the currently programmed kernel); everything enters every executable
        traced, so — like the frontend contract — reprogramming never
        recompiles (asserted via ``cache_info()`` in the test suite).
        """
        if kernel is None and bn_offset is None and head_params is None:
            raise ValueError(
                "reprogram needs kernel, bn_offset and/or head_params"
            )
        if kernel is not None:
            super().reprogram(kernel, bn_offset)
        elif bn_offset is not None:
            super().reprogram(self._require_weights(), bn_offset)
        if head_params is not None:
            if kernel is None and bn_offset is None:
                # head-only rewrite: the base reprogram (and its span) did
                # not run, so count and trace it here
                with telemetry.span("reprogram"):
                    self._head_params = self.model_program.bind_head_params(
                        head_params
                    )
                    self.stats.reprograms += 1
            else:
                self._head_params = self.model_program.bind_head_params(
                    head_params
                )
        return self

    def _require_head(self) -> Any:
        if self._head_params is None:
            raise RuntimeError(
                "no head parameters programmed: call "
                "reprogram(head_params=...) first (or pass head_params= to "
                "compile())"
            )
        return self._head_params

    # -- execution -----------------------------------------------------------
    def run(
        self,
        images: Any,
        *,
        block_mask: np.ndarray | None = None,
        window_keep: np.ndarray | None = None,
    ) -> Any:
        """Serve one frame or batch through the fused frontend+head jit.

        Classifiers return logits ``(n_classes,)`` / ``(B, n_classes)``;
        detection models return :class:`repro.models.heads.Detections`
        (scores + boxes split lazily from the raw per-cell map)."""
        out = super().run(
            images, block_mask=block_mask, window_keep=window_keep
        )
        dc = self.detect_classes
        if dc is not None:
            from repro.models.heads import Detections

            return Detections.from_raw(out, dc)
        return out

    def run_weighted(
        self,
        kernel: jax.Array,
        bn_offset: jax.Array,
        images: jax.Array,
        window_keep: np.ndarray | None = None,
        *,
        head_params: Any | None = None,
    ) -> jax.Array:
        """One fused frontend+head call -> ``(b,) + head_out_shape`` raw
        outputs (logits, or per-cell detection maps for a detection head —
        :meth:`run` wraps those in :class:`repro.models.heads.Detections`).

        Routed through the same padding / sharding / sticky-bucket engine as
        the frontend handle; the executable itself is the backend's
        :meth:`~repro.fpca.Backend.make_model_executable` closure (ONE jit).
        An all-skipped batch short-circuits the frontend launch and serves
        the head on the exact-zero activation map instead.
        """
        hp = self._require_head() if head_params is None else head_params
        self._m_runs.add(1)
        self._m_frames.add(int(np.shape(images)[0]))

        def empty(b: int, h_o: int, w_o: int, c_o: int) -> jax.Array:
            zeros = jnp.zeros((b, h_o, w_o, c_o), jnp.float32)
            return self._head_executable()(hp, zeros)

        return self._dispatch_weighted(
            kernel, bn_offset, images, window_keep,
            executable_for=lambda m: self._model_executable(m),
            extra=(hp,),
            empty=empty,
        )

    def run_frontend_weighted(
        self,
        kernel: jax.Array,
        bn_offset: jax.Array,
        images: jax.Array,
        window_keep: np.ndarray | None = None,
    ) -> jax.Array:
        """The frontend stage alone (SS-ADC counts) — what the streaming
        paths use before the skip-aware head patch.  Executables are keyed
        by the FRONTEND signature, so they are shared with plain frontend
        handles on the same cache."""
        return self._dispatch_weighted(kernel, bn_offset, images, window_keep)

    def head_logits(self, counts: Any, head_params: Any | None = None) -> jax.Array:
        """Digital head on an explicit activation map (non-blocking)."""
        hp = self._require_head() if head_params is None else head_params
        self._m_runs.add(1)
        return self._head_executable()(hp, jnp.asarray(counts, jnp.float32))

    def patched_logits(
        self,
        counts: Any,
        prev_eff: Any,
        window_keep: Any,
        head_params: Any | None = None,
    ) -> tuple[jax.Array, jax.Array]:
        """Skip-aware head step: patch kept windows of ``counts`` into
        ``prev_eff`` and run the head on the patched map.

        Returns ``(logits, effective)`` — callers carry ``effective``
        forward as the next tick's ``prev_eff``.  One jitted closure (shared
        LRU), dispatched asynchronously.
        """
        hp = self._require_head() if head_params is None else head_params
        self._m_runs.add(1)
        self._m_frames.add(int(np.shape(counts)[0]))
        return self._patch_executable()(
            hp,
            jnp.asarray(counts, jnp.float32),
            jnp.asarray(prev_eff, jnp.float32),
            jnp.asarray(window_keep),
        )

    def fused_patched_logits(
        self,
        head_params_rows: Any,
        counts: Any,
        prev_eff: Any,
        window_keep: Any,
    ) -> tuple[jax.Array, jax.Array]:
        """Shared-head fusion: ONE vmapped patch+head pass over stacked
        per-config rows, each row binding its OWN head parameters
        (``head_params_rows`` is the per-row pytree stack, leading axis ==
        ``counts.shape[0]``).

        Row-for-row bit-identical to per-config :meth:`patched_logits`
        calls — every op in the patch body and the head is row-independent,
        the same contract the segment parity harness already pins for the
        in-scan head — asserted by the fused-vs-unfused parity test.
        """
        self._m_runs.add(1)
        self._m_frames.add(int(np.shape(counts)[0]))
        return self._fused_patch_executable()(
            head_params_rows,
            jnp.asarray(counts, jnp.float32),
            jnp.asarray(prev_eff, jnp.float32),
            jnp.asarray(window_keep),
        )

    # -- device-compiled segments --------------------------------------------
    def run_segment_weighted(
        self,
        kernel: jax.Array,
        bn_offset: jax.Array,
        frames: Any,
        *,
        head_params: Any | None = None,
        length: int | None = None,
        state: "SegmentState | None" = None,
        gate: Any = _USE_PROGRAM,
        m_bucket: int | None = None,
        early_exit: int | None = None,
        donate: bool | None = None,
    ) -> "SegmentResult":
        """Model variant of :meth:`CompiledFrontend.run_segment_weighted`:
        the per-tick head pass (skip-aware effective-map patch + logits) runs
        inside the scan, carrying the previous effective map and logits on
        the device.  ``result.logits`` is ``(K,) + head_out_shape`` (class
        logits, or raw per-cell maps — ``result.detections()`` splits
        those)."""
        hp = self._require_head() if head_params is None else head_params
        seg = self._dispatch_segment(
            kernel, bn_offset, frames, length=length, state=state, gate=gate,
            m_bucket=m_bucket, early_exit=early_exit, donate=donate,
            head_params=hp,
        )
        self._m_runs.add(1)
        self._m_frames.add(seg.ticks)
        return seg

    # -- streaming -----------------------------------------------------------
    def _stream_launch(
        self, frame: np.ndarray, window: np.ndarray | None, state: dict
    ) -> dict:
        h_o, w_o = output_dims(self.spec)
        counts = self.run_frontend_weighted(
            self._require_weights(), self._bn, jnp.asarray(frame)[None],
            None if window is None else window[None],
        )
        # the effective activation map lives in the ITERATOR's state, never
        # on the handle: concurrent stream() iterators stay independent
        prev = state.get("eff")
        if prev is None:
            prev = jnp.zeros((1, h_o, w_o, self.out_channels), jnp.float32)
        keep = (
            np.ones((1, h_o, w_o), bool) if window is None else window[None]
        )
        logits, eff = self.patched_logits(counts, prev, keep)
        state["eff"] = eff
        return {"counts": counts, "logits": logits}

    def _stream_extra_results(self, entry: dict) -> dict:
        lg = np.asarray(entry["logits"])[0]
        out: dict = {"logits": lg}
        dc = self.detect_classes
        if dc is not None:
            from repro.models.heads import Detections

            out["detections"] = Detections.from_raw(lg, dc)
        return out

    # -- internals -----------------------------------------------------------
    def _model_executable(self, m_bucket: int | None) -> Callable:
        if m_bucket is not None and not self.backend.bucket_sensitive:
            m_bucket = -1
        key = self._model_sig + (self.backend.name, "model", m_bucket)

        def build() -> Callable:
            return self.backend.instrumented(
                self.backend.make_model_executable(
                    self.model_program,
                    self.model,
                    interpret=self.interpret,
                    m_bucket=m_bucket,
                ),
                site="model",
            )

        return self._cache.get(key, build)

    def _head_executable(self) -> Callable:
        key = self._model_sig + ("head",)
        head = self.model_program.apply_head

        def build() -> Callable:
            @jax.jit
            def run(head_params, counts):
                return head(head_params, counts)

            return self.backend.instrumented(run, site="head")

        return self._cache.get(key, build)

    def _patch_executable(self) -> Callable:
        key = self._model_sig + ("head-patch",)
        head = self.model_program.apply_head

        def build() -> Callable:
            @jax.jit
            def run(head_params, counts, prev_eff, window_keep):
                eff = jnp.where(window_keep[..., None], counts, prev_eff)
                return head(head_params, eff), eff

            return self.backend.instrumented(run, site="head_patch")

        return self._cache.get(key, build)

    def _fused_patch_executable(self) -> Callable:
        key = self._model_sig + ("head-patch-fused",)
        head = self.model_program.apply_head

        def build() -> Callable:
            def one(hp, c, pe, wk):
                eff = jnp.where(wk[..., None], c, pe)
                return head(hp, eff[None])[0], eff

            run = jax.jit(jax.vmap(one))
            return self.backend.instrumented(run, site="head_patch_fused")

        return self._cache.get(key, build)


def compile(  # noqa: A001  (torch.compile-style public name)
    program: FPCAProgram | FPCASpec,
    *,
    backend: str | Backend | None = None,
    mesh: jax.sharding.Mesh | None = None,
    weights: Any | None = None,
    bn_offset: Any | None = None,
    head_params: Any | None = None,
    model: BucketCurvefitModel | None = None,
    cache: ExecutableCache | None = None,
    cache_capacity: int = 8,
    bucket_patience: int = 1,
    interpret: bool | None = None,
    stats_parent: Any | None = None,
) -> CompiledFrontend:
    """Compile an :class:`FPCAProgram` into a held executable handle.

    An :class:`repro.fpca.FPCAModelProgram` (frontend + digital CNN head)
    compiles to a :class:`CompiledModel` whose ``.run()`` serves class
    logits through ONE fused jit; ``head_params`` then programs the trained
    head the way ``weights`` programs the NVM planes.

    Args:
      program: the validated program spec (a bare :class:`FPCASpec` is
        wrapped in a default program for convenience; an
        :class:`FPCAModelProgram` yields a :class:`CompiledModel`).
      backend: registered backend name (see
        :func:`repro.fpca.available_backends`) or a :class:`Backend`
        instance; ``None`` auto-selects by platform (Pallas on TPU, the XLA
        basis form elsewhere).
      mesh: optional ``jax.sharding.Mesh`` — batches shard over its data
        axes and batch padding rounds up to the data-axis extent.
      weights / bn_offset: optionally program the NVM planes immediately
        (equivalent to calling :meth:`CompiledFrontend.reprogram`).
      model: fitted :class:`BucketCurvefitModel`; fitted on demand from
        ``program.circuit`` when omitted (a one-off ~seconds calibration, as
        a deployment would run once).
      cache: share a bounded :class:`ExecutableCache` across handles (the
        pipeline does this to bound total live executables); a private cache
        of ``cache_capacity`` otherwise.
      bucket_patience: sticky-bucket hysteresis for region-skip row buckets
        (``1`` = stateless).
      interpret: forwarded to Pallas (default: interpret off-TPU).
      stats_parent: optional :class:`repro.fpca.telemetry.StatsView` whose
        same-named cells receive every increment of the handle's stats
        (how ``FPCAPipeline`` single-sources its fleet totals).
    """
    from repro.fpca.program import FPCAModelProgram

    if isinstance(program, FPCASpec):
        program = FPCAProgram(spec=program)
    is_model = isinstance(program, FPCAModelProgram)
    if not is_model and not isinstance(program, FPCAProgram):
        raise TypeError(
            f"expected FPCAProgram, FPCAModelProgram or FPCASpec, "
            f"got {type(program)}"
        )
    if head_params is not None and not is_model:
        raise ValueError("head_params= needs an FPCAModelProgram")
    frontend = program.frontend if is_model else program
    be = get_backend(backend if backend is not None else default_backend_name())
    with telemetry.span("compile", {"backend": be.name, "model": is_model}):
        if model is None:
            model = fit_bucket_model(
                frontend.circuit, n_pixels=frontend.spec.n_active_pixels
            )
        common = dict(
            backend=be,
            model=model,
            mesh=mesh,
            cache=cache,
            cache_capacity=cache_capacity,
            bucket_patience=bucket_patience,
            interpret=interpret,
            stats_parent=stats_parent,
        )
        if is_model:
            handle: CompiledFrontend = CompiledModel(
                program, head_params=head_params, **common
            )
        else:
            handle = CompiledFrontend(program, **common)
        if weights is not None:
            handle.reprogram(weights, bn_offset)
    return handle
