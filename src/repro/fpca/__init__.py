"""``repro.fpca`` — the unified compile/execute API for the FPCA frontend.

One program spec, explicit executables, pluggable backends::

    from repro import fpca
    from repro.core.mapping import FPCASpec

    program = fpca.FPCAProgram(
        spec=FPCASpec(image_h=96, image_w=96, out_channels=8, kernel=5,
                      stride=5),
        gate=fpca.DeltaGateConfig(threshold=0.02),
    )
    fe = fpca.compile(program, backend="basis", weights=kernel)
    counts = fe.run(batch)                  # fused serving call
    fe.reprogram(new_kernel)                # NVM rewrite — zero recompiles
    for result in fe.stream(camera_frames):  # delta-gated continuous vision
        ...

Layer map:

* :mod:`repro.fpca.program`    — :class:`FPCAProgram` (the one validated
  spec), :class:`FPCAModelProgram` (frontend + digital CNN head — the
  paper's whole workload as one compileable model, served as class logits
  by :class:`CompiledModel`) + stable :func:`spec_signature`;
* :mod:`repro.fpca.backends`   — the :class:`Backend` registry
  (``reference`` / ``pallas`` / ``basis`` built in, third parties register
  via :func:`register_backend`);
* :mod:`repro.fpca.executable` — :func:`compile` and
  :class:`CompiledFrontend` (bounded executable LRU, sticky region-skip
  buckets, mesh sharding, stats);
* :mod:`repro.fpca.cache`      — the introspectable
  :class:`ExecutableCache` / :class:`CacheInfo`;
* :mod:`repro.fpca.zoo`        — the model-zoo meta-architecture registry
  (:func:`register_arch` / :func:`build_model`): config-driven construction
  of classifier and detection model programs over
  :class:`repro.models.heads.HeadGraph` head graphs;
* :mod:`repro.fpca.telemetry`  — the process-wide metrics registry every
  stats object reports into, span traces
  (``telemetry.enable(jsonl_path=...)``) and opt-in device-profile hooks.

The batch scheduler (:class:`repro.serving.fpca_pipeline.FPCAPipeline`) and
the streaming fleet server (:class:`repro.serving.streaming.StreamServer`)
are thin orchestration layers over :class:`CompiledFrontend`.
"""

from __future__ import annotations

from repro.core.adc import ADCConfig
from repro.core.device_models import CircuitParams
from repro.core.fpca_sim import WeightEncoding
from repro.core.mapping import FPCASpec
from repro.fpca.backends import (
    Backend,
    available_backends,
    default_backend_name,
    get_backend,
    register_backend,
)
from repro.fpca import telemetry
from repro.fpca.cache import CacheInfo, CacheInfoVerbose, ExecutableCache
from repro.fpca.executable import (
    CompiledFrontend,
    CompiledModel,
    FrontendStats,
    SegmentResult,
    SegmentState,
    compile,
)
from repro.fpca.program import (
    ActivationSpec,
    ConvSpec,
    DeltaGateConfig,
    DenseSpec,
    FPCAModelProgram,
    FPCAProgram,
    GateControllerConfig,
    PoolSpec,
    ProgrammedConfig,
    ProgrammedModel,
    spec_signature,
)
from repro.models.heads import (
    AddSpec,
    ConcatSpec,
    DetectSpec,
    Detections,
    HeadGraph,
    Node,
)
from repro.models.quant import (
    calibrate_head_scales,
    logit_parity,
    quantize_head_params,
)
from repro.fpca.zoo import available_archs, build_model, register_arch

__all__ = [
    # program spec
    "FPCAProgram",
    "ProgrammedConfig",
    "DeltaGateConfig",
    "GateControllerConfig",
    "spec_signature",
    # multi-layer model programs (frontend + digital CNN head)
    "FPCAModelProgram",
    "ProgrammedModel",
    "ConvSpec",
    "PoolSpec",
    "DenseSpec",
    "ActivationSpec",
    "CompiledModel",
    # quantised int8 serving (precision="int8" on FPCAModelProgram)
    "quantize_head_params",
    "calibrate_head_scales",
    "logit_parity",
    # model zoo (meta-arch registry + head graphs + detections)
    "register_arch",
    "build_model",
    "available_archs",
    "HeadGraph",
    "Node",
    "AddSpec",
    "ConcatSpec",
    "DetectSpec",
    "Detections",
    # re-exported building blocks of a program
    "FPCASpec",
    "CircuitParams",
    "ADCConfig",
    "WeightEncoding",
    # compile/execute
    "compile",
    "CompiledFrontend",
    "FrontendStats",
    "ExecutableCache",
    "CacheInfo",
    "CacheInfoVerbose",
    # observability (metrics registry, span traces, device hooks)
    "telemetry",
    # device-compiled streaming segments
    "SegmentState",
    "SegmentResult",
    # backend registry
    "Backend",
    "register_backend",
    "get_backend",
    "available_backends",
    "default_backend_name",
]
