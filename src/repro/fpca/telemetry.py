"""Process-wide telemetry: metrics registry, span traces, device hooks.

One substrate behind every stats surface in the stack.  The ad-hoc counter
objects (``FrontendStats``, ``PipelineStats``, ``StreamStats``) are thin
:class:`StatsView` wrappers over registry cells, so the numbers a test reads
off ``pipe.stats`` and the numbers ``registry().render()`` exports are the
*same* cells — there is nothing to reconcile because nothing is copied.
Parent-chained cells single-source the frontier counters
(``windows_executed`` / ``launches_skipped``): a ``CompiledFrontend`` owned
by a ``FPCAPipeline`` increments one cell and the delta propagates up the
chain, replacing the old before/after delta-mirroring in the serving layer.

Three export surfaces:

* ``registry().render()``   — Prometheus-style text snapshot.
* ``enable(jsonl_path=...)``— structured JSONL event log (spans, servo
  actuations, device-time samples), strict RFC 8259 JSON (no NaN/Infinity;
  ``benchmarks/_util.py`` delegates to :func:`jsonable` here).
* ``repro.serving.observe.fleet_report()`` — per-(stream, config) table.

Everything is zero-overhead when disabled: ``span()`` returns one shared
null context manager (no allocation), launch wrappers are a single
``is None`` check, and no hot-path code builds dicts or syncs the device
unless a session is active.  Device-profile hooks
(``jax.profiler.TraceAnnotation`` + sampled ``block_until_ready`` for
honest device time) are opt-in per session and rate-limited so
steady-state dispatch stays non-blocking.
"""

from __future__ import annotations

import bisect
import itertools
import json
import threading
import time
import weakref
from pathlib import Path
from typing import Any, Callable, Iterator, Optional

import numpy as np

__all__ = [
    "MetricsRegistry",
    "MetricFamily",
    "StatsView",
    "TelemetrySession",
    "enable",
    "disable",
    "enabled",
    "session",
    "registry",
    "span",
    "event",
    "instrument_launch",
    "jsonable",
    "read_jsonl",
    "OVERFLOW_LABEL",
]

# Label value substituted when a family hits its cardinality bound; the
# overflow cell keeps counting so totals stay honest even when the label
# space explodes.
OVERFLOW_LABEL = "__overflow__"

# log-spaced latency buckets (seconds); +inf bucket is implicit.
DEFAULT_BUCKETS = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0,
)


# --------------------------------------------------------------------------
# strict-JSON helpers (single source; benchmarks/_util.py delegates here)


def jsonable(obj):
    """Recursively map non-finite floats (inf / -inf / NaN) to None."""
    if isinstance(obj, dict):
        return {k: jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        obj = obj.item()
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def read_jsonl(path: Path | str) -> list[dict]:
    """Parse a telemetry JSONL log back into a list of event dicts."""
    out = []
    with open(path, "r") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# --------------------------------------------------------------------------
# cells


class _Cell:
    """One mutable metric value.  ``parent`` chains deltas upward: a handle
    owned by a pipeline adds into its own cell and the same delta lands in
    the pipeline's cell — the single-source fix for the old double-mirrored
    ``windows_executed`` / ``launches_skipped`` counters."""

    __slots__ = ("value", "parent", "__weakref__")

    def __init__(self, value: float = 0, parent: "_Cell | None" = None):
        self.value = value
        self.parent = parent

    def add(self, delta) -> None:
        self.value += delta
        p = self.parent
        while p is not None:
            p.value += delta
            p = p.parent

    def set(self, value) -> None:
        self.value = value


class _HistCell:
    """Bounded histogram: fixed bucket edges, counts, sum and count."""

    __slots__ = ("edges", "counts", "sum", "count", "__weakref__")

    def __init__(self, edges=DEFAULT_BUCKETS):
        self.edges = tuple(edges)
        self.counts = [0] * (len(self.edges) + 1)  # last = +inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1


# --------------------------------------------------------------------------
# metric families


class MetricFamily:
    """A named metric with a fixed label schema and bounded cardinality.

    ``labels(**kw)`` interns one cell per distinct label-value tuple.  Once
    ``max_label_sets`` distinct sets exist, further *new* sets all map to a
    single shared overflow cell (label values replaced by
    :data:`OVERFLOW_LABEL`) and ``overflowed`` counts how many sets were
    folded — totals stay correct, memory stays bounded.
    """

    def __init__(self, name: str, kind: str, help: str,
                 label_names: tuple[str, ...] = (),
                 max_label_sets: int = 64,
                 buckets=DEFAULT_BUCKETS):
        self.name = name
        self.kind = kind  # "counter" | "gauge" | "histogram"
        self.help = help
        self.label_names = tuple(label_names)
        self.max_label_sets = max_label_sets
        self.buckets = tuple(buckets)
        self.overflowed = 0
        self._cells: dict[tuple, Any] = {}
        self._lock = threading.Lock()

    def _new_cell(self):
        if self.kind == "histogram":
            return _HistCell(self.buckets)
        return _Cell()

    def labels(self, **kw):
        key = tuple(str(kw.get(n, "")) for n in self.label_names)
        cell = self._cells.get(key)
        if cell is not None:
            return cell
        with self._lock:
            cell = self._cells.get(key)
            if cell is not None:
                return cell
            if len(self._cells) >= self.max_label_sets:
                self.overflowed += 1
                okey = tuple(OVERFLOW_LABEL for _ in self.label_names)
                cell = self._cells.get(okey)
                if cell is None:
                    cell = self._new_cell()
                    self._cells[okey] = cell
                return cell
            cell = self._new_cell()
            self._cells[key] = cell
            return cell

    def cell(self):
        """The unlabeled cell (families declared with no label names)."""
        return self.labels()

    def samples(self) -> Iterator[tuple[dict, Any]]:
        for key, cell in self._cells.items():
            yield dict(zip(self.label_names, key)), cell


class MetricsRegistry:
    """Process-wide registry of metric families plus live stats views.

    Stats views (and :class:`~repro.fpca.cache.ExecutableCache` instances)
    are tracked through weakrefs so handles stay garbage-collectable; dead
    views silently drop out of ``render()`` / ``snapshot()``.
    """

    def __init__(self):
        self._families: dict[str, MetricFamily] = {}
        self._views: list = []  # weakrefs to StatsView
        self._collectors: list[Callable[[], list]] = []
        self._instance_counters: dict[str, Iterator[int]] = {}
        self._lock = threading.Lock()

    # -- family constructors ------------------------------------------------

    def _family(self, name, kind, help, label_names, max_label_sets,
                buckets=DEFAULT_BUCKETS) -> MetricFamily:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}")
            return fam
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = MetricFamily(name, kind, help, label_names,
                                   max_label_sets, buckets)
                self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                label_names: tuple[str, ...] = (),
                max_label_sets: int = 64) -> MetricFamily:
        return self._family(name, "counter", help, label_names,
                            max_label_sets)

    def gauge(self, name: str, help: str = "",
              label_names: tuple[str, ...] = (),
              max_label_sets: int = 64) -> MetricFamily:
        return self._family(name, "gauge", help, label_names, max_label_sets)

    def histogram(self, name: str, help: str = "",
                  label_names: tuple[str, ...] = (),
                  max_label_sets: int = 64,
                  buckets=DEFAULT_BUCKETS) -> MetricFamily:
        return self._family(name, "histogram", help, label_names,
                            max_label_sets, buckets)

    # -- stats views / collectors ------------------------------------------

    def next_instance(self, prefix: str) -> str:
        with self._lock:
            c = self._instance_counters.setdefault(prefix, itertools.count())
            return f"{prefix}{next(c)}"

    def track_view(self, view: "StatsView") -> None:
        with self._lock:
            self._views.append(weakref.ref(view))

    def add_collector(self, fn: Callable[[], list]) -> None:
        """Register a pull collector returning
        ``[(name, kind, labels_dict, value), ...]`` at collect time."""
        with self._lock:
            self._collectors.append(fn)

    def live_views(self) -> list:
        out, alive = [], []
        with self._lock:
            refs = list(self._views)
        for r in refs:
            v = r()
            if v is not None:
                out.append(v)
                alive.append(r)
        with self._lock:
            self._views = alive
        return out

    # -- export -------------------------------------------------------------

    def collect(self) -> list[tuple[str, str, dict, Any]]:
        """Flatten everything into ``(name, kind, labels, value)`` rows.

        Histogram rows carry ``(sum, count, counts_by_bucket)`` tuples as
        their value; counter/gauge rows carry plain numbers.
        """
        rows: list[tuple[str, str, dict, Any]] = []
        for fam in list(self._families.values()):
            for labels, cell in fam.samples():
                if fam.kind == "histogram":
                    rows.append((fam.name, fam.kind, labels,
                                 (cell.sum, cell.count, tuple(cell.counts))))
                else:
                    rows.append((fam.name, fam.kind, labels, cell.value))
            if fam.overflowed:
                rows.append((fam.name + "_label_overflow", "counter",
                             {}, fam.overflowed))
        for view in self.live_views():
            prefix = view._PREFIX
            labels = dict(view._labels)
            for f in view._FIELDS:
                rows.append((f"{prefix}_{f}", "counter", labels,
                             view._cells[f].value))
            for f in getattr(view, "_DERIVED", ()):
                rows.append((f"{prefix}_{f}", "gauge", labels,
                             getattr(view, f)))
        for fn in list(self._collectors):
            rows.extend(fn())
        return rows

    def snapshot(self) -> dict:
        """Nested strict-JSON-able dict of every metric (for artifacts)."""
        out: dict[str, list] = {}
        for name, kind, labels, value in self.collect():
            if isinstance(value, tuple):  # histogram
                s, c, counts = value
                value = {"sum": s, "count": c, "buckets": list(counts)}
            out.setdefault(name, []).append(
                {"labels": labels, "kind": kind, "value": value})
        return jsonable(out)

    def render(self) -> str:
        """Prometheus text exposition of every family and live stats view."""
        by_name: dict[str, list] = {}
        kinds: dict[str, str] = {}
        for name, kind, labels, value in self.collect():
            by_name.setdefault(name, []).append((labels, value))
            kinds[name] = kind
        lines = []
        for name in sorted(by_name):
            kind = kinds[name]
            fam = self._families.get(name)
            if fam is not None and fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {kind}")
            for labels, value in by_name[name]:
                lab = _fmt_labels(labels)
                if kind == "histogram":
                    s, c, counts = value
                    edges = (fam.buckets if fam is not None
                             else DEFAULT_BUCKETS)
                    acc = 0
                    for edge, n in zip(edges, counts):
                        acc += n
                        lines.append(
                            f"{name}_bucket{_fmt_labels(labels, le=edge)}"
                            f" {acc}")
                    acc += counts[-1]
                    lines.append(
                        f"{name}_bucket{_fmt_labels(labels, le='+Inf')}"
                        f" {acc}")
                    lines.append(f"{name}_sum{lab} {_fmt_num(s)}")
                    lines.append(f"{name}_count{lab} {c}")
                else:
                    lines.append(f"{name}{lab} {_fmt_num(value)}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Zero every family cell (cells stay interned so cached references
        held by instrumented closures keep working). Stats views are owned
        by their handles and are not touched."""
        for fam in list(self._families.values()):
            for _, cell in fam.samples():
                if isinstance(cell, _HistCell):
                    cell.counts = [0] * (len(cell.edges) + 1)
                    cell.sum = 0.0
                    cell.count = 0
                else:
                    cell.value = 0
            fam.overflowed = 0


def _fmt_labels(labels: dict, **extra) -> str:
    items = {**labels, **{k: v for k, v in extra.items()}}
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items.items())
    return "{" + body + "}"


def _fmt_num(v) -> str:
    # None is the repo-wide zero-work sentinel (undefined sample, e.g. fps
    # with nothing executed); Prometheus spells "no value" as NaN
    if v is None:
        return "NaN"
    if isinstance(v, float):
        if v != v or v in (float("inf"), float("-inf")):
            return "NaN" if v != v else ("+Inf" if v > 0 else "-Inf")
        return repr(v)
    return str(v)


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide registry every stats object reports into."""
    return _REGISTRY


# --------------------------------------------------------------------------
# stats views


class StatsView:
    """Base for the legacy stats dataclass-alikes, now registry-backed.

    Subclasses declare ``_PREFIX`` (metric name prefix), ``_FIELDS`` (the
    counter names, in snapshot order) and optionally ``_PARENT_MAP``
    (child field -> parent field; defaults to same-name).  Attribute reads
    return cell values and ``stats.field += n`` propagates the delta up the
    parent chain, so the old ``FrontendStats``-style call sites keep
    working unchanged while every increment lands in exactly one place.
    """

    _PREFIX = "fpca_stats"
    _FIELDS: tuple[str, ...] = ()
    _PARENT_MAP: dict[str, Optional[str]] = {}
    _DERIVED: tuple[str, ...] = ()

    __slots__ = ("_cells", "_labels", "__weakref__")

    def __init__(self, parent: "StatsView | None" = None,
                 labels: dict | None = None):
        cells: dict[str, _Cell] = {}
        pcells = parent._cells if parent is not None else {}
        for f in self._FIELDS:
            pf = self._PARENT_MAP.get(f, f)
            pcell = pcells.get(pf) if pf is not None else None
            cells[f] = _Cell(0, pcell)
        object.__setattr__(self, "_cells", cells)
        lab = dict(labels or {})
        lab.setdefault("instance", _REGISTRY.next_instance(self._PREFIX))
        object.__setattr__(self, "_labels", lab)
        _REGISTRY.track_view(self)

    def __getattr__(self, name: str):
        cells = object.__getattribute__(self, "_cells")
        try:
            return cells[name].value
        except KeyError:
            raise AttributeError(
                f"{type(self).__name__} has no field {name!r}") from None

    def __setattr__(self, name: str, value) -> None:
        cell = object.__getattribute__(self, "_cells").get(name)
        if cell is None:
            raise AttributeError(
                f"{type(self).__name__} has no field {name!r}")
        delta = value - cell.value
        if delta:
            cell.add(delta)
        else:
            cell.value = value

    def snapshot(self) -> tuple:
        cells = object.__getattribute__(self, "_cells")
        return tuple(cells[f].value for f in self._FIELDS)

    def as_dict(self) -> dict:
        cells = object.__getattribute__(self, "_cells")
        d = {f: cells[f].value for f in self._FIELDS}
        for f in self._DERIVED:
            d[f] = getattr(self, f)
        return d

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v}" for k, v in self.as_dict().items())
        return f"{type(self).__name__}({body})"

    def __eq__(self, other) -> bool:
        if isinstance(other, StatsView):
            return (type(self) is type(other)
                    and self.as_dict() == other.as_dict())
        return NotImplemented

    __hash__ = object.__hash__


# --------------------------------------------------------------------------
# session / spans / events


class TelemetrySession:
    """One enabled telemetry run: JSONL sink + device-hook policy."""

    def __init__(self, jsonl_path: Path | str | None = None, *,
                 profile: bool = False, device_time_rate: int = 0,
                 run_labels: dict | None = None):
        self.jsonl_path = Path(jsonl_path) if jsonl_path else None
        self.profile = bool(profile)
        # sample honest device time (block_until_ready) on every Nth
        # instrumented launch; 0 disables blocking entirely.
        self.device_time_rate = int(device_time_rate)
        self.run_labels = dict(run_labels or {})
        self.events_written = 0
        self._fh = None
        self._lock = threading.Lock()
        if self.jsonl_path is not None:
            self.jsonl_path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.jsonl_path, "w")
        self.event("session_start", labels=self.run_labels)

    def event(self, kind: str, **fields) -> None:
        if self._fh is None:
            self.events_written += 1
            return
        rec = {"ts": time.time(), "event": kind, **fields}
        line = json.dumps(jsonable(rec), allow_nan=False)
        with self._lock:
            self._fh.write(line + "\n")
            self.events_written += 1

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        self.event("session_end", events=self.events_written)
        if self._fh is not None:
            self._fh.flush()
            self._fh.close()
            self._fh = None


class _State(threading.local):
    def __init__(self):
        self.stack: list[str] = []


_LOCAL = _State()
_SESSION: TelemetrySession | None = None


def enable(jsonl_path: Path | str | None = None, *,
           profile: bool = False, device_time_rate: int = 0,
           run_labels: dict | None = None) -> TelemetrySession:
    """Turn telemetry on for the process (spans, JSONL, device hooks).

    Counters in stats views are *always* live (they are plain attribute
    adds); what ``enable`` switches on is the expensive part: span timing,
    JSONL event emission, and the opt-in device-profile hooks
    (``profile=True`` wraps launches in ``jax.profiler.TraceAnnotation``;
    ``device_time_rate=N`` blocks on every Nth launch for honest device
    time — leave 0 to never sync).
    """
    global _SESSION
    if _SESSION is not None:
        _SESSION.close()
    _SESSION = TelemetrySession(jsonl_path, profile=profile,
                                device_time_rate=device_time_rate,
                                run_labels=run_labels)
    return _SESSION


def disable() -> None:
    """Close the active session (if any) and return to zero-overhead mode."""
    global _SESSION
    if _SESSION is not None:
        _SESSION.close()
        _SESSION = None


def enabled() -> bool:
    return _SESSION is not None


def session() -> TelemetrySession | None:
    return _SESSION


def event(kind: str, **fields) -> None:
    """Emit one JSONL event if telemetry is enabled; no-op otherwise."""
    s = _SESSION
    if s is not None:
        s.event(kind, **fields)


class _NullSpan:
    """Shared no-op context manager: ``span()`` returns this exact object
    when telemetry is disabled, so the hot path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "fields", "t0", "_session")

    def __init__(self, sess: TelemetrySession, name: str,
                 fields: dict | None):
        self.name = name
        self.fields = fields
        self._session = sess
        self.t0 = 0.0

    def __enter__(self):
        _LOCAL.stack.append(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self.t0
        stack = _LOCAL.stack
        stack.pop()
        parent = stack[-1] if stack else None
        _SPAN_HIST.labels(span=self.name).observe(dt)
        self._session.event(
            "span", span=self.name, dur_s=dt, parent=parent,
            depth=len(stack), **(self.fields or {}))
        return False


_SPAN_HIST = _REGISTRY.histogram(
    "fpca_span_seconds", "wall-clock duration of traced spans",
    ("span",), max_label_sets=64)


def span(name: str, fields: dict | None = None):
    """``with telemetry.span("serve_tick", {"stream": sid}): ...``

    Returns the shared null context manager when disabled — one module
    global ``is None`` check and nothing else.  ``fields`` is a plain
    optional dict (not ``**kwargs``) so a disabled-mode call in a tick hot
    path allocates nothing; hot call sites prebuild their label dict once
    per stream and pass the same object every tick."""
    s = _SESSION
    if s is None:
        return _NULL_SPAN
    return _Span(s, name, fields)


# --------------------------------------------------------------------------
# device-profile hooks


_LAUNCHES = _REGISTRY.counter(
    "fpca_launches_total", "instrumented executable invocations",
    ("site", "backend"), max_label_sets=128)
_DEVICE_SECONDS = _REGISTRY.histogram(
    "fpca_device_seconds", "sampled honest device time per launch "
    "(block_until_ready)", ("site", "backend"), max_label_sets=128)


def instrument_launch(fn: Callable, *, site: str, backend: str) -> Callable:
    """Wrap a jitted executable with the opt-in device-profile hooks.

    Disabled mode costs one module-global ``is None`` check per call.
    Enabled mode counts the launch; with ``profile=True`` on the session it
    runs under ``jax.profiler.TraceAnnotation`` (visible in TensorBoard /
    perfetto traces); with ``device_time_rate=N`` every Nth call blocks on
    the result for an honest device-time sample (steady-state calls stay
    non-blocking).
    """
    counter = _LAUNCHES.labels(site=site, backend=backend)
    hist = _DEVICE_SECONDS.labels(site=site, backend=backend)
    tag = f"fpca:{site}:{backend}"
    state = {"n": 0}

    def launch(*args, **kwargs):
        s = _SESSION
        if s is None:
            return fn(*args, **kwargs)
        counter.add(1)
        state["n"] += 1
        if s.profile:
            import jax
            with jax.profiler.TraceAnnotation(tag):
                out = fn(*args, **kwargs)
        else:
            out = fn(*args, **kwargs)
        rate = s.device_time_rate
        if rate > 0 and state["n"] % rate == 0:
            import jax
            t0 = time.perf_counter()
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            hist.observe(dt)
            s.event("device_time", site=site, backend=backend, dur_s=dt,
                    launch=state["n"])
        return out

    launch.__wrapped__ = fn
    launch._fpca_site = site
    return launch
