"""Model zoo: the meta-architecture registry (``register_arch`` /
``build_model``).

d2go-style config-driven model construction: an architecture is a named
builder ``fn(cfg) -> FPCAModelProgram`` registered under a string name;
``build_model({"arch": name, ...})`` dispatches to it.  The built program is
stamped with ``arch=name`` so model-side telemetry (the ``fpca_model_*``
families in :mod:`repro.fpca.executable`) and ``fleet_report()`` break out
workloads per architecture.

Three architectures ship registered:

* ``"fpca_cnn"`` — the repo's original sequential classifier, *unchanged*:
  the builder constructs the exact same chain-head tuple as
  ``repro.configs.fpca_cnn.make_model_program``, so its ``signature()`` is
  byte-identical and every warm executable is shared (zero recompiles,
  pinned in ``tests/test_zoo.py``);
* ``"fpca_resnet"`` — a residual classifier over a
  :class:`repro.models.heads.HeadGraph` (conv trunk, post-add relu join);
* ``"fpca_detect"`` — a detection head: per-coarse-cell class scores + box
  regression (:class:`repro.models.heads.DetectSpec`), streaming per-tick
  :class:`repro.models.heads.Detections` through ``serve`` / ``run_segment``.

``cfg`` keys every builder understands: ``spec`` (an
:class:`repro.core.mapping.FPCASpec` or kwargs mapping; defaults to the
repo config's ``FRONTEND_SPEC``), ``frontend`` (a full
:class:`repro.fpca.FPCAProgram`, or extra ``FPCAProgram`` kwargs such as
``gate``), ``input_scale``, ``n_classes``; per-arch knobs (``hidden``,
``width``, ``detect_kernel``) are documented on each builder.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping

from repro.core.mapping import FPCASpec
from repro.fpca.program import (
    ConvSpec,
    DenseSpec,
    FPCAModelProgram,
    FPCAProgram,
    PoolSpec,
)
from repro.models.heads import AddSpec, DetectSpec, HeadGraph, Node

__all__ = ["register_arch", "build_model", "available_archs"]

_ARCHS: dict[str, Callable[[Mapping], FPCAModelProgram]] = {}


def register_arch(name: str, *, overwrite: bool = False):
    """Decorator registering a builder ``fn(cfg) -> FPCAModelProgram`` under
    ``name``.  Duplicate names are an error unless ``overwrite=True`` —
    silently shadowing an architecture would silently change what a fleet
    serves."""
    if not name or not isinstance(name, str):
        raise ValueError("architecture name must be a non-empty string")

    def deco(fn: Callable[[Mapping], FPCAModelProgram]):
        if name in _ARCHS and not overwrite:
            raise ValueError(
                f"architecture {name!r} already registered; pass "
                f"overwrite=True to replace it"
            )
        _ARCHS[name] = fn
        return fn

    return deco


def available_archs() -> tuple[str, ...]:
    """Registered architecture names, sorted."""
    return tuple(sorted(_ARCHS))


def build_model(cfg: Mapping | None = None, **overrides) -> FPCAModelProgram:
    """Build the architecture named by ``cfg["arch"]`` (kwargs override cfg
    keys).  The returned program carries ``arch=name`` for telemetry; the
    signature is untouched by that stamp."""
    merged: dict[str, Any] = {**(dict(cfg) if cfg else {}), **overrides}
    if "arch" not in merged:
        raise KeyError(
            "build_model(cfg) needs an 'arch' key naming a registered "
            "architecture"
        )
    name = merged["arch"]
    builder = _ARCHS.get(name)
    if builder is None:
        raise KeyError(
            f"unknown architecture {name!r}; registered: "
            f"{list(available_archs())}"
        )
    model = builder(merged)
    if model.arch != name:
        model = model.replace(arch=name)
    return model


def _frontend(cfg: Mapping) -> FPCAProgram:
    fe = cfg.get("frontend")
    if isinstance(fe, FPCAProgram):
        return fe
    spec = cfg.get("spec")
    if spec is None:
        from repro.configs.fpca_cnn import FRONTEND_SPEC

        spec = FRONTEND_SPEC
    if isinstance(spec, Mapping):
        spec = FPCASpec(**spec)
    kw = dict(fe) if isinstance(fe, Mapping) else {}
    return FPCAProgram(spec=spec, **kw)


# ---------------------------------------------------------------------------
# Registered architectures
# ---------------------------------------------------------------------------

@register_arch("fpca_cnn")
def _build_fpca_cnn(cfg: Mapping) -> FPCAModelProgram:
    """The original sequential classifier.  Knobs: ``hidden`` (dense width),
    ``n_classes``, or a full ``head`` tuple.  The default head tuple equals
    ``repro.configs.fpca_cnn.HEAD`` — byte-identical signature, shared
    executables."""
    from repro.configs import fpca_cnn as defaults

    head = cfg.get("head")
    if head is None:
        hidden = int(cfg.get("hidden", defaults.N_HIDDEN))
        n_classes = int(cfg.get("n_classes", defaults.N_CLASSES))
        head = (DenseSpec(hidden, activation="relu"), DenseSpec(n_classes))
    return FPCAModelProgram(
        frontend=_frontend(cfg),
        head=tuple(head),
        input_scale=float(cfg.get("input_scale", 1.0)),
    )


@register_arch("fpca_resnet")
def _build_fpca_resnet(cfg: Mapping) -> FPCAModelProgram:
    """Residual classifier: SAME-conv stem, two-conv residual branch joined
    by a post-add relu, avg-pool, two dense stages.  Knobs: ``width`` (conv
    channels), ``hidden``, ``n_classes``."""
    width = int(cfg.get("width", 16))
    hidden = int(cfg.get("hidden", 32))
    n_classes = int(cfg.get("n_classes", 2))
    graph = HeadGraph(
        nodes=(
            Node("stem", ConvSpec(width, 3, padding="SAME"), ("input",)),
            Node("conv1", ConvSpec(width, 3, padding="SAME"), ("stem",)),
            Node("conv2",
                 ConvSpec(width, 3, padding="SAME", activation=None),
                 ("conv1",)),
            Node("join", AddSpec(activation="relu"), ("stem", "conv2")),
            Node("pool", PoolSpec(2, kind="avg"), ("join",)),
            Node("fc", DenseSpec(hidden, activation="relu"), ("pool",)),
            Node("logits", DenseSpec(n_classes), ("fc",)),
        ),
        output="logits",
    )
    return FPCAModelProgram(
        frontend=_frontend(cfg),
        head=graph,
        input_scale=float(cfg.get("input_scale", 1.0)),
    )


@register_arch("fpca_detect")
def _build_fpca_detect(cfg: Mapping) -> FPCAModelProgram:
    """Detection head: SAME-conv trunk then a :class:`DetectSpec` emitting
    ``n_classes`` class scores + 4 box channels per coarse cell of the
    frontend grid.  Knobs: ``width`` (trunk channels), ``n_classes``,
    ``detect_kernel`` (SAME conv size of the detect stage)."""
    width = int(cfg.get("width", 16))
    n_classes = int(cfg.get("n_classes", 2))
    graph = HeadGraph(
        nodes=(
            Node("trunk", ConvSpec(width, 3, padding="SAME"), ("input",)),
            Node("det",
                 DetectSpec(n_classes, kernel=int(cfg.get("detect_kernel", 1))),
                 ("trunk",)),
        ),
        output="det",
    )
    return FPCAModelProgram(
        frontend=_frontend(cfg),
        head=graph,
        input_scale=float(cfg.get("input_scale", 1.0)),
    )
