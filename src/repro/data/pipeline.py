"""Deterministic, resumable, sharded synthetic data pipelines.

Production posture (DESIGN.md §5):

* **Stateless addressing** — every batch is a pure function of
  ``(seed, step)``; the only pipeline state is the step cursor saved in the
  checkpoint manifest, so restarts resume bit-exactly and elastic re-shards
  (different dp size) slice the same global batch differently without
  re-reading history.
* **Host sharding** — ``batch_at(step, shard, n_shards)`` returns just this
  host's slice of the global batch.
* **Straggler mitigation** — ``PrefetchIterator`` overlaps host batch
  synthesis with device steps on a worker thread and, past a deadline,
  reports the stall instead of silently blocking (the hook a real cluster
  wires to its health monitor).

The LM stream is a noisy affine-recurrence language (next token mostly
determined by the previous token), so cross-entropy measurably falls within
a few hundred steps — real signal for the end-to-end examples, zero data
downloads.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import numpy as np

__all__ = [
    "LMStreamConfig",
    "SyntheticLM",
    "SyntheticVWW",
    "SyntheticMovingObject",
    "PrefetchIterator",
]


@dataclasses.dataclass(frozen=True)
class LMStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05     # fraction of tokens replaced by uniform noise


class SyntheticLM:
    """Markov-ish synthetic token stream with deterministic addressing."""

    def __init__(self, cfg: LMStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self._mult = int(rng.integers(3, 97)) | 1          # odd multiplier
        self._add = int(rng.integers(1, v))

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> dict[str, Any]:
        cfg = self.cfg
        if cfg.global_batch % n_shards:
            raise ValueError("global_batch must divide by n_shards")
        per = cfg.global_batch // n_shards
        rng = np.random.default_rng((cfg.seed, step, shard))
        v = cfg.vocab_size
        seq = np.empty((per, cfg.seq_len + 1), np.int64)
        seq[:, 0] = rng.integers(0, v, per)
        noise_mask = rng.random((per, cfg.seq_len)) < cfg.noise
        noise_tok = rng.integers(0, v, (per, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = (seq[:, t] * self._mult + self._add) % v
            seq[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict[str, Any]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticVWW:
    """Visual-wake-word-like image stream for the FPCA frontend examples.

    Both classes place blobs of the *same total brightness* on the same
    clutter; what differs is **shape**: 'person' = two vertically stacked
    blobs (head over torso), 'no person' = one wide blob.  Global brightness
    is jittered per image, so intensity statistics do not separate the
    classes — the classifier has to learn spatial features through the FPCA
    frontend, which is exactly the regime where the analog non-linearity and
    quantisation matter.
    """

    def __init__(self, image_hw: tuple[int, int] = (60, 60), seed: int = 0):
        self.h, self.w = image_hw
        self.seed = seed

    def batch_at(self, step: int, batch: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        h, w = self.h, self.w
        imgs = rng.uniform(0.0, 0.30, (batch, h, w, 3)).astype(np.float32)
        labels = rng.integers(0, 2, batch).astype(np.int32)
        yy, xx = np.mgrid[0:h, 0:w]
        for i in range(batch):
            cy = rng.integers(h // 3, 2 * h // 3)
            cx = rng.integers(w // 3, 2 * w // 3)
            color = rng.uniform(0.6, 1.0, 3)
            if labels[i]:
                # head-over-torso: two stacked blobs
                parts = ((h // 10, 0, h // 8, 0.45), (-h // 8, 0, h // 14, 0.45))
            else:
                # single wide blob, matched total energy
                parts = ((0, 0, h // 6, 0.40),)
            for (dy, dx, r, amp) in parts:
                d2 = (yy - cy - dy) ** 2 + (xx - cx - dx) ** 2
                imgs[i] += (amp * np.exp(-d2 / (2.0 * r * r)))[..., None] * color
            # brightness jitter kills intensity shortcuts
            imgs[i] *= rng.uniform(0.7, 1.1)
        return {"images": np.clip(imgs, 0.0, 1.0), "labels": labels}


class SyntheticMovingObject:
    """Deterministic video stream: static cluttered scene + one moving blob.

    The streaming-frontend workload: frame-to-frame, only the pixels under
    the blob's old and new positions change, so a temporal delta gate keeps a
    small block fraction (tunable via ``radius``/``speed``).  ``frame_at(t)``
    is a pure function of ``(seed, t)`` — streams restart and shard exactly
    like the other synthetic pipelines here.
    """

    def __init__(
        self,
        image_hw: tuple[int, int] = (96, 96),
        seed: int = 0,
        radius: float = 7.0,
        speed: float = 0.17,
        amplitude: float = 0.55,
    ):
        self.h, self.w = image_hw
        self.radius = radius
        self.speed = speed
        self.amplitude = amplitude
        rng = np.random.default_rng(seed)
        # static background: low-frequency clutter, fixed for the stream
        base = rng.uniform(0.05, 0.35, (self.h // 8 + 1, self.w // 8 + 1, 3))
        self._background = np.clip(
            np.kron(base, np.ones((8, 8, 1)))[: self.h, : self.w], 0.0, 1.0
        ).astype(np.float32)
        self._yy, self._xx = np.mgrid[0 : self.h, 0 : self.w]
        self._color = rng.uniform(0.6, 1.0, 3).astype(np.float32)

    def frame_at(self, t: int) -> np.ndarray:
        """Frame ``t``: the blob orbits the scene centre."""
        cy = self.h / 2 + 0.30 * self.h * np.sin(self.speed * t)
        cx = self.w / 2 + 0.30 * self.w * np.cos(self.speed * t)
        d2 = (self._yy - cy) ** 2 + (self._xx - cx) ** 2
        blob = self.amplitude * np.exp(-d2 / (2.0 * self.radius**2))
        frame = self._background + blob[..., None].astype(np.float32) * self._color
        return np.clip(frame, 0.0, 1.0).astype(np.float32)

    def frames(self, n: int, start: int = 0):
        for t in range(start, start + n):
            yield self.frame_at(t)


class PrefetchIterator:
    """Thread-prefetching wrapper with a stall deadline (straggler hook)."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2, timeout_s: float = 60.0):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._timeout = timeout_s
        self._stalls = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            self._q.put((step, batch))
            step += 1

    @property
    def stalls(self) -> int:
        return self._stalls

    def __next__(self):
        try:
            return self._q.get(timeout=self._timeout)
        except queue.Empty:
            self._stalls += 1
            raise TimeoutError(
                f"data pipeline stalled > {self._timeout}s (stall #{self._stalls}); "
                "a production deployment skips the straggler shard here"
            )

    def close(self):
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()
