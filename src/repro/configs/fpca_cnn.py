"""The paper's own workload: a small CNN with an FPCA first layer
(VWW-class visual wake-word classification, paper §1/§5).

Not part of the assigned LM pool — this is the FPCA technique's native
application, used by examples/train_fpca_cnn.py, examples/serve_fpca_cnn.py
and the Fig. 9 benchmarks.  ``HEAD`` is the canonical digital backend the
in-pixel layer feeds (the head trained by train_fpca_cnn.py); wrap frontend
and head together with :func:`make_model_program` and compile the whole
network with ``repro.fpca.compile``.
"""
from repro.core.mapping import FPCASpec
from repro.fpca import DenseSpec, FPCAModelProgram, FPCAProgram

# 5x5x3 kernel, 8 output channels, stride 5 (the paper's energy sweet spot)
FRONTEND_SPEC = FPCASpec(
    image_h=120, image_w=120, out_channels=8, kernel=5, stride=5, max_kernel=5
)
N_CLASSES = 2
N_HIDDEN = 64

# The digital classifier head behind the analog frontend: the MLP of
# examples/train_fpca_cnn.py as validated layer specs (last stage = logits).
HEAD = (DenseSpec(N_HIDDEN, activation="relu"), DenseSpec(N_CLASSES))


# Model-zoo config for the same network: ``repro.fpca.zoo.build_model(CFG)``
# (or ``build()`` below) constructs a byte-identical model program — same
# signature, shared warm executables, zero recompiles — stamped with
# ``arch="fpca_cnn"`` for the per-workload telemetry breakout.
CFG = {
    "arch": "fpca_cnn",
    "spec": FRONTEND_SPEC,
    "hidden": N_HIDDEN,
    "n_classes": N_CLASSES,
    "input_scale": 1.0,
}


def build(cfg=None, **overrides):
    """Zoo-built twin of :func:`make_model_program` (defaults = ``CFG``)."""
    from repro.fpca.zoo import build_model

    return build_model({**CFG, **(dict(cfg) if cfg else {})}, **overrides)


def make_model_program(
    spec: FPCASpec = FRONTEND_SPEC,
    *,
    head: tuple = HEAD,
    input_scale: float = 1.0,
    **frontend_kw,
) -> FPCAModelProgram:
    """The whole VWW-class network as one compileable model program.

    ``frontend_kw`` (circuit / adc / enc / gate / controller) configure the
    analog first layer; ``input_scale`` is the counts -> activation-unit
    digital gain a trained export bakes in (``adc.lsb * gain``).
    """
    return FPCAModelProgram(
        frontend=FPCAProgram(spec=spec, **frontend_kw),
        head=head,
        input_scale=input_scale,
    )
