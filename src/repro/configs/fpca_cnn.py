"""The paper's own workload: a small CNN with an FPCA first layer
(VWW-class visual wake-word classification, paper §1/§5).

Not part of the assigned LM pool — this is the FPCA technique's native
application, used by examples/train_fpca_cnn.py and the Fig. 9 benchmarks.
"""
from repro.core.mapping import FPCASpec

# 5x5x3 kernel, 8 output channels, stride 5 (the paper's energy sweet spot)
FRONTEND_SPEC = FPCASpec(
    image_h=120, image_w=120, out_channels=8, kernel=5, stride=5, max_kernel=5
)
N_CLASSES = 2
