"""seamless-m4t-medium — Meta SeamlessM4T (medium), enc-dec multimodal.

12L enc + 12L dec, d_model=1024 16H (kv=16) d_ff=4096, vocab 256206.
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings (assignment requirement). [arXiv:2308.11596; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,            # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    frontend_dim=1024,      # precomputed speech-frame embedding width
)
