"""Model/config schema shared by all assigned architectures.

Every architecture file in this package exports ``CONFIG`` (the exact
published configuration) and ``smoke_config()`` (a reduced same-family
variant for CPU smoke tests).  ``repro.configs.ARCHS`` is the registry keyed
by ``--arch`` id.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "reduce_for_smoke"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_renormalize: bool = True

    # --- attention details ---------------------------------------------------
    qk_norm: bool = False
    window: int | None = None       # sliding-window attention (tokens)
    rope_theta: float = 1e4
    attn_block_k: int = 512         # flash KV-block size (perf knob)
    moe_capacity_factor: float = 1.25  # expert capacity slack (perf knob)
    logits_vocab_shard: bool = True    # reshard table vocab-over-model at unembed
    moe_local_dispatch: bool = False   # per-sequence expert routing (perf lever)

    # --- SSM (Mamba2) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # --- hybrid (Zamba2): shared attention block every k SSM layers -----------
    hybrid_attn_period: int = 0

    # --- encoder-decoder (Seamless) -------------------------------------------
    n_enc_layers: int = 0

    # --- modality frontend stub (audio frames / vision patches) ---------------
    frontend: str | None = None     # 'audio' | 'vision'
    frontend_dim: int = 0           # stub embedding width
    frontend_tokens: int = 0        # prepended tokens (vision patches)

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing (enc-dec included)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k routed + shared experts).

        This is the N in MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference)."""
        if self.family != "moe":
            return self.param_count()
        dense_like = dataclasses.replace(
            self,
            n_experts=self.top_k,
            # shared experts always run; keep them via n_shared_experts
        )
        return dense_like.param_count()

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            return d * self.head_dim * (2 * self.n_heads + 2 * self.n_kv_heads)

        def mlp_params(ff: int, gated: bool = True) -> int:
            return d * ff * (3 if gated else 2)

        def moe_params() -> int:
            p = d * self.n_experts + self.n_experts * mlp_params(self.moe_d_ff)
            if self.n_shared_experts:
                p += mlp_params(self.n_shared_experts * self.moe_d_ff) + d
            return p

        def mamba_params() -> int:
            d_inner = self.ssm_expand * d
            gn = self.ssm_groups * self.ssm_state
            nh = d_inner // self.ssm_head_dim
            in_dim = 2 * d_inner + 2 * gn + nh
            conv_dim = d_inner + 2 * gn
            return d * in_dim + self.ssm_conv * conv_dim + d_inner * d + 3 * nh + d_inner

        if self.family in ("dense", "vlm"):
            per_layer = attn_params() + mlp_params(self.d_ff)
            total += self.n_layers * per_layer
            if self.family == "vlm":
                total += self.frontend_dim * d + d * d  # projector MLP
        elif self.family == "moe":
            total += self.n_layers * (attn_params() + moe_params())
        elif self.family == "ssm":
            total += self.n_layers * mamba_params()
        elif self.family == "hybrid":
            total += self.n_layers * mamba_params()
            total += attn_params() + mlp_params(self.d_ff)  # one shared block
        elif self.family == "encdec":
            enc_layer = attn_params() + mlp_params(self.d_ff, gated=False)
            dec_layer = 2 * attn_params() + mlp_params(self.d_ff, gated=False)
            total += self.n_enc_layers * enc_layer + self.n_layers * dec_layer
            if self.frontend:
                total += self.frontend_dim * d
        return total


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md §4)"
    return True, ""


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: runs a forward/train step on CPU in seconds."""
    changes: dict = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.family != "hybrid" else 3),
        d_model=64,
        vocab_size=256,
        dtype="float32",
    )
    if cfg.n_heads:
        changes.update(n_heads=4, n_kv_heads=2, head_dim=16)
    if cfg.d_ff:
        changes.update(d_ff=128)
    if cfg.n_experts:
        changes.update(n_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=32)
    if cfg.n_shared_experts:
        changes.update(n_shared_experts=2)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.hybrid_attn_period:
        changes.update(hybrid_attn_period=2)
    if cfg.n_enc_layers:
        changes.update(n_enc_layers=2)
    if cfg.frontend_dim:
        changes.update(frontend_dim=32)
    if cfg.frontend_tokens:
        changes.update(frontend_tokens=4)
    if cfg.window:
        changes.update(window=32)
    return dataclasses.replace(cfg, **changes)
