"""Architecture registry: ``--arch <id>`` -> ModelConfig."""

from repro.configs import (
    granite_moe_3b_a800m,
    h2o_danube_1_8b,
    internvl2_76b,
    mamba2_2_7b,
    phi3_medium_14b,
    qwen2_moe_a2_7b,
    qwen3_1_7b,
    seamless_m4t_medium,
    yi_9b,
    zamba2_7b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, reduce_for_smoke, shape_applicable

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_moe_3b_a800m,
        qwen2_moe_a2_7b,
        seamless_m4t_medium,
        internvl2_76b,
        h2o_danube_1_8b,
        phi3_medium_14b,
        qwen3_1_7b,
        yi_9b,
        zamba2_7b,
        mamba2_2_7b,
    )
}

__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "reduce_for_smoke",
    "shape_applicable",
]
