"""internvl2-76b — InternVL2 (InternViT frontend + LLaMA-arch 70B-class LM).

80L d_model=8192 64H (GQA kv=8) d_ff=28672, vocab 128256.
Vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (InternViT hidden size 3200); the projector MLP
is part of the model.  An FPCA patch-embed frontend is available as an
opt-in for the real-image path (DESIGN.md §4). [arXiv:2404.16821; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision",
    frontend_dim=3200,
    frontend_tokens=256,
)
