"""zamba2-7b — Zyphra Zamba2 (Mamba2 backbone + shared attention blocks).

81 Mamba2 layers, d_model=3584, ssm_state=64; one *shared* attention+MLP
block (32H, kv=32, d_ff=14336) applied every 6 SSM layers (weights reused
across applications — the Zamba2 trick). vocab 32000.
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    hybrid_attn_period=6,
)
