"""mamba2-2.7b — Mamba2 (SSD, attention-free).

64L d_model=2560, ssm_state=128, expand=2 (d_inner=5120, 80 heads of 64),
vocab 50280. [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    vocab_size=50280,
    ssm_state=128,
)
