"""granite-moe-3b-a800m — IBM Granite 3.0 MoE.

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512, vocab 49155,
40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,          # kept for reference; experts use moe_d_ff
    moe_d_ff=512,
    n_experts=40,
    top_k=8,
    vocab_size=49155,
    tie_embeddings=True,
)
