"""FPCA core — the paper's contribution as composable JAX modules.

* :mod:`repro.core.device_models` — physics-inspired analog circuit oracle
  (the SPICE stand-in);
* :mod:`repro.core.curvefit`      — two-step bucket-select curvefit model
  (paper §4), hard and differentiable variants;
* :mod:`repro.core.mapping`       — RS/SW/ColP/switch-matrix schedule, Eq. 1
  cycle model, region skipping;
* :mod:`repro.core.adc`           — up/down SS-ADC with BN fold + ReLU clamp;
* :mod:`repro.core.fpca_sim`      — end-to-end functional frontend simulator;
* :mod:`repro.core.frontend`      — trainable FPCAFrontend layer;
* :mod:`repro.core.analysis`      — energy / latency / bandwidth models
  (Eqs. 2--8, Fig. 9).
"""

from repro.core.adc import ADCConfig, quantize_voltage, updown_readout
from repro.core.analysis import (
    FrontendConstants,
    bandwidth_reduction,
    conventional_cis,
    frontend_energy,
    frontend_latency,
)
from repro.core.curvefit import (
    BucketCurvefitModel,
    PolySurface,
    fit_bucket_model,
    predict_hard,
    predict_sigmoid,
)
from repro.core.device_models import CircuitParams, analog_dot_product, pixel_drive
from repro.core.fpca_sim import (
    WeightEncoding,
    calibrate_gain,
    encode_weights,
    extract_windows,
    fpca_forward,
)
from repro.core.frontend import FPCAFrontend
from repro.core.mapping import (
    FPCASpec,
    active_window_mask,
    n_cycles,
    n_cycles_with_skipping,
    output_dims,
    schedule,
)


def __getattr__(name: str):
    # deprecated names forward lazily so `import repro.core` stays clean
    # under -W error::DeprecationWarning; accessing them warns (see
    # repro.core.frontend / repro.fpca for the canonical replacements)
    if name == "FPCAFrontendConfig":
        from repro.core import frontend

        return frontend.FPCAFrontendConfig
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ADCConfig",
    "BucketCurvefitModel",
    "CircuitParams",
    "FPCAFrontend",
    "FPCAFrontendConfig",
    "FPCASpec",
    "FrontendConstants",
    "PolySurface",
    "WeightEncoding",
    "active_window_mask",
    "analog_dot_product",
    "bandwidth_reduction",
    "calibrate_gain",
    "conventional_cis",
    "encode_weights",
    "extract_windows",
    "fit_bucket_model",
    "fpca_forward",
    "frontend_energy",
    "frontend_latency",
    "n_cycles",
    "n_cycles_with_skipping",
    "output_dims",
    "pixel_drive",
    "predict_hard",
    "predict_sigmoid",
    "quantize_voltage",
    "schedule",
    "updown_readout",
]
