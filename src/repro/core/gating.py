"""Temporal delta-gate math, shared between the host loop and the device.

The streaming stack keeps two copies of the gate state machine alive: the
host-side per-tick loop (:class:`repro.serving.streaming.StreamSession`) and
the device-compiled segment executor (one ``jax.lax.scan`` over K ticks with
the gate in the carry — :meth:`repro.fpca.CompiledFrontend.run_segment`).
The segment parity contract is *bit-identity, tick for tick*, and the fragile
part is the threshold comparison ``block_delta > threshold``: a 1-ulp
difference between a numpy and an XLA reduction flips a keep/skip decision
and breaks the whole downstream trace.  So there is exactly ONE
implementation of the gate numerics — the jnp functions here — and the host
path evaluates it through the per-spec jitted kernels of
:func:`host_gate_kernels` while the scan body inlines the same functions into
its trace.  Both sides therefore compare identical float32 bits against
identical float32 thresholds.

Everything in this module depends only on :mod:`repro.core.mapping` (no
serving imports), so the backend registry can build scan bodies from it
without import cycles.

State-machine semantics (mirrors ``streaming._GateState.step`` exactly):

* block ages start at ``hysteresis + 1`` (everything stale);
* a block's age resets to 0 when its mean |Δ| exceeds the threshold, else
  increments — but only once a previous frame exists;
* a tick is a keyframe on the first frame, then whenever
  ``keyframe_interval > 0`` and ``frame_idx % keyframe_interval == 0``;
* keep = everything on a keyframe, else ``age <= hysteresis``; keyframes do
  NOT reset ages (a static scene goes quiet again right after the refresh).
"""

from __future__ import annotations

import functools
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mapping import FPCASpec, output_dims

__all__ = [
    "GateCarry",
    "block_grid",
    "effective_frame",
    "block_reduce_mean",
    "block_delta",
    "window_mask_from_blocks",
    "gate_tick",
    "init_gate_carry",
    "host_gate_kernels",
]


class GateCarry(NamedTuple):
    """Device-resident delta-gate state (the scan carry's gate slice).

    ``has_prev`` gates the age update and forces the first-frame keyframe;
    ``prev_eff`` is the previous *effective* (binned grayscale) frame;
    ``age`` counts frames since each block last changed (int32 — identical
    to the host's int64 trajectory for any stream shorter than 2^31 ticks);
    ``frame_idx`` drives the keyframe cadence.
    """

    has_prev: jax.Array   # () bool
    prev_eff: jax.Array   # (eff_h, eff_w) float32
    age: jax.Array        # (bh, bw) int32
    frame_idx: jax.Array  # () int32


def block_grid(spec: FPCASpec) -> tuple[int, int]:
    """Shape of the per-block keep/age grids (periphery SRAM geometry)."""
    b = spec.skip_block
    return math.ceil(spec.eff_h / b), math.ceil(spec.eff_w / b)


def effective_frame(frame: jax.Array, spec: FPCASpec) -> jax.Array:
    """Frame as the pixel array sees it: binned (average pool) grayscale."""
    img = jnp.mean(jnp.asarray(frame, jnp.float32), axis=-1)
    b = spec.binning
    if b > 1:
        h, w = img.shape
        img = img[: h // b * b, : w // b * b].reshape(
            h // b, b, w // b, b
        ).mean((1, 3))
    return img


def block_reduce_mean(x: jax.Array, block: int) -> jax.Array:
    """Mean over ``block x block`` tiles (ragged edge tiles average their
    real pixels only), shape ``(ceil(h/b), ceil(w/b))``."""
    h, w = x.shape
    bh, bw = math.ceil(h / block), math.ceil(w / block)
    padded = jnp.pad(x, ((0, bh * block - h), (0, bw * block - w)))
    sums = padded.reshape(bh, block, bw, block).sum((1, 3))
    ones = np.zeros((bh * block, bw * block), np.float32)
    ones[:h, :w] = 1.0
    counts = ones.reshape(bh, block, bw, block).sum((1, 3))
    return sums / counts


def block_delta(
    prev_eff: jax.Array, cur_eff: jax.Array, spec: FPCASpec
) -> jax.Array:
    """Mean absolute per-block change between two effective frames."""
    return block_reduce_mean(jnp.abs(cur_eff - prev_eff), spec.skip_block)


def window_mask_from_blocks(block_keep: jax.Array, spec: FPCASpec) -> jax.Array:
    """Trace-friendly twin of :func:`repro.core.mapping.active_window_mask`.

    A window executes iff *any* of its pixels lies in a kept block.  Window
    footprints that run past the effective frame read as not-kept — the same
    clipping the numpy slicing fallback applies.  Returns ``(h_o, w_o)``
    bool.
    """
    b = spec.skip_block
    h_o, w_o = output_dims(spec)
    n, s = spec.max_kernel, spec.stride
    pixel = jnp.repeat(jnp.repeat(block_keep, b, axis=0), b, axis=1)[
        : spec.eff_h, : spec.eff_w
    ]
    r_idx = (np.arange(h_o)[:, None] * s + np.arange(n)[None, :]).reshape(-1)
    c_idx = (np.arange(w_o)[:, None] * s + np.arange(n)[None, :]).reshape(-1)
    rows = jnp.take(
        pixel, jnp.asarray(r_idx), axis=0, mode="fill", fill_value=False
    )
    patch = jnp.take(
        rows, jnp.asarray(c_idx), axis=1, mode="fill", fill_value=False
    )
    return patch.reshape(h_o, n, w_o, n).any(axis=(1, 3))


def init_gate_carry(spec: FPCASpec, hysteresis: int) -> GateCarry:
    """Fresh gate state: no previous frame, every block stale (so the first
    non-keyframe tick after warm-up drops unchanged blocks, like the host)."""
    bh, bw = block_grid(spec)
    return GateCarry(
        has_prev=jnp.zeros((), bool),
        prev_eff=jnp.zeros((spec.eff_h, spec.eff_w), jnp.float32),
        age=jnp.full((bh, bw), int(hysteresis) + 1, jnp.int32),
        frame_idx=jnp.zeros((), jnp.int32),
    )


def gate_tick(
    spec: FPCASpec,
    carry: GateCarry,
    cur_eff: jax.Array,
    threshold: jax.Array,
    hysteresis: jax.Array,
    keyframe_interval: jax.Array,
) -> tuple[GateCarry, jax.Array, jax.Array]:
    """One delta-gate transition; gate knobs enter *traced* so retuning the
    threshold (the boundary servo) or the cadence never recompiles.

    Returns ``(new_carry, keep_blocks (bh, bw) bool, keyframe () bool)``.
    """
    delta = block_delta(carry.prev_eff, cur_eff, spec)
    changed = delta > threshold
    age = jnp.where(
        carry.has_prev,
        jnp.where(changed, jnp.zeros_like(carry.age), carry.age + 1),
        carry.age,
    )
    ki = keyframe_interval
    keyframe = jnp.logical_or(
        ~carry.has_prev,
        jnp.logical_and(ki > 0, carry.frame_idx % jnp.maximum(ki, 1) == 0),
    )
    keep = jnp.logical_or(keyframe, age <= hysteresis)
    new_carry = GateCarry(
        has_prev=jnp.ones((), bool),
        prev_eff=cur_eff,
        age=age,
        frame_idx=carry.frame_idx + 1,
    )
    return new_carry, keep, keyframe


class HostGateKernels(NamedTuple):
    """Per-spec jitted gate kernels for the host per-tick loop — the SAME
    jnp numerics the scan body inlines, so host and device gate decisions
    compare identical float32 bits.  ``step`` fuses the effective-frame and
    block-delta stages into ONE dispatch (the serving hot loop blocks on the
    gate result before it can build the tick's window mask, so per-call
    overhead is paid synchronously).  ``step_batch`` is its vmapped twin:
    a fleet tick gates every stream of a group in one dispatch instead of
    one per stream, which is what keeps the per-tick host cost flat as the
    fleet grows (the weak-scaling lane of ``benchmarks/fleet_bench.py``).
    It compiles once per fleet size; the per-row math is the identical
    trace, so batched and solo gate decisions agree bit for bit."""

    eff: Callable        # frame -> effective frame
    delta: Callable      # (prev_eff, cur_eff) -> block |Δ| grid
    step: Callable       # (prev_eff, frame) -> (cur_eff, block |Δ| grid)
    step_batch: Callable  # (n, ...) stacked twin of ``step``


@functools.lru_cache(maxsize=None)
def host_gate_kernels(spec: FPCASpec) -> HostGateKernels:
    eff = jax.jit(lambda frame: effective_frame(frame, spec))
    delta = jax.jit(lambda prev, cur: block_delta(prev, cur, spec))

    def _step(prev_eff, frame):
        cur = effective_frame(frame, spec)
        return cur, block_delta(prev_eff, cur, spec)

    return HostGateKernels(
        eff, delta, jax.jit(_step), jax.jit(jax.vmap(_step))
    )
