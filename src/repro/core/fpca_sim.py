"""End-to-end functional simulator of one FPCA first-layer convolution.

Glue between the scheduler (:mod:`repro.core.mapping`), the analog models
(:mod:`repro.core.device_models` / :mod:`repro.core.curvefit`) and the SS-ADC
(:mod:`repro.core.adc`):

    image --binning--> photocurrents --windows--> bitline reads (pos & neg
    cycle per channel) --SS-ADC up/down + BN offset--> ReLU'd counts

Three evaluation modes share one code path:

* ``"oracle"``         — fixed-point circuit solve (deployment ground truth);
* ``"bucket_hard"``    — paper's step-function bucket select;
* ``"bucket_sigmoid"`` — paper's differentiable single equation (trainable).

Two execution backends serve those modes (``fpca_forward(backend=...)``):

* ``"reference"`` — the dense jnp path in this module (every mode; the only
  differentiable backend, used for training and as the parity oracle);
* ``"pallas"`` / ``"basis"`` — the fused production kernels in
  :mod:`repro.kernels.fpca_conv` (``bucket_sigmoid`` + hard ADC only, i.e.
  deployment-mode serving of the calibrated sensor model).  ``"pallas"`` is
  the TPU kernel (``interpret=True`` elsewhere); ``"basis"`` is the same
  basis-expanded matmul-bank math lowered through XLA — the fast path on
  hosts where Pallas does not compile.

Images may carry a leading batch dimension; all windows of all frames are
evaluated through one fused call (the MXU-friendly layout); the cycle
*schedule* is accounted analytically by the energy/latency models.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping
from repro.core.adc import ADCConfig, ste_round, updown_readout
from repro.core.curvefit import BucketCurvefitModel, predict_hard, predict_sigmoid
from repro.core.device_models import CircuitParams, analog_dot_product

__all__ = [
    "WeightEncoding",
    "encode_weights",
    "extract_windows",
    "fpca_forward",
    "calibrate_gain",
]

Mode = Literal["oracle", "bucket_hard", "bucket_sigmoid"]
# Backend names resolve through the repro.fpca.backends registry; the Literal
# documents the built-ins, third-party registrations are equally valid.
Backend = Literal["reference", "pallas", "basis"]


@dataclasses.dataclass(frozen=True)
class WeightEncoding:
    """Float kernel -> NVM conductance-pair encoding (paper §3.2 / Fig. 2)."""

    n_levels: int = 16      # NVM programmable conductance levels (4-bit device)
    w_scale: float = 1.0    # |K| mapped to full conductance at this magnitude

    def quantize(self, w01: jax.Array, *, hard: bool = True) -> jax.Array:
        """Quantize normalised conductances to the device's discrete levels."""
        q = w01 * (self.n_levels - 1)
        q = jnp.round(q) if hard else ste_round(q)
        return q / (self.n_levels - 1)


def encode_weights(
    kernel: jax.Array,
    spec: mapping.FPCASpec,
    enc: WeightEncoding,
    *,
    hard: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Split a float kernel into (positive, negative) NVM conductance planes.

    Args:
      kernel: ``(c_o, k, k, c_i)`` float weights (logical kernel size k).

    Returns:
      ``(w_pos, w_neg)`` each ``(c_o, n*n*c_i)`` in [0, 1], zero-padded to the
      physical max kernel ``n`` (paper §3.4.1: unused slots hold conductance 0)
      and flattened channel-major to match ``extract_windows``.
    """
    c_o, k, _, c_i = kernel.shape
    n = spec.max_kernel
    if k != spec.kernel or c_i != spec.in_channels:
        raise ValueError(f"kernel shape {kernel.shape} inconsistent with spec {spec}")
    w01 = jnp.clip(jnp.abs(kernel) / enc.w_scale, 0.0, 1.0)
    w_pos = jnp.where(kernel > 0, w01, 0.0)
    w_neg = jnp.where(kernel < 0, w01, 0.0)

    def _layout(w: jax.Array) -> jax.Array:
        w = enc.quantize(w, hard=hard)
        w = jnp.transpose(w, (0, 3, 1, 2))                      # (c_o, c_i, k, k)
        w = jnp.pad(w, ((0, 0), (0, 0), (0, n - k), (0, n - k)))  # zero NVM slots
        return w.reshape(c_o, c_i * n * n)

    return _layout(w_pos), _layout(w_neg)


def extract_windows(image: jax.Array, spec: mapping.FPCASpec) -> jax.Array:
    """Image(s) -> photocurrent windows.

    Accepts one image ``(H, W, c_i)`` or a batch ``(B, H, W, c_i)``; returns
    ``(h_o, w_o, c_i*n*n)`` or ``(B, h_o, w_o, c_i*n*n)`` respectively.  The
    batched path is a single fused extraction (no per-image Python loop), so
    it is jit/vmap-friendly and shards cleanly over a leading data axis.

    Applies pixel binning (average pool, Fig. 9(b)) and zero padding first.
    Flattening is channel-major ``(c_i, n, n)`` to match ``encode_weights``.
    """
    squeeze = image.ndim == 3
    if squeeze:
        image = image[None]
    if image.ndim != 4 or image.shape[-1] != spec.in_channels:
        raise ValueError(
            f"expected (H, W, {spec.in_channels}) or (B, H, W, {spec.in_channels}) "
            f"image, got {image.shape}"
        )
    img = jnp.asarray(image, jnp.float32)
    b = spec.binning
    if b > 1:
        B, h, w, c = img.shape
        img = (
            img[:, : h // b * b, : w // b * b]
            .reshape(B, h // b, b, w // b, b, c)
            .mean((2, 4))
        )
    n, s, p = spec.max_kernel, spec.stride, spec.padding
    if s == n and p == 0:
        # non-overlapping windows (the paper's energy-optimal stride): a pure
        # reshape — no gather/conv work at all (perf path, §Perf target 3)
        B, h, w, c = img.shape
        h_o, w_o = h // n, w // n
        tiles = img[:, : h_o * n, : w_o * n].reshape(B, h_o, n, w_o, n, c)
        out = tiles.transpose(0, 1, 3, 5, 2, 4).reshape(B, h_o, w_o, c * n * n)
    else:
        patches = jax.lax.conv_general_dilated_patches(
            img.transpose(0, 3, 1, 2),              # NCHW
            filter_shape=(n, n),
            window_strides=(s, s),
            padding=((p, p), (p, p)),
        )                                           # (B, c_i*n*n, h_o, w_o)
        out = jnp.transpose(patches, (0, 2, 3, 1))  # (B, h_o, w_o, c_i*n*n)
    return out[0] if squeeze else out


def _analog_read(
    I: jax.Array,
    W: jax.Array,
    mode: Mode,
    circuit: CircuitParams,
    model: BucketCurvefitModel | None,
    n_active: int,
) -> jax.Array:
    """Batched bitline read: I ``(..., N)``, W ``(c_o, N)`` -> ``(..., c_o)``."""
    Ib = I[..., None, :]  # (..., 1, N) broadcast against channels
    if mode == "oracle":
        return analog_dot_product(
            jnp.broadcast_to(Ib, Ib.shape[:-2] + W.shape), W, circuit, n_pixels=n_active
        )
    assert model is not None, "bucket modes need a fitted BucketCurvefitModel"
    fn = predict_hard if mode == "bucket_hard" else predict_sigmoid
    return fn(model, jnp.broadcast_to(Ib, Ib.shape[:-2] + W.shape), W)


def fpca_forward(
    image: jax.Array,
    kernel: jax.Array,
    spec: mapping.FPCASpec,
    *,
    circuit: CircuitParams | None = None,
    model: BucketCurvefitModel | None = None,
    adc: ADCConfig | None = None,
    enc: WeightEncoding | None = None,
    bn_offset_counts: jax.Array | float = 0.0,
    mode: Mode = "oracle",
    hard: bool = True,
    block_mask: np.ndarray | None = None,
    backend: Backend = "reference",
    interpret: bool | None = None,
) -> dict[str, jax.Array]:
    """Simulate the FPCA frontend for one image or a batch of images.

    ``image`` is ``(H, W, c_i)`` or ``(B, H, W, c_i)``; ``counts`` in the
    returned dict follows with ``(h_o, w_o, c_o)`` or ``(B, h_o, w_o, c_o)``
    (integer SS-ADC output).

    ``backend="reference"`` (default) is the dense jnp simulation and also
    returns the raw ``v_pos`` / ``v_neg`` bitline voltages for analysis.
    ``backend="pallas"`` / ``"basis"`` dispatch deployment-mode evaluation to
    the fused production kernel (:func:`repro.kernels.fpca_conv.ops.fpca_conv`):
    one flattened ``(B*h_o*w_o, N)`` patch matrix through a single kernel call
    with the SS-ADC epilogue fused in, so only ``counts`` is available.  The
    fused backends implement the calibrated bucket-sigmoid model with hard ADC
    rounding — they require ``mode="bucket_sigmoid"``, ``hard=True`` and a
    fitted ``model``; ``interpret`` is forwarded to Pallas (default: interpret
    off-TPU).

    ``block_mask`` (region skipping, §3.4.5) is applied post-hoc on the
    reference backend (every window still evaluated — the parity oracle) but
    *in-kernel* on the fused backends: kept windows are compacted before the
    call, so skipped windows never execute.
    """
    circuit = circuit or CircuitParams()
    adc = adc or ADCConfig()
    enc = enc or WeightEncoding()
    # resolve through the pluggable backend registry (repro.fpca.backends);
    # imported lazily — the registry package imports this module
    from repro.fpca.backends import get_backend

    be = get_backend(backend)
    if not be.fused and be.name != "reference":
        # a registered non-fused third-party backend has no entry point
        # here: falling through to the built-in dense path would silently
        # serve reference-sim outputs under the third party's name
        raise ValueError(
            f"backend {be.name!r} is not servable through fpca_forward; "
            f"use repro.fpca.compile(program, backend={be.name!r}).run(images)"
        )
    if be.fused:
        warnings.warn(
            "fpca_forward(backend=...) fused serving is a deprecation shim; "
            "use repro.fpca.compile(program, backend=...).run(images) — the "
            "explicit executable handle with a held cache and "
            "reprogram-without-recompile",
            DeprecationWarning,
            stacklevel=2,
        )
        if mode != "bucket_sigmoid" or not hard:
            raise ValueError(
                f"backend={backend!r} serves the calibrated bucket model with hard "
                "ADC rounding (mode='bucket_sigmoid', hard=True); use "
                "backend='reference' for the circuit oracle or training"
            )
        if model is None:
            raise ValueError("fused backends need a fitted BucketCurvefitModel")
        if be.conv is None:
            raise ValueError(
                f"backend {be.name!r} registers no one-shot conv entry point; "
                f"serve it through repro.fpca.compile(program, "
                f"backend={be.name!r}).run(images)"
            )

        images = image if image.ndim == 4 else image[None]
        c_o = kernel.shape[0]
        bn = jnp.broadcast_to(
            jnp.asarray(bn_offset_counts, jnp.float32).reshape(-1), (c_o,)
        )
        window_mask = None
        if block_mask is not None:
            # in-kernel region skipping: kept windows are compacted before the
            # fused call, so skipped windows never execute (the dense path
            # below stays the bit-exact oracle on kept windows)
            keep = mapping.active_window_mask(spec, block_mask)
            window_mask = np.broadcast_to(keep, (images.shape[0],) + keep.shape)
        counts = be.conv(
            images, kernel, model, spec=spec, adc=adc, enc=enc, bn_offset=bn,
            interpret=interpret, window_mask=window_mask,
        )
        if image.ndim == 3:
            counts = counts[0]
        return {"counts": counts}
    w_pos, w_neg = encode_weights(kernel, spec, enc, hard=hard)
    I = extract_windows(image, spec)                      # ([B,] h_o, w_o, N)
    n_active = spec.n_active_pixels
    v_pos = _analog_read(I, w_pos, mode, circuit, model, n_active)
    v_neg = _analog_read(I, w_neg, mode, circuit, model, n_active)
    counts = updown_readout(v_pos, v_neg, adc, bn_offset_counts, hard=hard)
    if block_mask is not None:
        keep = jnp.asarray(mapping.active_window_mask(spec, block_mask))
        counts = counts * keep[..., None]
    return {"counts": counts, "v_pos": v_pos, "v_neg": v_neg}


def calibrate_gain(
    spec: mapping.FPCASpec,
    *,
    circuit: CircuitParams | None = None,
    adc: ADCConfig | None = None,
    enc: WeightEncoding | None = None,
    n_samples: int = 2048,
    seed: int = 0,
) -> tuple[float, float]:
    """Fit ``ideal_conv ≈ gain * (v_pos - v_neg) + bias`` on random operating
    points — the digital-gain calibration a deployment would run once.

    Returns ``(gain, r2)``; ``acts = counts * lsb * gain`` then approximates
    the ideal (quantized-weight) convolution, and ``r2`` quantifies the
    paper's "fairly linear" claim (Fig. 7(c)/(f)).
    """
    circuit = circuit or CircuitParams()
    enc = enc or WeightEncoding()
    adc = adc or ADCConfig()
    rng = np.random.default_rng(seed)
    N = spec.n_active_pixels
    I = jnp.asarray(rng.uniform(0, 1, (n_samples, N)), jnp.float32)
    W = jnp.asarray(rng.uniform(0, 1, (n_samples, N)), jnp.float32)
    Wq = enc.quantize(W)
    v = analog_dot_product(I, Wq, circuit, n_pixels=N)
    ideal = jnp.sum(I * Wq, axis=-1) * enc.w_scale
    A = np.stack([np.asarray(v), np.ones(n_samples)], axis=1)
    (gain, bias), res, *_ = np.linalg.lstsq(A, np.asarray(ideal), rcond=None)
    ss_tot = float(((ideal - ideal.mean()) ** 2).sum())
    r2 = 1.0 - float(res[0]) / ss_tot if len(res) else 1.0
    del bias
    return float(gain), float(r2)
