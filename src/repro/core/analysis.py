"""Frontend energy / latency / bandwidth models (paper §5, Eqs. 2--8, Fig. 9).

The constants marked "paper" are taken directly from the paper (TSMC 28nm
simulation + cited IO work); timing constants the paper uses but does not
print (exposure, ADC ramp) are stated assumptions, documented in DESIGN.md §7.
What we reproduce is the *model* and the shape of the Fig. 9 trade-off curves,
with property tests on their qualitative claims (energy falls with stride,
c_o=32 erases the savings, BR grows with stride, binning buys frame rate).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import mapping

__all__ = [
    "FrontendConstants",
    "DigitalConstants",
    "frontend_energy",
    "frontend_latency",
    "head_flops",
    "head_report",
    "model_streaming_report",
    "streaming_frontend_report",
    "bandwidth_reduction",
    "conventional_cis",
]


@dataclasses.dataclass(frozen=True)
class FrontendConstants:
    e_px: float = 148e-12       # J / convolution read cycle        [paper §5.0.1]
    e_adc: float = 41.9e-12     # J / ADC read                       [paper, Kaiser'23]
    e_io: float = 12.34e-12     # J / bit, LVDS                      [paper, Teja'21]
    b_adc: int = 8              # ADC bit precision                  [paper]
    bw_io: float = 1e9          # bit/s per IO pad                   [paper §5.0.2]
    n_io_pads: int = 24         # IO pads                            [paper §5.0.2]
    raw_bits: int = 12          # raw Bayer bit depth                [paper Eq. 6]
    t_exp: float = 20e-6        # s, exposure per read cycle         [assumption]
    t_adc: float = 1.28e-6      # s, SS ramp: 2^8 counts @ 200 MHz   [assumption]

    @property
    def e_px_unit(self) -> float:
        """Per-pixel share of the 75-pixel convolution read energy, used for
        the conventional-CIS baseline (one pixel read at a time)."""
        return self.e_px / 75.0


# ---------------------------------------------------------------------------
# FPCA frontend (Eqs. 1--5)
# ---------------------------------------------------------------------------


def frontend_energy(
    spec: mapping.FPCASpec,
    const: FrontendConstants = FrontendConstants(),
    block_mask: np.ndarray | None = None,
) -> dict[str, float]:
    """Eq. 2 + Eq. 3: ``E = N_C (e_PX + e_ADC) + E_IO``."""
    n_c = mapping.n_cycles_with_skipping(spec, block_mask)
    h_o, w_o = mapping.output_dims(spec)
    if block_mask is not None:
        active = int(mapping.active_window_mask(spec, block_mask).sum())
    else:
        active = h_o * w_o
    e_io = active * spec.out_channels * const.b_adc * const.e_io
    e_total = n_c * (const.e_px + const.e_adc) + e_io
    return {
        "n_cycles": n_c,
        "e_io": e_io,
        "e_total": e_total,
        "active_windows": active,
    }


def frontend_latency(
    spec: mapping.FPCASpec,
    const: FrontendConstants = FrontendConstants(),
    block_mask: np.ndarray | None = None,
) -> dict[str, float]:
    """Eq. 4 + Eq. 5: per-cycle exposure + ramp + IO; frame rate = 1/T.

    With ``block_mask``, only the cycles that actually fire under region
    skipping (§3.4.5) are counted; per-cycle IO keeps the dense ``w_o``
    window estimate (RS/SW gating is row/phase-granular, the IO bus is not).
    """
    n_c = mapping.n_cycles_with_skipping(spec, block_mask)
    _, w_o = mapping.output_dims(spec)
    t_io = w_o * const.b_adc / (const.bw_io * const.n_io_pads)
    t_total = n_c * (const.t_exp + const.t_adc + t_io)
    # an all-skipped frame fires zero cycles (t_total == 0): the sensor is
    # idle — fps is undefined, not infinite.  None is the zero-work sentinel
    # everywhere (observe.fleet_report, strict-JSON artifacts reject Infinity)
    fps = 1.0 / t_total if t_total > 0 else None
    return {"n_cycles": n_c, "t_io": t_io, "t_total": t_total, "fps": fps}


def streaming_frontend_report(
    spec: mapping.FPCASpec,
    block_masks: list[np.ndarray | None],
    const: FrontendConstants = FrontendConstants(),
) -> dict[str, float]:
    """Aggregate executed-window accounting over a gated frame history.

    Unlike the single-frame models above, this reflects what a streaming
    deployment *actually executed*: each frame's delta-gate mask contributes
    its skipped-cycle energy/latency (Eqs. 2--5 with §3.4.5 gating), and the
    summary reports the effective frame rate and the savings versus a dense
    readout of the same stream.
    """
    if not block_masks:
        raise ValueError("empty mask history")
    dense_e = frontend_energy(spec, const)
    dense_t = frontend_latency(spec, const)
    h_o, w_o = mapping.output_dims(spec)
    e_total = t_total = 0.0
    cycles = windows = 0
    for mask in block_masks:
        e = frontend_energy(spec, const, block_mask=mask)
        t = frontend_latency(spec, const, block_mask=mask)
        e_total += e["e_total"]
        t_total += t["t_total"]
        cycles += e["n_cycles"]
        windows += e["active_windows"]
    n = len(block_masks)
    return {
        "frames": n,
        "executed_cycles": cycles,
        "executed_windows": windows,
        "kept_window_frac": windows / (n * h_o * w_o),
        "e_total": e_total,
        "t_total": t_total,
        # a history of all-skipped frames executes nothing (t_total == 0);
        # fps is undefined (None, the shared zero-work sentinel), not Infinity
        "fps_effective": n / t_total if t_total > 0 else None,
        "energy_vs_dense": e_total / (n * dense_e["e_total"]),
        "latency_vs_dense": t_total / (n * dense_t["t_total"]),
    }


# ---------------------------------------------------------------------------
# Digital CNN head (the backend a model program attaches to the frontend)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DigitalConstants:
    """Edge digital-backend cost model for the CNN head of a model program.

    Representative 28nm edge-DSP numbers (stated assumptions, same posture
    as the timing constants above): per-MAC energy and sustained MAC
    throughput of the digital classifier the FPCA frontend feeds, for both
    the full-precision serving datapath and the quantised int8 lowering
    (``FPCAModelProgram(precision="int8")``).  The int8 datapath of an edge
    MAC array is ~4x cheaper per op and ~4x higher throughput than the
    full-precision one on the same silicon (narrower multipliers, 4-wide
    SIMD lanes).
    """

    e_mac: float = 1.0e-12        # J / MAC, full-precision serving datapath
    macs_per_s: float = 4e9       # sustained MAC/s, full-precision
    e_mac_int8: float = 0.25e-12  # J / MAC, int8 datapath (4-wide SIMD)
    macs_per_s_int8: float = 16e9  # sustained int8 MAC/s


def head_flops(model) -> dict:
    """Per-inference digital-head cost of an
    :class:`repro.fpca.FPCAModelProgram` (one frame through the head).

    Returns per-layer ``(kind, macs, params)`` rows plus totals; pooling and
    activation stages count as element ops, not MACs.

    Zoo :class:`repro.models.heads.HeadGraph` heads are costed per node in
    topological order: Conv/Dense/Detect nodes carry MACs + params (a
    DetectSpec is a SAME conv emitting ``n_classes + 4`` channels),
    Add/Concat joins and activations count as element ops.
    """
    from repro.fpca.program import ConvSpec, DenseSpec, PoolSpec

    if getattr(model, "is_graph_head", False):
        return _graph_head_flops(model)
    shapes = model.head_shapes()
    per_layer: list[dict] = []
    macs = params = elem_ops = 0
    for i, layer in enumerate(model.head):
        cur, nxt = shapes[i], shapes[i + 1]
        if isinstance(layer, ConvSpec):
            k2c = layer.kernel * layer.kernel * cur[-1]
            l_macs = nxt[0] * nxt[1] * nxt[2] * k2c
            l_params = layer.out_channels * (k2c + 1)
            # fused activations cost the same element ops as standalone
            # ActivationSpec stages — two spellings of one head must report
            # one cost
            l_elem = int(np.prod(nxt)) if layer.activation else 0
        elif isinstance(layer, DenseSpec):
            d_in = 1
            for d in cur:
                d_in *= int(d)
            l_macs = d_in * layer.features
            l_params = layer.features * (d_in + 1)
            l_elem = layer.features if layer.activation else 0
        elif isinstance(layer, PoolSpec):
            l_macs = l_params = 0
            l_elem = nxt[0] * nxt[1] * nxt[2] * layer.size * layer.size
        else:                           # ActivationSpec
            l_macs = l_params = 0
            l_elem = int(np.prod(nxt))
        per_layer.append(
            {"layer": type(layer).__name__, "macs": l_macs,
             "params": l_params, "elem_ops": l_elem}
        )
        macs += l_macs
        params += l_params
        elem_ops += l_elem
    return {
        "per_layer": per_layer,
        "macs": macs,
        "flops": 2 * macs,
        "params": params,
        "elem_ops": elem_ops,
    }


def _graph_head_flops(model) -> dict:
    """Per-node cost of a :class:`repro.models.heads.HeadGraph` head."""
    from repro.fpca.program import ConvSpec, DenseSpec, PoolSpec
    from repro.models.heads import AddSpec, ConcatSpec, DetectSpec

    graph = model.head
    shapes = graph.shapes(model.frontend.out_shape)
    per_layer: list[dict] = []
    macs = params = elem_ops = 0
    for node in graph.toposort():
        op = node.op
        cur = shapes[node.inputs[0]]
        nxt = shapes[node.name]
        if isinstance(op, (ConvSpec, DetectSpec)):
            kernel = op.kernel
            k2c = kernel * kernel * cur[-1]
            l_macs = nxt[0] * nxt[1] * nxt[2] * k2c
            l_params = op.out_channels * (k2c + 1)
            act = getattr(op, "activation", None)
            l_elem = int(np.prod(nxt)) if act else 0
        elif isinstance(op, DenseSpec):
            d_in = 1
            for d in cur:
                d_in *= int(d)
            l_macs = d_in * op.features
            l_params = op.features * (d_in + 1)
            l_elem = op.features if op.activation else 0
        elif isinstance(op, PoolSpec):
            l_macs = l_params = 0
            l_elem = nxt[0] * nxt[1] * nxt[2] * op.size * op.size
        elif isinstance(op, (AddSpec, ConcatSpec)):
            l_macs = l_params = 0
            # one element op per joined input element (+ the activation)
            l_elem = sum(int(np.prod(shapes[r])) for r in node.inputs)
            if op.activation:
                l_elem += int(np.prod(nxt))
        else:                           # ActivationSpec
            l_macs = l_params = 0
            l_elem = int(np.prod(nxt))
        per_layer.append(
            {"layer": f"{node.name}:{type(op).__name__}", "macs": l_macs,
             "params": l_params, "elem_ops": l_elem}
        )
        macs += l_macs
        params += l_params
        elem_ops += l_elem
    return {
        "per_layer": per_layer,
        "macs": macs,
        "flops": 2 * macs,
        "params": params,
        "elem_ops": elem_ops,
    }


def head_report(model, digital: DigitalConstants = DigitalConstants()) -> dict:
    """Energy / latency of one frame through the digital head (Eq.-2-style
    accounting for the backend the frontend feeds).

    Reports both precisions side by side (``e_head_f32``/``e_head_int8``,
    same for ``t_``) plus the datapath ratios; the headline ``e_head`` /
    ``t_head`` follow the model program's own ``precision`` so downstream
    aggregates (:func:`model_streaming_report`) account the lowering that
    actually serves.
    """
    fl = head_flops(model)
    ops = fl["macs"] + fl["elem_ops"]
    e_f32, t_f32 = ops * digital.e_mac, ops / digital.macs_per_s
    e_int8, t_int8 = ops * digital.e_mac_int8, ops / digital.macs_per_s_int8
    precision = getattr(model, "precision", "f32")
    e_head, t_head = (e_int8, t_int8) if precision == "int8" else (e_f32, t_f32)
    return {
        **fl,
        "precision": precision,
        "e_head": e_head,
        "t_head": t_head,
        "e_head_f32": e_f32,
        "t_head_f32": t_f32,
        "e_head_int8": e_int8,
        "t_head_int8": t_int8,
        "int8_energy_ratio": e_int8 / e_f32,
        "int8_speedup": t_f32 / t_int8,
    }


def model_streaming_report(
    model,
    block_masks: list[np.ndarray | None],
    const: FrontendConstants = FrontendConstants(),
    digital: DigitalConstants = DigitalConstants(),
) -> dict:
    """Whole-model executed-cost accounting over a gated frame history:
    the frontend's executed-window stats (:func:`streaming_frontend_report`)
    with the digital head's FLOPs / energy / latency next to them.

    The skip-aware serving path runs the head on the *patched* effective
    activation map every tick (class logits per tick), so the head cost is
    dense per frame even when the frontend skips — which is exactly why the
    analog frontend carries the savings story.
    """
    rep = streaming_frontend_report(model.frontend.spec, block_masks, const)
    head = head_report(model, digital)
    n = rep["frames"]
    e_model = rep["e_total"] + n * head["e_head"]
    t_model = rep["t_total"] + n * head["t_head"]
    dense_e = frontend_energy(model.frontend.spec, const)["e_total"] + head["e_head"]
    dense_t = frontend_latency(model.frontend.spec, const)["t_total"] + head["t_head"]
    return {
        **rep,
        "head_macs_per_frame": head["macs"],
        "head_flops_per_frame": head["flops"],
        "head_params": head["params"],
        "e_head_total": n * head["e_head"],
        "t_head_total": n * head["t_head"],
        "e_model_total": e_model,
        "t_model_total": t_model,
        # undefined when zero work executed (None — the zero-work sentinel)
        "model_fps_effective": n / t_model if t_model > 0 else None,
        "model_energy_vs_dense": e_model / (n * dense_e),
        "model_latency_vs_dense": t_model / (n * dense_t),
    }


def bandwidth_reduction(spec: mapping.FPCASpec) -> float:
    """Eq. 6: ``BR = (I / O) * (4/3) * (12 / b_ADC)``."""
    h_o, w_o = mapping.output_dims(spec)
    i_elems = spec.image_h * spec.image_w * spec.in_channels
    o_elems = h_o * w_o * spec.out_channels
    return (i_elems / o_elems) * (4.0 / 3.0) * (12.0 / 8.0)


# ---------------------------------------------------------------------------
# Conventional RGB CIS baseline (the red dotted line of Fig. 9(a))
# ---------------------------------------------------------------------------


def conventional_cis(
    image_h: int, image_w: int, const: FrontendConstants = FrontendConstants()
) -> dict[str, float]:
    """Plain sensor readout: every pixel digitised once, raw Bayer shipped out.

    Rolling shutter with column-parallel ADCs: exposure pipelines with the
    row readout, so frame time ≈ rows x (ramp + row IO).
    """
    n_px = image_h * image_w
    e_total = n_px * (const.e_px_unit + const.e_adc) + n_px * const.raw_bits * const.e_io
    t_row_io = image_w * const.raw_bits / (const.bw_io * const.n_io_pads)
    t_total = image_h * (const.t_adc + t_row_io)
    return {"e_total": e_total, "t_total": t_total, "fps": 1.0 / t_total}
