"""Peripheral single-slope ADC model (paper §2, inherited from P²M).

The SS-ADC integrates the FPCA's two-cycle weight scheme into ReLU + BN:

* the counter is *initialised* with the folded BatchNorm offset (in counts);
* during the positive-kernel cycle (``CH_i``) it counts **up** while the ramp
  crosses the bitline voltage;
* during the negative-kernel cycle (``CH_i_bar``) it counts **down**;
* the final count is clamped to ``[0, 2^b - 1]`` — the lower clamp (via the
  CDS circuit) *is* the ReLU, the upper clamp is ADC saturation.

Everything here is bit-exact integer arithmetic in the forward pass, with a
straight-through estimator so the FPCA frontend can train through it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["ADCConfig", "quantize_voltage", "updown_readout", "ste_round"]


@dataclasses.dataclass(frozen=True)
class ADCConfig:
    bits: int = 8          # b_ADC (paper uses 8-bit activations)
    v_ref: float = 1.0     # full-scale ramp voltage

    @property
    def levels(self) -> int:
        return 2**self.bits

    @property
    def lsb(self) -> float:
        return self.v_ref / self.levels


def ste_round(x: jax.Array) -> jax.Array:
    """Round with a straight-through gradient (identity backward)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def quantize_voltage(v: jax.Array, cfg: ADCConfig, *, hard: bool = True) -> jax.Array:
    """Single-slope conversion of a bitline voltage to a ramp count.

    ``hard=True`` returns exact integer counts (deployment semantics);
    ``hard=False`` uses the STE so gradients flow (training semantics).
    """
    counts = v / cfg.lsb
    counts = jnp.round(counts) if hard else ste_round(counts)
    return jnp.clip(counts, 0, cfg.levels - 1)


def updown_readout(
    v_pos: jax.Array,
    v_neg: jax.Array,
    cfg: ADCConfig,
    bn_offset_counts: jax.Array | float = 0.0,
    *,
    hard: bool = True,
) -> jax.Array:
    """Two-cycle up/down SS-ADC readout: BN offset + ReLU + saturation.

    count = clip( offset + Q(v_pos) - Q(v_neg), 0, 2^b - 1 )

    The lower clamp implements ReLU (paper §2: "the final ADC count, post CDS
    operation ... results in a non-negative value").
    """
    up = quantize_voltage(v_pos, cfg, hard=hard)
    down = quantize_voltage(v_neg, cfg, hard=hard)
    count = bn_offset_counts + up - down
    return jnp.clip(count, 0, cfg.levels - 1)
