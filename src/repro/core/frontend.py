"""Trainable FPCA frontend layer — the paper's technique as a first-class
framework feature.

``FPCAFrontend`` is a drop-in first-conv layer: training runs through the
paper's differentiable sigmoid bucket-select model (with STEs through the NVM
level quantiser and the SS-ADC), deployment evaluates through the circuit
oracle.  The gap between the two *is* the hardware/algorithm co-design story:
``examples/train_fpca_cnn.py`` shows that a network trained through the bucket
model keeps its accuracy when evaluated on the oracle, while a naively trained
network (ideal conv) degrades.

The layer is configured by an :class:`repro.fpca.FPCAProgram` (the unified
program spec); the former ``FPCAFrontendConfig`` name is a deprecated alias
of it, kept importable from here.
"""

from __future__ import annotations

import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.curvefit import BucketCurvefitModel, fit_bucket_model
from repro.core.fpca_sim import calibrate_gain, fpca_forward
from repro.core.mapping import output_dims

__all__ = ["FPCAFrontendConfig", "FPCAFrontend"]


def __getattr__(name: str) -> Any:
    if name == "FPCAFrontendConfig":
        warnings.warn(
            "FPCAFrontendConfig is deprecated; use repro.fpca.FPCAProgram "
            "(same fields: spec, circuit, adc, enc)",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.fpca.program import FPCAProgram

        return FPCAProgram
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class FPCAFrontend:
    """Functional module: ``init(key) -> params``, ``apply(params, x) -> y``.

    ``config`` is an :class:`repro.fpca.FPCAProgram` (``spec`` / ``circuit``
    / ``adc`` / ``enc`` are the fields this layer reads).
    """

    def __init__(self, config: Any, model: BucketCurvefitModel | None = None):
        self.config = config
        # One fitted bucket model per circuit configuration (cached by caller
        # across layers/experiments; fitting is a one-off ~seconds cost).
        self.model = model or fit_bucket_model(
            config.circuit, n_pixels=config.spec.n_active_pixels
        )
        gain, r2 = calibrate_gain(
            config.spec, circuit=config.circuit, adc=config.adc, enc=config.enc
        )
        self.gain = gain
        self.calibration_r2 = r2

    @property
    def out_shape(self) -> tuple[int, int, int]:
        h_o, w_o = output_dims(self.config.spec)
        return (h_o, w_o, self.config.spec.out_channels)

    def init(self, key: jax.Array) -> dict[str, Any]:
        s = self.config.spec
        k = s.kernel
        fan_in = k * k * s.in_channels
        kernel = jax.random.normal(key, (s.out_channels, k, k, s.in_channels)) * (
            self.config.enc.w_scale / jnp.sqrt(fan_in)
        )
        return {
            "kernel": kernel.astype(jnp.float32),
            # BN offset folded into the SS-ADC counter init (paper §2), in counts.
            "bn_offset": jnp.zeros((s.out_channels,), jnp.float32),
        }

    def apply(
        self,
        params: dict[str, Any],
        images: jax.Array,
        *,
        train: bool = True,
        backend: str = "reference",
    ) -> jax.Array:
        """images ``(B, H, W, c_i)`` in [0, 1] -> activations ``(B, h_o, w_o, c_o)``.

        ``train=True``: differentiable path (sigmoid bucket model + STEs);
        reference backend only.
        ``train=False``: deployment path.  ``backend="reference"`` evaluates
        the circuit oracle (ground truth); fused backends route through the
        (deprecated) ``fpca_forward`` shim — prefer
        ``repro.fpca.compile(program).run(images)`` for fused serving.
        """
        cfg = self.config
        if train and backend != "reference":
            raise ValueError(
                "training needs the differentiable reference backend "
                "(fused kernels round the ADC hard)"
            )
        mode = "bucket_sigmoid" if (train or backend != "reference") else "oracle"
        out = fpca_forward(
            images,
            params["kernel"],
            cfg.spec,
            circuit=cfg.circuit,
            model=self.model,
            adc=cfg.adc,
            enc=cfg.enc,
            bn_offset_counts=params["bn_offset"],
            mode=mode,
            hard=not train,
            backend=backend,
        )
        # counts -> approximate convolution units (digital gain calibration)
        return out["counts"] * (cfg.adc.lsb * self.gain)
