"""Physics-inspired circuit oracle for the FPCA pixel array.

This module plays the role of the paper's TSMC-28nm SPICE netlist: it is the
ground truth that every curvefit in :mod:`repro.core.curvefit` is fitted
against and validated on.  It is intentionally *not* a polynomial, so the
bucket-select curvefit has something real to approximate.

Model structure (per paper §3.1 / §4):

* each activated unit pixel ``j`` pulls up the shared bitline with a drive
  ``g(I_j, W_j)`` that depends strongly on its own photocurrent ``I_j``
  (normalised light intensity, [0, 1]) and its own NVM weight conductance
  ``W_j`` (normalised, [0, 1]);
* the drive is mildly non-linear in ``I*W`` (source-follower + NVM I-V
  curvature) and degraded by the metal-line series resistance between the
  weight die and the pixel die (0--5 mm, paper Fig. 7(c)/(f));
* the bitline voltage saturates (supply clamp) and *couples back* into every
  pixel's operating point: the higher the bitline, the weaker each pixel's
  marginal contribution.  This is the weak cumulative interaction the paper's
  two-step bucket-select model is designed to capture.

The coupled output is the fixed point of

    V = v_sat * tanh( (1 - lam * V / v_sat) * sum_j g(I_j, W_j) / (N * s0) )

solved with a few (differentiable) fixed-point iterations; ``lam`` is small so
the iteration is strongly contracting.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "CircuitParams",
    "pixel_drive",
    "analog_dot_product",
    "analog_dot_product_from_drive",
]


@dataclasses.dataclass(frozen=True)
class CircuitParams:
    """Device/circuit constants for the FPCA analog oracle.

    Defaults are chosen so that a 75-pixel (5x5x3 kernel) convolution sweeps
    the full [0, ~0.97] V output range, single-pixel transfer curves look like
    the paper's Fig. 7(a)/(b), and the ideal-vs-analog scatter (Fig. 7(c)/(f))
    is "fairly linear" with visible curvature at the top of the range.
    """

    v_sat: float = 1.0          # bitline supply clamp [V]
    s0: float = 0.37            # per-pixel drive normalisation
    drive_a: float = 0.15       # I^2 W curvature (photocurrent compression)
    drive_b: float = -0.10      # I W^2 curvature (NVM I-V bowing)
    drive_c: float = 0.25       # soft compression of the I*W product
    coupling: float = 0.15      # bitline -> pixel operating-point feedback
    kappa_r: float = 0.012      # metal-line degradation per mm per unit drive
    r_metal_mm: float = 0.0     # weight-die <-> pixel-die metal length [mm]
    fp_iters: int = 8           # fixed-point iterations (contracting; 8 >> enough)

    def replace(self, **kw: Any) -> "CircuitParams":
        return dataclasses.replace(self, **kw)


def pixel_drive(I: jax.Array, W: jax.Array, params: CircuitParams) -> jax.Array:
    """Per-pixel bitline drive ``g(I, W)`` (elementwise).

    Strongly a function of the pixel's own photocurrent and weight only; the
    bitline coupling is applied outside, in :func:`analog_dot_product`.
    """
    I = jnp.asarray(I, jnp.float32)
    W = jnp.asarray(W, jnp.float32)
    iw = I * W
    num = iw + params.drive_a * (I * iw) + params.drive_b * (W * iw)
    g = num / (1.0 + params.drive_c * iw)
    # Metal-line series resistance between the shared weight block (weight
    # die) and the unit pixel: larger drive -> larger IR drop -> compression.
    g = g / (1.0 + params.kappa_r * params.r_metal_mm * g)
    return g


def analog_dot_product_from_drive(
    g: jax.Array, n_pixels: int, params: CircuitParams
) -> jax.Array:
    """Bitline voltage given per-pixel drives ``g`` summed over the last axis.

    ``n_pixels`` is the number of *activated* pixels (the paper activates a
    fixed n*n*3 region regardless of logical kernel size, so this is a static
    schedule constant, not ``g.shape[-1]`` — padded zero-weight slots still
    count as activated pixels).
    """
    s = jnp.sum(g, axis=-1)
    denom = n_pixels * params.s0
    v = params.v_sat * jnp.tanh(s / denom)  # uncoupled initial guess
    for _ in range(params.fp_iters):
        eff = (1.0 - params.coupling * v / params.v_sat) * s
        v = params.v_sat * jnp.tanh(eff / denom)
    return v


def analog_dot_product(
    I: jax.Array, W: jax.Array, params: CircuitParams, n_pixels: int | None = None
) -> jax.Array:
    """Analog convolution output for one bitline read cycle.

    Args:
      I: photocurrents, shape ``(..., N)`` — normalised light intensities.
      W: NVM conductances for this cycle (positive *or* negative kernel half),
         shape broadcastable to ``I``.
      params: circuit constants.
      n_pixels: activated-pixel count; defaults to ``I.shape[-1]``.

    Returns:
      Bitline voltage, shape ``(...,)``, in ``[0, v_sat)``.
    """
    I = jnp.asarray(I, jnp.float32)
    W = jnp.broadcast_to(jnp.asarray(W, jnp.float32), I.shape)
    n = I.shape[-1] if n_pixels is None else n_pixels
    g = pixel_drive(I, W, params)
    return analog_dot_product_from_drive(g, n, params)
