"""Bucket-select curvefit model of the FPCA analog convolution (paper §4).

Two-step method, fitted against the circuit oracle in
:mod:`repro.core.device_models` (the SPICE stand-in):

* **Step 1** — a *generic* surface ``f_avg(I, W)`` is fitted to the oracle
  output when all ``N`` activated pixels share the same ``(I, W)``, swept over
  a 2-D grid.  For a heterogeneous window the step-1 estimate is
  ``V_est = f_avg(mean I, mean W)`` (the output is a strong function of the
  *cumulative* pixel state; see DESIGN.md §2 for why mean-field is the right
  reading of the paper).
* **Step 2** — ``V_est`` selects one of ``n_buckets`` range-specific surfaces
  ``f_buc_i``.  Bucket ``i`` is fitted by sweeping a small subset of
  ``n_sweep`` pixels while the remaining ``N - n_sweep`` are pinned at a
  centre operating point ``(I_C_i, W_C_i)`` chosen so the output sits at the
  bucket's centre voltage.  The final prediction applies the per-pixel bucket
  correction (paper's step-2 equation):

      V_pd = sum_j [f_buc_s(I_j, W_j) - v_c_s] / n_sweep + v_c_s

* The **differentiable single equation** replaces the bucket argmax with
  paired sigmoids ``sigma(k (x - lo_i)) + sigma(k (hi_i - x)) - 1`` (paper
  Fig. 6(b)), so the whole model backpropagates inside an ML framework.

Every surface is a bivariate polynomial; this is what makes the model
MXU-friendly: windowed sums of polynomials factor into dot products between
elementwise powers of the image patch and of the kernel (see
``repro/kernels/fpca_conv``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_models import CircuitParams, analog_dot_product

__all__ = [
    "PolySurface",
    "BucketCurvefitModel",
    "fit_poly_surface",
    "fit_bucket_model",
    "predict_hard",
    "predict_sigmoid",
]


def _exponent_pairs(degree: int) -> np.ndarray:
    """All (a, b) with a + b <= degree, deterministic order."""
    return np.array(
        [(a, b) for total in range(degree + 1) for a in range(total + 1) for b in [total - a]],
        dtype=np.int32,
    )


@dataclasses.dataclass(frozen=True)
class PolySurface:
    """Bivariate polynomial surface ``f(I, W) = sum_t c_t I^a_t W^b_t``."""

    coeffs: jax.Array  # (n_terms,) float32
    exps: np.ndarray   # (n_terms, 2) int — static, shared across buckets

    @property
    def degree(self) -> int:
        return int(self.exps.sum(axis=1).max())

    def __call__(self, I: jax.Array, W: jax.Array) -> jax.Array:
        basis = _design(jnp.asarray(I, jnp.float32), jnp.asarray(W, jnp.float32), self.exps)
        return basis @ self.coeffs


def _design(I: jax.Array, W: jax.Array, exps: np.ndarray) -> jax.Array:
    """Design matrix of monomials, shape ``I.shape + (n_terms,)``."""
    max_deg = int(exps.max())
    # powers[k] = x**k computed once, reused across terms.
    pow_i = [jnp.ones_like(I)]
    pow_w = [jnp.ones_like(W)]
    for _ in range(max_deg):
        pow_i.append(pow_i[-1] * I)
        pow_w.append(pow_w[-1] * W)
    cols = [pow_i[a] * pow_w[b] for a, b in exps]
    return jnp.stack(cols, axis=-1)


def fit_poly_surface(
    I: np.ndarray, W: np.ndarray, V: np.ndarray, degree: int
) -> PolySurface:
    """Least-squares fit of a bivariate polynomial to samples ``V(I, W)``."""
    exps = _exponent_pairs(degree)
    A = np.asarray(_design(jnp.asarray(I.ravel()), jnp.asarray(W.ravel()), exps))
    coeffs, *_ = np.linalg.lstsq(A, V.ravel(), rcond=None)
    return PolySurface(coeffs=jnp.asarray(coeffs, jnp.float32), exps=exps)


@dataclasses.dataclass(frozen=True)
class BucketCurvefitModel:
    """Fitted two-step bucket-select model for one circuit configuration."""

    f_avg: PolySurface
    bucket_coeffs: jax.Array      # (n_buckets, n_terms_buc)
    bucket_exps: np.ndarray       # (n_terms_buc, 2)
    centers: jax.Array            # (n_buckets, 2) — (I_C_i, W_C_i)
    v_centers: jax.Array          # (n_buckets,) — f_avg at centre = V at all-centre
    n_pixels: int                 # N (75 for a 5x5x3 kernel)
    n_sweep: int                  # subset size used for bucket fits (5)
    v_range: float                # bucket span upper edge (v_sat)
    sharpness: float = 100.0      # paper uses sigma(100 x)

    @property
    def n_buckets(self) -> int:
        return int(self.bucket_coeffs.shape[0])

    # -- (de)serialisation so fits can be cached in artifacts/ ---------------
    def to_dict(self) -> dict:
        return {
            "f_avg_coeffs": np.asarray(self.f_avg.coeffs),
            "f_avg_exps": self.f_avg.exps,
            "bucket_coeffs": np.asarray(self.bucket_coeffs),
            "bucket_exps": self.bucket_exps,
            "centers": np.asarray(self.centers),
            "v_centers": np.asarray(self.v_centers),
            "n_pixels": self.n_pixels,
            "n_sweep": self.n_sweep,
            "v_range": self.v_range,
            "sharpness": self.sharpness,
        }

    @staticmethod
    def from_dict(d: dict) -> "BucketCurvefitModel":
        return BucketCurvefitModel(
            f_avg=PolySurface(
                coeffs=jnp.asarray(d["f_avg_coeffs"], jnp.float32),
                exps=np.asarray(d["f_avg_exps"], np.int32),
            ),
            bucket_coeffs=jnp.asarray(d["bucket_coeffs"], jnp.float32),
            bucket_exps=np.asarray(d["bucket_exps"], np.int32),
            centers=jnp.asarray(d["centers"], jnp.float32),
            v_centers=jnp.asarray(d["v_centers"], jnp.float32),
            n_pixels=int(d["n_pixels"]),
            n_sweep=int(d["n_sweep"]),
            v_range=float(d["v_range"]),
            sharpness=float(d["sharpness"]),
        )


# ---------------------------------------------------------------------------
# Fitting (step 1 + step 2 simulation setups, paper §4)
# ---------------------------------------------------------------------------


def _all_shared_output(
    t_i: jax.Array, t_w: jax.Array, n_pixels: int, params: CircuitParams
) -> jax.Array:
    """Oracle output when all N pixels share (t_i, t_w); broadcasts grids."""
    I = jnp.broadcast_to(t_i[..., None], t_i.shape + (n_pixels,))
    W = jnp.broadcast_to(t_w[..., None], t_w.shape + (n_pixels,))
    return analog_dot_product(I, W, params, n_pixels=n_pixels)


def _find_center(
    target_v: float, n_pixels: int, params: CircuitParams
) -> tuple[float, float]:
    """Bisect t so that V(all pixels at (t, t)) hits ``target_v``.

    The all-shared transfer curve is monotonic in t, so plain bisection works;
    if the target exceeds the achievable output the centre saturates at t=1.
    """
    lo, hi = 0.0, 1.0
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        v = float(_all_shared_output(jnp.asarray(mid), jnp.asarray(mid), n_pixels, params))
        if v < target_v:
            lo = mid
        else:
            hi = mid
    t = 0.5 * (lo + hi)
    return t, t


def fit_bucket_model(
    params: CircuitParams | None = None,
    *,
    n_pixels: int = 75,
    n_buckets: int = 5,
    n_sweep: int = 5,
    degree_avg: int = 4,
    degree_buc: int = 3,
    grid: int = 41,
    i_range: tuple[float, float] = (0.0, 1.0),
    w_range: tuple[float, float] = (0.0, 1.0),
) -> BucketCurvefitModel:
    """Run the paper's two fitting setups against the circuit oracle.

    Defaults reproduce the paper's configuration: a 5x5x3 kernel (75 pixels),
    5 buckets over [0, 1] V, bucket fits sweeping a 5-pixel subset.
    """
    params = params or CircuitParams()
    ti = jnp.linspace(i_range[0], i_range[1], grid)
    tw = jnp.linspace(w_range[0], w_range[1], grid)
    gi, gw = jnp.meshgrid(ti, tw, indexing="ij")

    # ---- step 1: generic surface, all N pixels swept together --------------
    v_avg = _all_shared_output(gi, gw, n_pixels, params)
    f_avg = fit_poly_surface(np.asarray(gi), np.asarray(gw), np.asarray(v_avg), degree_avg)

    # ---- step 2: one tailored surface per bucket ----------------------------
    v_range = params.v_sat
    bucket_exps = _exponent_pairs(degree_buc)
    bucket_coeffs, centers, v_centers = [], [], []
    n_fixed = n_pixels - n_sweep
    for b in range(n_buckets):
        target = (b + 0.5) / n_buckets * v_range
        ic, wc = _find_center(target, n_pixels, params)
        # n_sweep pixels sweep the grid; the rest pin the bitline into bucket b.
        I = jnp.concatenate(
            [
                jnp.broadcast_to(gi[..., None], gi.shape + (n_sweep,)),
                jnp.full(gi.shape + (n_fixed,), ic),
            ],
            axis=-1,
        )
        W = jnp.concatenate(
            [
                jnp.broadcast_to(gw[..., None], gw.shape + (n_sweep,)),
                jnp.full(gw.shape + (n_fixed,), wc),
            ],
            axis=-1,
        )
        v_buc = analog_dot_product(I, W, params, n_pixels=n_pixels)
        surf = fit_poly_surface(np.asarray(gi), np.asarray(gw), np.asarray(v_buc), degree_buc)
        bucket_coeffs.append(np.asarray(surf.coeffs))
        centers.append((ic, wc))
        v_centers.append(
            float(_all_shared_output(jnp.asarray(ic), jnp.asarray(wc), n_pixels, params))
        )

    return BucketCurvefitModel(
        f_avg=f_avg,
        bucket_coeffs=jnp.asarray(np.stack(bucket_coeffs), jnp.float32),
        bucket_exps=bucket_exps,
        centers=jnp.asarray(np.asarray(centers), jnp.float32),
        v_centers=jnp.asarray(np.asarray(v_centers), jnp.float32),
        n_pixels=n_pixels,
        n_sweep=n_sweep,
        v_range=float(v_range),
    )


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------


def _estimate(model: BucketCurvefitModel, I: jax.Array, W: jax.Array) -> jax.Array:
    """Step-1 estimate ``V_est`` for heterogeneous windows (mean-field)."""
    return model.f_avg(jnp.mean(I, axis=-1), jnp.mean(W, axis=-1))


def _bucket_prediction(
    model: BucketCurvefitModel, I: jax.Array, W: jax.Array
) -> jax.Array:
    """Per-bucket full prediction B_i, shape ``(..., n_buckets)``.

    B_i = sum_j [f_buc_i(I_j, W_j) - v_c_i] / n_sweep + v_c_i
    """
    basis = _design(jnp.asarray(I, jnp.float32), jnp.asarray(W, jnp.float32), model.bucket_exps)
    # (..., N, n_terms) @ (n_terms, n_buckets) -> (..., N, n_buckets)
    per_pixel = basis @ model.bucket_coeffs.T
    summed = jnp.sum(per_pixel, axis=-2)  # (..., n_buckets)
    n = I.shape[-1]
    return (summed - n * model.v_centers) / model.n_sweep + model.v_centers


def predict_hard(model: BucketCurvefitModel, I: jax.Array, W: jax.Array) -> jax.Array:
    """Step-function bucket selection (paper's three-step procedure)."""
    v_est = _estimate(model, I, W)
    idx = jnp.clip(
        jnp.floor(v_est / model.v_range * model.n_buckets).astype(jnp.int32),
        0,
        model.n_buckets - 1,
    )
    preds = _bucket_prediction(model, I, W)
    return jnp.take_along_axis(preds, idx[..., None], axis=-1)[..., 0]


def predict_sigmoid(model: BucketCurvefitModel, I: jax.Array, W: jax.Array) -> jax.Array:
    """The paper's single differentiable equation (sigmoid bucket gates)."""
    x = _estimate(model, I, W) / model.v_range
    k = model.sharpness
    edges_lo = jnp.arange(model.n_buckets, dtype=jnp.float32) / model.n_buckets
    edges_hi = (jnp.arange(model.n_buckets, dtype=jnp.float32) + 1.0) / model.n_buckets
    gates = (
        jax.nn.sigmoid(k * (x[..., None] - edges_lo))
        + jax.nn.sigmoid(k * (edges_hi - x[..., None]))
        - 1.0
    )
    preds = _bucket_prediction(model, I, W)
    return jnp.sum(gates * preds, axis=-1)


def make_predict_fn(
    model: BucketCurvefitModel, differentiable: bool = True
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Convenience closure used by the frontend layer and kernels."""
    fn = predict_sigmoid if differentiable else predict_hard
    return lambda I, W: fn(model, I, W)
