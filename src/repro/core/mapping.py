"""FPCA mapping & scheduling model (paper §3.3--§3.4).

Reproduces, cycle by cycle, how the FPCA control fabric maps a first-layer
convolution onto the pixel array:

* ``CH_i`` / ``CH_i_bar`` — output-channel select; the two lines of a channel
  fire in consecutive cycles (positive-kernel phase, then negative), which is
  the factor 2 in Eq. 1;
* ``ColP_i`` — maps kernel column *i* onto a pixel column (horizontal stride);
* ``RS`` / ``SW`` — row/column unit-pixel enables (vertical stride, region
  skipping);
* the switch matrix routes the ``n`` SM lines so that adjacent pixel rows see
  the right kernel rows (vertical striding re-routes it).

The numerics of a cycle run batched on the MXU (all parallel windows of the
cycle at once); the *schedule* here is what the energy/latency analysis and
the Eq. 1 property tests consume.

Key hardware facts encoded (and tested):

* the physical kernel footprint is always the max ``n x n`` — smaller logical
  kernels are implemented by writing zero weights (paper §3.4.1), so the
  output grid (Eq. 8) is computed with ``n``, not the logical ``k``;
* windows computed in the same cycle share a ``ColP`` phase and are spaced
  ``lcm(S, n)`` pixel columns apart (disjoint column groups), giving
  ``lcm(S, n) / S`` horizontal phases per output row — the last factor of
  Eq. 1: ``N_C = 2 * h_o * c_o * lcm(S, n) / S``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator

import numpy as np

__all__ = ["FPCASpec", "Cycle", "n_cycles", "output_dims", "schedule", "active_window_mask"]


@dataclasses.dataclass(frozen=True)
class FPCASpec:
    """Static configuration of one FPCA first-layer convolution."""

    image_h: int
    image_w: int
    out_channels: int
    kernel: int                 # logical kernel size k (k <= max_kernel)
    stride: int
    max_kernel: int = 5         # physical n (weight-die provisioning)
    in_channels: int = 3        # RGB planes, processed concurrently (§3.2)
    padding: int = 0
    binning: int = 1            # pixel binning factor (Fig. 9(b))
    skip_block: int = 8         # region-skipping block granularity (§3.4.5)

    def __post_init__(self) -> None:
        if self.kernel > self.max_kernel:
            raise ValueError(f"kernel {self.kernel} exceeds max_kernel {self.max_kernel}")
        if not (1 <= self.stride <= self.max_kernel):
            raise ValueError("stride must be in [1, max_kernel] (paper §3.4.3)")

    # -- derived geometry -----------------------------------------------------
    @property
    def eff_h(self) -> int:
        return self.image_h // self.binning

    @property
    def eff_w(self) -> int:
        return self.image_w // self.binning

    @property
    def n_active_pixels(self) -> int:
        """Pixels activated per window read — always the full n*n*in_ch region."""
        return self.max_kernel * self.max_kernel * self.in_channels

    @property
    def horizontal_phases(self) -> int:
        """lcm(S, n) / S — ColP phases needed to cover one output row."""
        return math.lcm(self.stride, self.max_kernel) // self.stride

    @property
    def weights_per_column(self) -> int:
        """NVM devices per pixel column in the weight die (§3.2)."""
        return 2 * self.max_kernel**2 * self.in_channels * self.out_channels


def output_dims(spec: FPCASpec) -> tuple[int, int]:
    """Eq. 8 with the *physical* kernel n (zero-padded logical kernels)."""
    n, s, p = spec.max_kernel, spec.stride, spec.padding
    h_o = (spec.eff_h - n + 2 * p) // s + 1
    w_o = (spec.eff_w - n + 2 * p) // s + 1
    if h_o <= 0 or w_o <= 0:
        raise ValueError("image smaller than physical kernel footprint")
    return h_o, w_o


def n_cycles(spec: FPCASpec) -> int:
    """Eq. 1: ``N_C = 2 * h_o * c_o * lcm(S, n) / S``."""
    h_o, _ = output_dims(spec)
    return 2 * h_o * spec.out_channels * spec.horizontal_phases


@dataclasses.dataclass(frozen=True)
class Cycle:
    """One read cycle of the rolling-shutter convolution schedule."""

    sign: int                   # +1: CH_i phase, -1: CH_i_bar phase
    channel: int                # output channel (CH line index)
    out_row: int                # output row r (RS group)
    phase: int                  # ColP phase p in [0, lcm(S,n)/S)
    window_cols: np.ndarray     # output-column indices computed in parallel

    stride: int = 1
    max_kernel: int = 5

    @property
    def colp_line(self) -> int:
        """ColP line pulled up in this cycle: which kernel column is mapped
        onto the first pixel column of each window group (§3.4.3 — e.g. for
        s=1, ColP1 activation is followed by ColP2 as the kernel slides)."""
        return (self.phase * self.stride) % self.max_kernel


def schedule(spec: FPCASpec) -> Iterator[Cycle]:
    """Yield the full cycle schedule; ``len(list(...)) == n_cycles(spec)``.

    Parallel windows of a cycle: output columns ``w`` whose horizontal start
    ``x = w * S`` satisfies ``x ≡ p*S (mod lcm(S, n))`` — their ``n``-wide
    column groups are disjoint, so they can share the cycle (§3.4.3).
    """
    h_o, w_o = output_dims(spec)
    n, s = spec.max_kernel, spec.stride
    period = math.lcm(s, n)
    phases = spec.horizontal_phases
    all_cols = np.arange(w_o)
    for channel in range(spec.out_channels):
        for out_row in range(h_o):
            for phase in range(phases):
                cols = all_cols[(all_cols * s) % period == phase * s]
                for sign in (+1, -1):
                    yield Cycle(
                        sign=sign,
                        channel=channel,
                        out_row=out_row,
                        phase=phase,
                        window_cols=cols,
                        stride=s,
                        max_kernel=n,
                    )


def active_window_mask(spec: FPCASpec, block_mask: np.ndarray | None) -> np.ndarray:
    """Region skipping (§3.4.5): which output windows actually execute.

    ``block_mask`` is the per-block keep/skip grid stored in the periphery
    SRAMs, shape ``(ceil(H/B), ceil(W/B))`` booleans (True = keep).  A window
    executes iff *any* of its pixels lies in a kept block (RS/SW lines for
    fully-skipped regions are never raised).

    Returns a boolean ``(h_o, w_o)`` mask.
    """
    h_o, w_o = output_dims(spec)
    if block_mask is None:
        return np.ones((h_o, w_o), dtype=bool)
    b = spec.skip_block
    exp_h, exp_w = math.ceil(spec.eff_h / b), math.ceil(spec.eff_w / b)
    if block_mask.shape != (exp_h, exp_w):
        raise ValueError(f"block_mask shape {block_mask.shape} != {(exp_h, exp_w)}")
    pixel_keep = np.kron(block_mask, np.ones((b, b), dtype=bool))[: spec.eff_h, : spec.eff_w]
    n, s = spec.max_kernel, spec.stride
    if (h_o - 1) * s + n <= spec.eff_h and (w_o - 1) * s + n <= spec.eff_w:
        # no padding: every window footprint is in-bounds — vectorised form
        # (the streaming hot path gates every frame of every stream here)
        windows = np.lib.stride_tricks.sliding_window_view(pixel_keep, (n, n))
        return windows[::s, ::s].any(axis=(2, 3))[:h_o, :w_o]
    mask = np.zeros((h_o, w_o), dtype=bool)
    for r in range(h_o):
        for c in range(w_o):
            mask[r, c] = pixel_keep[r * s : r * s + n, c * s : c * s + n].any()
    return mask


def n_cycles_with_skipping(spec: FPCASpec, block_mask: np.ndarray | None) -> int:
    """Executed cycles under region skipping: a cycle fires iff it contains
    at least one active window (the RS/SW gating is row/phase-granular)."""
    if block_mask is None:
        return n_cycles(spec)
    mask = active_window_mask(spec, block_mask)
    h_o, w_o = mask.shape
    n, s = spec.max_kernel, spec.stride
    period = math.lcm(s, n)
    executed_row_phases = 0
    all_cols = np.arange(w_o)
    for r in range(h_o):
        for phase in range(spec.horizontal_phases):
            cols = all_cols[(all_cols * s) % period == phase * s]
            if mask[r, cols].any():
                executed_row_phases += 1
    return 2 * spec.out_channels * executed_row_phases
