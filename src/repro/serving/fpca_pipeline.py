"""Batched multi-spec FPCA frontend serving pipeline.

The paper's headline claim is *field-programmability*: one pixel array serves
many (kernel, stride, channel, binning) configurations.  This module is the
serving-side counterpart — a reconfiguration scheduler that accepts a
heterogeneous stream of frontend requests, buckets them by their
compiled-kernel signature, and drives each bucket through one fused batched
call of the production kernel (:func:`repro.kernels.fpca_conv.ops.fpca_conv`).

Flow per :meth:`FPCAPipeline.submit`:

1. every request names a registered *configuration* (an :class:`FPCASpec`
   plus programmed NVM weights — what a physical FPCA would hold in its
   weight die) and carries one frame;
2. requests are grouped by configuration; each group's frames are stacked
   into one ``(B, H, W, c_i)`` batch, padded up to a power-of-two bucket (and
   to the mesh's data-axis extent) so recompiles stay bounded;
3. each group runs through a jitted executable fetched from a **bounded LRU
   cache** keyed by the configuration's compile signature
   (:func:`spec_signature`) — configurations sharing (spec, c_o, adc, enc)
   share one executable because weights enter traced, mirroring how a
   deployment reprograms NVM planes without recompiling the readout;
4. results are un-padded and scattered back to the original request order.

Region skipping is **in-kernel**: request ``block_mask``\\ s become per-window
keep masks that compact the window list before the fused call (static
power-of-two row buckets, so recompiles stay bounded), and batch-padding
frames are masked out the same way — skipped windows cost no compute, not
just zeroed results.  :meth:`FPCAPipeline.run_config_batch` exposes this as
the low-level non-blocking entry point the streaming server
(:mod:`repro.serving.streaming`) dispatches through.

With ``cross_config_batching=True``, request groups whose configurations
share a compile signature are additionally merged into ONE executable call
by stacking their NVM weight planes along the channel axis (each request's
counts are sliced from its configuration's channel range) — one dispatch and
one big MXU launch instead of several small ones, at the cost of evaluating
the merged channel set for every frame in the merged batch.

Backend selection mirrors :func:`repro.core.fpca_sim.fpca_forward`:
``"pallas"`` on TPU (interpret-mode elsewhere — validation only), ``"basis"``
for the XLA lowering of the same math (the fast path on CPU hosts), and data
parallelism over a host/production mesh via :mod:`repro.launch.mesh` helpers.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adc import ADCConfig
from repro.core.curvefit import BucketCurvefitModel, fit_bucket_model
from repro.core.fpca_sim import WeightEncoding
from repro.core.mapping import FPCASpec, active_window_mask, output_dims
from repro.kernels.fpca_conv.ops import StickyBucket, make_fpca_conv_executable
from repro.launch.mesh import data_axes

__all__ = [
    "FrontendRequest",
    "FrontendConfig",
    "PipelineStats",
    "FPCAPipeline",
    "spec_signature",
]


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """One programmed FPCA configuration (spec + NVM weight planes)."""

    name: str
    spec: FPCASpec
    kernel: jax.Array               # (c_o, k, k, c_i)
    bn_offset: jax.Array            # (c_o,) counts

    @property
    def out_shape(self) -> tuple[int, int, int]:
        h_o, w_o = output_dims(self.spec)
        return (h_o, w_o, self.spec.out_channels)


@dataclasses.dataclass(frozen=True)
class FrontendRequest:
    """One frame for one registered configuration."""

    config: str                     # registered FrontendConfig name
    image: Any                      # (H, W, c_i) float in [0, 1]
    block_mask: np.ndarray | None = None   # region skipping (§3.4.5)


def spec_signature(
    spec: FPCASpec, out_channels: int, adc: ADCConfig, enc: WeightEncoding
) -> tuple:
    """Hashable compiled-kernel signature.

    Everything that is *static* to the jitted executable: the spec pins patch
    geometry, ``out_channels`` the weight-plane width, adc/enc the epilogue
    constants.  Weights and BN offsets enter traced, so reprogramming the
    NVM planes does NOT change the signature (no recompile — the point of
    field-programmability).
    """
    return (spec, out_channels, adc, enc)


@dataclasses.dataclass
class PipelineStats:
    requests: int = 0
    batches: int = 0                # fused kernel invocations
    cache_hits: int = 0
    cache_misses: int = 0
    evictions: int = 0
    merged_groups: int = 0          # cross-config channel-stacked batches
    fanout_batches: int = 0         # multi-config stream fan-out calls
    windows_total: int = 0          # windows submitted (incl. batch padding)
    windows_executed: int = 0       # windows that actually reached the kernel
    launches_skipped: int = 0       # all-skipped batches short-circuited
    bucket_switches: int = 0        # served bucket-size transitions
    bucket_shrinks_deferred: int = 0  # flap events sticky hysteresis absorbed


class _ExecutableCache:
    """Bounded LRU of jitted executables keyed by compile signature."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._entries: collections.OrderedDict[tuple, Callable] = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def get(self, key: tuple, build: Callable[[], Callable], stats: PipelineStats) -> Callable:
        if key in self._entries:
            self._entries.move_to_end(key)
            stats.cache_hits += 1
            return self._entries[key]
        stats.cache_misses += 1
        fn = build()
        self._entries[key] = fn
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            stats.evictions += 1
        return fn


def _round_up_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class FPCAPipeline:
    """Spec-bucketed reconfiguration scheduler over the fused FPCA kernel.

    Args:
      model: fitted :class:`BucketCurvefitModel` (or dict keyed by
        ``n_active_pixels``); missing entries are fitted on demand (a one-off
        ~seconds cost per pixel count, as a deployment would calibrate once).
      backend: ``"pallas"`` or ``"basis"`` (see module docstring); ``None``
        (default) auto-selects by platform — Pallas on TPU, the XLA basis
        form elsewhere (interpret-mode Pallas is validation-only, far too
        slow to serve).
      mesh: optional ``jax.sharding.Mesh`` — batches are sharded over its
        data axes (:func:`repro.launch.mesh.data_axes`) for data-parallel
        serving; batch padding also rounds up to the data-axis extent.
      cache_capacity: bound on simultaneously-held jitted executables.
      cross_config_batching: merge request groups whose configurations share
        a compile signature into one channel-stacked executable call (see
        module docstring).  Off by default: the per-config path preserves the
        exact reprogram-without-recompile executable reuse the base tests pin.
      bucket_patience: sticky-bucket hysteresis for the region-skip row
        buckets (:class:`repro.kernels.fpca_conv.ops.StickyBucket`).  Each
        (compile signature, window count) keeps its own sticky state; a
        bucket grows immediately but only shrinks after ``bucket_patience``
        consecutive under-full batches, cutting executable-cache switches on
        busy streams.  The default ``1`` is the stateless behaviour
        (shrink immediately — exactly the pre-hysteresis pipeline).
        Trade-off: a deferred shrink serves an up-to-2x-oversized row bucket
        for up to ``bucket_patience`` ticks, so hysteresis pays off where a
        switch is expensive (a recompile on a real-TPU serving path) and can
        *cost* throughput where switches are cheap (warm-cache CPU hosts —
        see the flap-vs-sticky numbers in ``BENCH_stream.json``).
    """

    def __init__(
        self,
        model: BucketCurvefitModel | dict[int, BucketCurvefitModel] | None = None,
        *,
        adc: ADCConfig | None = None,
        enc: WeightEncoding | None = None,
        backend: str | None = None,
        interpret: bool | None = None,
        cache_capacity: int = 8,
        mesh: jax.sharding.Mesh | None = None,
        cross_config_batching: bool = False,
        bucket_patience: int = 1,
    ):
        if backend is None:
            backend = "pallas" if jax.default_backend() == "tpu" else "basis"
        if backend not in ("pallas", "basis"):
            raise ValueError(f"unknown backend {backend!r}")
        self.adc = adc or ADCConfig()
        self.enc = enc or WeightEncoding()
        self.backend = backend
        self.interpret = interpret
        self.mesh = mesh
        self.cross_config_batching = cross_config_batching
        if bucket_patience < 1:
            raise ValueError("bucket_patience must be >= 1")
        self.bucket_patience = bucket_patience
        self._sticky: dict[tuple, StickyBucket] = {}
        self._models: dict[int, BucketCurvefitModel] = {}
        if isinstance(model, BucketCurvefitModel):
            self._models[model.n_pixels] = model
        elif isinstance(model, dict):
            self._models.update(model)
        self._configs: dict[str, FrontendConfig] = {}
        # channel-stacked (kernel, bn) planes per fan-out tuple: configs are
        # immutable once registered, so the concat is paid once, not per tick
        self._stacked: dict[tuple[str, ...], tuple[jax.Array, jax.Array]] = {}
        self._cache = _ExecutableCache(cache_capacity)
        self.stats = PipelineStats()

    # -- configuration registry ----------------------------------------------
    def register(
        self,
        name: str,
        spec: FPCASpec,
        kernel: jax.Array,
        bn_offset: jax.Array | None = None,
    ) -> FrontendConfig:
        """Program one FPCA configuration (idempotent per unique name)."""
        if name in self._configs:
            raise ValueError(f"config {name!r} already registered")
        c_o = int(kernel.shape[0])
        if bn_offset is None:
            bn_offset = jnp.zeros((c_o,), jnp.float32)
        cfg = FrontendConfig(
            name=name,
            spec=spec,
            kernel=jnp.asarray(kernel, jnp.float32),
            bn_offset=jnp.asarray(bn_offset, jnp.float32),
        )
        self._configs[name] = cfg
        return cfg

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def _model_for(self, n_pixels: int) -> BucketCurvefitModel:
        if n_pixels not in self._models:
            self._models[n_pixels] = fit_bucket_model(n_pixels=n_pixels)
        return self._models[n_pixels]

    # -- scheduling ----------------------------------------------------------
    def group_requests(
        self, requests: Sequence[FrontendRequest]
    ) -> dict[str, list[int]]:
        """Request indices bucketed by configuration (insertion-ordered)."""
        groups: dict[str, list[int]] = {}
        for i, req in enumerate(requests):
            if req.config not in self._configs:
                raise KeyError(f"unknown config {req.config!r}")
            groups.setdefault(req.config, []).append(i)
        return groups

    def _padded_batch(self, b: int) -> int:
        padded = _round_up_pow2(b)
        if self.mesh is not None:
            n_data = int(np.prod([self.mesh.shape[a] for a in data_axes(self.mesh)]))
            padded = -(-padded // n_data) * n_data
        return padded

    def _executable(
        self, spec: FPCASpec, c_o: int, m_bucket: int | None = None
    ) -> Callable:
        sig = spec_signature(spec, c_o, self.adc, self.enc) + (m_bucket,)

        def build() -> Callable:
            # a FRESH jit per signature: the compiled programs are owned by
            # this closure, so LRU eviction genuinely frees the executable
            # (the shared fpca_conv entry point would keep them alive in the
            # module-level jit cache).
            return make_fpca_conv_executable(
                self._model_for(spec.n_active_pixels),
                spec=spec, adc=self.adc, enc=self.enc,
                impl=self.backend, interpret=self.interpret, m_bucket=m_bucket,
            )

        return self._cache.get(sig, build, self.stats)

    def _shard_batch(self, images: jax.Array) -> jax.Array:
        if self.mesh is None:
            return images
        P = jax.sharding.PartitionSpec
        sharding = jax.sharding.NamedSharding(
            self.mesh, P(data_axes(self.mesh), *([None] * (images.ndim - 1)))
        )
        return jax.device_put(images, sharding)

    def _run_batch(
        self,
        spec: FPCASpec,
        kernel: jax.Array,
        bn_offset: jax.Array,
        images: jax.Array,
        window_keep: np.ndarray | None = None,
    ) -> jax.Array:
        """One fused executable call; the core dispatch everything routes to.

        ``images`` is a ``(b, H, W, c_i)`` batch of ONE spec; ``window_keep``
        an optional per-window ``(b, h_o, w_o)`` boolean keep grid.  The batch
        is padded to its pow-2 bucket (mesh-aligned), padding frames are
        masked out *in-kernel* whenever a keep grid is present, and the call
        is dispatched asynchronously — the returned array is unrealised, so
        callers can overlap host prep with device compute and block later.
        """
        b = images.shape[0]
        h_o, w_o = output_dims(spec)
        if window_keep is not None and window_keep.shape != (b, h_o, w_o):
            raise ValueError(
                f"window_keep shape {window_keep.shape} != {(b, h_o, w_o)}"
            )
        padded = self._padded_batch(b)
        if padded > b:
            images = jnp.pad(images, ((0, padded - b), (0, 0), (0, 0), (0, 0)))
            if window_keep is not None:
                window_keep = np.concatenate(
                    [window_keep, np.zeros((padded - b, h_o, w_o), bool)]
                )
        c_o = int(kernel.shape[0])
        m_total = padded * h_o * w_o
        self.stats.windows_total += m_total
        if window_keep is None:
            images = self._shard_batch(images)
            self.stats.batches += 1
            run = self._executable(spec, c_o)
            self.stats.windows_executed += m_total
            return run(images, kernel, bn_offset)[:b]
        n_keep = int(np.count_nonzero(window_keep))
        if n_keep == 0:
            # all-skipped tick: the result is exact zeros by contract, so no
            # kernel launches at all (0 executed windows in the stats); the
            # sticky bucket still counts the tick as under-full so a stale
            # large bucket shrinks on the first active tick after the lull
            self.stats.launches_skipped += 1
            sticky = self._sticky.get(
                spec_signature(spec, c_o, self.adc, self.enc) + (m_total,)
            )
            if sticky is not None:
                sticky.observe_idle()
            return jnp.zeros((b, h_o, w_o, c_o), jnp.float32)
        images = self._shard_batch(images)
        self.stats.batches += 1
        m_bucket = self._bucket_for(spec, c_o, n_keep, m_total)
        run = self._executable(spec, c_o, m_bucket=m_bucket)
        self.stats.windows_executed += m_bucket
        return run(images, kernel, bn_offset, jnp.asarray(window_keep))[:b]

    def reset_bucket_state(self) -> None:
        """Forget all sticky row-bucket state (counters in ``stats`` remain).

        Benchmarks use this to make repeated serves of one scene evolve their
        bucket sequence identically (so a timed pass replays only executables
        the warm-up pass already compiled)."""
        self._sticky.clear()

    def _bucket_for(self, spec: FPCASpec, c_o: int, n_keep: int, m_total: int) -> int:
        """Sticky row bucket for one (signature, window-count) batch shape.

        With ``bucket_patience=1`` this is exactly
        :func:`repro.kernels.fpca_conv.ops.window_bucket`, but bucket
        transitions are still counted — ``stats.bucket_switches`` is the
        flap count a hysteresis-free pipeline pays.
        """
        key = spec_signature(spec, c_o, self.adc, self.enc) + (m_total,)
        sticky = self._sticky.get(key)
        if sticky is None:
            sticky = self._sticky[key] = StickyBucket(self.bucket_patience)
        before = (sticky.switches, sticky.shrinks_deferred)
        m_bucket = sticky.bucket(n_keep, m_total)
        self.stats.bucket_switches += sticky.switches - before[0]
        self.stats.bucket_shrinks_deferred += sticky.shrinks_deferred - before[1]
        return m_bucket

    def run_config_batch(
        self,
        name: str | Sequence[str],
        images: Any,
        window_keep: np.ndarray | None = None,
    ) -> jax.Array:
        """Non-blocking fused call for a frame batch of registered config(s).

        With a single config name, returns ``(b, h_o, w_o, c_o)`` SS-ADC
        counts, dispatched but not blocked on — the streaming server's
        double-buffered loop lives on this method.  ``window_keep`` rows
        belonging to skipped windows come back as exact zeros without having
        been computed.

        With a *sequence* of config names (multi-config fan-out: one camera
        feeding several programmed configurations), every named config must
        share the first one's :class:`FPCASpec`; their NVM weight planes are
        stacked along the channel axis and the whole fan-out runs as ONE
        fused call — the cross-config channel stacking of
        :meth:`_submit_merged`, reused per streaming tick.  Returns
        ``(b, h_o, w_o, sum(c_o))``; slice per-config channel ranges with
        :meth:`config_channel_slices`.
        """
        names = [name] if isinstance(name, str) else list(name)
        if not names:
            raise ValueError("need at least one config name")
        for n in names:
            if n not in self._configs:
                raise KeyError(f"unknown config {n!r}")
        cfgs = [self._configs[n] for n in names]
        spec = cfgs[0].spec
        for cfg in cfgs[1:]:
            if cfg.spec != spec:
                raise ValueError(
                    f"multi-config fan-out requires a shared spec: config "
                    f"{cfg.name!r} differs from {cfgs[0].name!r}"
                )
        images = jnp.asarray(images, jnp.float32)
        want = (spec.image_h, spec.image_w, spec.in_channels)
        if images.ndim != 4 or images.shape[1:] != want:
            raise ValueError(
                f"expected (b, {want[0]}, {want[1]}, {want[2]}) batch for "
                f"config {names[0]!r}, got {images.shape}"
            )
        if len(cfgs) == 1:
            cfg = cfgs[0]
            return self._run_batch(
                spec, cfg.kernel, cfg.bn_offset, images, window_keep
            )
        stacked = self._stacked.get(tuple(names))
        if stacked is None:
            stacked = self._stacked[tuple(names)] = (
                jnp.concatenate([c.kernel for c in cfgs], axis=0),
                jnp.concatenate([c.bn_offset for c in cfgs], axis=0),
            )
        kernel, bn = stacked
        batches_before = self.stats.batches
        counts = self._run_batch(spec, kernel, bn, images, window_keep)
        # a zero-kept tick short-circuits inside _run_batch: only count the
        # fan-outs that actually launched a stacked call
        self.stats.fanout_batches += self.stats.batches - batches_before
        return counts

    def config_channel_slices(
        self, names: Sequence[str]
    ) -> list[tuple[str, int, int]]:
        """Per-config ``(name, lo, hi)`` channel ranges of a stacked fan-out
        call (the channel order :meth:`run_config_batch` concatenates in)."""
        slices: list[tuple[str, int, int]] = []
        lo = 0
        for n in names:
            c_o = int(self._configs[n].kernel.shape[0])
            slices.append((n, lo, lo + c_o))
            lo += c_o
        return slices

    def _group_window_keep(
        self, cfg: FrontendConfig, reqs: list[FrontendRequest]
    ) -> np.ndarray | None:
        """Stacked per-window keep grid for a request group (None = dense)."""
        if all(r.block_mask is None for r in reqs):
            return None
        h_o, w_o = output_dims(cfg.spec)
        return np.stack(
            [
                active_window_mask(cfg.spec, r.block_mask)
                if r.block_mask is not None
                else np.ones((h_o, w_o), bool)
                for r in reqs
            ]
        )

    def _check_geometry(
        self, name: str, requests: Sequence[FrontendRequest], idxs: list[int]
    ) -> None:
        cfg = self._configs[name]
        want_shape = (cfg.spec.image_h, cfg.spec.image_w, cfg.spec.in_channels)
        for i in idxs:
            got = np.shape(requests[i].image)
            if got != want_shape:
                raise ValueError(
                    f"request {i}: frame shape {got} does not match config "
                    f"{name!r} sensor geometry {want_shape}"
                )

    def submit(self, requests: Sequence[FrontendRequest]) -> list[jax.Array]:
        """Serve a heterogeneous request mix; results in request order.

        Returns one SS-ADC count map ``(h_o, w_o, c_o)`` per request.
        """
        results: list[jax.Array | None] = [None] * len(requests)
        groups = self.group_requests(requests)
        self.stats.requests += len(requests)
        merged: dict[tuple, list[str]] = {}
        for name in groups:
            cfg = self._configs[name]
            sig = spec_signature(
                cfg.spec, int(cfg.kernel.shape[0]), self.adc, self.enc
            )
            key = sig if self.cross_config_batching else (name,)
            merged.setdefault(key, []).append(name)
        for names in merged.values():
            if len(names) == 1:
                self._submit_group(names[0], groups[names[0]], requests, results)
            else:
                self._submit_merged(names, groups, requests, results)
        return results  # type: ignore[return-value]

    def _submit_group(
        self,
        name: str,
        idxs: list[int],
        requests: Sequence[FrontendRequest],
        results: list,
    ) -> None:
        cfg = self._configs[name]
        self._check_geometry(name, requests, idxs)
        images = jnp.stack(
            [jnp.asarray(requests[i].image, jnp.float32) for i in idxs]
        )
        window_keep = self._group_window_keep(cfg, [requests[i] for i in idxs])
        counts = self._run_batch(
            cfg.spec, cfg.kernel, cfg.bn_offset, images, window_keep
        )
        for j, i in enumerate(idxs):
            results[i] = counts[j]

    def _submit_merged(
        self,
        names: list[str],
        groups: dict[str, list[int]],
        requests: Sequence[FrontendRequest],
        results: list,
    ) -> None:
        """Cross-config batching: configs sharing a compile signature run as
        ONE call with their NVM weight planes stacked along the channel axis;
        each request's counts are sliced from its config's channel range."""
        cfgs = [self._configs[n] for n in names]
        spec = cfgs[0].spec
        for name in names:
            self._check_geometry(name, requests, groups[name])
        kernel = jnp.concatenate([c.kernel for c in cfgs], axis=0)
        bn = jnp.concatenate([c.bn_offset for c in cfgs], axis=0)
        idxs = [i for n in names for i in groups[n]]
        images = jnp.stack(
            [jnp.asarray(requests[i].image, jnp.float32) for i in idxs]
        )
        window_keep = self._group_window_keep(
            cfgs[0], [requests[i] for i in idxs]
        )
        counts = self._run_batch(spec, kernel, bn, images, window_keep)
        self.stats.merged_groups += 1
        offsets = np.cumsum([0] + [int(c.kernel.shape[0]) for c in cfgs])
        row = 0
        for g, name in enumerate(names):
            lo, hi = int(offsets[g]), int(offsets[g + 1])
            for i in groups[name]:
                results[i] = counts[row, ..., lo:hi]
                row += 1
