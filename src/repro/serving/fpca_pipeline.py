"""Batched multi-spec FPCA frontend serving pipeline.

The paper's headline claim is *field-programmability*: one pixel array serves
many (kernel, stride, channel, binning) configurations.  This module is the
serving-side counterpart — a reconfiguration scheduler that accepts a
heterogeneous stream of frontend requests, buckets them by their compile
signature, and drives each bucket through one fused batched call.

Since the :mod:`repro.fpca` redesign the pipeline is a **thin orchestration
layer over explicit executables**: every distinct compile signature gets one
:class:`repro.fpca.CompiledFrontend` handle (all handles share ONE bounded
:class:`repro.fpca.ExecutableCache`, so the total number of live jitted
executables stays bounded across every registered configuration), and the
batch padding / mesh sharding / sticky region-skip buckets / zero-kept
short-circuit all live behind the handle.  What remains here is pure
scheduling:

1. every request names a registered *configuration* (an
   :class:`repro.fpca.ProgrammedConfig` — a program plus programmed NVM
   weights, what a physical FPCA would hold in its weight die) and carries
   one frame;
2. requests are grouped by configuration; each group's frames are stacked
   into one ``(B, H, W, c_i)`` batch;
3. each group runs through its signature's handle — configurations sharing
   (spec, c_o, adc, enc) share one handle and therefore one executable,
   because weights enter traced: reprogramming NVM planes never recompiles;
4. results are un-padded and scattered back to the original request order.

With ``cross_config_batching=True``, request groups whose configurations
share a compile signature are additionally merged into ONE executable call
by stacking their NVM weight planes along the channel axis (each request's
counts are sliced from its configuration's channel range).

Entry points: :meth:`FPCAPipeline.serve` (request mix), and
:meth:`FPCAPipeline.run_config_batch` — the low-level non-blocking call the
streaming server (:mod:`repro.serving.streaming`) dispatches through.
:meth:`FPCAPipeline.submit` is a deprecation shim forwarding to ``serve``.
"""

from __future__ import annotations

import dataclasses
import warnings
import weakref
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import fpca as _fpca
from repro.fpca import telemetry
from repro.core.adc import ADCConfig
from repro.core.curvefit import BucketCurvefitModel, fit_bucket_model
from repro.core.device_models import CircuitParams
from repro.core.fpca_sim import WeightEncoding
from repro.core.mapping import FPCASpec, active_window_mask, output_dims
from repro.fpca.cache import ExecutableCache
from repro.models.heads import Detections
from repro.fpca.executable import (
    _USE_PROGRAM,
    CompiledFrontend,
    CompiledModel,
    SegmentResult,
)
from repro.fpca.program import (
    FPCAModelProgram,
    FPCAProgram,
    ProgrammedConfig,
    ProgrammedModel,
    spec_signature,
)

__all__ = [
    "FrontendRequest",
    "FrontendConfig",
    "PipelineStats",
    "FPCAPipeline",
    "CalibrationKeyError",
    "spec_signature",
]


class CalibrationKeyError(ValueError):
    """A calibration handed to :class:`FPCAPipeline` as a plain
    :class:`BucketCurvefitModel` is implicitly keyed to the **default**
    :class:`CircuitParams` — serving a program that carries a custom circuit
    from it would silently pair the wrong physics with the wrong program
    (either by mis-using the supplied calibration or by quietly refitting and
    ignoring it).  Key calibrations explicitly as
    ``{(circuit, n_pixels): model}`` to serve custom-circuit programs."""


def __getattr__(name: str) -> Any:
    if name == "FrontendConfig":
        warnings.warn(
            "FrontendConfig is deprecated; use repro.fpca.ProgrammedConfig "
            "(an FPCAProgram bound to NVM weights)",
            DeprecationWarning,
            stacklevel=2,
        )
        return ProgrammedConfig
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class FrontendRequest:
    """One frame for one registered configuration."""

    config: str                     # registered configuration name
    image: Any                      # (H, W, c_i) float in [0, 1]
    block_mask: np.ndarray | None = None   # region skipping (§3.4.5)


class PipelineStats(telemetry.StatsView):
    """Fleet-level serving counters — registry cells, single-sourced.

    Fields:

    * ``requests``       — frames accepted by :meth:`FPCAPipeline.serve`
    * ``batches``        — fused kernel invocations (fed by the handles'
      ``runs`` cells through the parent chain)
    * ``merged_groups``  — cross-config channel-stacked batches
    * ``fanout_batches`` — multi-config stream fan-out calls
    * ``windows_total`` / ``windows_executed`` / ``launches_skipped`` /
      ``bucket_switches`` / ``bucket_shrinks_deferred`` / ``segments`` /
      ``segment_ticks`` — parent-chained from every owned handle's
      :class:`repro.fpca.executable.FrontendStats`: the handle increments
      ONE cell and the delta lands here too, replacing the old before/after
      delta-mirroring (which double-counted by construction if a call path
      mirrored twice, and missed direct handle use entirely).

    ``cache_hits`` / ``cache_misses`` / ``evictions`` are **derived** reads
    of the shared :class:`repro.fpca.ExecutableCache` — the same counters
    ``cache_info()`` reports, never a copy that can drift.
    """

    _PREFIX = "fpca_pipeline"
    _FIELDS = (
        "requests",
        "batches",
        "merged_groups",
        "fanout_batches",
        "windows_total",
        "windows_executed",
        "launches_skipped",
        "bucket_switches",
        "bucket_shrinks_deferred",
        "segments",
        "segment_ticks",
    )
    _DERIVED = ("cache_hits", "cache_misses", "evictions")

    __slots__ = ("_cache_ref",)

    def __init__(self, cache: ExecutableCache | None = None,
                 labels: dict | None = None):
        super().__init__(labels=labels)
        object.__setattr__(
            self, "_cache_ref",
            weakref.ref(cache) if cache is not None else None,
        )

    def _cache(self) -> ExecutableCache | None:
        ref = object.__getattribute__(self, "_cache_ref")
        return ref() if ref is not None else None

    @property
    def cache_hits(self) -> int:
        c = self._cache()
        return c.hits if c is not None else 0

    @property
    def cache_misses(self) -> int:
        c = self._cache()
        return c.misses if c is not None else 0

    @property
    def evictions(self) -> int:
        c = self._cache()
        return c.evictions if c is not None else 0


class FPCAPipeline:
    """Spec-bucketed reconfiguration scheduler over compiled FPCA handles.

    Args:
      model: fitted :class:`BucketCurvefitModel` (or dict keyed by
        ``n_active_pixels``, or by ``(CircuitParams, n_active_pixels)`` for
        custom-circuit programs); entries without an explicit circuit key
        are taken as default-``CircuitParams`` calibrations.  Missing
        entries are fitted on demand against the registering program's
        circuit (a one-off ~seconds cost per (circuit, pixel count), as a
        deployment would calibrate once).
      backend: any name registered in :mod:`repro.fpca.backends` —
        ``"pallas"`` (TPU kernel), ``"basis"`` (XLA lowering of the same
        math; the fast path on CPU hosts), ``"reference"`` (dense oracle), or
        a third-party registration.  ``None`` (default) auto-selects by
        platform via :func:`repro.fpca.default_backend_name`.
      mesh: optional ``jax.sharding.Mesh`` — batches are sharded over its
        data axes for data-parallel serving; batch padding also rounds up to
        the data-axis extent.
      cache_capacity: bound on simultaneously-held jitted executables,
        shared across ALL registered configurations (one
        :class:`repro.fpca.ExecutableCache` backs every handle).
      cross_config_batching: merge request groups whose configurations share
        a compile signature into one channel-stacked executable call (see
        module docstring).  Off by default: the per-config path preserves the
        exact reprogram-without-recompile executable reuse the base tests pin.
      bucket_patience: sticky-bucket hysteresis for the region-skip row
        buckets (held per handle; a bucket grows immediately but only
        shrinks after ``bucket_patience`` consecutive under-full batches,
        cutting executable-cache switches on busy streams).  The default
        ``1`` is the stateless behaviour.  Trade-off: a deferred shrink
        serves an up-to-2x-oversized row bucket for up to
        ``bucket_patience`` ticks, so hysteresis pays off where a switch is
        expensive (a recompile on a real-TPU serving path) and can *cost*
        throughput where switches are cheap (warm-cache CPU hosts — see the
        flap-vs-sticky numbers in ``BENCH_stream.json``).
    """

    def __init__(
        self,
        model: BucketCurvefitModel | dict[int, BucketCurvefitModel] | None = None,
        *,
        adc: ADCConfig | None = None,
        enc: WeightEncoding | None = None,
        backend: str | None = None,
        interpret: bool | None = None,
        cache_capacity: int = 8,
        mesh: jax.sharding.Mesh | None = None,
        cross_config_batching: bool = False,
        bucket_patience: int = 1,
    ):
        self._backend = _fpca.get_backend(
            backend if backend is not None else _fpca.default_backend_name()
        )
        self.backend = self._backend.name
        self.adc = adc or ADCConfig()
        self.enc = enc or WeightEncoding()
        self.interpret = interpret
        self.mesh = mesh
        self.cross_config_batching = cross_config_batching
        if bucket_patience < 1:
            raise ValueError("bucket_patience must be >= 1")
        self.bucket_patience = bucket_patience
        # fitted bucket models keyed by (circuit, n_active_pixels): programs
        # registering a custom circuit get a model fitted against THAT
        # circuit (matching fpca.compile), not the default calibration.
        # Models passed in here are taken as default-CircuitParams
        # calibrations unless keyed by an explicit (circuit, n_pixels) tuple.
        default_circuit = CircuitParams()
        self._models: dict[tuple[CircuitParams, int], BucketCurvefitModel] = {}
        # keys that came in WITHOUT an explicit circuit: these are trusted
        # only for default-circuit programs (see CalibrationKeyError)
        self._implicitly_keyed: set[tuple[CircuitParams, int]] = set()
        if isinstance(model, BucketCurvefitModel):
            key = (default_circuit, model.n_pixels)
            self._models[key] = model
            self._implicitly_keyed.add(key)
        elif isinstance(model, dict):
            for k, v in model.items():
                key = k if isinstance(k, tuple) else (default_circuit, k)
                self._models[key] = v
                if not isinstance(k, tuple):
                    self._implicitly_keyed.add(key)
        self._configs: dict[str, ProgrammedConfig | ProgrammedModel] = {}
        # one CompiledFrontend per compile signature, all sharing one bounded
        # executable cache — reprogramming weights never recompiles, and the
        # total live-executable count stays bounded across configurations
        self._handles: dict[tuple, CompiledFrontend] = {}
        self._cache = ExecutableCache(cache_capacity)
        # channel-stacked (kernel, bn, program) per fan-out tuple: configs are
        # immutable once registered, so the concat is paid once, not per tick
        self._stacked: dict[
            tuple[str, ...], tuple[jax.Array, jax.Array, FPCAProgram]
        ] = {}
        # handle stats parent-chain into these cells; cache counters are
        # derived reads of self._cache — nothing is mirrored by hand
        self.stats = PipelineStats(cache=self._cache)

    # -- configuration registry ----------------------------------------------
    def register(
        self,
        name: str,
        spec: FPCASpec | FPCAProgram | FPCAModelProgram,
        kernel: jax.Array,
        bn_offset: jax.Array | None = None,
        *,
        head_params: Any | None = None,
    ) -> ProgrammedConfig | ProgrammedModel:
        """Program one FPCA configuration (idempotent per unique name).

        ``spec`` may be a bare :class:`FPCASpec` (wrapped into a program with
        this pipeline's adc/enc), a full :class:`repro.fpca.FPCAProgram`, or
        an :class:`repro.fpca.FPCAModelProgram` — a whole model (frontend +
        digital CNN head) whose trained ``head_params`` bind here the way the
        NVM ``kernel`` does.  Model configurations serve class *logits*
        through :meth:`serve`, stack channels with frontend configurations
        that share a compile signature, and get the skip-aware per-tick head
        in :class:`repro.serving.StreamServer`.
        """
        if name in self._configs:
            raise ValueError(f"config {name!r} already registered")
        c_o = int(kernel.shape[0])
        if isinstance(spec, FPCAModelProgram):
            if int(spec.out_channels) != c_o:
                raise ValueError(
                    f"kernel has {c_o} output channels; model program for "
                    f"{name!r} specifies {spec.out_channels}"
                )
            if head_params is None:
                raise ValueError(
                    f"model program {name!r} needs head_params= (the trained "
                    f"head pytree; see FPCAModelProgram.init_head)"
                )
            if bn_offset is None:
                bn_offset = jnp.zeros((c_o,), jnp.float32)
            mcfg = ProgrammedModel(
                name=name,
                model=spec,
                kernel=jnp.asarray(kernel, jnp.float32),
                bn_offset=jnp.asarray(bn_offset, jnp.float32),
                head_params=spec.bind_head_params(head_params),
            )
            self._configs[name] = mcfg
            return mcfg
        if head_params is not None:
            raise ValueError("head_params= needs an FPCAModelProgram")
        if isinstance(spec, FPCAProgram):
            if int(spec.out_channels) != c_o:
                raise ValueError(
                    f"kernel has {c_o} output channels; program for "
                    f"{name!r} specifies {spec.out_channels}"
                )
            program = spec
        else:
            program = FPCAProgram(
                spec=spec, adc=self.adc, enc=self.enc, out_channels=c_o
            )
        if bn_offset is None:
            bn_offset = jnp.zeros((c_o,), jnp.float32)
        cfg = ProgrammedConfig(
            name=name,
            program=program,
            kernel=jnp.asarray(kernel, jnp.float32),
            bn_offset=jnp.asarray(bn_offset, jnp.float32),
        )
        self._configs[name] = cfg
        return cfg

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def cache_info(self, verbose: bool = False):
        """Counters of the shared executable cache (all handles);
        ``verbose=True`` adds per-key hit/miss splits, LRU-ordered resident
        keys and the bounded eviction log."""
        return self._cache.info(verbose)

    def _model_for(self, program: FPCAProgram) -> BucketCurvefitModel:
        key = (program.circuit, program.spec.n_active_pixels)
        if key not in self._models:
            implicit_key = (CircuitParams(), key[1])
            if implicit_key in self._implicitly_keyed:
                raise CalibrationKeyError(
                    f"this pipeline holds a calibration for "
                    f"n_pixels={key[1]} passed as a plain "
                    f"BucketCurvefitModel (implicitly a default-CircuitParams "
                    f"calibration), but the program being served carries a "
                    f"custom CircuitParams — refusing to guess which physics "
                    f"it was fitted against.  Pass calibrations keyed "
                    f"explicitly as {{(circuit, n_pixels): model}}."
                )
            self._models[key] = fit_bucket_model(
                program.circuit, n_pixels=key[1]
            )
        return self._models[key]

    def handle_for(
        self, program: FPCAProgram | FPCASpec, out_channels: int | None = None
    ) -> CompiledFrontend:
        """The shared :class:`CompiledFrontend` serving one compile signature.

        Created lazily, keyed by ``program.signature()`` (a bare spec is
        wrapped with this pipeline's adc/enc); handles never hold weights
        (requests supply them per call through ``run_weighted``), so
        configurations sharing a signature genuinely share the executable.
        """
        if isinstance(program, FPCASpec):
            program = FPCAProgram(
                spec=program, adc=self.adc, enc=self.enc,
                out_channels=out_channels,
            )
        elif out_channels is not None and int(out_channels) != int(
            program.out_channels
        ):
            program = program.replace(out_channels=int(out_channels))
        key = program.signature()
        handle = self._handles.get(key)
        if handle is None:
            handle = CompiledFrontend(
                program,
                backend=self._backend,
                model=self._model_for(program),
                mesh=self.mesh,
                cache=self._cache,
                bucket_patience=self.bucket_patience,
                interpret=self.interpret,
                stats_parent=self.stats,
            )
            self._handles[key] = handle
        return handle

    def model_handle_for(self, model: FPCAModelProgram) -> CompiledModel:
        """The shared :class:`repro.fpca.CompiledModel` serving one model
        compile signature (lazily created, same dict as the frontend
        handles — model signatures extend frontend ones so the key spaces
        are disjoint by construction).  Handles hold no parameters; every
        call supplies the programmed NVM planes and head pytree."""
        key = model.signature()
        handle = self._handles.get(key)
        if handle is None:
            handle = CompiledModel(
                model,
                backend=self._backend,
                model=self._model_for(model.frontend),
                mesh=self.mesh,
                cache=self._cache,
                bucket_patience=self.bucket_patience,
                interpret=self.interpret,
                stats_parent=self.stats,
            )
            self._handles[key] = handle
        return handle  # type: ignore[return-value]

    def reset_bucket_state(self) -> None:
        """Forget all sticky row-bucket state (counters in ``stats`` remain).

        Benchmarks use this to make repeated serves of one scene evolve their
        bucket sequence identically (so a timed pass replays only executables
        the warm-up pass already compiled)."""
        for handle in self._handles.values():
            handle.reset_bucket_state()

    # -- scheduling ----------------------------------------------------------
    def group_requests(
        self, requests: Sequence[FrontendRequest]
    ) -> dict[str, list[int]]:
        """Request indices bucketed by configuration (insertion-ordered)."""
        groups: dict[str, list[int]] = {}
        for i, req in enumerate(requests):
            if req.config not in self._configs:
                raise KeyError(f"unknown config {req.config!r}")
            groups.setdefault(req.config, []).append(i)
        return groups

    def _run_batch(
        self,
        program: FPCAProgram,
        kernel: jax.Array,
        bn_offset: jax.Array,
        images: jax.Array,
        window_keep: np.ndarray | None = None,
        *,
        handle: CompiledFrontend | None = None,
        head_params: Any | None = None,
    ) -> jax.Array:
        """One fused handle call.  No counter mirroring happens here: the
        handle's stats cells are parent-chained into ``self.stats`` (handle
        ``runs`` land in ``batches``; window/launch/bucket/segment counters
        share names), and the cache counters are derived reads of the shared
        cache — the single-source fix for the old double-mirroring risk.

        With an explicit :class:`CompiledModel` ``handle`` (and its
        ``head_params``), the call serves class logits through the fused
        frontend+head executable instead of SS-ADC counts.
        """
        if handle is None:
            handle = self.handle_for(program, int(kernel.shape[0]))
        if head_params is not None:
            counts = handle.run_weighted(
                kernel, bn_offset, images, window_keep, head_params=head_params
            )
        else:
            counts = handle.run_weighted(kernel, bn_offset, images, window_keep)
        return counts

    def run_config_batch(
        self,
        name: str | Sequence[str],
        images: Any,
        window_keep: np.ndarray | None = None,
    ) -> jax.Array:
        """Non-blocking fused call for a frame batch of registered config(s).

        With a single config name, returns ``(b, h_o, w_o, c_o)`` SS-ADC
        counts, dispatched but not blocked on — the streaming server's
        double-buffered loop lives on this method.  ``window_keep`` rows
        belonging to skipped windows come back as exact zeros without having
        been computed.

        With a *sequence* of config names (multi-config fan-out: one camera
        feeding several programmed configurations), every named config must
        share the first one's :class:`FPCASpec`; their NVM weight planes are
        stacked along the channel axis and the whole fan-out runs as ONE
        fused call — the cross-config channel stacking of
        :meth:`_submit_merged`, reused per streaming tick.  Returns
        ``(b, h_o, w_o, sum(c_o))``; slice per-config channel ranges with
        :meth:`config_channel_slices`.
        """
        names = [name] if isinstance(name, str) else list(name)
        if not names:
            raise ValueError("need at least one config name")
        for n in names:
            if n not in self._configs:
                raise KeyError(f"unknown config {n!r}")
        cfgs = [self._configs[n] for n in names]
        spec = cfgs[0].spec
        for cfg in cfgs[1:]:
            if cfg.spec != spec:
                raise ValueError(
                    f"multi-config fan-out requires a shared spec: config "
                    f"{cfg.name!r} differs from {cfgs[0].name!r}"
                )
        images = jnp.asarray(images, jnp.float32)
        want = (spec.image_h, spec.image_w, spec.in_channels)
        if images.ndim != 4 or images.shape[1:] != want:
            raise ValueError(
                f"expected (b, {want[0]}, {want[1]}, {want[2]}) batch for "
                f"config {names[0]!r}, got {images.shape}"
            )
        if len(cfgs) == 1:
            cfg = cfgs[0]
            return self._run_batch(
                cfg.program, cfg.kernel, cfg.bn_offset, images, window_keep
            )
        kernel, bn, stacked_program = self._stacked_planes(names, cfgs)
        batches_before = self.stats.batches
        counts = self._run_batch(stacked_program, kernel, bn, images, window_keep)
        # a zero-kept tick short-circuits inside the handle: only count the
        # fan-outs that actually launched a stacked call
        self.stats.fanout_batches += self.stats.batches - batches_before
        return counts

    def run_config_segment(
        self,
        name: str,
        frames: Any,
        *,
        state: Any | None = None,
        gate: Any = _USE_PROGRAM,
        m_bucket: int | None = None,
        early_exit: int | None = None,
    ) -> SegmentResult:
        """Serve K streaming ticks of one registered configuration as ONE
        device-compiled segment (``jax.lax.scan`` — see
        :meth:`repro.fpca.CompiledFrontend.run_segment`).

        ``frames`` is ``(K, H, W, c_i)``; ``state`` threads the previous
        segment's :attr:`SegmentResult.state`.  Model configurations serve
        per-tick logits through the in-scan skip-aware head.  Handle
        counters (including the in-scan zero-kept launch skips and the
        ``segments`` / ``segment_ticks`` pair) land in ``stats`` through the
        parent chain — single-sourced, never mirrored.
        """
        cfg = self._configs.get(name)
        if cfg is None:
            raise KeyError(f"unknown config {name!r}")
        if isinstance(cfg, ProgrammedModel):
            handle: CompiledFrontend = self.model_handle_for(cfg.model)
        else:
            handle = self.handle_for(cfg.program, int(cfg.kernel.shape[0]))
        kwargs: dict[str, Any] = dict(
            state=state, gate=gate, m_bucket=m_bucket, early_exit=early_exit
        )
        if isinstance(cfg, ProgrammedModel):
            seg = handle.run_segment_weighted(
                cfg.kernel, cfg.bn_offset, frames,
                head_params=cfg.head_params, **kwargs,
            )
        else:
            seg = handle.run_segment_weighted(
                cfg.kernel, cfg.bn_offset, frames, **kwargs
            )
        return seg

    def _stacked_planes(
        self, names: Sequence[str], cfgs: Sequence[ProgrammedConfig]
    ) -> tuple[jax.Array, jax.Array, FPCAProgram]:
        """Channel-stacked (kernel, bn, program) for one fan-out tuple.

        Cached per tuple — configs are immutable once registered, so the
        concat (and the compile-signature compatibility check: one stacked
        launch serves ONE adc/enc/circuit epilogue) is paid once, not per
        tick.
        """
        key = tuple(names)
        stacked = self._stacked.get(key)
        if stacked is None:
            base = cfgs[0].program.fanout_signature()
            for cfg in cfgs[1:]:
                if cfg.program.fanout_signature() != base:
                    raise ValueError(
                        f"multi-config fan-out requires a shared spec and "
                        f"compile signature (adc/enc/circuit): config "
                        f"{cfg.name!r} differs from {cfgs[0].name!r}"
                    )
            kernel = jnp.concatenate([c.kernel for c in cfgs], axis=0)
            stacked = self._stacked[key] = (
                kernel,
                jnp.concatenate([c.bn_offset for c in cfgs], axis=0),
                cfgs[0].program.replace(out_channels=int(kernel.shape[0])),
            )
        return stacked

    def config_channel_slices(
        self, names: Sequence[str]
    ) -> list[tuple[str, int, int]]:
        """Per-config ``(name, lo, hi)`` channel ranges of a stacked fan-out
        call (the channel order :meth:`run_config_batch` concatenates in)."""
        slices: list[tuple[str, int, int]] = []
        lo = 0
        for n in names:
            c_o = int(self._configs[n].kernel.shape[0])
            slices.append((n, lo, lo + c_o))
            lo += c_o
        return slices

    def _group_window_keep(
        self, cfg: ProgrammedConfig, reqs: list[FrontendRequest]
    ) -> np.ndarray | None:
        """Stacked per-window keep grid for a request group (None = dense)."""
        if all(r.block_mask is None for r in reqs):
            return None
        h_o, w_o = output_dims(cfg.spec)
        return np.stack(
            [
                active_window_mask(cfg.spec, r.block_mask)
                if r.block_mask is not None
                else np.ones((h_o, w_o), bool)
                for r in reqs
            ]
        )

    def _check_geometry(
        self, name: str, requests: Sequence[FrontendRequest], idxs: list[int]
    ) -> None:
        cfg = self._configs[name]
        want_shape = (cfg.spec.image_h, cfg.spec.image_w, cfg.spec.in_channels)
        for i in idxs:
            got = np.shape(requests[i].image)
            if got != want_shape:
                raise ValueError(
                    f"request {i}: frame shape {got} does not match config "
                    f"{name!r} sensor geometry {want_shape}"
                )

    def serve(self, requests: Sequence[FrontendRequest]) -> list[jax.Array]:
        """Serve a heterogeneous request mix; results in request order.

        Returns one SS-ADC count map ``(h_o, w_o, c_o)`` per request — or,
        for requests naming a **model** configuration
        (:class:`repro.fpca.ProgrammedModel`), the ``(n_classes,)`` class
        logits of the fused frontend+head executable.
        """
        with telemetry.span("serve"):
            results: list[jax.Array | None] = [None] * len(requests)
            groups = self.group_requests(requests)
            self.stats.requests += len(requests)
            merged: dict[tuple, list[str]] = {}
            for name in groups:
                cfg = self._configs[name]
                key = (
                    cfg.program.signature()
                    if self.cross_config_batching
                    else (name,)
                )
                merged.setdefault(key, []).append(name)
            for names in merged.values():
                if len(names) == 1:
                    self._submit_group(
                        names[0], groups[names[0]], requests, results
                    )
                else:
                    self._submit_merged(names, groups, requests, results)
            return results  # type: ignore[return-value]

    def submit(self, requests: Sequence[FrontendRequest]) -> list[jax.Array]:
        """Deprecation shim for :meth:`serve` (the pre-``repro.fpca`` name)."""
        warnings.warn(
            "FPCAPipeline.submit is deprecated; use FPCAPipeline.serve "
            "(same semantics) or compile an explicit handle via "
            "repro.fpca.compile",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.serve(requests)

    def _submit_group(
        self,
        name: str,
        idxs: list[int],
        requests: Sequence[FrontendRequest],
        results: list,
    ) -> None:
        cfg = self._configs[name]
        self._check_geometry(name, requests, idxs)
        images = jnp.stack(
            [jnp.asarray(requests[i].image, jnp.float32) for i in idxs]
        )
        window_keep = self._group_window_keep(cfg, [requests[i] for i in idxs])
        dc = None
        if isinstance(cfg, ProgrammedModel):
            # whole-model config: ONE fused frontend+head jit -> logits
            counts = self._run_batch(
                cfg.program, cfg.kernel, cfg.bn_offset, images, window_keep,
                handle=self.model_handle_for(cfg.model),
                head_params=cfg.head_params,
            )
            dc = cfg.model.detect_classes
        else:
            counts = self._run_batch(
                cfg.program, cfg.kernel, cfg.bn_offset, images, window_keep
            )
        for j, i in enumerate(idxs):
            results[i] = (
                Detections.from_raw(counts[j], dc)
                if dc is not None
                else counts[j]
            )

    def _submit_merged(
        self,
        names: list[str],
        groups: dict[str, list[int]],
        requests: Sequence[FrontendRequest],
        results: list,
    ) -> None:
        """Cross-config batching: configs sharing a compile signature run as
        ONE call with their NVM weight planes stacked along the channel axis;
        each request's counts are sliced from its config's channel range.

        Model configurations stack exactly like frontend ones (the stacked
        launch serves the shared analog epilogue); their digital heads then
        run per config on the sliced channel range — each request of a model
        config resolves to class logits, bit-identical to serving that
        config alone.
        """
        cfgs = [self._configs[n] for n in names]
        for name in names:
            self._check_geometry(name, requests, groups[name])
        kernel, bn, program = self._stacked_planes(names, cfgs)
        idxs = [i for n in names for i in groups[n]]
        images = jnp.stack(
            [jnp.asarray(requests[i].image, jnp.float32) for i in idxs]
        )
        window_keep = self._group_window_keep(
            cfgs[0], [requests[i] for i in idxs]
        )
        counts = self._run_batch(program, kernel, bn, images, window_keep)
        self.stats.merged_groups += 1
        offsets = np.cumsum([0] + [int(c.kernel.shape[0]) for c in cfgs])
        row = 0
        for g, (name, cfg) in enumerate(zip(names, cfgs)):
            lo, hi = int(offsets[g]), int(offsets[g + 1])
            rows = groups[name]
            if isinstance(cfg, ProgrammedModel):
                handle = self.model_handle_for(cfg.model)
                logits = handle.head_logits(
                    counts[row : row + len(rows), ..., lo:hi],
                    head_params=cfg.head_params,
                )
                dc = cfg.model.detect_classes
                for j, i in enumerate(rows):
                    results[i] = (
                        Detections.from_raw(logits[j], dc)
                        if dc is not None
                        else logits[j]
                    )
                row += len(rows)
            else:
                for i in rows:
                    results[i] = counts[row, ..., lo:hi]
                    row += 1
