"""Fleet observability: per-(stream, config) serving report + reconciliation.

The third telemetry export surface (next to ``registry().render()`` and the
JSONL event log): :func:`fleet_report` folds a :class:`StreamServer`'s live
sessions, servo controllers, executable cache and registry-backed counters
into one strict-JSON-able table — what a deployment dashboard (or
``benchmarks/perf_compare.py --telemetry``) reads per scrape.

Because every stats surface is a :class:`repro.fpca.telemetry.StatsView`
over shared registry cells, the report needs no delta bookkeeping of its
own; :func:`assert_reconciled` makes that contract executable — the legacy
counter objects, the registry export and the parent-chained handle cells
must agree *exactly*, every time, or telemetry is lying.
"""

from __future__ import annotations

from typing import Any

from repro.core import analysis
from repro.fpca import telemetry

__all__ = ["fleet_report", "render_fleet_report", "assert_reconciled"]


def _stream_rows(server, const) -> list[dict]:
    rows: list[dict] = []
    for stream_id, session in server.sessions.items():
        for cfg_name in session.configs:
            row: dict[str, Any] = {
                "stream": stream_id,
                "config": cfg_name,
                "frames": session.frame_idx,
                "gated": session.gating,
            }
            st = session.state_for(cfg_name)
            if st is not None and st.block_masks:
                rep = session.energy_report(const, config=cfg_name)
                row.update(
                    kept_window_frac=rep["kept_window_frac"],
                    executed_windows=rep["executed_windows"],
                    executed_cycles=rep["executed_cycles"],
                    e_total=rep["e_total"],
                    energy_vs_dense=rep["energy_vs_dense"],
                    latency_vs_dense=rep["latency_vs_dense"],
                    fps_effective=rep["fps_effective"],
                )
            ctl = st.controller if st is not None else None
            if ctl is not None:
                row.update(
                    servo={
                        "controller": ctl.name,
                        "metric": ctl.config.metric,
                        "target": ctl.config.target,
                        "threshold": ctl.threshold,
                        "ema": ctl.ema,
                        "converged_tick": ctl.converged_tick(),
                        "ticks": len(ctl.history),
                    }
                )
            rows.append(row)
    return rows


def fleet_report(
    server,
    const: analysis.FrontendConstants | None = None,
    fleet=None,
) -> dict:
    """Per-(stream, config) serving table plus fleet-level totals.

    Every number is either a live registry cell read (:class:`StreamStats`
    / :class:`PipelineStats` fields, cache counters) or derived from the
    per-session gate history through
    :func:`repro.core.analysis.streaming_frontend_report` — nothing is
    sampled or mirrored, so the report reconciles exactly with the legacy
    stats objects (see :func:`assert_reconciled`).  Strict-JSON-able
    (non-finite floats map to ``None`` via
    :func:`repro.fpca.telemetry.jsonable`).

    With a :class:`repro.serving.fleet.FleetController` passed as
    ``fleet``, the report also carries its ``arbitration`` table — budget,
    per-stream priority/activity/allocation and admission counters.

    The ``workloads`` table breaks the fleet out per architecture: every
    arch-labeled ``fpca_model_*`` / ``fpca_events_*`` registry row (model
    zoo classifier/detector traffic, neuromorphic event lanes), summed
    across instances.
    """
    s = server.stats
    pipe = server.pipeline
    info = pipe.cache_info()
    gets = info.hits + info.misses
    fleet_totals = {
        "ticks": s.ticks,
        "frames": s.frames,
        "windows_total": s.windows_total,
        "windows_kept": s.windows_kept,
        "kept_fraction": s.windows_kept / max(s.windows_total, 1),
        "launches_skipped": s.launches_skipped,
        "bucket_switches": s.bucket_switches,
        "bucket_shrinks_deferred": s.bucket_shrinks_deferred,
        "segments": s.segments,
        "segment_ticks": s.segment_ticks,
        "fused_head_calls": s.fused_head_calls,
        "serve_seconds": s.serve_seconds,
        "fps_wall": (
            s.frames / s.serve_seconds if s.serve_seconds > 0 else None
        ),
        "cache": {
            "hits": info.hits,
            "misses": info.misses,
            "hit_rate": info.hits / gets if gets else None,
            "evictions": info.evictions,
            "currsize": info.currsize,
            "maxsize": info.maxsize,
        },
    }
    report = {
        "streams": _stream_rows(server, const),
        "fleet": fleet_totals,
        "workloads": _workload_rows(),
    }
    if fleet is not None:
        report["arbitration"] = fleet.arbitration_table()
    return telemetry.jsonable(report)


def _workload_rows() -> dict[str, dict[str, float]]:
    """Per-architecture workload breakout: every arch-labeled registry row
    (the ``fpca_model_*`` run/frame counters stamped by
    :class:`repro.fpca.CompiledModel` and the ``fpca_events_*`` lanes of
    attached :class:`repro.serving.events.EventTap`\\ s), summed across
    instances.  Registry-global by design — one dashboard row per workload
    kind regardless of how many compiled handles serve it."""
    workloads: dict[str, dict[str, float]] = {}
    for name, _kind, labels, value in telemetry.registry().collect():
        arch = labels.get("arch")
        if arch is None:
            continue
        if not (name.startswith("fpca_model_")
                or name.startswith("fpca_events_")):
            continue
        row = workloads.setdefault(arch, {})
        row[name] = row.get(name, 0) + value
    return workloads


_COLS = (
    ("stream", "stream"),
    ("config", "config"),
    ("frames", "frames"),
    ("kept_window_frac", "kept"),
    ("energy_vs_dense", "e/dense"),
    ("fps_effective", "fps_eff"),
)


def render_fleet_report(report: dict) -> str:
    """Plain-text table of a :func:`fleet_report` result (for CLI output)."""

    def _fmt(v) -> str:
        if v is None:
            return "-"
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)

    rows = []
    for r in report["streams"]:
        servo = r.get("servo")
        rows.append(
            [_fmt(r.get(key)) for key, _ in _COLS]
            + [
                _fmt(servo["threshold"]) if servo else "-",
                _fmt(servo["converged_tick"]) if servo else "-",
            ]
        )
    headers = [h for _, h in _COLS] + ["thr", "conv@"]
    widths = [
        max(len(h), *(len(row[i]) for row in rows)) if rows else len(h)
        for i, h in enumerate(headers)
    ]
    lines = [
        "  ".join(h.ljust(w) for h, w in zip(headers, widths)),
        "  ".join("-" * w for w in widths),
    ]
    for row in rows:
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
    f = report["fleet"]
    lines.append(
        f"fleet: {f['frames']} frames in {f['ticks']} ticks, "
        f"kept {f['kept_fraction']:.3f}, "
        f"cache hit-rate {_fmt(f['cache']['hit_rate'])}, "
        f"wall fps {_fmt(f['fps_wall'])}"
    )
    arb = report.get("arbitration")
    if arb:
        lines.append(
            f"arbitration: budget {_fmt(arb['budget'])} "
            f"(allocated {_fmt(arb['allocated'])}), "
            f"{arb['admitted']}/{arb['capacity']} streams admitted, "
            f"{len(arb['queued'])} queued, {arb['rejections']} rejected, "
            f"{arb['rebalances']} rebalances"
        )
        for r in arb["streams"]:
            lines.append(
                f"  {r['stream']}: prio {_fmt(r['priority'])}  "
                f"activity {_fmt(r['activity'])}  "
                f"allocation {_fmt(r['allocation'])}  "
                f"thr {_fmt(r['threshold'])}"
            )
    return "\n".join(lines)


def _registry_rows_for(view: telemetry.StatsView) -> dict[str, Any]:
    """The registry's exported rows for one stats view, keyed by field."""
    prefix = view._PREFIX
    inst = view._labels.get("instance")
    out: dict[str, Any] = {}
    for name, _kind, labels, value in telemetry.registry().collect():
        if labels.get("instance") == inst and name.startswith(prefix + "_"):
            out[name[len(prefix) + 1:]] = value
    return out


def assert_reconciled(pipeline, server=None) -> None:
    """Assert the three stats surfaces agree *exactly* — no tolerance.

    1. Registry export rows == legacy attribute reads, for
       :class:`PipelineStats` (and :class:`StreamStats` when a server is
       given) — they are the same cells, so any drift is a wiring bug.
    2. The pipeline's ``windows_executed`` / ``launches_skipped`` /
       ``windows_total`` equal the sum over its compiled handles' cells —
       the parent-chain single-sourcing contract (no double counting, no
       missed increments).
    3. Derived cache counters == :meth:`ExecutableCache.info`.
    4. Event-tap accounting (server streams with ``events=True``): the
       polarity split sums to the event total, and the tap's event count
       equals the gate's own changed-block count — per-tick and
       segment-reconstructed packets both honour it.
    """
    views = [pipeline.stats] + ([server.stats] if server is not None else [])
    taps = list(getattr(server, "event_taps", {}).values()) if server else []
    views.extend(t.stats for t in taps)
    for view in views:
        exported = _registry_rows_for(view)
        legacy = view.as_dict()
        for field, value in legacy.items():
            assert field in exported, (
                f"{type(view).__name__}.{field} missing from registry export"
            )
            assert exported[field] == value, (
                f"{type(view).__name__}.{field}: registry export "
                f"{exported[field]} != legacy counter {value}"
            )
    chained = ("windows_total", "windows_executed", "launches_skipped",
               "bucket_switches", "bucket_shrinks_deferred",
               "segments", "segment_ticks")
    handles = [
        h for h in pipeline._handles.values()
        if isinstance(getattr(h, "stats", None), telemetry.StatsView)
    ]
    for field in chained:
        total = sum(getattr(h.stats, field) for h in handles)
        have = getattr(pipeline.stats, field)
        assert total == have, (
            f"parent-chain mismatch on {field}: handles sum to {total}, "
            f"pipeline cell holds {have}"
        )
    info = pipeline.cache_info()
    assert pipeline.stats.cache_hits == info.hits
    assert pipeline.stats.cache_misses == info.misses
    assert pipeline.stats.evictions == info.evictions
    for tap in taps:
        es = tap.stats
        assert es.events == es.events_pos + es.events_neg, (
            f"event polarity split {es.events_pos}+{es.events_neg} != "
            f"total {es.events} on stream {tap.session.stream_id!r}"
        )
        st = tap.session._primary
        assert st is not None and es.events == st.changed_total, (
            f"event stream {tap.session.stream_id!r}: tap counted "
            f"{es.events} events, gate counted "
            f"{st.changed_total if st is not None else None} changed blocks"
        )
