"""Neuromorphic event streams: the delta gate's per-block changes as a
first-class sensor output.

The temporal delta gate (:mod:`repro.serving.streaming`) already computes,
every tick, which coarse blocks of the effective frame changed beyond a
threshold — exactly the statistic an event camera / P2M pixel array emits as
address-event spikes.  :class:`EventTap` surfaces it as a per-tick
:class:`EventPacket` stream: block coordinates, polarity (sign of the mean
block change) and a wall-clock timestamp, with its own registry-backed
:class:`EventStats` accounting (labeled ``arch="events"`` so
``fleet_report()``'s workload table and the Prometheus render break the
event lane out next to classifier / detection traffic).

Two emission paths, one numerics contract:

* **per-tick** — :meth:`EventTap.observe_tick` reads the gate state the
  session just stepped (the *same* ``changed`` array the gate counted, plus
  a signed block-mean delta computed before the previous frame is
  overwritten), so the tap's event counts and the gate's changed-block
  accounting can never drift (:func:`repro.serving.observe.assert_reconciled`
  asserts exact equality);
* **segment** — a device-compiled segment never materialises per-tick gate
  internals on the host, so :func:`segment_events` *re-derives* them from
  the frames and the carried previous effective frame through the same
  :mod:`repro.core.gating` kernels the in-scan gate traces — bit-identical
  decisions, pinned by the per-tick-vs-segment differential test.

Attach a tap with ``StreamServer.add_stream(..., events=True)`` (or through
``FleetController.add_stream``); packets ride on
``StreamFrameResult.events``.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import numpy as np

from repro.core import gating, mapping
from repro.fpca import telemetry
from repro.serving.streaming import _block_reduce_mean

__all__ = ["EventPacket", "EventStats", "EventTap", "segment_events"]


class EventStats(telemetry.StatsView):
    """Per-tap event accounting, registry-backed (labels carry
    ``arch="events"`` and the stream id).

    * ``ticks``      — gate ticks observed (packets emitted, incl. empty)
    * ``packets``    — packets emitted (== ticks; kept separate so a future
      coalescing tap stays honest)
    * ``events``     — total events (changed blocks) across all packets
    * ``events_pos`` / ``events_neg`` — polarity split; their sum is
      ``events`` *exactly* (asserted by ``assert_reconciled``)
    """

    _PREFIX = "fpca_events"
    _FIELDS = ("ticks", "packets", "events", "events_pos", "events_neg")


@dataclasses.dataclass(frozen=True)
class EventPacket:
    """One tick's address-events for one stream.

    ``coords`` are block-grid coordinates ``(row, col)`` on the
    ``grid_shape`` grid (``block`` effective pixels per side);  ``polarity``
    is the sign of the mean block intensity change (+1 brighter, -1
    darker).  ``timestamp`` is the host emission wall-clock (for segment
    reconstruction: when the packet was rebuilt, not when the tick ran).
    A tick whose delta crossed no threshold (or the stream's first frame,
    which has no delta) emits an *empty* packet — per-tick alignment with
    the serving loop is part of the contract.
    """

    stream_id: str
    frame_idx: int
    coords: np.ndarray               # (n, 2) int32 block (row, col)
    polarity: np.ndarray             # (n,) int8 in {+1, -1}
    timestamp: float
    grid_shape: tuple[int, int]
    block: int

    @property
    def n_events(self) -> int:
        return int(self.coords.shape[0])

    def raster(self) -> np.ndarray:
        """Signed event grid: +1 / -1 at event blocks, 0 elsewhere."""
        grid = np.zeros(self.grid_shape, np.int8)
        if self.n_events:
            grid[self.coords[:, 0], self.coords[:, 1]] = self.polarity
        return grid


def _packet(
    stream_id: str,
    frame_idx: int,
    changed: np.ndarray | None,
    signed: np.ndarray | None,
    grid_shape: tuple[int, int],
    block: int,
) -> EventPacket:
    if changed is None or not changed.any():
        coords = np.zeros((0, 2), np.int32)
        polarity = np.zeros((0,), np.int8)
    else:
        ys, xs = np.nonzero(changed)
        coords = np.stack([ys, xs], axis=-1).astype(np.int32)
        polarity = np.where(signed[ys, xs] >= 0, 1, -1).astype(np.int8)
    return EventPacket(
        stream_id=stream_id,
        frame_idx=int(frame_idx),
        coords=coords,
        polarity=polarity,
        timestamp=time.time(),
        grid_shape=grid_shape,
        block=block,
    )


class EventTap:
    """Per-stream event emitter over a :class:`StreamSession`'s delta gate.

    Requires a gated, shared-gate session (per-config fan-out gates would
    emit ambiguous per-block decisions).  ``packets`` retains the last
    ``history`` packets; :attr:`stats` is the registry-backed accounting.
    """

    def __init__(self, session: Any, history: int = 512):
        if session.per_config:
            raise NotImplementedError(
                "event streams need one shared gate per stream; per-config "
                "fan-out gates are unsupported"
            )
        if not session.gating:
            raise ValueError(
                f"event stream needs a gated stream; stream "
                f"{session.stream_id!r} is dense"
            )
        self.session = session
        session.want_events = True     # session computes the signed delta
        spec = session.spec
        self.grid_shape = gating.block_grid(spec)
        self.block = int(spec.skip_block)
        self.stats = EventStats(
            labels={"arch": "events", "stream": session.stream_id}
        )
        self.packets: collections.deque[EventPacket] = collections.deque(
            maxlen=history
        )

    def observe_tick(self, frame_idx: int) -> EventPacket:
        """Emit this tick's packet from the gate state the session just
        stepped.  Reads the *same* ``changed`` array the gate's
        ``changed_total`` counted — the per-tick reconciliation contract."""
        st = self.session._primary
        packet = _packet(
            self.session.stream_id,
            frame_idx,
            st.last_changed,
            self.session._last_signed,
            self.grid_shape,
            self.block,
        )
        self._record(packet)
        return packet

    def absorb_packets(self, packets: list[EventPacket]) -> None:
        """Fold segment-reconstructed packets (:func:`segment_events`) into
        the tap AND the gate-side changed-block accounting — the in-scan
        gate never touched the host ``_GateState``, so both sides of the
        reconciliation advance here in lock-step (the segment differential
        test pins the packet counts to the in-scan gate's decisions)."""
        st = self.session._primary
        for p in packets:
            self._record(p)
            st.changed_total += p.n_events

    def _record(self, packet: EventPacket) -> None:
        self.stats.ticks += 1
        self.stats.packets += 1
        n = packet.n_events
        if n:
            pos = int((packet.polarity > 0).sum())
            self.stats.events += n
            self.stats.events_pos += pos
            self.stats.events_neg += n - pos
        self.packets.append(packet)


def segment_events(
    spec: mapping.FPCASpec,
    frames: Any,
    prev_eff: Any | None,
    threshold: float,
    stream_id: str,
    first_frame_idx: int,
) -> list[EventPacket]:
    """Re-derive per-tick event packets for a device-compiled segment.

    ``frames`` are the segment's served ticks (``(ticks, H, W, c_i)``);
    ``prev_eff`` the effective frame carried *into* the segment (``None``
    at stream start); ``threshold`` the gate threshold the segment traced
    (captured *before* the boundary servo actuates).  Uses the same jitted
    :mod:`repro.core.gating` kernels the in-scan gate inlines, so the
    changed-block decisions are bit-identical to what the device computed.
    """
    kernels = gating.host_gate_kernels(spec)
    grid_shape = gating.block_grid(spec)
    block = int(spec.skip_block)
    prev = None if prev_eff is None else np.asarray(prev_eff, np.float32)
    packets: list[EventPacket] = []
    for t, frame in enumerate(np.asarray(frames, np.float32)):
        cur = np.asarray(kernels.eff(frame))
        if prev is None:
            changed = signed = None
        else:
            delta = np.asarray(kernels.delta(prev, cur))
            changed = delta > np.float32(threshold)
            signed = _block_reduce_mean(cur - prev, block)
        packets.append(
            _packet(stream_id, first_frame_idx + t, changed, signed,
                    grid_shape, block)
        )
        prev = cur
    return packets
