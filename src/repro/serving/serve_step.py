"""Serving steps: batched prefill and single-token decode (greedy/sampled).

These are the functions the decode/prefill dry-run cells lower: a prefill
step returning (next-token logits, cache), and a decode step consuming and
producing the cache in place (donated in real serving).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import forward_decode, forward_prefill, init_cache

__all__ = ["make_prefill_step", "make_decode_step", "greedy_sample"]


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def make_prefill_step(cfg: ModelConfig, *, max_len: int | None = None, remat: str = "dots"):
    def prefill_step(params, tokens, frontend_embeds=None):
        logits, cache = forward_prefill(
            params, cfg, tokens, frontend_embeds=frontend_embeds,
            max_len=max_len, remat=remat,
        )
        return greedy_sample(logits), logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache, pos):
        logits, cache = forward_decode(params, cfg, token, cache, pos)
        return greedy_sample(logits)[:, None], logits, cache

    return decode_step


def make_empty_cache(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return init_cache(cfg, batch, max_len)
