"""Fleet-scale budget arbitration: one envelope, many streams.

A :class:`repro.serving.streaming.StreamServer` gives every stream its own
PI servo (:class:`repro.serving.control.GateController`) against its own
budget — but a real deployment has one device-seconds/energy envelope for
the whole camera fleet, not one per camera (the system-level accounting of
the P2M tri-design line of work; Neuromorphic-P2M motivates letting busy
scenes borrow budget from static ones).  :class:`FleetController` closes
that gap one layer up:

* **One global budget.**  ``FleetConfig.budget`` is the *summed*
  kept-window (or executed-energy) fraction the fleet may spend per tick —
  e.g. ``budget=0.6`` across four streams averages 15% kept windows each,
  however unevenly arbitration splits it.

* **Priority × activity arbitration.**  Each admitted stream carries a
  priority class and an activity EMA folded from its realised per-tick kept
  fractions (the same numbers :class:`~repro.serving.streaming.StreamStats`
  aggregates fleet-wide).  Every rebalance solves a water-filling split of
  the budget proportional to ``priority * activity``, clamped to
  ``[floor, ceiling]`` per stream, and **pushes each share into that
  stream's PI servo** via :meth:`GateController.retarget` — the servos then
  chase their new targets with their own bounded dynamics (bumpless
  handoff: EMA and integrator state carry over).

* **Re-solve cadence.**  Per-tick serving rebalances every
  ``rebalance_ticks`` observed ticks; device-compiled segment serving
  rebalances at every segment boundary (the only point a traced threshold
  can move anyway).

* **Admission control.**  Every admitted stream reserves at least
  ``floor`` of the budget, so the fleet holds at most
  ``floor(budget / floor)`` streams; past that, :meth:`add_stream` rejects
  (default) or queues the request — :meth:`remove_stream` admits queued
  streams FIFO as capacity frees up.

* **Per-tenant rollups.**  The PR-7 registry carries
  ``fpca_fleet_budget``, per-stream ``fpca_fleet_allocation{stream=}`` /
  ``fpca_fleet_activity{stream=}`` gauges and admission/rebalance counters;
  :func:`repro.serving.observe.fleet_report` renders the same numbers as an
  arbitration table when given the fleet.

Multi-device execution composes underneath, not here: build the pipeline
with ``FPCAPipeline(..., mesh=make_host_mesh(data=N))`` and every fused
union-masked fleet batch shards over the mesh's data axes
(:meth:`repro.fpca.CompiledFrontend.data_parallelism`), while all gate and
arbitration state stays host-local.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Iterator, Mapping

from repro.fpca import telemetry
from repro.serving.streaming import (
    StreamFrameResult,
    StreamServer,
    StreamSession,
    _USE_SERVER,
)

__all__ = ["FleetConfig", "FleetController", "FleetAdmissionError"]

# Fleet observability: the budget is one process-wide cell; allocations and
# activities are labeled per stream (interned at admission — rebalances on
# the serving loop are plain cell writes).
_G_BUDGET = telemetry.registry().gauge(
    "fpca_fleet_budget",
    "global kept-fraction/energy budget (summed over admitted streams)")
_G_ALLOC = telemetry.registry().gauge(
    "fpca_fleet_allocation",
    "per-stream budget share pushed at the last rebalance", ("stream",),
    max_label_sets=256)
_G_ACTIVITY = telemetry.registry().gauge(
    "fpca_fleet_activity",
    "per-stream activity EMA (realised kept-window fraction)", ("stream",),
    max_label_sets=256)
_C_ADMITTED = telemetry.registry().counter(
    "fpca_fleet_admitted_total", "streams admitted into the fleet")
_C_REJECTED = telemetry.registry().counter(
    "fpca_fleet_rejected_total",
    "add_stream requests rejected or queued over budget")
_C_REBALANCES = telemetry.registry().counter(
    "fpca_fleet_rebalances_total", "global budget re-solves pushed to servos")


class FleetAdmissionError(RuntimeError):
    """The fleet is at capacity and ``admission="reject"`` (the default)."""


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs of the global arbiter (see module docstring).

    ``budget`` is the summed per-stream budget-metric envelope;
    ``floor`` / ``ceiling`` bound any single stream's share (the floor is
    also the admission reservation: capacity = ``budget // floor``);
    ``ema_alpha`` weights the newest realised kept fraction in each
    stream's activity EMA; ``rebalance_ticks`` is the per-tick re-solve
    cadence (segment serving re-solves every boundary regardless);
    ``activity_floor`` keeps a momentarily-silent stream's arbitration
    weight positive so it can win budget back the moment its scene wakes.
    """

    budget: float = 0.6
    floor: float = 0.02
    ceiling: float = 0.9
    ema_alpha: float = 0.3
    rebalance_ticks: int = 8
    admission: str = "reject"       # "reject" | "queue"
    activity_floor: float = 1e-3

    def __post_init__(self) -> None:
        if self.budget <= 0.0:
            raise ValueError("budget must be > 0")
        if not 0.0 < self.floor <= self.ceiling <= 1.0:
            raise ValueError("need 0 < floor <= ceiling <= 1")
        if self.floor > self.budget:
            raise ValueError("floor must not exceed the budget")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError("ema_alpha must be in (0, 1]")
        if self.rebalance_ticks < 1:
            raise ValueError("rebalance_ticks must be >= 1")
        if self.admission not in ("reject", "queue"):
            raise ValueError(f"unknown admission policy {self.admission!r}")
        if not 0.0 < self.activity_floor <= 1.0:
            raise ValueError("activity_floor must be in (0, 1]")


@dataclasses.dataclass
class _Member:
    """One admitted stream's arbitration state."""

    stream_id: str
    session: StreamSession
    priority: float
    activity: float | None = None   # EMA of realised kept fraction
    allocation: float = 0.0         # share pushed at the last rebalance
    ticks_observed: int = 0


def _waterfill(
    weights: Mapping[str, float], budget: float, lo: float, hi: float
) -> dict[str, float]:
    """Split ``budget`` proportionally to ``weights`` within ``[lo, hi]``.

    Every key starts at the floor; the remainder is distributed
    weight-proportionally, re-spreading whatever the ceiling claws back
    (classic water-filling — terminates because each pass caps >= 1 key).
    Sums to ``min(budget, n * hi)`` up to float error.
    """
    alloc = {k: lo for k in weights}
    rem = budget - lo * len(weights)
    active = set(weights)
    while rem > 1e-12 and active:
        wsum = sum(weights[k] for k in active)
        capped = [
            k for k in active if alloc[k] + rem * weights[k] / wsum >= hi
        ]
        if not capped:
            for k in active:
                alloc[k] += rem * weights[k] / wsum
            break
        for k in capped:
            rem -= hi - alloc[k]
            alloc[k] = hi
            active.remove(k)
    return alloc


class FleetController:
    """Global budget arbiter over one :class:`StreamServer` (module docstring).

    Streams join through :meth:`add_stream` (admission-controlled) and are
    served through :meth:`run` / :meth:`serve` / :meth:`serve_segments`,
    which fold realised kept fractions into the activity EMAs and re-solve
    the split on cadence.  Driving the underlying server directly still
    works — call :meth:`observe` / :meth:`rebalance` yourself.
    """

    def __init__(self, server: StreamServer, config: FleetConfig | None = None):
        self.server = server
        self.config = config or FleetConfig()
        self._members: dict[str, _Member] = {}
        self._queued: list[tuple[str, Any, dict]] = []
        self.rejections = 0
        self.rebalances = 0
        self._ticks_since_solve = 0
        _G_BUDGET.cell().set(self.config.budget)

    # -- membership ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Streams the budget can hold at the per-stream floor."""
        return int(self.config.budget / self.config.floor + 1e-9)

    @property
    def queued(self) -> tuple[str, ...]:
        """Stream ids waiting for admission (``admission="queue"`` only)."""
        return tuple(sid for sid, _, _ in self._queued)

    def add_stream(
        self,
        stream_id: str,
        config: Any,
        *,
        priority: float = 1.0,
        gate: Any = _USE_SERVER,
        controller: Any = _USE_SERVER,
        events: bool = False,
    ) -> StreamSession | None:
        """Admission-controlled :meth:`StreamServer.add_stream`.

        Over capacity, the request is rejected (:class:`FleetAdmissionError`)
        or — with ``admission="queue"`` — parked and admitted FIFO by
        :meth:`remove_stream`; queued requests return ``None``.  Admitted
        streams must carry a :class:`GateController` (the push target of
        every rebalance), inherit the server default or pass ``controller=``.
        ``events=True`` attaches the server's
        :class:`repro.serving.events.EventTap` on admission (queued requests
        keep the flag and attach when admitted).
        """
        if priority <= 0.0:
            raise ValueError("priority must be > 0")
        if stream_id in self._members:
            raise ValueError(f"stream {stream_id!r} already admitted")
        if len(self._members) >= self.capacity:
            self.rejections += 1
            _C_REJECTED.cell().add(1)
            if self.config.admission == "queue":
                if stream_id not in self.queued:
                    self._queued.append(
                        (stream_id, config,
                         dict(priority=priority, gate=gate,
                              controller=controller, events=events))
                    )
                return None
            raise FleetAdmissionError(
                f"fleet at capacity ({self.capacity} streams x floor "
                f"{self.config.floor} fills budget {self.config.budget}); "
                f"cannot admit {stream_id!r}"
            )
        session = self.server.add_stream(
            stream_id, config, gate=gate, controller=controller, events=events
        )
        if not any(st.controller is not None for st in session._states):
            # roll the attach back — an unservoed stream has no actuator for
            # arbitration to push targets into
            self.server.sessions.pop(stream_id, None)
            self.server.event_taps.pop(stream_id, None)
            self.server._seg_fields.pop(stream_id, None)
            raise ValueError(
                f"fleet stream {stream_id!r} needs a GateController "
                "(give the server a controller= default or pass one here)"
            )
        self._members[stream_id] = _Member(
            stream_id, session, float(priority)
        )
        _C_ADMITTED.cell().add(1)
        self.rebalance()            # the newcomer gets its share immediately
        return session

    def remove_stream(self, stream_id: str) -> list[StreamSession]:
        """Detach a stream, free its share, admit queued streams FIFO.

        Returns the sessions admitted from the queue (empty when none)."""
        if self._members.pop(stream_id, None) is None:
            raise KeyError(f"stream {stream_id!r} is not admitted")
        self.server.sessions.pop(stream_id, None)
        self.server._seg_fields.pop(stream_id, None)
        self.server.event_taps.pop(stream_id, None)
        _G_ALLOC.labels(stream=stream_id).set(0.0)
        _G_ACTIVITY.labels(stream=stream_id).set(0.0)
        admitted: list[StreamSession] = []
        while self._queued and len(self._members) < self.capacity:
            sid, cfg, kw = self._queued.pop(0)
            session = self.add_stream(sid, cfg, **kw)
            if session is not None:
                admitted.append(session)
        if not admitted:
            self.rebalance()
        return admitted

    # -- observation + arbitration -------------------------------------------
    def observe(self, results: Iterable[StreamFrameResult]) -> None:
        """Fold realised results into the activity EMAs (one serve tick).

        The observation is each result's realised kept-window fraction —
        the same per-stream numbers :class:`StreamStats` sums fleet-wide —
        so a busy scene's EMA rises toward 1 and a static scene's decays
        toward its keyframe duty cycle.  Re-solves every
        ``rebalance_ticks`` calls.
        """
        a = self.config.ema_alpha
        seen: set[tuple[str, int]] = set()
        for r in results:
            m = self._members.get(r.stream_id)
            if m is None:
                continue
            kf = r.kept_fraction
            m.activity = (
                kf if m.activity is None
                else a * kf + (1.0 - a) * m.activity
            )
            # one tick per (stream, frame) — a multi-config stream yields a
            # result per config and a segment folds K ticks in one call
            if (r.stream_id, r.frame_idx) not in seen:
                seen.add((r.stream_id, r.frame_idx))
                m.ticks_observed += 1
        if seen:
            self._ticks_since_solve += 1
            if self._ticks_since_solve >= self.config.rebalance_ticks:
                self.rebalance()

    def rebalance(self) -> dict[str, float]:
        """Re-solve the split and push every share into its stream's servo.

        Weights are ``priority * max(activity, activity_floor)``; a stream
        never observed yet weighs in at full activity (its first keyframe
        keeps everything anyway).  Returns ``{stream_id: allocation}``.
        """
        cfg = self.config
        self._ticks_since_solve = 0
        members = list(self._members.values())
        if not members:
            return {}
        weights = {
            m.stream_id: m.priority * max(
                m.activity if m.activity is not None else 1.0,
                cfg.activity_floor,
            )
            for m in members
        }
        alloc = _waterfill(weights, cfg.budget, cfg.floor, cfg.ceiling)
        for m in members:
            share = alloc[m.stream_id]
            m.allocation = share
            for st in m.session._states:
                if st.controller is not None:
                    st.controller.retarget(share)
            _G_ALLOC.labels(stream=m.stream_id).set(share)
            _G_ACTIVITY.labels(stream=m.stream_id).set(
                m.activity if m.activity is not None else 0.0
            )
        self.rebalances += 1
        _C_REBALANCES.cell().add(1)
        if telemetry.enabled():
            telemetry.event(
                "fleet_rebalance", budget=cfg.budget,
                allocations={k: round(v, 6) for k, v in alloc.items()},
            )
        return alloc

    # -- serving wrappers ----------------------------------------------------
    def run(
        self, ticks: Iterable[Mapping[str, Any]]
    ) -> Iterator[list[StreamFrameResult]]:
        """:meth:`StreamServer.run` with arbitration in the loop: every
        realised tick feeds :meth:`observe` (which re-solves on cadence)."""
        for results in self.server.run(ticks):
            self.observe(results)
            yield results

    def serve(
        self, stream_id: str, frames: Iterable[Any]
    ) -> Iterator[StreamFrameResult]:
        """Single-stream convenience twin of :meth:`StreamServer.serve`."""
        for results in self.run({stream_id: f} for f in frames):
            yield from results

    def run_segment(
        self, stream_id: str, frames: Any, **kwargs
    ) -> list[StreamFrameResult]:
        """One device-compiled segment, then a boundary re-solve — the
        segment boundary is the only point a traced threshold can move, so
        arbitration always re-solves there."""
        results = self.server.run_segment(stream_id, frames, **kwargs)
        self.observe(results)
        self.rebalance()
        return results

    def serve_segments(
        self, stream_id: str, frames: Iterable[Any], **kwargs
    ) -> Iterator[StreamFrameResult]:
        """Segment-mode twin of :meth:`serve` (re-solves every boundary)."""

        def _boundary(results: list[StreamFrameResult]) -> None:
            self.observe(results)
            self.rebalance()

        yield from self.server.serve_segments(
            stream_id, frames, on_segment=_boundary, **kwargs
        )

    # -- reporting -----------------------------------------------------------
    def arbitration_table(self) -> dict:
        """Strict-JSON-able arbitration state — what
        :func:`repro.serving.observe.fleet_report` embeds.
        """
        rows = []
        for m in self._members.values():
            ctl = m.session.controller
            rows.append({
                "stream": m.stream_id,
                "priority": m.priority,
                "activity": m.activity,
                "allocation": m.allocation,
                "target": None if ctl is None else ctl.config.target,
                "threshold": None if ctl is None else ctl.threshold,
                "ticks_observed": m.ticks_observed,
            })
        return telemetry.jsonable({
            "budget": self.config.budget,
            "allocated": sum(m.allocation for m in self._members.values()),
            "capacity": self.capacity,
            "admitted": len(self._members),
            "queued": list(self.queued),
            "rejections": self.rejections,
            "rebalances": self.rebalances,
            "streams": rows,
        })
