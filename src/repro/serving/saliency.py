"""Content-driven block saliency for region skipping (paper §3.4.5).

Library home of the cheap host-side saliency pass the examples used to carry
inline: pick the ``skip_block``-sized blocks whose content is worth reading
and hand the keep grid to the frontend (post-hoc for the dense reference,
compacted in-kernel for the fused serving path).

For *streaming* workloads the temporal delta gate in
:mod:`repro.serving.streaming` supersedes this — saliency needs the full
frame it is trying to avoid reading, while the delta gate only needs the
previous frame's block statistics.  Saliency remains the right tool for
single-shot inference where a low-resolution preview exposure is available.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core import mapping

__all__ = ["saliency_mask"]


def saliency_mask(
    image: np.ndarray,
    spec: mapping.FPCASpec,
    keep_frac: float = 0.4,
) -> np.ndarray:
    """Block-wise brightness variance -> keep the liveliest blocks.

    Operates on the *effective* (binned) frame so the grid matches the
    periphery SRAM layout :func:`repro.core.mapping.active_window_mask`
    expects: boolean ``(ceil(eff_h/B), ceil(eff_w/B))``, True = keep.
    """
    if not 0.0 < keep_frac <= 1.0:
        raise ValueError("keep_frac must be in (0, 1]")
    img = np.asarray(image, np.float32)
    bf = spec.binning
    if bf > 1:
        h, w, c = img.shape
        img = (
            img[: h // bf * bf, : w // bf * bf]
            .reshape(h // bf, bf, w // bf, bf, c)
            .mean((1, 3))
        )
    b = spec.skip_block
    h, w, c = img.shape
    bh, bw = math.ceil(h / b), math.ceil(w / b)
    var = np.zeros((bh, bw), np.float32)
    for r in range(bh):
        for cc in range(bw):
            var[r, cc] = img[r * b : (r + 1) * b, cc * b : (cc + 1) * b].var()
    k = max(1, int(keep_frac * var.size))
    thresh = np.partition(var.ravel(), -k)[-k]
    return var >= thresh
