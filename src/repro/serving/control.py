"""Adaptive streaming control plane: the keep-fraction / energy servo.

The FPCA's point is *field*-programmability — §3.4.5 region skipping and the
delta gate of :mod:`repro.serving.streaming` only become deployable once the
gate threshold stops being a magic constant.  A sensor in the field must hold
a frame-rate / energy budget while the scene changes under it (the servoed
compute budget of the PPA line of work: Bose et al. 2019, Kaiser et al.
2023).  This module closes that loop:

* :class:`GateController` servos a stream's ``DeltaGateConfig.threshold``
  against a **target kept-window fraction** (or executed-energy fraction)
  per tick.  Each non-keyframe tick it observes the executed-window stats of
  the latest gate mask — the kept fraction straight from the window keep
  grid (bit-identical to
  :func:`repro.core.analysis.streaming_frontend_report`'s
  ``kept_window_frac``, minus its dense-baseline work), or
  ``energy_vs_dense`` through that full report for the energy metric —
  folds them into an EMA, and applies a proportional–integral step to the
  threshold **in log space** (the block-delta statistics span decades;
  multiplicative steps behave the same at 1e-3 as at 1e-1).

* The step is **bounded** (``max_step`` nats per tick) and the threshold is
  clamped to ``[min_threshold, max_threshold]``; the integrator uses
  conditional **anti-windup** — it only accumulates while the actuator is
  unsaturated, so a long stretch pinned at a bound (e.g. an empty scene that
  can never reach the budget) does not wind up error that would overshoot for
  seconds once the scene wakes up.

* **Keyframe ticks are held out**: a keyframe keeps every block by
  construction, so its kept fraction says nothing about the threshold.  The
  controller records the tick in its history but neither updates the EMA nor
  moves the threshold.

Wiring: :class:`repro.serving.streaming.StreamServer` instantiates one
controller per stream when given a :class:`GateControllerConfig`; each
:class:`~repro.serving.streaming.StreamSession` then re-derives its own
``DeltaGateConfig`` after every frame, so many cameras on one server converge
independently to the shared budget.
"""

from __future__ import annotations

import collections
import dataclasses
import math

import numpy as np

from repro.core import analysis, mapping
from repro.fpca import telemetry
from repro.fpca.program import GateControllerConfig

__all__ = ["GateControllerConfig", "GateController"]

# Servo observability: one labeled cell per controller, interned once at
# construction so the per-tick updates are plain attribute writes (no dict
# churn on the serving hot loop).
_G_THRESHOLD = telemetry.registry().gauge(
    "fpca_gate_threshold", "current delta-gate threshold per servo",
    ("controller",), max_label_sets=128)
_G_EMA = telemetry.registry().gauge(
    "fpca_gate_ema", "budget-metric EMA per servo", ("controller",),
    max_label_sets=128)
_G_ERR = telemetry.registry().gauge(
    "fpca_gate_servo_error", "last relative budget error per servo",
    ("controller",), max_label_sets=128)
_C_ACTUATIONS = telemetry.registry().counter(
    "fpca_gate_actuations_total", "bounded PI steps applied per servo",
    ("controller",), max_label_sets=128)


class GateController:
    """Per-stream PI servo on the delta-gate threshold (see module docstring).

    Call :meth:`observe` once per gated tick with that tick's block keep
    mask; it returns the threshold the *next* tick should gate with.  The
    trajectory is kept in :attr:`history` (one dict per tick, bounded to the
    last ``history_len`` ticks so a long-running stream does not leak) so
    benchmarks and tests can audit convergence.
    """

    def __init__(
        self,
        config: GateControllerConfig,
        spec: mapping.FPCASpec,
        threshold: float,
        const: analysis.FrontendConstants | None = None,
        name: str = "",
    ):
        self.config = config
        self.spec = spec
        self.const = const or analysis.FrontendConstants()
        self.name = name or telemetry.registry().next_instance("gate")
        self._g_thr = _G_THRESHOLD.labels(controller=self.name)
        self._g_ema = _G_EMA.labels(controller=self.name)
        self._g_err = _G_ERR.labels(controller=self.name)
        self._c_act = _C_ACTUATIONS.labels(controller=self.name)
        self.threshold = float(
            np.clip(threshold, config.min_threshold, config.max_threshold)
        )
        self._g_thr.set(self.threshold)
        self._log_thr = math.log(self.threshold)
        # dense baseline depends only on (spec, const): pay it once, not
        # per tick on the serving hot loop
        self._dense_e = analysis.frontend_energy(spec, self.const)["e_total"]
        self._ema: float | None = None
        self._integral = 0.0
        self._tick = 0
        self.history: collections.deque[dict] = collections.deque(
            maxlen=config.history_len
        )

    @property
    def ema(self) -> float | None:
        """Current budget-metric EMA (None until the first non-keyframe tick)."""
        return self._ema

    def converged_tick(self, rel_tol: float = 0.2) -> int | None:
        """First tick from which the EMA stays within ``±rel_tol`` of the
        target for the rest of the *retained* history (None = never settled)."""
        lo = self.config.target * (1.0 - rel_tol)
        hi = self.config.target * (1.0 + rel_tol)
        settled: int | None = None
        for h in self.history:
            if h["ema"] is not None and lo <= h["ema"] <= hi:
                if settled is None:
                    settled = h["tick"]
            else:
                settled = None
        return settled

    def _observation(self, block_mask: np.ndarray) -> float:
        if self.config.metric == "keep":
            # identical to streaming_frontend_report's kept_window_frac for
            # a single mask, without the dense-baseline / cycle-schedule
            # work — this runs on the host side of the serving hot loop
            return float(mapping.active_window_mask(self.spec, block_mask).mean())
        # identical to streaming_frontend_report's energy_vs_dense for a
        # single mask, with the constant dense baseline hoisted to __init__
        e = analysis.frontend_energy(self.spec, self.const, block_mask=block_mask)
        return float(e["e_total"] / self._dense_e)

    def observe(
        self,
        block_mask: np.ndarray,
        *,
        keyframe: bool = False,
        observation: float | None = None,
    ) -> float:
        """Fold one tick's gate mask into the servo; returns the new threshold.

        Keyframe ticks (mask keeps everything by construction) are recorded
        but do not move the EMA or the threshold.  ``observation`` lets a
        caller that already derived this tick's budget metric (the streaming
        server computes the window keep grid anyway) pass it in instead of
        having it re-derived from ``block_mask``.
        """
        cfg = self.config
        observed: float | None = None
        if not keyframe:
            observed = (
                observation if observation is not None
                else self._observation(block_mask)
            )
            self._ema = (
                observed
                if self._ema is None
                else cfg.ema_alpha * observed + (1.0 - cfg.ema_alpha) * self._ema
            )
            err = float(
                np.clip(
                    (self._ema - cfg.target) / cfg.target, cfg.err_low, cfg.err_high
                )
            )
            self._g_ema.set(self._ema)
            self._g_err.set(err)
            if abs(err) > cfg.deadband:
                self._actuate(err)
        self.history.append(
            {
                "tick": self._tick,
                "threshold": self.threshold,
                "observed": observed,
                "ema": self._ema,
                "keyframe": keyframe,
            }
        )
        self._tick += 1
        return self.threshold

    def _actuate(self, err: float) -> None:
        """One bounded PI step on the log-threshold (anti-windup as in
        :meth:`observe` — the integrator freezes while saturated)."""
        cfg = self.config
        u = cfg.kp * err + cfg.ki * self._integral
        step = float(np.clip(u, -cfg.max_step, cfg.max_step))
        new_log = float(
            np.clip(
                self._log_thr + step,
                math.log(cfg.min_threshold),
                math.log(cfg.max_threshold),
            )
        )
        saturated = (step != u) or (new_log != self._log_thr + step)
        self._integral = float(
            np.clip(
                cfg.leak * self._integral + (0.0 if saturated else err),
                -cfg.windup,
                cfg.windup,
            )
        )
        self._log_thr = new_log
        self.threshold = math.exp(new_log)
        self._c_act.add(1)
        self._g_thr.set(self.threshold)
        if telemetry.enabled():
            telemetry.event(
                "servo_actuate", controller=self.name, tick=self._tick,
                err=err, step=step, saturated=saturated,
                threshold=self.threshold, ema=self._ema,
            )

    def retarget(self, target: float) -> None:
        """Re-point the servo at a new budget (fleet arbitration pushes a
        fresh per-stream target at every rebalance).  EMA, integrator and
        history carry over, so the handoff is bumpless — the next
        observation simply servos toward the new target."""
        target = float(target)
        if target != self.config.target:
            # dataclasses.replace re-runs GateControllerConfig validation
            self.config = dataclasses.replace(self.config, target=target)
            if telemetry.enabled():
                telemetry.event(
                    "servo_retarget", controller=self.name,
                    tick=self._tick, target=target,
                )

    def observe_segment(
        self,
        block_masks: "np.ndarray | list",
        *,
        keyframes: "np.ndarray | list | None" = None,
        observations: "list[float | None] | None" = None,
    ) -> float:
        """Fold one device-compiled segment's per-tick gate masks into the
        servo; returns the threshold the *next segment* should gate with.

        A compiled segment serves K ticks from one launch, so the per-tick
        actuation of :meth:`observe` cannot run — the threshold is traced
        into the scan and constant for the whole segment.  This boundary
        variant keeps the EMA per-tick honest (each non-keyframe tick folds
        its own observation, keyframes held out exactly as in per-tick
        serving, all ticks recorded in :attr:`history` at the segment's
        constant threshold) and applies ONE bounded PI step at the end — so
        a K-tick segment moves the threshold at most ``max_step`` nats, the
        same actuation bound a single per-tick observation gets.
        """
        cfg = self.config
        n = len(block_masks)
        if n == 0:
            # zero-tick segment (early-exit fired before serving anything):
            # no observation was made, so neither fold the (possibly stale)
            # EMA nor spend this boundary's actuation on it — the threshold
            # must be exactly what the last real observation left it at
            return self.threshold
        for i in range(n):
            kf = bool(keyframes[i]) if keyframes is not None else False
            observed: float | None = None
            if not kf:
                obs = observations[i] if observations is not None else None
                observed = (
                    obs if obs is not None
                    else self._observation(np.asarray(block_masks[i]))
                )
                self._ema = (
                    observed
                    if self._ema is None
                    else cfg.ema_alpha * observed
                    + (1.0 - cfg.ema_alpha) * self._ema
                )
            self.history.append(
                {
                    "tick": self._tick,
                    "threshold": self.threshold,
                    "observed": observed,
                    "ema": self._ema,
                    "keyframe": kf,
                }
            )
            self._tick += 1
        if self._ema is not None:
            err = float(
                np.clip(
                    (self._ema - cfg.target) / cfg.target,
                    cfg.err_low,
                    cfg.err_high,
                )
            )
            self._g_ema.set(self._ema)
            self._g_err.set(err)
            if abs(err) > cfg.deadband:
                self._actuate(err)
        return self.threshold
