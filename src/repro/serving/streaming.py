"""Streaming video frontend: temporal delta-gated region skipping with an
async double-buffered serving loop.

The paper's extreme-edge scenario is a sensor *watching a scene*, not a batch
oracle: §3.4.5's region skipping only pays off when the block keep/skip masks
are derived frame-to-frame.  This module closes that loop:

* :class:`StreamSession` holds per-stream state — the previous (effective)
  frame, the per-block change ages, and the registered
  :class:`~repro.serving.fpca_pipeline.FrontendConfig` it is programmed
  against.  Each frame steps a **temporal delta gate**
  (:func:`block_delta_mask`): per-``skip_block`` change detection against the
  previous frame, with hysteresis (a changed block stays live for a few
  frames, riding out sensor noise and slow motion) and periodic keyframe
  refresh (a full readout every ``keyframe_interval`` frames bounds drift).

* The resulting block mask is pushed *into the compute*: it becomes the
  per-window keep mask that the fused kernel path compacts on
  (:mod:`repro.kernels.fpca_conv`), so skipped windows never execute — the
  savings §3.4.5 accounts analytically become real executed-window savings.

* :class:`StreamServer` drives everything through an **async double-buffered
  loop**: jax dispatch is non-blocking, so the host-side work for frame
  ``t+1`` (window extraction geometry, delta gating, mask building) overlaps
  device compute for frame ``t``; a two-slot in-flight buffer (``depth``)
  bounds queue growth, and results are realised — and yielded — strictly in
  frame order.  Multiple streams (many cameras) registered on the same
  configuration fan into ONE device batch per tick, reusing the pipeline's
  LRU executable cache and mesh sharding.

Adaptive control plane (the deployment loop on top):

* **Keep-fraction servo** — pass a
  :class:`~repro.serving.control.GateControllerConfig` and every stream gets
  its own :class:`~repro.serving.control.GateController`, closed-loop
  servoing its gate threshold against a kept-fraction / energy budget from
  the executed-window stats of each tick (EMA + bounded PI step in log
  space, anti-windup; keyframe ticks held out).

* **Multi-config fan-out** — a stream may be attached to *several*
  registered configurations sharing one spec
  (``add_stream(sid, ("edges", "blobs"))``); each tick gates the frame once
  and serves every configuration through ONE channel-stacked fused call
  (:meth:`FPCAPipeline.run_config_batch` with a name list), yielding one
  :class:`StreamFrameResult` per (stream, config).

* **Sticky buckets** — the pipeline's ``bucket_patience`` keeps the
  compacted row bucket from flapping between power-of-two neighbours on
  busy scenes; the server mirrors the switch counters into
  :class:`StreamStats`.

Bit-exactness contract: kept-window activations are identical to a dense
readout (the dense reference in :mod:`repro.core.fpca_sim` is the oracle);
skipped windows read as exact zeros.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Iterable, Iterator, Mapping, Sequence

import jax
import numpy as np

from repro.core import analysis, mapping
from repro.serving.control import GateController, GateControllerConfig
from repro.serving.fpca_pipeline import FPCAPipeline

__all__ = [
    "DeltaGateConfig",
    "GateController",
    "GateControllerConfig",
    "StreamSession",
    "StreamFrameResult",
    "StreamServer",
    "block_delta_mask",
]


@dataclasses.dataclass(frozen=True)
class DeltaGateConfig:
    """Temporal delta gate knobs (per-stream)."""

    threshold: float = 0.02      # mean |Δ| per block that counts as "changed"
    hysteresis: int = 1          # frames a block stays live after its change
    keyframe_interval: int = 30  # full-frame refresh period (0 = never)


def _effective_frame(frame: np.ndarray, spec: mapping.FPCASpec) -> np.ndarray:
    """Frame as the pixel array sees it: binned (average pool) grayscale."""
    img = np.asarray(frame, np.float32).mean(axis=-1)
    b = spec.binning
    if b > 1:
        h, w = img.shape
        img = img[: h // b * b, : w // b * b].reshape(h // b, b, w // b, b).mean((1, 3))
    return img


def _block_reduce_mean(x: np.ndarray, block: int) -> np.ndarray:
    """Mean over ``block x block`` tiles (ragged edge tiles average their
    real pixels only), shape ``(ceil(h/b), ceil(w/b))``."""
    h, w = x.shape
    bh, bw = math.ceil(h / block), math.ceil(w / block)
    padded = np.zeros((bh * block, bw * block), x.dtype)
    padded[:h, :w] = x
    sums = padded.reshape(bh, block, bw, block).sum((1, 3))
    ones = np.zeros((bh * block, bw * block), np.float32)
    ones[:h, :w] = 1.0
    counts = ones.reshape(bh, block, bw, block).sum((1, 3))
    return sums / counts


def block_delta_mask(
    prev_eff: np.ndarray,
    cur_eff: np.ndarray,
    spec: mapping.FPCASpec,
    threshold: float,
) -> np.ndarray:
    """Per-block change detection between two *effective* (binned) frames.

    Returns the boolean ``(ceil(eff_h/B), ceil(eff_w/B))`` grid the periphery
    SRAM would hold (True = block changed beyond ``threshold`` mean absolute
    intensity) — the shape :func:`repro.core.mapping.active_window_mask`
    consumes.
    """
    delta = np.abs(cur_eff - prev_eff)
    return _block_reduce_mean(delta, spec.skip_block) > threshold


class StreamSession:
    """Per-stream state: previous frame, block ages, programmed config(s).

    ``config`` may be one registered configuration name or a sequence of
    names sharing one spec (multi-config fan-out); :attr:`configs` always
    holds the normalised tuple and :attr:`config` the primary name.  With a
    ``controller``, every gated frame feeds the closed-loop threshold servo
    and the session's :attr:`gate` is re-derived for the next frame.
    """

    def __init__(
        self,
        stream_id: str,
        config: str | Sequence[str],
        spec: mapping.FPCASpec,
        gate: DeltaGateConfig | None,
        history: int = 512,
        controller: GateController | None = None,
    ):
        self.stream_id = stream_id
        self.configs: tuple[str, ...] = (
            (config,) if isinstance(config, str) else tuple(config)
        )
        if not self.configs:
            raise ValueError("need at least one config name")
        self.spec = spec
        self.gate = gate                       # None = gating off (dense)
        self.controller = controller if gate is not None else None
        self.frame_idx = 0
        self.last_keyframe = False
        self.last_window_mask: np.ndarray | None = None
        self._prev: np.ndarray | None = None
        bh = math.ceil(spec.eff_h / spec.skip_block)
        bw = math.ceil(spec.eff_w / spec.skip_block)
        stale = (gate.hysteresis + 1) if gate else 0
        self._age = np.full((bh, bw), stale, np.int64)
        # gate history for energy accounting, bounded so a long-running
        # stream does not leak (the report covers the retained window)
        self.block_masks: collections.deque[np.ndarray] = collections.deque(
            maxlen=history
        )

    @property
    def config(self) -> str:
        """Primary configuration name (first of :attr:`configs`)."""
        return self.configs[0]

    def step(self, frame: np.ndarray) -> np.ndarray | None:
        """Advance one frame; returns the block keep mask (None = dense).

        A block is kept iff it changed within the last ``hysteresis + 1``
        frames; keyframes (the first frame, then every ``keyframe_interval``)
        keep everything but do NOT reset the ages — a static scene goes quiet
        again immediately after the refresh.  With a controller attached, the
        mask also feeds the threshold servo, so the NEXT frame gates with the
        servoed threshold.
        """
        if self.gate is None:
            self.frame_idx += 1
            return None
        cur = _effective_frame(frame, self.spec)
        if self._prev is not None:
            changed = block_delta_mask(self._prev, cur, self.spec, self.gate.threshold)
            self._age = np.where(changed, 0, self._age + 1)
        keyframe = self._prev is None or (
            self.gate.keyframe_interval > 0
            and self.frame_idx % self.gate.keyframe_interval == 0
        )
        keep = (
            np.ones_like(self._age, bool)
            if keyframe
            else self._age <= self.gate.hysteresis
        )
        self._prev = cur
        self.frame_idx += 1
        self.last_keyframe = keyframe
        self.block_masks.append(keep)
        # derive the per-window keep grid ONCE per frame: the dispatch loop
        # reuses it (last_window_mask) and the keep-metric servo observes its
        # mean instead of re-deriving it
        window = mapping.active_window_mask(self.spec, keep)
        self.last_window_mask = window
        if self.controller is not None:
            obs = (
                float(window.mean())
                if self.controller.config.metric == "keep"
                else None
            )
            new_thr = self.controller.observe(
                keep, keyframe=keyframe, observation=obs
            )
            if new_thr != self.gate.threshold:
                self.gate = dataclasses.replace(self.gate, threshold=new_thr)
        return keep

    def energy_report(self, const: analysis.FrontendConstants | None = None) -> dict:
        """Executed-window energy/cycle accounting over the retained gate
        history (the last ``history`` frames)."""
        return analysis.streaming_frontend_report(
            self.spec, list(self.block_masks), const or analysis.FrontendConstants()
        )


@dataclasses.dataclass
class StreamFrameResult:
    """One (stream, config)'s activations for one tick of the serving loop.

    Single-config streams yield one result per tick; a multi-config stream
    yields one per fanned-out configuration (same ``frame_idx`` and
    ``block_mask``, per-config ``counts``), distinguished by ``config``.
    """

    stream_id: str
    frame_idx: int
    counts: np.ndarray              # (h_o, w_o, c_o) SS-ADC counts
    block_mask: np.ndarray | None   # gate output (None = dense readout)
    kept_windows: int
    total_windows: int
    config: str = ""                # configuration these counts belong to

    @property
    def kept_fraction(self) -> float:
        return self.kept_windows / max(self.total_windows, 1)


@dataclasses.dataclass
class StreamStats:
    ticks: int = 0
    frames: int = 0
    windows_total: int = 0
    windows_kept: int = 0           # logical kept windows (pre-bucket-pad)
    launches_skipped: int = 0       # all-skipped ticks (no kernel launch)
    bucket_switches: int = 0        # served bucket-size transitions
    bucket_shrinks_deferred: int = 0  # flap events sticky hysteresis absorbed


class StreamServer:
    """Async double-buffered multi-stream driver over :class:`FPCAPipeline`.

    Args:
      pipeline: the serving pipeline whose registered configurations,
        executable cache and mesh sharding this server reuses.
      gate: delta-gate configuration applied to every stream; pass
        ``gating=False`` for a dense baseline server (no skipping — what the
        benchmark compares against).  With a ``controller``, this is only the
        *initial* gate — each stream's threshold is then servoed
        independently.
      controller: optional :class:`GateControllerConfig`; every stream added
        afterwards gets its own :class:`GateController` closed-loop servoing
        the gate threshold against the configured budget.
      depth: maximum in-flight ticks.  ``2`` is classic double buffering:
        while the device chews on tick ``t``, the host gates and batches tick
        ``t+1``; results for ``t`` are realised only when ``t+2`` is about to
        dispatch.
    """

    def __init__(
        self,
        pipeline: FPCAPipeline,
        gate: DeltaGateConfig = DeltaGateConfig(),
        *,
        depth: int = 2,
        gating: bool = True,
        controller: GateControllerConfig | None = None,
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.pipeline = pipeline
        self.gate = gate if gating else None
        self.controller = controller if gating else None
        self.depth = depth
        self.sessions: dict[str, StreamSession] = {}
        self.stats = StreamStats()

    def add_stream(
        self, stream_id: str, config: str | Sequence[str]
    ) -> StreamSession:
        """Attach a camera stream to registered pipeline configuration(s).

        A sequence of names fans the stream out to several programmed
        configurations sharing one spec: each tick is gated once and served
        through one channel-stacked fused call, yielding one
        :class:`StreamFrameResult` per configuration.
        """
        if stream_id in self.sessions:
            raise ValueError(f"stream {stream_id!r} already attached")
        names = (config,) if isinstance(config, str) else tuple(config)
        cfgs = []
        for n in names:
            cfg = self.pipeline._configs.get(n)
            if cfg is None:
                raise KeyError(f"unknown config {n!r}")
            cfgs.append(cfg)
        spec = cfgs[0].spec
        for cfg in cfgs[1:]:
            if cfg.spec != spec:
                raise ValueError(
                    f"multi-config stream needs a shared spec: config "
                    f"{cfg.name!r} differs from {cfgs[0].name!r}"
                )
        ctl = (
            GateController(self.controller, spec, self.gate.threshold)
            if (self.controller is not None and self.gate is not None)
            else None
        )
        session = StreamSession(stream_id, names, spec, self.gate, controller=ctl)
        self.sessions[stream_id] = session
        return session

    # -- serving loop --------------------------------------------------------
    def _dispatch(self, frames: Mapping[str, Any]) -> list[dict]:
        """Host side of one tick: gate every stream, fan streams into one
        batch per configuration group, dispatch without blocking."""
        per_group: dict[tuple[str, ...], list[tuple[StreamSession, np.ndarray]]] = {}
        for stream_id, frame in frames.items():
            session = self.sessions.get(stream_id)
            if session is None:
                raise KeyError(f"unknown stream {stream_id!r}")
            per_group.setdefault(session.configs, []).append(
                (session, np.asarray(frame, np.float32))
            )
        pstats = self.pipeline.stats
        before = (
            pstats.bucket_switches,
            pstats.bucket_shrinks_deferred,
            pstats.launches_skipped,
        )
        launches: list[dict] = []
        for configs, members in per_group.items():
            spec = members[0][0].spec
            h_o, w_o = mapping.output_dims(spec)
            entries = []
            keeps = []
            gated = self.gate is not None
            for session, frame in members:
                frame_idx = session.frame_idx
                block = session.step(frame)
                window = session.last_window_mask if gated else None
                kept = int(window.sum()) if window is not None else h_o * w_o
                entries.append(
                    {
                        "stream_id": session.stream_id,
                        "frame_idx": frame_idx,
                        "block_mask": block,
                        "kept": kept,
                        "total": h_o * w_o,
                    }
                )
                if gated:
                    keeps.append(window)
                self.stats.frames += 1
                self.stats.windows_total += h_o * w_o
                self.stats.windows_kept += kept
            images = np.stack([frame for _, frame in members])
            counts = self.pipeline.run_config_batch(
                configs[0] if len(configs) == 1 else list(configs),
                images,
                np.stack(keeps) if gated else None,
            )
            slices = (
                self.pipeline.config_channel_slices(configs)
                if len(configs) > 1
                else [(configs[0], None, None)]
            )
            launches.append({"counts": counts, "entries": entries, "slices": slices})
        self.stats.bucket_switches += pstats.bucket_switches - before[0]
        self.stats.bucket_shrinks_deferred += pstats.bucket_shrinks_deferred - before[1]
        self.stats.launches_skipped += pstats.launches_skipped - before[2]
        return launches

    def _finalize(self, launches: list[dict]) -> list[StreamFrameResult]:
        """Device side of one tick: realise the batch (blocks) and unpack."""
        results: list[StreamFrameResult] = []
        for launch in launches:
            counts = np.asarray(launch["counts"])     # blocks until ready
            for row, e in enumerate(launch["entries"]):
                for name, lo, hi in launch["slices"]:
                    results.append(
                        StreamFrameResult(
                            stream_id=e["stream_id"],
                            frame_idx=e["frame_idx"],
                            counts=counts[row] if lo is None else counts[row, ..., lo:hi],
                            block_mask=e["block_mask"],
                            kept_windows=e["kept"],
                            total_windows=e["total"],
                            config=name,
                        )
                    )
        return results

    def run(
        self, ticks: Iterable[Mapping[str, Any]]
    ) -> Iterator[list[StreamFrameResult]]:
        """Serve a stream of ticks; yields one result list per tick, in order.

        Each tick maps ``stream_id -> frame``.  Up to ``depth`` ticks are in
        flight at once: dispatch is non-blocking (jax async), so tick ``t``'s
        device compute overlaps tick ``t+1``'s host gating/batching; results
        are realised oldest-first, preserving frame order per stream.
        """
        inflight: collections.deque[list[dict]] = collections.deque()
        for frames in ticks:
            inflight.append(self._dispatch(frames))
            self.stats.ticks += 1
            while len(inflight) > self.depth:
                yield self._finalize(inflight.popleft())
        while inflight:
            yield self._finalize(inflight.popleft())

    def serve(self, stream_id: str, frames: Iterable[Any]) -> Iterator[StreamFrameResult]:
        """Single-stream convenience wrapper around :meth:`run`.

        Yields one result per tick for a single-config stream; a
        multi-config stream yields its per-config results back to back
        (same ``frame_idx``, distinguished by ``result.config``).
        """
        for results in self.run({stream_id: f} for f in frames):
            yield from results
