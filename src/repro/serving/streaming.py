"""Streaming video frontend: temporal delta-gated region skipping with an
async double-buffered serving loop.

The paper's extreme-edge scenario is a sensor *watching a scene*, not a batch
oracle: §3.4.5's region skipping only pays off when the block keep/skip masks
are derived frame-to-frame.  This module closes that loop:

* :class:`StreamSession` holds per-stream state — the previous (effective)
  frame, the per-block change ages, and the registered configuration(s) it
  is programmed against.  Each frame steps a **temporal delta gate**
  (:func:`block_delta_mask`): per-``skip_block`` change detection against the
  previous frame, with hysteresis (a changed block stays live for a few
  frames, riding out sensor noise and slow motion) and periodic keyframe
  refresh (a full readout every ``keyframe_interval`` frames bounds drift).

* The resulting block mask is pushed *into the compute*: it becomes the
  per-window keep mask the fused kernel path compacts on (behind
  :class:`repro.fpca.CompiledFrontend`), so skipped windows never execute —
  the savings §3.4.5 accounts analytically become real executed-window
  savings.

* :class:`StreamServer` drives everything through an **async double-buffered
  loop**: jax dispatch is non-blocking, so the host-side work for frame
  ``t+1`` (window extraction geometry, delta gating, mask building) overlaps
  device compute for frame ``t``; a two-slot in-flight buffer (``depth``)
  bounds queue growth, and results are realised — and yielded — strictly in
  frame order.  Multiple streams (many cameras) registered on the same
  configuration fan into ONE device batch per tick, reusing the pipeline's
  shared executable cache and mesh sharding.

Adaptive control plane (the deployment loop on top):

* **Keep-fraction / energy servo** — pass a
  :class:`~repro.fpca.GateControllerConfig` and every stream gets its own
  :class:`~repro.serving.control.GateController`, closed-loop servoing its
  gate threshold against a kept-fraction / energy budget from the
  executed-window stats of each tick (EMA + bounded PI step in log space,
  anti-windup; keyframe ticks held out).

* **Multi-config fan-out** — a stream may be attached to *several*
  registered configurations sharing one spec
  (``add_stream(sid, ("edges", "blobs"))``); each tick gates the frame and
  serves every configuration through ONE channel-stacked fused call
  (:meth:`FPCAPipeline.run_config_batch` with a name list), yielding one
  :class:`StreamFrameResult` per (stream, config).

* **Per-config gate thresholds** — a multi-config stream may give each
  configuration its OWN delta gate (and its own servo):
  ``add_stream(sid, ("A", "B"), gate={"A": DeltaGateConfig(...), "B": ...})``.
  Each config keeps independent block ages / thresholds / controllers; the
  fused call executes the **union** of the per-config window masks (still
  one launch), and each config's channel slice is masked back to exactly its
  own keep decision — bit-identical to serving that config alone with that
  gate.

* **Sticky buckets** — the pipeline's ``bucket_patience`` keeps the
  compacted row bucket from flapping between power-of-two neighbours on
  busy scenes; the server mirrors the switch counters into
  :class:`StreamStats`.

Bit-exactness contract: kept-window activations are identical to a dense
readout (the dense reference in :mod:`repro.core.fpca_sim` is the oracle);
skipped windows read as exact zeros.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Iterable, Iterator, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, gating, mapping
from repro.fpca import telemetry
from repro.fpca.program import (
    DeltaGateConfig,
    GateControllerConfig,
    ProgrammedModel,
)
from repro.models.heads import Detections
from repro.serving.control import GateController
from repro.serving.fpca_pipeline import FPCAPipeline

__all__ = [
    "DeltaGateConfig",
    "GateController",
    "GateControllerConfig",
    "StreamSession",
    "StreamFrameResult",
    "StreamServer",
    "block_delta",
    "block_delta_mask",
]

_USE_SERVER = object()   # add_stream sentinel: "inherit the server default"


def _effective_frame(frame: np.ndarray, spec: mapping.FPCASpec) -> np.ndarray:
    """Frame as the pixel array sees it: binned (average pool) grayscale.

    Evaluated through the jitted :mod:`repro.core.gating` kernel — the SAME
    jnp numerics the device-compiled segment executor inlines into its scan —
    so host and device gate decisions compare identical float32 bits (the
    segment parity contract)."""
    kernels = gating.host_gate_kernels(spec)
    return np.asarray(kernels.eff(np.asarray(frame, np.float32)))


def _block_reduce_mean(x: np.ndarray, block: int) -> np.ndarray:
    """Mean over ``block x block`` tiles (ragged edge tiles average their
    real pixels only), shape ``(ceil(h/b), ceil(w/b))``."""
    h, w = x.shape
    bh, bw = math.ceil(h / block), math.ceil(w / block)
    padded = np.zeros((bh * block, bw * block), x.dtype)
    padded[:h, :w] = x
    sums = padded.reshape(bh, block, bw, block).sum((1, 3))
    ones = np.zeros((bh * block, bw * block), np.float32)
    ones[:h, :w] = 1.0
    counts = ones.reshape(bh, block, bw, block).sum((1, 3))
    return sums / counts


def block_delta(
    prev_eff: np.ndarray, cur_eff: np.ndarray, spec: mapping.FPCASpec
) -> np.ndarray:
    """Mean absolute per-block change between two *effective* (binned)
    frames — the statistic every per-config threshold compares against.
    Jitted :mod:`repro.core.gating` numerics, bit-shared with the in-scan
    gate (see :func:`_effective_frame`)."""
    kernels = gating.host_gate_kernels(spec)
    return np.asarray(
        kernels.delta(
            np.asarray(prev_eff, np.float32), np.asarray(cur_eff, np.float32)
        )
    )


def block_delta_mask(
    prev_eff: np.ndarray,
    cur_eff: np.ndarray,
    spec: mapping.FPCASpec,
    threshold: float,
) -> np.ndarray:
    """Per-block change detection between two *effective* (binned) frames.

    Returns the boolean ``(ceil(eff_h/B), ceil(eff_w/B))`` grid the periphery
    SRAM would hold (True = block changed beyond ``threshold`` mean absolute
    intensity) — the shape :func:`repro.core.mapping.active_window_mask`
    consumes.
    """
    return block_delta(prev_eff, cur_eff, spec) > threshold


class _GateState:
    """Delta-gate state for one configuration of one stream: its own gate
    knobs, block-age grid, servo controller and retained mask history."""

    def __init__(
        self,
        name: str,
        gate: DeltaGateConfig,
        controller: GateController | None,
        block_shape: tuple[int, int],
        history: int,
    ):
        self.name = name
        self.gate = gate
        self.controller = controller
        self.age = np.full(block_shape, gate.hysteresis + 1, np.int64)
        self.last_keyframe = False
        self.last_block_mask: np.ndarray | None = None
        self.last_window_mask: np.ndarray | None = None
        # changed-block accounting for the event stream: ``last_changed`` is
        # the raw threshold comparison of the most recent gated tick (None
        # before the first delta), ``changed_total`` its running count —
        # EventTap packets must reconcile with it EXACTLY
        # (repro.serving.observe.assert_reconciled)
        self.last_changed: np.ndarray | None = None
        self.changed_total = 0
        # gate history for energy accounting, bounded so a long-running
        # stream does not leak (the report covers the retained window)
        self.block_masks: collections.deque[np.ndarray] = collections.deque(
            maxlen=history
        )

    def step(
        self,
        spec: mapping.FPCASpec,
        delta_blocks: np.ndarray | None,
        frame_idx: int,
    ) -> np.ndarray:
        """Advance this config's gate by one frame (``delta_blocks`` is the
        shared per-block |Δ| grid, ``None`` on the first frame)."""
        if delta_blocks is not None:
            # float32 threshold on BOTH sides (numpy promotes the comparison
            # otherwise) — the same comparison the in-scan gate traces, so a
            # delta within 1 ulp of the threshold decides identically
            changed = delta_blocks > np.float32(self.gate.threshold)
            self.age = np.where(changed, 0, self.age + 1)
            self.last_changed = changed
            self.changed_total += int(changed.sum())
        else:
            self.last_changed = None
        keyframe = delta_blocks is None or (
            self.gate.keyframe_interval > 0
            and frame_idx % self.gate.keyframe_interval == 0
        )
        keep = (
            np.ones_like(self.age, bool)
            if keyframe
            else self.age <= self.gate.hysteresis
        )
        self.last_keyframe = keyframe
        self.last_block_mask = keep
        self.block_masks.append(keep)
        # derive the per-window keep grid ONCE per frame: the dispatch loop
        # reuses it (last_window_mask) and the keep-metric servo observes its
        # mean instead of re-deriving it
        window = mapping.active_window_mask(spec, keep)
        self.last_window_mask = window
        if self.controller is not None:
            obs = (
                float(window.mean())
                if self.controller.config.metric == "keep"
                else None
            )
            new_thr = self.controller.observe(
                keep, keyframe=keyframe, observation=obs
            )
            if new_thr != self.gate.threshold:
                self.gate = dataclasses.replace(self.gate, threshold=new_thr)
        return keep


class StreamSession:
    """Per-stream state: previous frame, block ages, programmed config(s).

    ``config`` may be one registered configuration name or a sequence of
    names sharing one spec (multi-config fan-out); :attr:`configs` always
    holds the normalised tuple and :attr:`config` the primary name.

    ``gate`` is one :class:`DeltaGateConfig` shared by every fanned-out
    configuration (the classic behaviour), or a mapping
    ``{config_name: DeltaGateConfig}`` giving each configuration its own
    independent gate (per-config block ages and thresholds); ``controller``
    follows the same shape with :class:`GateController` instances.  With
    controllers attached, every gated frame feeds the closed-loop threshold
    servo(s) and the per-config gates are re-derived for the next frame.
    """

    def __init__(
        self,
        stream_id: str,
        config: str | Sequence[str],
        spec: mapping.FPCASpec,
        gate: DeltaGateConfig | Mapping[str, DeltaGateConfig] | None,
        history: int = 512,
        controller: GateController | Mapping[str, GateController] | None = None,
    ):
        self.stream_id = stream_id
        self.configs: tuple[str, ...] = (
            (config,) if isinstance(config, str) else tuple(config)
        )
        if not self.configs:
            raise ValueError("need at least one config name")
        self.spec = spec
        self.per_config = isinstance(gate, Mapping) or isinstance(
            controller, Mapping
        )
        self.frame_idx = 0
        self._prev: np.ndarray | None = None
        bh = math.ceil(spec.eff_h / spec.skip_block)
        bw = math.ceil(spec.eff_w / spec.skip_block)
        self.last_window_mask: np.ndarray | None = None
        # per-config effective activation map (model configs only): the
        # running frontend output with each tick's kept windows patched in —
        # what the skip-aware digital head classifies
        self._eff: dict[str, Any] = {}
        # device-resident carry threaded between compiled segment launches
        # (None until the stream first serves a segment)
        self._segment_state: Any | None = None
        # set by an attached EventTap: step() then retains the SIGNED block
        # mean delta (the gate only needs |Δ|) so event polarity can be read
        # after the previous frame is overwritten
        self.want_events = False
        self._last_signed: np.ndarray | None = None

        def _pick(mapping_or_one: Any, name: str, kind: str) -> Any:
            if isinstance(mapping_or_one, Mapping):
                try:
                    return mapping_or_one[name]
                except KeyError:
                    raise KeyError(
                        f"per-config {kind} mapping is missing config "
                        f"{name!r} of stream {stream_id!r}"
                    ) from None
            return mapping_or_one

        self._states: list[_GateState] = []
        self._by_name: dict[str, _GateState] = {}
        # gating-off sessions still expose a (never-appended) mask history so
        # dense baselines keep the pre-redesign block_masks / energy_report
        # surface
        self._fallback_masks: collections.deque[np.ndarray] = collections.deque(
            maxlen=history
        )
        if gate is None and not self.per_config:
            self.gating = False
            return
        self.gating = True
        if self.per_config:
            for name in self.configs:
                g = _pick(gate, name, "gate")
                if g is None:
                    raise ValueError(
                        f"per-config gating needs a DeltaGateConfig for "
                        f"config {name!r}"
                    )
                st = _GateState(
                    name, g, _pick(controller, name, "controller"),
                    (bh, bw), history,
                )
                self._states.append(st)
                self._by_name[name] = st
        else:
            st = _GateState(
                self.configs[0], gate, controller, (bh, bw), history
            )
            self._states.append(st)
            for name in self.configs:
                self._by_name[name] = st

    # -- back-compat accessors (primary config's gate state) ----------------
    @property
    def config(self) -> str:
        """Primary configuration name (first of :attr:`configs`)."""
        return self.configs[0]

    @property
    def _primary(self) -> _GateState | None:
        return self._states[0] if self._states else None

    @property
    def gate(self) -> DeltaGateConfig | None:
        """Primary config's gate (None = gating off / dense)."""
        st = self._primary
        return st.gate if st is not None else None

    @property
    def controller(self) -> GateController | None:
        st = self._primary
        return st.controller if st is not None else None

    @property
    def last_keyframe(self) -> bool:
        st = self._primary
        return st.last_keyframe if st is not None else False

    @property
    def block_masks(self) -> collections.deque:
        st = self._primary
        return st.block_masks if st is not None else self._fallback_masks

    def state_for(self, config: str) -> _GateState | None:
        """This config's gate state (shared state unless per-config)."""
        return self._by_name.get(config)

    def step(
        self,
        frame: np.ndarray,
        precomputed: tuple[np.ndarray, np.ndarray] | None = None,
    ) -> np.ndarray | None:
        """Advance one frame; returns the block keep mask (None = dense).

        A block is kept iff it changed within the last ``hysteresis + 1``
        frames; keyframes (the first frame, then every ``keyframe_interval``)
        keep everything but do NOT reset the ages — a static scene goes quiet
        again immediately after the refresh.  With controllers attached, the
        masks also feed the threshold servo(s), so the NEXT frame gates with
        the servoed threshold(s).

        With per-config gates, the returned mask (and
        :attr:`last_window_mask`) is the **union** over configs — what the
        fused call must execute; each config's own decision is on its
        :meth:`state_for` entry.

        ``precomputed`` is this tick's ``(effective frame, block |Δ| grid)``
        when the server already computed it in a fleet-batched gate dispatch
        (:func:`repro.core.gating.HostGateKernels.step_batch` — bit-identical
        to the solo kernel); the per-config threshold comparisons and age
        bookkeeping still run here, per stream.
        """
        if not self.gating:
            self.frame_idx += 1
            return None
        delta_blocks = None
        if precomputed is not None:
            cur = np.asarray(precomputed[0])
            delta_blocks = np.asarray(precomputed[1])
        elif self._prev is None:
            kernels = gating.host_gate_kernels(self.spec)
            cur = np.asarray(kernels.eff(np.asarray(frame, np.float32)))
        else:
            # ONE fused dispatch per tick (effective frame + block delta):
            # the gate result is needed synchronously to build this tick's
            # window mask, so per-call overhead sits on the serving hot loop
            kernels = gating.host_gate_kernels(self.spec)
            cur_d, delta_d = kernels.step(
                np.asarray(self._prev, np.float32),
                np.asarray(frame, np.float32),
            )
            cur = np.asarray(cur_d)
            delta_blocks = np.asarray(delta_d)
        if self.want_events:
            # polarity source for the event tap: signed block-mean change,
            # captured before ``_prev`` is overwritten below
            self._last_signed = (
                None
                if delta_blocks is None or self._prev is None
                else _block_reduce_mean(
                    cur - np.asarray(self._prev, np.float32),
                    self.spec.skip_block,
                )
            )
        union_keep: np.ndarray | None = None
        union_window: np.ndarray | None = None
        for st in self._states:
            keep = st.step(self.spec, delta_blocks, self.frame_idx)
            union_keep = keep if union_keep is None else union_keep | keep
            window = st.last_window_mask
            union_window = (
                window if union_window is None else union_window | window
            )
        self._prev = cur
        self.frame_idx += 1
        self.last_window_mask = union_window
        return union_keep

    def absorb_segment(self, seg) -> None:
        """Fold one finished device-compiled segment into this session.

        A segment serves K ticks from one launch; the host session never saw
        those frames, so its mirror of the gate state (previous frame, block
        ages, frame index, mask history, servo) is rebuilt here from the
        segment's realised bookkeeping — after this call, per-tick
        :meth:`step` serving continues bit-identically from where the
        segment stopped, and :meth:`energy_report` /
        :meth:`GateController.converged_tick` audits cover the in-segment
        ticks as if they had been served one by one.  The servo applies ONE
        bounded actuation at the boundary
        (:meth:`GateController.observe_segment`).
        """
        if self.per_config:
            raise NotImplementedError(
                "compiled segments serve one gate per stream; per-config "
                "fan-out streams must use per-tick serving"
            )
        ticks = seg.ticks
        if not seg.gated or not self.gating:
            if seg.gated != self.gating:
                raise ValueError(
                    "segment gating does not match this session "
                    f"(segment gated={seg.gated}, session gating={self.gating})"
                )
            self.frame_idx += ticks
            return
        st = self._primary
        masks = [np.asarray(m) for m in seg.block_masks[:ticks]]
        for m in masks:
            st.block_masks.append(m)
        if ticks:
            st.last_keyframe = bool(seg.keyframes[ticks - 1])
            st.last_block_mask = masks[-1]
            window = mapping.active_window_mask(self.spec, masks[-1])
            st.last_window_mask = window
            self.last_window_mask = window
        st.age = np.asarray(seg.state.age, np.int64)
        self._prev = np.asarray(seg.state.prev_eff, np.float32)
        self.frame_idx = int(seg.state.frame_idx)
        if st.controller is not None and ticks:
            obs = None
            if st.controller.config.metric == "keep":
                h_o, w_o = mapping.output_dims(self.spec)
                obs = [
                    float(k) / float(h_o * w_o)
                    for k in seg.kept_windows[:ticks]
                ]
            new_thr = st.controller.observe_segment(
                masks,
                keyframes=seg.keyframes[:ticks],
                observations=obs,
            )
            if new_thr != st.gate.threshold:
                st.gate = dataclasses.replace(st.gate, threshold=new_thr)

    def energy_report(
        self,
        const: analysis.FrontendConstants | None = None,
        config: str | None = None,
    ) -> dict:
        """Executed-window energy/cycle accounting over the retained gate
        history (the last ``history`` frames).  ``config`` selects one
        fanned-out configuration's gate history (default: the primary's —
        which under shared gating is *the* history)."""
        if config is not None:
            st = self._by_name.get(config)
            if st is None:
                raise KeyError(f"unknown config {config!r} for this session")
            masks = st.block_masks
        else:
            masks = self.block_masks
        return analysis.streaming_frontend_report(
            self.spec, list(masks), const or analysis.FrontendConstants()
        )


@dataclasses.dataclass
class StreamFrameResult:
    """One (stream, config)'s activations for one tick of the serving loop.

    Single-config streams yield one result per tick; a multi-config stream
    yields one per fanned-out configuration (same ``frame_idx``; per-config
    ``counts``, and per-config ``block_mask`` / ``kept_windows`` when the
    stream uses per-config gates), distinguished by ``config``.

    Streams attached to a **model** configuration
    (:class:`repro.fpca.ProgrammedModel`) also carry per-tick class
    ``logits``: the skip-aware head path patches this tick's kept-window
    activations into the stream's previous effective activation map and runs
    the digital head on the patched map, so even a mostly-skipped tick
    yields a class decision (an all-skipped tick reproduces the previous
    logits exactly).
    """

    stream_id: str
    frame_idx: int
    counts: np.ndarray              # (h_o, w_o, c_o) SS-ADC counts
    block_mask: np.ndarray | None   # gate output (None = dense readout)
    kept_windows: int
    total_windows: int
    config: str = ""                # configuration these counts belong to
    logits: np.ndarray | None = None  # (n_classes,) logits, or the raw
    #                                 # (gh, gw, n_classes + 4) detection map
    detections: Any | None = None   # heads.Detections — detection configs
    events: Any | None = None       # events.EventPacket — event-tap streams

    @property
    def kept_fraction(self) -> float:
        return self.kept_windows / max(self.total_windows, 1)

    @property
    def predicted_class(self) -> int | None:
        """Argmax class of a classifier tick; None for dense-counts-only
        ticks AND for detection ticks (whose logits are per-cell maps —
        use :attr:`detections`)."""
        if self.logits is None or np.ndim(self.logits) != 1:
            return None
        return int(np.argmax(self.logits))


class StreamStats(telemetry.StatsView):
    """Fleet-level serving counters, registry-backed (see
    :class:`repro.fpca.telemetry.StatsView`).

    ``windows_kept`` counts logical kept windows (pre-bucket-pad);
    ``launches_skipped`` counts all-skipped ticks (per-tick serving
    short-circuits AND zero-kept ticks inside device-compiled segments);
    ``bucket_switches`` / ``bucket_shrinks_deferred`` mirror the sticky
    bucket hysteresis; ``segments`` / ``segment_ticks`` cover compiled
    segment launches; ``fused_head_calls`` counts shared-head fusion
    launches (several same-signature model configs served by ONE batched
    head pass — see :meth:`StreamServer._model_head_pass`);
    ``serve_seconds`` accumulates wall-clock time spent
    in the serving loop (dispatch + realisation) — the denominator
    :func:`repro.serving.observe.fleet_report` derives fps from.

    The server deliberately does NOT parent-chain into the pipeline's
    stats: it is a scoped observer of a *shared* pipeline (other callers
    may drive the same pipeline), so the bucket/skip counters are
    delta-mirrored around each launch instead.
    """

    _PREFIX = "fpca_stream"
    _FIELDS = (
        "ticks",
        "frames",
        "windows_total",
        "windows_kept",
        "launches_skipped",
        "bucket_switches",
        "bucket_shrinks_deferred",
        "segments",
        "segment_ticks",
        "fused_head_calls",
        "serve_seconds",
    )


class StreamServer:
    """Async double-buffered multi-stream driver over :class:`FPCAPipeline`.

    A thin fleet-orchestration layer: gating and batching happen here, every
    fused launch goes through the pipeline's per-signature
    :class:`repro.fpca.CompiledFrontend` handles (single-camera workloads
    can skip this class entirely and use
    :meth:`repro.fpca.CompiledFrontend.stream`).

    Args:
      pipeline: the serving pipeline whose registered configurations,
        executable cache and mesh sharding this server reuses.
      gate: delta-gate configuration applied to every stream; pass
        ``gating=False`` for a dense baseline server (no skipping — what the
        benchmark compares against).  With a ``controller``, this is only the
        *initial* gate — each stream's threshold is then servoed
        independently.  Both can be overridden per stream (and per config)
        in :meth:`add_stream`.
      controller: optional :class:`GateControllerConfig`; every stream added
        afterwards gets its own :class:`GateController` closed-loop servoing
        the gate threshold against the configured budget.
      depth: maximum in-flight ticks.  ``2`` is classic double buffering:
        while the device chews on tick ``t``, the host gates and batches tick
        ``t+1``; results for ``t`` are realised only when ``t+2`` is about to
        dispatch.
    """

    def __init__(
        self,
        pipeline: FPCAPipeline,
        gate: DeltaGateConfig = DeltaGateConfig(),
        *,
        depth: int = 2,
        gating: bool = True,
        controller: GateControllerConfig | None = None,
        fuse_shared_heads: bool = True,
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.pipeline = pipeline
        self.gate = gate if gating else None
        self.controller = controller if gating else None
        self.depth = depth
        # when several model configs of one fused launch bind the SAME model
        # signature (zoo archs sharing a head, A/B weight variants), run ONE
        # vmapped head pass over all (config, stream) rows instead of one
        # call per config; bit-identical to the per-config path (pinned in
        # tests) because the patched-head math is row-independent
        self.fuse_shared_heads = fuse_shared_heads
        self.sessions: dict[str, StreamSession] = {}
        self.event_taps: dict[str, Any] = {}
        self.stats = StreamStats()
        # prebuilt span label dicts (one per server / per stream) so an
        # enabled-telemetry tick allocates no dicts on the hot loop
        self._span_fields = {"server": self.stats._labels["instance"]}
        self._seg_fields: dict[str, dict] = {}

    def add_stream(
        self,
        stream_id: str,
        config: str | Sequence[str],
        *,
        gate: Any = _USE_SERVER,
        controller: Any = _USE_SERVER,
        events: bool = False,
    ) -> StreamSession:
        """Attach a camera stream to registered pipeline configuration(s).

        ``events=True`` attaches an :class:`repro.serving.events.EventTap`:
        every served tick additionally emits the delta gate's changed blocks
        as an address-event packet on ``StreamFrameResult.events`` (requires
        a gated, shared-gate stream).

        A sequence of names fans the stream out to several programmed
        configurations sharing one spec: each tick is gated and served
        through one channel-stacked fused call, yielding one
        :class:`StreamFrameResult` per configuration.

        ``gate`` / ``controller`` override the server-wide defaults for this
        stream: a :class:`DeltaGateConfig` /
        :class:`GateControllerConfig` replaces the default, an explicit
        ``None`` disables gating / servoing for this stream (a per-stream
        dense baseline even on a gated server), and omitting the argument
        inherits the server default.  Passing a mapping
        ``{config_name: DeltaGateConfig}`` (and / or
        ``{config_name: GateControllerConfig}``) gives each fanned-out
        configuration its own independent gate state and servo — the fused
        call then executes the union of the per-config masks and each
        config's results are masked back to its own keep decision.
        """
        if stream_id in self.sessions:
            raise ValueError(f"stream {stream_id!r} already attached")
        names = (config,) if isinstance(config, str) else tuple(config)
        cfgs = []
        for n in names:
            cfg = self.pipeline._configs.get(n)
            if cfg is None:
                raise KeyError(f"unknown config {n!r}")
            cfgs.append(cfg)
        spec = cfgs[0].spec
        base = cfgs[0].program.fanout_signature()
        for cfg in cfgs[1:]:
            # one stacked call per tick serves one adc/enc/circuit epilogue:
            # require full compile-signature compatibility, not just a
            # shared spec (a 3-bit-ADC config stacked with an 8-bit one
            # would silently serve the wrong saturation)
            if cfg.program.fanout_signature() != base:
                raise ValueError(
                    f"multi-config stream needs a shared spec and compile "
                    f"signature (adc/enc/circuit): config {cfg.name!r} "
                    f"differs from {cfgs[0].name!r}"
                )
        eff_gate = self.gate if gate is _USE_SERVER else gate
        eff_ctl = self.controller if controller is _USE_SERVER else controller
        per_config = isinstance(eff_gate, Mapping) or isinstance(eff_ctl, Mapping)

        def _controller_for(g: DeltaGateConfig, name: str) -> GateController | None:
            if eff_ctl is None or g is None:
                return None
            conf = (
                eff_ctl[name]
                if isinstance(eff_ctl, Mapping)
                else eff_ctl
            )
            if not conf:
                return None
            return GateController(
                conf, spec, g.threshold, name=f"{stream_id}/{name}"
            )

        if per_config:
            if eff_gate is None:
                raise ValueError(
                    "per-config controllers need gating enabled (pass gate=)"
                )
            for kind, m in (("gate", eff_gate), ("controller", eff_ctl)):
                if isinstance(m, Mapping):
                    missing = [n for n in names if n not in m]
                    if missing:
                        raise KeyError(
                            f"per-config {kind} mapping is missing config "
                            f"{missing[0]!r} of stream {stream_id!r}"
                        )
            gate_map = {
                n: (eff_gate[n] if isinstance(eff_gate, Mapping) else eff_gate)
                for n in names
            }
            ctl_map = {n: _controller_for(gate_map[n], n) for n in names}
            session = StreamSession(
                stream_id, names, spec, gate_map, controller=ctl_map
            )
        else:
            ctl = (
                _controller_for(eff_gate, names[0])
                if eff_gate is not None
                else None
            )
            session = StreamSession(
                stream_id, names, spec, eff_gate, controller=ctl
            )
        self.sessions[stream_id] = session
        self._seg_fields[stream_id] = {"stream": stream_id}
        if events:
            from repro.serving.events import EventTap

            try:
                self.event_taps[stream_id] = EventTap(session)
            except Exception:
                # leave no half-attached stream behind: the session was
                # registered above, but an events=True caller asked for a
                # contract this stream cannot honour
                del self.sessions[stream_id]
                del self._seg_fields[stream_id]
                raise
        return session

    # -- serving loop --------------------------------------------------------
    def _dispatch(self, frames: Mapping[str, Any]) -> list[dict]:
        """Host side of one tick: gate every stream, fan streams into one
        batch per configuration group, dispatch without blocking."""
        per_group: dict[tuple[str, ...], list[tuple[StreamSession, np.ndarray]]] = {}
        for stream_id, frame in frames.items():
            session = self.sessions.get(stream_id)
            if session is None:
                raise KeyError(f"unknown stream {stream_id!r}")
            per_group.setdefault(session.configs, []).append(
                (session, np.asarray(frame, np.float32))
            )
        pstats = self.pipeline.stats
        before = (
            pstats.bucket_switches,
            pstats.bucket_shrinks_deferred,
            pstats.launches_skipped,
        )
        launches: list[dict] = []
        for configs, members in per_group.items():
            spec = members[0][0].spec
            h_o, w_o = mapping.output_dims(spec)
            entries = []
            keeps = []
            gated = any(session.gating for session, _ in members)
            # fleet-batched host gating: every warmed-up gated stream of the
            # group computes its effective frame + block |Δ| grid in ONE
            # vmapped dispatch (bit-identical to the solo kernel), so the
            # per-tick host cost stays flat as the fleet grows; first-frame
            # and dense streams fall through to the per-stream path
            pre: dict[int, tuple[np.ndarray, np.ndarray]] = {}
            rows = [
                i for i, (s, _) in enumerate(members)
                if s.gating and s._prev is not None
            ]
            if len(rows) > 1:
                kern = gating.host_gate_kernels(spec)
                curs, deltas = kern.step_batch(
                    np.stack([
                        np.asarray(members[i][0]._prev, np.float32)
                        for i in rows
                    ]),
                    np.stack([
                        np.asarray(members[i][1], np.float32) for i in rows
                    ]),
                )
                curs, deltas = np.asarray(curs), np.asarray(deltas)
                pre = {i: (curs[j], deltas[j]) for j, i in enumerate(rows)}
            for row, (session, frame) in enumerate(members):
                frame_idx = session.frame_idx
                block = session.step(frame, precomputed=pre.get(row))
                window = session.last_window_mask if session.gating else None
                kept = int(window.sum()) if window is not None else h_o * w_o
                entry = {
                    "stream_id": session.stream_id,
                    "frame_idx": frame_idx,
                    "block_mask": block,
                    "kept": kept,
                    "total": h_o * w_o,
                }
                if session.per_config:
                    entry["per_config"] = {
                        st.name: (
                            st.last_block_mask,
                            int(st.last_window_mask.sum()),
                            st.last_window_mask,
                        )
                        for st in session._states
                    }
                tap = self.event_taps.get(session.stream_id)
                if tap is not None:
                    # emit this tick's address-event packet from the gate
                    # state session.step() just wrote (same changed array the
                    # gate counted — the reconciliation contract)
                    entry["events"] = tap.observe_tick(frame_idx)
                entries.append(entry)
                if gated:
                    keeps.append(
                        window
                        if window is not None
                        else np.ones((h_o, w_o), bool)
                    )
                self.stats.frames += 1
                self.stats.windows_total += h_o * w_o
                self.stats.windows_kept += kept
            images = np.stack([frame for _, frame in members])
            counts = self.pipeline.run_config_batch(
                configs[0] if len(configs) == 1 else list(configs),
                images,
                np.stack(keeps) if gated else None,
            )
            slices = (
                self.pipeline.config_channel_slices(configs)
                if len(configs) > 1
                else [(configs[0], None, None)]
            )
            launch = {"counts": counts, "entries": entries, "slices": slices}
            self._model_head_pass(launch, members, h_o, w_o)
            launches.append(launch)
        self.stats.bucket_switches += pstats.bucket_switches - before[0]
        self.stats.bucket_shrinks_deferred += pstats.bucket_shrinks_deferred - before[1]
        self.stats.launches_skipped += pstats.launches_skipped - before[2]
        return launches

    def _model_head_pass(
        self, launch: dict, members: list, h_o: int, w_o: int
    ) -> None:
        """Skip-aware digital head for model configurations of one group.

        For every :class:`repro.fpca.ProgrammedModel` slice of the fused
        launch: patch each member stream's kept windows into its previous
        effective activation map (per-config masks when the stream gates per
        config) and dispatch the head on the patched maps — ONE batched,
        non-blocking call per model config, so the double-buffered overlap
        is preserved.  An all-skipped tick patches nothing and reproduces
        the previous logits exactly.

        **Shared-head fusion** (``fuse_shared_heads``): model configs of one
        launch binding the SAME model signature (zoo archs sharing a head
        graph, A/B weight variants) collapse into ONE vmapped head pass over
        all stacked (config, stream) rows — each row binds its own config's
        head parameters.  The patched-head math is row-independent, so fused
        and per-config results are bit-identical (pinned in the zoo tests).
        """
        counts = launch["counts"]
        logits_by_config: dict[str, Any] = {}
        detect_by_config: dict[str, int] = {}
        model_slices: list[tuple] = []
        for name, lo, hi in launch["slices"]:
            cfg = self.pipeline._configs[name]
            if not isinstance(cfg, ProgrammedModel):
                continue
            model_slices.append((name, lo, hi, cfg))
            dc = cfg.model.detect_classes
            if dc is not None:
                detect_by_config[name] = dc
        if not model_slices:
            return

        def gather(name, lo, hi, cfg):
            sliced = counts if lo is None else counts[..., lo:hi]
            prevs, keeps = [], []
            for session, _ in members:
                prev = session._eff.get(name)
                if prev is None:
                    prev = jnp.zeros((h_o, w_o, cfg.out_channels), jnp.float32)
                prevs.append(prev)
                st = session.state_for(name)
                if session.gating and st is not None and st.last_window_mask is not None:
                    keeps.append(st.last_window_mask)
                else:
                    keeps.append(np.ones((h_o, w_o), bool))
            return sliced, prevs, keeps

        groups: dict[tuple, list[tuple]] = {}
        for item in model_slices:
            groups.setdefault(item[3].model.signature(), []).append(item)
        n = len(members)
        for group in groups.values():
            handle = self.pipeline.model_handle_for(group[0][3].model)
            if len(group) == 1 or not self.fuse_shared_heads:
                for name, lo, hi, cfg in group:
                    sliced, prevs, keeps = gather(name, lo, hi, cfg)
                    logits, eff = handle.patched_logits(
                        sliced, jnp.stack(prevs), np.stack(keeps),
                        head_params=cfg.head_params,
                    )
                    for row, (session, _) in enumerate(members):
                        session._eff[name] = eff[row]
                    logits_by_config[name] = logits
            else:
                # config-major row stacking: rows [g*n, (g+1)*n) are group
                # member g's streams, each row binding g's head params
                rows_c, rows_p, rows_k, hp_rows = [], [], [], []
                for name, lo, hi, cfg in group:
                    sliced, prevs, keeps = gather(name, lo, hi, cfg)
                    rows_c.append(sliced)
                    rows_p.extend(prevs)
                    rows_k.extend(keeps)
                    hp_rows.extend([cfg.head_params] * n)
                hp_stack = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *hp_rows
                )
                logits, eff = handle.fused_patched_logits(
                    hp_stack,
                    jnp.concatenate(rows_c, axis=0),
                    jnp.stack(rows_p),
                    np.stack(rows_k),
                )
                self.stats.fused_head_calls += 1
                for g, (name, lo, hi, cfg) in enumerate(group):
                    base = g * n
                    for row, (session, _) in enumerate(members):
                        session._eff[name] = eff[base + row]
                    logits_by_config[name] = logits[base:base + n]
        if logits_by_config:
            launch["logits"] = logits_by_config
        if detect_by_config:
            launch["detect"] = detect_by_config

    def _finalize(self, launches: list[dict]) -> list[StreamFrameResult]:
        """Device side of one tick: realise the batch (blocks) and unpack.

        Per-config-gated streams executed the union mask; here each config's
        channel slice is masked back to exactly its own keep decision (kept
        windows are bit-identical to solo serving — row-independent math —
        and windows the config skipped read as exact zeros)."""
        results: list[StreamFrameResult] = []
        for launch in launches:
            counts = np.asarray(launch["counts"])     # blocks until ready
            logits_np = {
                name: np.asarray(lg)
                for name, lg in launch.get("logits", {}).items()
            }
            detect = launch.get("detect", {})
            for row, e in enumerate(launch["entries"]):
                per_config = e.get("per_config")
                for idx, (name, lo, hi) in enumerate(launch["slices"]):
                    sliced = (
                        counts[row] if lo is None else counts[row, ..., lo:hi]
                    )
                    block, kept = e["block_mask"], e["kept"]
                    if per_config is not None and name in per_config:
                        block, kept, window = per_config[name]
                        sliced = sliced * window[..., None].astype(sliced.dtype)
                    lg = logits_np.get(name)
                    det = None
                    if lg is not None and name in detect:
                        det = Detections.from_raw(lg[row], detect[name])
                    results.append(
                        StreamFrameResult(
                            stream_id=e["stream_id"],
                            frame_idx=e["frame_idx"],
                            counts=sliced,
                            block_mask=block,
                            kept_windows=kept,
                            total_windows=e["total"],
                            config=name,
                            logits=None if lg is None else lg[row],
                            detections=det,
                            # one packet per (stream, tick): attach to the
                            # first fanned-out config's result only
                            events=e.get("events") if idx == 0 else None,
                        )
                    )
        return results

    def run(
        self, ticks: Iterable[Mapping[str, Any]]
    ) -> Iterator[list[StreamFrameResult]]:
        """Serve a stream of ticks; yields one result list per tick, in order.

        Each tick maps ``stream_id -> frame``.  Up to ``depth`` ticks are in
        flight at once: dispatch is non-blocking (jax async), so tick ``t``'s
        device compute overlaps tick ``t+1``'s host gating/batching; results
        are realised oldest-first, preserving frame order per stream.
        """
        inflight: collections.deque[list[dict]] = collections.deque()
        for frames in ticks:
            # single-exit wall-clock billing: the dispatch half of the tick
            # is accumulated exactly once even when the gate/batch path
            # raises, so fps_wall never loses (or double-counts) time
            t0 = time.perf_counter()
            try:
                with telemetry.span("serve_tick", self._span_fields):
                    inflight.append(self._dispatch(frames))
                self.stats.ticks += 1
            finally:
                self.stats.serve_seconds += time.perf_counter() - t0
            while len(inflight) > self.depth:
                yield self._finalize_timed(inflight.popleft())
        while inflight:
            yield self._finalize_timed(inflight.popleft())

    def _finalize_timed(self, launches: list[dict]) -> list[StreamFrameResult]:
        """Realise one in-flight tick, billing its wall time exactly once
        (``try/finally`` — a device error mid-realisation still accounts
        the seconds already spent)."""
        t0 = time.perf_counter()
        try:
            return self._finalize(launches)
        finally:
            self.stats.serve_seconds += time.perf_counter() - t0

    def serve(self, stream_id: str, frames: Iterable[Any]) -> Iterator[StreamFrameResult]:
        """Single-stream convenience wrapper around :meth:`run`.

        Yields one result per tick for a single-config stream; a
        multi-config stream yields its per-config results back to back
        (same ``frame_idx``, distinguished by ``result.config``).
        """
        for results in self.run({stream_id: f} for f in frames):
            yield from results

    # -- device-compiled segment mode ----------------------------------------
    def run_segment(
        self,
        stream_id: str,
        frames: Any,
        *,
        m_bucket: int | None = None,
        early_exit: int | None = None,
    ) -> list[StreamFrameResult]:
        """Serve a ``(K, H, W, c_i)`` frame stack of one stream as ONE
        device-compiled segment (``jax.lax.scan`` tick loop — see
        :meth:`repro.fpca.CompiledFrontend.run_segment`).

        The session's gate runs *inside* the scan (bit-identical decisions —
        the host mirror is rebuilt from the segment's realised bookkeeping by
        :meth:`StreamSession.absorb_segment`, so per-tick :meth:`run` serving
        and segment serving interleave freely on one stream).  The threshold
        servo applies one bounded step at the segment boundary; with a
        ``"keep"``-metric controller the next segment's compacted row bucket
        defaults to the finished segment's realised kept counts.  Returns the
        per-tick results in frame order (fewer than K with ``early_exit`` —
        feed the unserved tail to the next call).  Single-config streams
        only; per-config fan-out must use per-tick :meth:`run`.
        """
        # same single-exit billing contract as run(): an exception inside
        # the segment launch still accounts the wall time already spent
        t0 = time.perf_counter()
        try:
            with telemetry.span("serve_segment", self._seg_fields.get(stream_id)):
                return self._run_segment_inner(
                    stream_id, frames, m_bucket=m_bucket, early_exit=early_exit
                )
        finally:
            self.stats.serve_seconds += time.perf_counter() - t0

    def _run_segment_inner(
        self,
        stream_id: str,
        frames: Any,
        *,
        m_bucket: int | None = None,
        early_exit: int | None = None,
    ) -> list[StreamFrameResult]:
        session = self.sessions.get(stream_id)
        if session is None:
            raise KeyError(f"unknown stream {stream_id!r}")
        if session.per_config or len(session.configs) > 1:
            raise NotImplementedError(
                "segment mode serves single-config streams; multi-config "
                "fan-out must use per-tick run()"
            )
        name = session.config
        state = session._segment_state
        if state is not None and int(state.frame_idx) != session.frame_idx:
            # per-tick serving advanced the stream since the last segment;
            # the device carry is stale — rebuild it from the host mirror
            state = None
        if state is None and session.frame_idx > 0:
            state = self._state_from_session(session, name)
        start_idx = session.frame_idx
        tap = self.event_taps.get(stream_id)
        # event reconstruction inputs, captured BEFORE the launch mutates
        # them: the effective frame carried INTO the segment and the
        # threshold the scan traces (the servo actuates only at the boundary,
        # inside absorb_segment)
        if tap is not None:
            prev_eff_in = (
                np.asarray(state.prev_eff, np.float32)
                if state is not None and bool(state.has_prev)
                else None
            )
            thr_in = float(session.gate.threshold)
        pstats = self.pipeline.stats
        before = (pstats.launches_skipped, pstats.segments, pstats.segment_ticks)
        seg = self.pipeline.run_config_segment(
            name,
            frames,
            state=state,
            gate=session.gate if session.gating else None,
            m_bucket=m_bucket,
            early_exit=early_exit,
        )
        session._segment_state = seg.state
        cfg = self.pipeline._configs[name]
        is_model = isinstance(cfg, ProgrammedModel)
        if is_model:
            session._eff[name] = seg.state.eff
        session.absorb_segment(seg)
        # a boundary servo step retunes the threshold for the NEXT segment —
        # the traced gate args pick it up without recompiling
        self.stats.launches_skipped += pstats.launches_skipped - before[0]
        self.stats.segments += pstats.segments - before[1]
        self.stats.segment_ticks += pstats.segment_ticks - before[2]
        ticks = seg.ticks
        h_o, w_o = mapping.output_dims(session.spec)
        total = h_o * w_o
        self.stats.ticks += ticks
        self.stats.frames += ticks
        self.stats.windows_total += ticks * total
        self.stats.windows_kept += int(seg.kept_windows[:ticks].sum())
        counts = np.asarray(seg.counts)        # blocks until the scan is done
        logits = None if seg.logits is None else np.asarray(seg.logits)
        packets = None
        if tap is not None:
            # the scan never materialises per-tick gate internals on the
            # host; re-derive the served ticks' event packets through the
            # same gating kernels the scan traced (bit-identical decisions —
            # the per-tick-vs-segment differential test pins it) and fold
            # them into tap + gate accounting in lock-step
            from repro.serving.events import segment_events

            packets = segment_events(
                session.spec,
                np.asarray(frames, np.float32)[:ticks],
                prev_eff_in,
                thr_in,
                stream_id,
                start_idx,
            )
            tap.absorb_packets(packets)
        detect_classes = cfg.model.detect_classes if is_model else None
        results = []
        for t in range(ticks):
            lg = None if logits is None else logits[t]
            results.append(
                StreamFrameResult(
                    stream_id=stream_id,
                    frame_idx=start_idx + t,
                    counts=counts[t],
                    block_mask=(
                        np.asarray(seg.block_masks[t]) if seg.gated else None
                    ),
                    kept_windows=int(seg.kept_windows[t]),
                    total_windows=total,
                    config=name,
                    logits=lg,
                    detections=(
                        Detections.from_raw(lg, detect_classes)
                        if lg is not None and detect_classes is not None
                        else None
                    ),
                    events=None if packets is None else packets[t],
                )
            )
        return results

    def _state_from_session(self, session: StreamSession, name: str):
        """Segment carry seeded from per-tick host state, so a stream that
        served ticks through :meth:`run` can continue in segment mode."""
        from repro.fpca.executable import SegmentState

        spec = session.spec
        prev = session._prev
        st = session._primary
        bh = math.ceil(spec.eff_h / spec.skip_block)
        bw = math.ceil(spec.eff_w / spec.skip_block)
        hyst = session.gate.hysteresis if session.gate is not None else 0
        state = SegmentState(
            has_prev=prev is not None,
            prev_eff=(
                prev
                if prev is not None
                else np.zeros((spec.eff_h, spec.eff_w), np.float32)
            ),
            age=(
                st.age if st is not None
                else np.full((bh, bw), hyst + 1, np.int64)
            ),
            frame_idx=session.frame_idx,
        )
        cfg = self.pipeline._configs[name]
        if isinstance(cfg, ProgrammedModel):
            h_o, w_o = mapping.output_dims(spec)
            eff = session._eff.get(name)
            if eff is None:
                eff = jnp.zeros((h_o, w_o, cfg.out_channels), jnp.float32)
            state.eff = eff
            # the scan's quiet-tick branch replays the carried logits; the
            # host path recomputes head(eff) each tick, which is the same bits
            handle = self.pipeline.model_handle_for(cfg.model)
            state.logits = handle.head_logits(
                eff, head_params=cfg.head_params
            )
        return state

    def serve_segments(
        self,
        stream_id: str,
        frames: Iterable[Any],
        *,
        segment_length: int = 16,
        m_bucket: int | None = None,
        early_exit: int | None = None,
        on_segment: Any = None,
    ) -> Iterator[StreamFrameResult]:
        """Segment-mode twin of :meth:`serve`: buffers the frame iterable
        into ``segment_length`` chunks and serves each as one compiled
        segment, yielding per-tick results in frame order.

        With ``early_exit`` a segment may serve fewer than ``segment_length``
        ticks; the unserved tail is carried into the next chunk.  The final
        partial chunk compiles one executable for its own length — steady
        streams see exactly one compile per distinct chunk length.

        ``on_segment`` (callable of the segment's result list) fires at
        every segment boundary, after the servo's boundary actuation —
        where :class:`repro.serving.fleet.FleetController` re-solves the
        fleet budget split.
        """
        if segment_length < 1:
            raise ValueError("segment_length must be >= 1")
        buf: list[np.ndarray] = []
        for f in frames:
            buf.append(np.asarray(f, np.float32))
            if len(buf) >= segment_length:
                results = self.run_segment(
                    stream_id,
                    np.stack(buf[:segment_length]),
                    m_bucket=m_bucket,
                    early_exit=early_exit,
                )
                if on_segment is not None:
                    on_segment(results)
                yield from results
                buf = buf[len(results):]
        while buf:
            results = self.run_segment(
                stream_id,
                np.stack(buf),
                m_bucket=m_bucket,
                early_exit=early_exit,
            )
            if on_segment is not None:
                on_segment(results)
            yield from results
            buf = buf[len(results):]
