"""Shared transformer building blocks (pure-functional, pytree params).

Conventions:
* params are nested dicts of jnp arrays; every module is an ``init`` +
  ``apply`` pair of pure functions;
* compute dtype is configurable (bf16 on TPU), numerics-critical reductions
  (norms, softmax) run in f32;
* weight layouts are chosen for the sharding rules in
  :mod:`repro.launch.sharding` (see DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

__all__ = [
    "rms_norm",
    "init_rms_norm",
    "init_dense",
    "dense",
    "init_swiglu",
    "swiglu",
    "init_mlp",
    "mlp",
    "init_embedding",
    "embed",
    "unembed",
    "rope",
    "cross_entropy_loss",
    "init_conv2d",
    "conv2d",
    "init_linear",
    "linear",
    "max_pool2d",
    "avg_pool2d",
]


def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


def init_dense(key: jax.Array, d_in: int, d_out: int, dtype=jnp.bfloat16) -> dict:
    scale = 1.0 / jnp.sqrt(d_in)
    return {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}


def dense(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["w"]


def init_swiglu(key: jax.Array, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d, d_ff, dtype),
        "up": init_dense(k2, d, d_ff, dtype),
        "down": init_dense(k3, d_ff, d, dtype),
    }


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    return dense(params["down"], jax.nn.silu(dense(params["gate"], x)) * dense(params["up"], x))


def init_mlp(key: jax.Array, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    """Plain GELU MLP (used by the Seamless enc-dec backbone)."""
    k1, k2 = jax.random.split(key)
    return {"up": init_dense(k1, d, d_ff, dtype), "down": init_dense(k2, d_ff, d, dtype)}


def mlp(params: dict, x: jax.Array) -> jax.Array:
    return dense(params["down"], jax.nn.gelu(dense(params["up"], x)))


def maybe_shard(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint iff tracing under a mesh (no-op in tests).

    Axis names in ``spec`` that don't exist in the ambient mesh are dropped
    (so the same model code lowers under 2-axis and 3-axis meshes)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x

    def clean(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in mesh.axis_names else None
        sub = tuple(a for a in entry if a in mesh.axis_names)
        return sub if sub else None

    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*(clean(s) for s in spec))
    )


def shard_batch(x: jax.Array) -> jax.Array:
    """Pin the leading batch axis to the data axes; keep the rest unsharded
    except a model-sharded last axis is preserved for (B, S, V) logits.

    GSPMD sometimes re-shards the residual-stream scan carry to a
    batch-replicated layout (observed: involuntary full remat around the
    vocab matmul); pinning the batch axis at block boundaries prevents the
    blow-up.  No-op without an ambient mesh.
    """
    return maybe_shard(x, ("pod", "data"), *([None] * (x.ndim - 1)))


def init_embedding(key: jax.Array, vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits in f32.

    The table is stored vocab-replicated / d-FSDP (clean token gathers); for
    the output projection we re-shard it vocab-over-model so the (B, S, V)
    logits are born vocab-sharded — never materialised whole on one device.
    The one-off table reshard per step is a deliberate trade (DESIGN.md §5).
    """
    table = maybe_shard(params["table"], "model", None)
    return (x @ table.T.astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Small-CNN building blocks (the digital head behind an FPCA frontend).
#
# NHWC layout, f32 by default: these serve the extreme-edge classifier heads
# (repro.fpca.FPCAModelProgram), where numerics-exactness against a reference
# composition matters more than bf16 throughput.
# ---------------------------------------------------------------------------


def init_conv2d(
    key: jax.Array, c_in: int, c_out: int, kernel: int, dtype=jnp.float32
) -> dict:
    """Biased conv params: ``w`` is ``(c_out, k, k, c_in)`` (FPCA kernel
    layout, so frontend and head convolutions read the same way)."""
    fan_in = kernel * kernel * c_in
    w = jax.random.normal(key, (c_out, kernel, kernel, c_in)) * fan_in ** -0.5
    return {"w": w.astype(dtype), "b": jnp.zeros((c_out,), dtype)}


def conv2d(
    params: dict, x: jax.Array, stride: int = 1, padding: str = "VALID"
) -> jax.Array:
    """NHWC convolution with bias; ``padding`` is ``"VALID"`` or ``"SAME"``."""
    out = jax.lax.conv_general_dilated(
        x.transpose(0, 3, 1, 2),
        params["w"].transpose(0, 3, 1, 2),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ).transpose(0, 2, 3, 1)
    return out + params["b"]


def init_linear(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> dict:
    """Biased dense params (``init_dense`` is the bias-free LM variant)."""
    w = jax.random.normal(key, (d_in, d_out)) * d_in ** -0.5
    return {"w": w.astype(dtype), "b": jnp.zeros((d_out,), dtype)}


def linear(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


def _pool(x: jax.Array, size: int, stride: int | None, init, op) -> jax.Array:
    s = size if stride is None else stride
    return jax.lax.reduce_window(
        x, init, op,
        window_dimensions=(1, size, size, 1),
        window_strides=(1, s, s, 1),
        padding="VALID",
    )


def max_pool2d(x: jax.Array, size: int, stride: int | None = None) -> jax.Array:
    return _pool(x, size, stride, -jnp.inf, jax.lax.max)


def avg_pool2d(x: jax.Array, size: int, stride: int | None = None) -> jax.Array:
    return _pool(x, size, stride, 0.0, jax.lax.add) / float(size * size)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D), positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Mean token CE in f32. logits (..., V), labels (...) int32.

    The gold logit is extracted with a fusable one-hot reduction rather than
    ``take_along_axis``: a gather over the vocab axis (which we keep sharded
    over 'model') forces the SPMD partitioner into involuntary full
    rematerialisation of the logits — the one-hot product reduces locally and
    cross-shard with a cheap all-reduce instead.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = labels[..., None] == jnp.arange(logits.shape[-1], dtype=labels.dtype)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
