"""Mamba2 (state-space duality) blocks: chunked SSD scan + O(1) decode.

The SSD algorithm (Dao & Gu 2024) splits the sequence into chunks: within a
chunk the recurrence is materialised as a (Q x Q) masked attention-like
contraction (MXU-friendly); across chunks only the (H, P, N) states propagate
through a scan.  ``ssd_chunked`` is the training/prefill path and the oracle
for the ``kernels/ssd`` Pallas kernel; ``ssd_decode_step`` is the O(1)-state
serving path (this is what makes ``long_500k`` decode trivial for SSM archs).

Shapes: x (B, L, H, P), dt (B, L, H), A (H,), B/C (B, L, G, N); G (state
groups) broadcasts over heads (G=1 for the assigned configs).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, rms_norm

__all__ = [
    "ssd_intra_chunk",
    "ssd_chunked",
    "ssd_decode_step",
    "init_mamba2_block",
    "mamba2_block",
    "mamba2_decode_step",
    "mamba2_state_shape",
]


def ssd_intra_chunk(
    xbar: jax.Array, Bh: jax.Array, Ch: jax.Array, cum: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quadratic-within-chunk piece of SSD (the MXU-heavy part).

    xbar (b,nc,q,h,p) = x * dt; Bh/Ch (b,nc,q,h,n); cum (b,nc,q,h) = cumsum
    of ``dt * A`` within the chunk.  Returns (y_intra, chunk states, chunk
    decay).  This function is the oracle for the ``kernels/ssd`` Pallas
    kernel.
    """
    q = xbar.shape[2]
    # L[i, j] = exp(cum_i - cum_j) for i >= j (segment-sum mask).  Mask the
    # upper triangle *before* the exp: those entries have positive arguments
    # that overflow to inf and would poison gradients through the where.
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (b,nc,i,j,h)
    causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    Lmask = jnp.exp(jnp.where(causal, seg, -1e30))
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", cb * Lmask, xbar)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)              # (b,nc,q,h)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", Bh, decay_to_end, xbar)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (b,nc,h)
    return y_intra, states, chunk_decay


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 128,
    initial_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, l)
    nc = -(-l // q)
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = x.reshape(b, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, q, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, g, n).astype(jnp.float32)
    if g == 1:  # broadcast state groups over heads
        Bh = jnp.broadcast_to(Bc, (b, nc, q, h, n))
        Ch = jnp.broadcast_to(Cc, (b, nc, q, h, n))
    else:
        rep = h // g
        Bh = jnp.repeat(Bc, rep, axis=3)
        Ch = jnp.repeat(Cc, rep, axis=3)

    logd = dtc * A.astype(jnp.float32)                  # (b, nc, q, h), <= 0
    cum = jnp.cumsum(logd, axis=2)
    xbar = xc * dtc[..., None]
    y_intra, states, chunk_decay = ssd_intra_chunk(xbar, Bh, Ch, cum)

    def body(s, inp):
        st, dec = inp
        s_new = dec[:, :, None, None] * s + st
        return s_new, s                                          # emit state *before* chunk

    s0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (b,nc,h,p,n)
    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp", Ch, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    state: jax.Array,
    x_t: jax.Array,
    dt_t: jax.Array,
    A: jax.Array,
    B_t: jax.Array,
    C_t: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """One-token recurrence. state (B,H,P,N), x_t (B,H,P), dt_t (B,H),
    B_t/C_t (B,G,N). Returns (y_t (B,H,P), new_state)."""
    b, h, p, n = state.shape
    g = B_t.shape[1]
    Bh = jnp.broadcast_to(B_t[:, :, None, :], (b, g, h // g, n)).reshape(b, h, n)
    Ch = jnp.broadcast_to(C_t[:, :, None, :], (b, g, h // g, n)).reshape(b, h, n)
    dA = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))  # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t.astype(jnp.float32), x_t.astype(jnp.float32), Bh)
    new_state = dA[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block (in_proj -> conv1d -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------


def _dims(cfg: Any) -> tuple[int, int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    head_p = cfg.ssm_head_dim
    n_heads = d_inner // head_p
    return d_inner, n_heads, head_p, cfg.ssm_groups, cfg.ssm_state


def mamba2_state_shape(cfg: Any, batch: int) -> dict[str, tuple]:
    d_inner, n_heads, head_p, g, n = _dims(cfg)
    conv_dim = d_inner + 2 * g * n
    return {
        "conv": (batch, cfg.ssm_conv - 1, conv_dim),
        "ssm": (batch, n_heads, head_p, n),
    }


def init_mamba2_block(key: jax.Array, cfg: Any, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    d_inner, n_heads, head_p, g, n = _dims(cfg)
    conv_dim = d_inner + 2 * g * n
    k_in, k_conv, k_out, k_dt = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * g * n + n_heads  # z, x, B, C, dt
    return {
        "in_proj": init_dense(k_in, d, in_dim, dtype),
        "conv_w": (jax.random.normal(k_conv, (cfg.ssm_conv, conv_dim)) / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": (jax.random.uniform(k_dt, (n_heads,), minval=-4.0, maxval=-1.0)).astype(jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": init_dense(k_out, d_inner, d, dtype),
    }


def _split_proj(zxbcdt: jax.Array, cfg: Any):
    d_inner, n_heads, head_p, g, n = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    return z, xbc, dt


def mamba2_block(params: dict, x: jax.Array, cfg: Any) -> tuple[jax.Array, dict]:
    """Training/prefill path. x (B, S, d) -> (y (B, S, d), final caches)."""
    Bsz, S, _ = x.shape
    d_inner, n_heads, head_p, g, n = _dims(cfg)
    zxbcdt = x @ params["in_proj"]["w"]
    z, xbc, dt = _split_proj(zxbcdt, cfg)

    # causal depthwise conv over (x, B, C)
    w = params["conv_w"]                                         # (K, conv_dim)
    K = w.shape[0]
    xbc_pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(
        xbc_pad[:, i : i + S, :] * w[i][None, None, :] for i in range(K)
    ) + params["conv_b"][None, None, :]
    conv = jax.nn.silu(conv)

    xs, Bmat, Cmat = jnp.split(conv, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(Bsz, S, n_heads, head_p)
    Bmat = Bmat.reshape(Bsz, S, g, n)
    Cmat = Cmat.reshape(Bsz, S, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])

    y, final_state = ssd_chunked(xs, dt, A, Bmat, Cmat, chunk=cfg.ssm_chunk)
    # keep everything in the block compute dtype: f32 constants (d_skip)
    # must not promote the residual path, or scan carries change type
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xs.astype(y.dtype)
    y = y.reshape(Bsz, S, d_inner)
    y = rms_norm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    out = (y @ params["out_proj"]["w"]).astype(x.dtype)
    caches = {"conv": xbc[:, -(K - 1) :, :], "ssm": final_state}
    return out, caches


def mamba2_decode_step(
    params: dict, x_t: jax.Array, cache: dict, cfg: Any
) -> tuple[jax.Array, dict]:
    """O(1) decode. x_t (B, 1, d), cache {conv (B,K-1,conv_dim), ssm (B,H,P,N)}."""
    Bsz = x_t.shape[0]
    d_inner, n_heads, head_p, g, n = _dims(cfg)
    zxbcdt = x_t[:, 0, :] @ params["in_proj"]["w"]
    z, xbc, dt = _split_proj(zxbcdt, cfg)

    w = params["conv_w"]
    K = w.shape[0]
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B, K, conv)
    conv = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"]
    conv = jax.nn.silu(conv)

    xs, Bmat, Cmat = jnp.split(conv, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(Bsz, n_heads, head_p)
    Bmat = Bmat.reshape(Bsz, g, n)
    Cmat = Cmat.reshape(Bsz, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])

    y, new_ssm = ssd_decode_step(cache["ssm"], xs, dt, A, Bmat, Cmat)
    y = y + params["d_skip"].astype(y.dtype)[None, :, None] * xs.astype(y.dtype)
    y = y.reshape(Bsz, d_inner)
    y = rms_norm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    out = (y @ params["out_proj"]["w"]).astype(x_t.dtype)[:, None, :]
    return out, {"conv": window[:, 1:, :].astype(cache["conv"].dtype), "ssm": new_ssm}
