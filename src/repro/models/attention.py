"""Attention: GQA + RoPE + optional qk-norm + optional sliding window.

Three execution paths share one set of projection weights:

* ``attend_full``      — einsum + masked softmax; reference/smoke path, also
                         the oracle for the flash_attention Pallas kernel;
* ``attend_blockwise`` — pure-JAX flash-style online-softmax scan over KV
                         blocks; memory O(S * block) instead of O(S^2) — the
                         path that keeps 32k-token prefill compilable;
* ``attend_decode``    — single-query attention against a KV cache (serving).

Layouts: q (B, S, H, D), k/v (B, S, KV, D); GQA groups G = H // KV are an
explicit axis in the score einsums so the TP sharding of the KV-head axis
survives the computation.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, init_rms_norm, rms_norm, rope

__all__ = [
    "init_attention",
    "attention",
    "attend_full",
    "attend_blockwise",
    "attend_decode",
]

_NEG_INF = -1e30


def init_attention(
    key: jax.Array,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    *,
    qk_norm: bool = False,
    dtype=jnp.bfloat16,
) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_dense(kq, d_model, n_heads * head_dim, dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = init_rms_norm(head_dim)
        p["k_norm"] = init_rms_norm(head_dim)
    return p


def _project_qkv(
    params: dict, x: jax.Array, positions: jax.Array, cfg: Any
) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]["w"]).reshape(B, S, H, D)
    k = (x @ params["wk"]["w"]).reshape(B, S, KV, D)
    v = (x @ params["wv"]["w"]).reshape(B, S, KV, D)
    if "q_norm" in params:
        q = rms_norm(params["q_norm"], q)
        k = rms_norm(params["k_norm"], k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _grouped(q: jax.Array, kv_heads: int) -> jax.Array:
    """(B, S, H, D) -> (B, S, KV, G, D)."""
    B, S, H, D = q.shape
    return q.reshape(B, S, kv_heads, H // kv_heads, D)


def attend_full(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    positions_q: jax.Array | None = None,
    positions_k: jax.Array | None = None,
) -> jax.Array:
    """Masked softmax attention. q (B,Sq,H,D), k/v (B,Sk,KV,D) -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    qg = _grouped(q, KV)
    scale = D ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    pos_q = positions_q if positions_q is not None else jnp.arange(Sq)
    pos_k = positions_k if positions_k is not None else jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        mask &= pos_q[:, None] - pos_k[None, :] < window
    scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def _kv_blocks(x: jax.Array, block_k: int) -> jax.Array:
    """(B, S, KV, D) -> (nb, B, block_k, KV, D), zero-padded."""
    B, S, KV, D = x.shape
    nb = -(-S // block_k)
    xp = jnp.pad(x, ((0, 0), (0, nb * block_k - S), (0, 0), (0, 0)))
    return xp.reshape(B, nb, block_k, KV, D).transpose(1, 0, 2, 3, 4)


def _block_mask(pos_q, blk_idx, block_k, S_k, causal, window):
    pos_k = blk_idx * block_k + jnp.arange(block_k)
    mask = pos_k[None, :] < S_k
    if causal:
        mask = mask & (pos_q[:, None] >= pos_k[None, :])
    if window is not None:
        mask = mask & (pos_q[:, None] - pos_k[None, :] < window)
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, window, block_k):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, block_k)
    return out


def _flash_fwd_impl(q, k, v, causal, window, block_k):
    """Online-softmax forward; returns (out, lse). Memory O(S * block_k)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    S_k = k.shape[1]
    scale = D ** -0.5
    kb = _kv_blocks(k, block_k)
    vb = _kv_blocks(v, block_k)
    qg = _grouped(q, KV).astype(jnp.float32)
    pos_q = jnp.arange(S)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_blk.astype(jnp.float32)) * scale
        mask = _block_mask(pos_q, blk_idx, block_k, S_k, causal, window)
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    nb = kb.shape[0]
    m0 = jnp.full((B, KV, H // KV, S), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, H // KV, S), jnp.float32)
    acc0 = jnp.zeros((B, KV, H // KV, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, jnp.arange(nb)))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D).astype(q.dtype)
    return out, lse


def _flash_fwd(q, k, v, causal, window, block_k):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, block_k, res, g):
    """Recompute-based backward (flash style): no O(S^2) residuals."""
    q, k, v, out, lse = res
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    S_k = k.shape[1]
    scale = D ** -0.5
    qg = _grouped(q, KV).astype(jnp.float32)
    og = _grouped(out, KV).astype(jnp.float32)
    dg = _grouped(g, KV).astype(jnp.float32)
    # delta_i = sum_d dO_i O_i  (per query)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", dg, og)
    dg_t = dg.transpose(0, 2, 3, 1, 4)   # (B,KV,G,S,D)
    kb = _kv_blocks(k, block_k)
    vb = _kv_blocks(v, block_k)
    pos_q = jnp.arange(S)

    def body(dq_acc, blk):
        k_blk, v_blk, blk_idx = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_blk.astype(jnp.float32)) * scale
        mask = _block_mask(pos_q, blk_idx, block_k, S_k, causal, window)
        s = jnp.where(mask, s, _NEG_INF)
        p = jnp.exp(s - lse[..., None])                       # (B,KV,G,S,bk)
        dv_blk = jnp.einsum("bhgqk,bhgqd->bkhd", p, dg_t)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dg_t, v_blk.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_blk.astype(jnp.float32))
        dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg)
        return dq_acc, (dk_blk, dv_blk)

    nb = kb.shape[0]
    dq0 = jnp.zeros((B, S, KV, G, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))
    dq = dq.reshape(B, S, H, D).astype(q.dtype)
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_k, KV, D)[:, :S_k].astype(k.dtype)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_k, KV, D)[:, :S_k].astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def attend_blockwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_k: int = 512,
) -> jax.Array:
    """Flash attention, pure JAX (custom_vjp; O(S * block) live memory both
    directions).  Exact math of the Pallas flash_attention kernel and its
    oracle; on CPU/dry-run it is also the execution path."""
    return _flash(q, k, v, causal, window, block_k)


def attend_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    """Single-step attention against a cache. q (B,1,H,D), caches (B,Smax,KV,D).

    ``cache_len`` — number of valid cache entries (new token included).
    Written as plain einsum + masked softmax so GSPMD can shard the cache
    sequence axis (long-context decode) and insert the reduction collectives.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    qg = _grouped(q, KV).astype(jnp.float32)
    scale = D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(jnp.float32)) * scale
    pos_k = jnp.arange(k_cache.shape[1])
    mask = pos_k[None, :] < cache_len[:, None]                  # (B, Smax)
    if window is not None:
        mask = mask & (pos_k[None, :] >= cache_len[:, None] - window)
    s = jnp.where(mask[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: Any,
    *,
    causal: bool = True,
    impl: str = "auto",
    block_k: int = 512,
) -> jax.Array:
    """Full self-attention layer: project -> attend -> output proj."""
    q, k, v = _project_qkv(params, x, positions, cfg)
    window = getattr(cfg, "window", None)
    S = x.shape[1]
    if impl == "auto":
        impl = "blockwise" if S > 2048 else "full"
    if impl == "blockwise":
        out = attend_blockwise(q, k, v, causal=causal, window=window, block_k=block_k)
    else:
        out = attend_full(q, k, v, causal=causal, window=window)
    B, S, H, D = out.shape
    return out.reshape(B, S, H * D) @ params["wo"]["w"]
