"""Mixture-of-Experts FFN: token-choice top-k routing with static-shape
capacity dispatch (+ optional shared experts, Qwen-style).

Dispatch strategy (DESIGN.md §5): the (T, E) affinity matrix built from the
top-k router probabilities is reduced per expert with a top-C selection
(C = capacity), giving fully static shapes with O(E * C * d) activation
memory — no (T, E, C) one-hot dispatch tensors.  Tokens beyond an expert's
capacity are dropped for that expert (standard capacity semantics; the
load-balance auxiliary keeps drops rare).  Expert weights are (E, d, ff)
einsum banks so tensor-parallel sharding of the ``ff`` axis works for any
expert count; expert-parallel sharding of the E axis is an opt-in when
``E % |model axis| == 0``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, init_swiglu, swiglu

__all__ = ["init_moe", "moe"]


def init_moe(key: jax.Array, cfg: Any, dtype=jnp.bfloat16) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    kr, kg, ku, kd, ks, ksg = jax.random.split(key, 6)
    scale_d = 1.0 / math.sqrt(d)
    scale_f = 1.0 / math.sqrt(ff)
    p = {
        "router": {"w": (jax.random.normal(kr, (d, e)) * scale_d).astype(jnp.float32)},
        "experts": {
            "gate": (jax.random.normal(kg, (e, d, ff)) * scale_d).astype(dtype),
            "up": (jax.random.normal(ku, (e, d, ff)) * scale_d).astype(dtype),
            "down": (jax.random.normal(kd, (e, ff, d)) * scale_f).astype(dtype),
        },
    }
    if cfg.n_shared_experts:
        shared_ff = cfg.n_shared_experts * ff
        p["shared"] = init_swiglu(ks, d, shared_ff, dtype)
        p["shared_gate"] = init_dense(ksg, d, 1, dtype)
    return p


def _dispatch(
    t: jax.Array,
    affinity: jax.Array,
    experts: dict,
    capacity: int,
) -> jax.Array:
    """Capacity-limited dispatch/combine over one token group.

    t (T, d), affinity (T, E) -> (y (T, d), kept assignment count).
    """
    T, d = t.shape
    E = affinity.shape[1]
    sel_w, sel_idx = jax.lax.top_k(affinity.T, capacity)          # (E, C)
    xe = t[sel_idx]                                               # (E, C, d)
    # NOTE (§Perf, refuted hypotheses): forcing d-replicated expert weights
    # at the use site (with_sharding_constraint) or storing them without
    # FSDP both made this 7-11x WORSE — the storage<->use reshard of the
    # f32 weight cotangents executes inside every remat'd scan-bwd
    # iteration.  ZeRO-3 storage + partitioner-chosen use layout wins.
    h_gate = jnp.einsum("ecd,edf->ecf", xe, experts["gate"])
    h_up = jnp.einsum("ecd,edf->ecf", xe, experts["up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h_gate) * h_up, experts["down"])
    ye = ye * sel_w[..., None].astype(ye.dtype)                   # zero-weight slots vanish
    y = jnp.zeros((T, d), ye.dtype)
    y = y.at[sel_idx.reshape(-1)].add(ye.reshape(E * capacity, d))
    return y, jnp.sum((sel_w > 0).astype(jnp.float32))


def moe(
    params: dict,
    x: jax.Array,
    cfg: Any,
    *,
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """MoE FFN. x (B, S, d) -> (y, aux losses).

    ``cfg.moe_local_dispatch`` (perf lever, DESIGN.md §5 / EXPERIMENTS.md
    §Perf): route within each *sequence* instead of globally.  Capacity is
    then per (sequence, expert) and all gathers/scatters stay inside the
    batch shard — no cross-device token exchange, which removes the SPMD
    partitioner's involuntary full rematerialisation of the token tensor.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    local = bool(getattr(cfg, "moe_local_dispatch", False)) and S > 1
    T = B * S
    t = x.reshape(T, d)

    logits = (t.astype(jnp.float32) @ params["router"]["w"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)                                  # (T, k)
    if getattr(cfg, "moe_renormalize", True):
        top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # sparse affinity matrix (T, E): routing weight of token t for expert e
    affinity = jnp.zeros((T, E), jnp.float32)
    affinity = affinity.at[jnp.arange(T)[:, None], top_idx].set(top_vals)

    # per-expert capacity selection: static shapes, no (T, E, C) one-hots.
    # Decode regime (tiny T): capacity = T, i.e. lossless — dropping tokens
    # is a training-throughput trade, never acceptable at serving time.
    group = S if local else T
    if group <= 256:
        capacity = group
    else:
        capacity = max(1, int(math.ceil(group * k * capacity_factor / E)))
        capacity = min(capacity, group)

    if local:
        y, kept = jax.vmap(
            lambda tb, ab: _dispatch(tb, ab, params["experts"], capacity)
        )(t.reshape(B, S, d), affinity.reshape(B, S, E))
        y = y.reshape(T, d)
        kept = jnp.sum(kept)
    else:
        y, kept = _dispatch(t, affinity, params["experts"], capacity)

    if "shared" in params:
        gate = jax.nn.sigmoid(t @ params["shared_gate"]["w"]).astype(y.dtype)
        y = y + gate * swiglu(params["shared"], t)

    # ---- auxiliary losses ----------------------------------------------------
    # load balance (Switch-style): E * sum_e (token fraction_e * prob mass_e)
    assigned = jnp.zeros((T, E), jnp.float32).at[jnp.arange(T)[:, None], top_idx].set(1.0)
    frac = jnp.mean(assigned, axis=0)
    mass = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(frac * mass) / k
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    # dropped (token, expert) assignment fraction — capacity tuning signal
    drop_frac = jnp.clip(1.0 - kept / jnp.maximum(jnp.sum(assigned), 1.0), 0.0, 1.0)

    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_drop_frac": drop_frac}
    return y.reshape(B, S, d).astype(x.dtype), aux
