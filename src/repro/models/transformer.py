"""Model assembly for all assigned architecture families.

One functional model API used by training, serving, and the dry-run:

* ``init_model(key, cfg)``                          -> params pytree
* ``forward_train(params, cfg, batch)``             -> (loss, metrics)
* ``forward_prefill(params, cfg, tokens, ...)``     -> (logits, cache)
* ``forward_decode(params, cfg, token, cache, pos)``-> (logits, cache)
* ``init_cache(cfg, batch, max_len)``               -> cache pytree

Families:
* dense / vlm  — pre-norm GQA + SwiGLU decoder (vlm adds a patch projector
                 and consumes precomputed patch embeddings — frontend stub);
* moe          — GQA + token-choice top-k MoE FFN (optional shared experts);
* ssm          — Mamba2 (SSD) stack, attention-free;
* hybrid       — Zamba2: Mamba2 backbone with ONE shared attention+MLP block
                 applied every ``hybrid_attn_period`` layers (weights reused);
* encdec       — Seamless: bidirectional encoder (audio-frame stub input) +
                 causal decoder with cross-attention.

Layer iteration uses ``lax.scan`` over stacked per-layer params (bounded HLO,
bounded compile time at 80+ layers) with a configurable remat policy.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    _project_qkv,
    attend_blockwise,
    attend_decode,
    attend_full,
    init_attention,
)
from repro.models.layers import (
    cross_entropy_loss,
    maybe_shard,
    shard_batch,
    dense,
    embed,
    init_dense,
    init_embedding,
    init_mlp,
    init_rms_norm,
    init_swiglu,
    mlp,
    rms_norm,
    swiglu,
    unembed,
)
from repro.models.moe import init_moe, moe
from repro.models.ssm import (
    init_mamba2_block,
    mamba2_block,
    mamba2_decode_step,
    mamba2_state_shape,
)

__all__ = [
    "init_model",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "init_cache",
    "REMAT_POLICIES",
]

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}

ZERO_AUX = {
    "moe_lb_loss": jnp.float32(0.0),
    "moe_z_loss": jnp.float32(0.0),
    "moe_drop_frac": jnp.float32(0.0),
}


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------


def _init_mamba_layer(key: jax.Array, cfg: ModelConfig, dt) -> dict:
    return {"ln": init_rms_norm(cfg.d_model), "block": init_mamba2_block(key, cfg, dt)}


def _init_attn_block(key: jax.Array, cfg: ModelConfig, *, use_moe: bool, cross: bool = False) -> dict:
    ka, kf, kc = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p = {
        "ln1": init_rms_norm(cfg.d_model),
        "attn": init_attention(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qk_norm=cfg.qk_norm, dtype=dt,
        ),
        "ln2": init_rms_norm(cfg.d_model),
    }
    if use_moe:
        p["moe"] = init_moe(kf, cfg, dtype=dt)
    elif cfg.family == "encdec":
        p["mlp"] = init_mlp(kf, cfg.d_model, cfg.d_ff, dtype=dt)
    else:
        p["mlp"] = init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype=dt)
    if cross:
        p["ln_cross"] = init_rms_norm(cfg.d_model)
        p["cross"] = init_attention(
            kc, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype=dt
        )
    return p


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    dt = _dtype(cfg)
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(keys[1], cfg.vocab_size, cfg.d_model, dt)

    def stack(init_fn, n, key):
        return jax.vmap(init_fn)(jax.random.split(key, n))

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        params["blocks"] = stack(
            lambda k: _init_attn_block(k, cfg, use_moe=(fam == "moe")), cfg.n_layers, keys[2]
        )
        if fam == "vlm":
            k1, k2 = jax.random.split(keys[3])
            params["projector"] = {
                "w1": init_dense(k1, cfg.frontend_dim, cfg.d_model, dt),
                "w2": init_dense(k2, cfg.d_model, cfg.d_model, dt),
            }
    elif fam == "ssm":
        params["blocks"] = stack(lambda k: _init_mamba_layer(k, cfg, dt), cfg.n_layers, keys[2])
    elif fam == "hybrid":
        period = cfg.hybrid_attn_period
        n_groups = cfg.n_layers // period
        n_tail = cfg.n_layers - n_groups * period
        gkeys = jax.vmap(lambda k: jax.random.split(k, period))(
            jax.random.split(keys[2], n_groups)
        )
        params["mamba_main"] = jax.vmap(
            jax.vmap(lambda k: _init_mamba_layer(k, cfg, dt))
        )(gkeys)
        if n_tail:
            params["mamba_tail"] = stack(lambda k: _init_mamba_layer(k, cfg, dt), n_tail, keys[3])
        params["shared_attn"] = _init_attn_block(keys[4], cfg, use_moe=False)
    elif fam == "encdec":
        params["enc_blocks"] = stack(
            lambda k: _init_attn_block(k, cfg, use_moe=False), cfg.n_enc_layers, keys[2]
        )
        params["dec_blocks"] = stack(
            lambda k: _init_attn_block(k, cfg, use_moe=False, cross=True), cfg.n_layers, keys[3]
        )
        params["enc_norm"] = init_rms_norm(cfg.d_model)
        params["src_proj"] = init_dense(keys[5], cfg.frontend_dim, cfg.d_model, dt)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ---------------------------------------------------------------------------
# Single-layer applies
# ---------------------------------------------------------------------------


def _ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    if "moe" in p:
        return moe(p["moe"], x, cfg, capacity_factor=getattr(cfg, "moe_capacity_factor", 1.25))
    if cfg.family == "encdec":
        return mlp(p["mlp"], x), dict(ZERO_AUX)
    return swiglu(p["mlp"], x), dict(ZERO_AUX)


def _attn_block_seq(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    causal: bool,
    enc_out: jax.Array | None = None,
    make_cache: bool = False,
) -> tuple[jax.Array, dict, dict | None]:
    """Full-sequence attention block (train / prefill / encoder)."""
    x = shard_batch(x)
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _project_qkv(p["attn"], h, positions, cfg)
    S = x.shape[1]
    if S > 2048:
        out = attend_blockwise(
            q, k, v, causal=causal, window=cfg.window,
            block_k=getattr(cfg, "attn_block_k", 512),
        )
    else:
        out = attend_full(q, k, v, causal=causal, window=cfg.window)
    B, _, H, D = out.shape
    x = x + out.reshape(B, S, H * D) @ p["attn"]["wo"]["w"]
    if enc_out is not None:
        hc = rms_norm(p["ln_cross"], x, cfg.norm_eps)
        qc = (hc @ p["cross"]["wq"]["w"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        kc = (enc_out @ p["cross"]["wk"]["w"]).reshape(B, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        vc = (enc_out @ p["cross"]["wv"]["w"]).reshape(B, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        if S > 2048 or enc_out.shape[1] > 2048:
            co = attend_blockwise(qc, kc, vc, causal=False)
        else:
            co = attend_full(qc, kc, vc, causal=False)
        x = x + co.reshape(B, S, -1) @ p["cross"]["wo"]["w"]
    h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
    y, aux = _ffn(p, h2, cfg)
    x = x + y
    cache = None
    if make_cache:
        cache = {"k": k, "v": v}
    return x, aux, cache


def _attn_block_decode(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache: dict,
    pos: jax.Array,
    *,
    cross_kv: dict | None = None,
) -> tuple[jax.Array, dict, dict]:
    """One-token attention block against a KV cache.

    ``cache`` holds padded k/v (B, Smax, KV, D); sliding-window archs use a
    ring buffer (Smax = window), everything else absolute slots.
    """
    B = x.shape[0]
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _project_qkv(p["attn"], h, jnp.full((1,), pos, jnp.int32), cfg)
    s_max = cache["k"].shape[1]
    ring = cfg.window is not None and s_max == cfg.window
    slot = (pos % s_max) if ring else jnp.minimum(pos, s_max - 1)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    valid = jnp.minimum(pos + 1, s_max)
    out = attend_decode(q, k_cache, v_cache, jnp.full((B,), valid, jnp.int32), window=None)
    x = x + out.reshape(B, 1, -1) @ p["attn"]["wo"]["w"]
    if cross_kv is not None:
        hc = rms_norm(p["ln_cross"], x, cfg.norm_eps)
        qc = (hc @ p["cross"]["wq"]["w"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        co = attend_decode(
            qc, cross_kv["k"], cross_kv["v"],
            jnp.full((B,), cross_kv["k"].shape[1], jnp.int32),
        )
        x = x + co.reshape(B, 1, -1) @ p["cross"]["wo"]["w"]
    h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
    y, aux = _ffn(p, h2, cfg)
    return x + y, aux, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Stacks (scan over layers)
# ---------------------------------------------------------------------------


def _scan_blocks(body, x, stacked, caches=None, remat: str = "dots"):
    policy = REMAT_POLICIES.get(remat)
    fn = jax.checkpoint(body, policy=policy) if remat != "none" else body

    def wrapped(carry, inp):
        return fn(carry, inp)

    xs = (stacked, caches) if caches is not None else (stacked, None)
    (x, aux), new_caches = jax.lax.scan(wrapped, (x, dict(ZERO_AUX)), xs)
    return x, aux, new_caches


def _accumulate(acc: dict, aux: dict) -> dict:
    return {k: acc[k] + aux[k] for k in acc}


def _decoder_stack_seq(params, cfg, x, positions, *, make_cache=False, remat="dots"):
    """dense/moe/vlm decoder over a full sequence (+ optional cache build)."""

    def body(carry, inp):
        h, acc = carry
        p_l, _ = inp
        h, aux, cache = _attn_block_seq(
            p_l, h, cfg, positions, causal=True, make_cache=make_cache
        )
        return (h, _accumulate(acc, aux)), cache

    return _scan_blocks(body, x, params["blocks"], None, remat)


def _ssm_stack_seq(params, cfg, x, *, make_cache=False, remat="dots"):
    def body(carry, inp):
        h, acc = carry
        p_l, _ = inp
        h = shard_batch(h)
        h2 = rms_norm(p_l["ln"], h, cfg.norm_eps)
        y, caches = mamba2_block(p_l["block"], h2, cfg)
        return (h + y, acc), (caches if make_cache else None)

    # mamba blocks carry their own ln inside the stacked dict
    return _scan_blocks(body, x, params["blocks"], None, remat)


# ---------------------------------------------------------------------------
# Public forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, tokens, frontend_embeds):
    x = embed(params["embed"], tokens).astype(_dtype(cfg))
    if cfg.family == "vlm":
        if frontend_embeds is None:
            raise ValueError("vlm family needs frontend_embeds (patch stub)")
        proj = dense(params["projector"]["w2"],
                     jax.nn.gelu(dense(params["projector"]["w1"],
                                       frontend_embeds.astype(_dtype(cfg)))))
        x = jnp.concatenate([proj, x], axis=1)
    return x


def _unembed(params, cfg, x):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    if not getattr(cfg, "logits_vocab_shard", True):
        return (x @ table["table"].T.astype(x.dtype)).astype(jnp.float32)
    return unembed(table, x)


def _hybrid_stack_seq(params, cfg, x, *, make_cache=False, remat="dots"):
    period = cfg.hybrid_attn_period
    n_groups = cfg.n_layers // period
    positions = jnp.arange(x.shape[1])

    def mamba_one(h, p_l):
        h = shard_batch(h)
        h2 = rms_norm(p_l["ln"], h, cfg.norm_eps)
        y, caches = mamba2_block(p_l["block"], h2, cfg)
        return h + y, caches

    def group_body(carry, inp):
        h, acc = carry
        p_group, _ = inp  # stacked (period, ...) mamba params

        def inner(c, p_l):
            h_in, _ = c
            h_out, caches = mamba_one(h_in, p_l)
            return (h_out, 0), caches

        (h, _), m_caches = jax.lax.scan(inner, (h, 0), p_group)
        h, aux, attn_cache = _attn_block_seq(
            params["shared_attn"], h, cfg, positions, causal=True, make_cache=make_cache
        )
        return (h, _accumulate(acc, aux)), {"mamba": m_caches, "attn": attn_cache}

    policy = REMAT_POLICIES.get(remat)
    body = jax.checkpoint(group_body, policy=policy) if remat != "none" else group_body
    (x, aux), group_caches = jax.lax.scan(body, (x, dict(ZERO_AUX)), (params["mamba_main"], None))
    tail_caches = None
    if "mamba_tail" in params:
        def tail_body(c, p_l):
            h_in, _ = c
            h_out, caches = mamba_one(h_in, p_l)
            return (h_out, 0), caches

        (x, _), tail_caches = jax.lax.scan(tail_body, (x, 0), params["mamba_tail"])
    return x, aux, {"groups": group_caches, "tail": tail_caches}


def forward_train(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    remat: str = "dots",
) -> tuple[jax.Array, dict]:
    """Next-token CE loss. batch: tokens (B,S), labels (B,S)[, frontend]."""
    tokens = batch["tokens"]
    fe = batch.get("frontend")
    if cfg.family == "encdec":
        return _encdec_train(params, cfg, batch, remat=remat)
    x = _embed_inputs(params, cfg, tokens, fe)
    positions = jnp.arange(x.shape[1])
    if cfg.family in ("dense", "moe", "vlm"):
        x, aux, _ = _decoder_stack_seq(params, cfg, x, positions, remat=remat)
    elif cfg.family == "ssm":
        x, aux, _ = _ssm_stack_seq(params, cfg, x, remat=remat)
    elif cfg.family == "hybrid":
        x, aux, _ = _hybrid_stack_seq(params, cfg, x, remat=remat)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.family == "vlm":  # only text positions carry labels
        x = x[:, cfg.frontend_tokens :, :]
    logits = _unembed(params, cfg, x)
    logits = maybe_shard(logits, ("pod", "data"), None, "model")
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))
    n_layers = max(cfg.n_layers, 1)
    metrics = {
        "ce_loss": loss,
        "moe_lb_loss": aux["moe_lb_loss"] / n_layers,
        "moe_z_loss": aux["moe_z_loss"] / n_layers,
        "moe_drop_frac": aux["moe_drop_frac"] / n_layers,
    }
    total = loss + 0.01 * metrics["moe_lb_loss"] + 0.001 * metrics["moe_z_loss"]
    return total, metrics


def _encdec_train(params, cfg, batch, *, remat="dots"):
    src = dense(params["src_proj"], batch["frontend"].astype(_dtype(cfg)))
    pos_src = jnp.arange(src.shape[1])

    def enc_body(carry, inp):
        h, acc = carry
        p_l, _ = inp
        h, aux, _ = _attn_block_seq(p_l, h, cfg, pos_src, causal=False)
        return (h, _accumulate(acc, aux)), None

    enc, _, _ = _scan_blocks(enc_body, src, params["enc_blocks"], None, remat)
    enc = rms_norm(params["enc_norm"], enc, cfg.norm_eps)

    x = embed(params["embed"], batch["tokens"]).astype(_dtype(cfg))
    pos_tgt = jnp.arange(x.shape[1])

    def dec_body(carry, inp):
        h, acc = carry
        p_l, _ = inp
        h, aux, _ = _attn_block_seq(p_l, h, cfg, pos_tgt, causal=True, enc_out=enc)
        return (h, _accumulate(acc, aux)), None

    x, _, _ = _scan_blocks(dec_body, x, params["dec_blocks"], None, remat)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    logits = maybe_shard(logits, ("pod", "data"), None, "model")
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"ce_loss": loss}


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Zeroed cache pytree (bf16 KV, f32 SSM states)."""
    kv_len = min(max_len, cfg.window) if cfg.window else max_len
    dt = _dtype(cfg)

    def attn_cache():
        shape = (batch, kv_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    def mamba_cache():
        shapes = mamba2_state_shape(cfg, batch)
        return {
            "conv": jnp.zeros(shapes["conv"], dt),
            "ssm": jnp.zeros(shapes["ssm"], jnp.float32),
        }

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return {
            "layers": jax.tree.map(
                lambda x: jnp.stack([x] * cfg.n_layers), attn_cache()
            )
        }
    if fam == "ssm":
        return {"layers": jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), mamba_cache())}
    if fam == "hybrid":
        period = cfg.hybrid_attn_period
        n_groups = cfg.n_layers // period
        n_tail = cfg.n_layers - n_groups * period
        out = {
            "groups": {
                "mamba": jax.tree.map(
                    lambda x: jnp.zeros((n_groups, period) + x.shape, x.dtype),
                    mamba_cache(),
                ),
                "attn": jax.tree.map(
                    lambda x: jnp.stack([x] * n_groups), attn_cache()
                ),
            }
        }
        if n_tail:
            out["tail"] = jax.tree.map(lambda x: jnp.stack([x] * n_tail), mamba_cache())
        return out
    if fam == "encdec":
        self_cache = jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), attn_cache())
        return {"layers": self_cache, "cross": None}  # cross filled at prefill
    raise ValueError(fam)


def forward_prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    frontend_embeds: jax.Array | None = None,
    max_len: int | None = None,
    remat: str = "dots",
) -> tuple[jax.Array, dict]:
    """Process a full prompt; returns (last-position logits (B, V), cache)."""
    B, S = tokens.shape
    fam = cfg.family
    if fam == "encdec":
        return _encdec_prefill(params, cfg, tokens, frontend_embeds, max_len=max_len)
    x = _embed_inputs(params, cfg, tokens, frontend_embeds)
    S_total = x.shape[1]
    positions = jnp.arange(S_total)
    max_len = max_len or S_total
    if fam in ("dense", "moe", "vlm"):
        x, aux, caches = _decoder_stack_seq(
            params, cfg, x, positions, make_cache=True, remat=remat
        )
        cache = {"layers": _pad_kv(caches, cfg, max_len)}
    elif fam == "ssm":
        x, aux, caches = _ssm_stack_seq(params, cfg, x, make_cache=True, remat=remat)
        cache = {"layers": caches}
    elif fam == "hybrid":
        x, aux, caches = _hybrid_stack_seq(params, cfg, x, make_cache=True, remat=remat)
        cache = {
            "groups": {
                "mamba": caches["groups"]["mamba"],
                "attn": _pad_kv(caches["groups"]["attn"], cfg, max_len),
            }
        }
        if caches["tail"] is not None:
            cache["tail"] = caches["tail"]
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, -1:, :])[:, 0, :]
    return logits, cache


def _pad_kv(caches: dict, cfg: ModelConfig, max_len: int) -> dict:
    """Pad prefill K/V (L, B, S, KV, D) to the serving cache length.

    Sliding-window caches are ring buffers indexed ``slot = pos % window``:
    the kept tail of the prompt is scattered to its ring slots so subsequent
    decode writes land consistently.
    """
    kv_len = min(max_len, cfg.window) if cfg.window else max_len

    def pad(x):
        S = x.shape[2]
        if S == kv_len:
            return x
        if S > kv_len:  # ring buffer: token t -> slot t % window
            import numpy as np

            kept_tokens = np.arange(S - kv_len, S)
            slots = kept_tokens % kv_len
            out = jnp.zeros(x.shape[:2] + (kv_len,) + x.shape[3:], x.dtype)
            return out.at[:, :, slots].set(x[:, :, S - kv_len :])
        return jnp.pad(x, ((0, 0), (0, 0), (0, kv_len - S), (0, 0), (0, 0)))

    return jax.tree.map(pad, caches)


def _encdec_prefill(params, cfg, tokens, frontend_embeds, max_len=None):
    src = dense(params["src_proj"], frontend_embeds.astype(_dtype(cfg)))
    pos_src = jnp.arange(src.shape[1])

    def enc_body(carry, inp):
        h, acc = carry
        p_l, _ = inp
        h, aux, _ = _attn_block_seq(p_l, h, cfg, pos_src, causal=False)
        return (h, acc), None

    enc, _, _ = _scan_blocks(enc_body, src, params["enc_blocks"], None, "none")
    enc = rms_norm(params["enc_norm"], enc, cfg.norm_eps)

    # precompute per-layer cross K/V once (reused by every decode step)
    def cross_kv(p_l):
        B, Se, _ = enc.shape
        k = (enc @ p_l["cross"]["wk"]["w"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        v = (enc @ p_l["cross"]["wv"]["w"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        return {"k": k, "v": v}

    cross = jax.vmap(cross_kv)(params["dec_blocks"])

    # full teacher-forced pass over the decoder prompt builds the self cache
    x = embed(params["embed"], tokens).astype(_dtype(cfg))
    pos_tgt = jnp.arange(x.shape[1])

    def dec_body(carry, p_l):
        h, acc = carry
        h, aux, c = _attn_block_seq(
            p_l, h, cfg, pos_tgt, causal=True, enc_out=enc, make_cache=True
        )
        return (h, acc), c

    (x, _), self_caches = jax.lax.scan(dec_body, (x, dict(ZERO_AUX)), params["dec_blocks"])
    max_len = max_len or tokens.shape[1]
    cache = {"layers": _pad_kv(self_caches, cfg, max_len), "cross": cross}
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, -1:, :])[:, 0, :]
    return logits, cache


def forward_decode(
    params: dict,
    cfg: ModelConfig,
    token: jax.Array,
    cache: dict,
    pos: jax.Array,
) -> tuple[jax.Array, dict]:
    """One decode step. token (B, 1) -> (logits (B, V), new cache)."""
    x = embed(params["embed"], token).astype(_dtype(cfg))
    fam = cfg.family
    aux0 = dict(ZERO_AUX)

    if fam in ("dense", "moe", "vlm"):
        def body(carry, inp):
            h, acc = carry
            p_l, c_l = inp
            h, aux, new_c = _attn_block_decode(p_l, h, cfg, c_l, pos)
            return (h, _accumulate(acc, aux)), new_c

        (x, _), new_caches = jax.lax.scan(body, (x, aux0), (params["blocks"], cache["layers"]))
        new_cache = {"layers": new_caches}
    elif fam == "ssm":
        def body(carry, inp):
            h, acc = carry
            p_l, c_l = inp
            h2 = rms_norm(p_l["ln"], h, cfg.norm_eps)
            y, new_c = mamba2_decode_step(p_l["block"], h2, c_l, cfg)
            return (h + y, acc), new_c

        (x, _), new_caches = jax.lax.scan(body, (x, aux0), (params["blocks"], cache["layers"]))
        new_cache = {"layers": new_caches}
    elif fam == "hybrid":
        def group_body(carry, inp):
            h, acc = carry
            p_group, c_group = inp

            def inner(c, inp2):
                h_in, _ = c
                p_l, c_l = inp2
                h2 = rms_norm(p_l["ln"], h_in, cfg.norm_eps)
                y, new_c = mamba2_decode_step(p_l["block"], h2, c_l, cfg)
                return (h_in + y, 0), new_c

            (h, _), new_m = jax.lax.scan(inner, (h, 0), (p_group, c_group["mamba"]))
            h, aux, new_a = _attn_block_decode(
                params["shared_attn"], h, cfg, c_group["attn"], pos
            )
            return (h, _accumulate(acc, aux)), {"mamba": new_m, "attn": new_a}

        (x, _), new_groups = jax.lax.scan(
            group_body, (x, aux0), (params["mamba_main"], cache["groups"])
        )
        new_cache = {"groups": new_groups}
        if "tail" in cache:
            def tail_body(c, inp2):
                h_in, _ = c
                p_l, c_l = inp2
                h2 = rms_norm(p_l["ln"], h_in, cfg.norm_eps)
                y, new_c = mamba2_decode_step(p_l["block"], h2, c_l, cfg)
                return (h_in + y, 0), new_c

            (x, _), new_tail = jax.lax.scan(tail_body, (x, 0), (params["mamba_tail"], cache["tail"]))
            new_cache["tail"] = new_tail
    elif fam == "encdec":
        cross = cache["cross"]

        def body(carry, inp):
            h, acc = carry
            p_l, c_l, cross_l = inp
            h, aux, new_c = _attn_block_decode(p_l, h, cfg, c_l, pos, cross_kv=cross_l)
            return (h, _accumulate(acc, aux)), new_c

        (x, _), new_caches = jax.lax.scan(
            body, (x, aux0), (params["dec_blocks"], cache["layers"], cross)
        )
        new_cache = {"layers": new_caches, "cross": cross}
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, cfg, x)[:, 0, :]
    return logits, new_cache
