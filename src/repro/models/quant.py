"""Quantised int8 lowering for digital heads (and the shared symmetric
int8 leaf numerics).

The FPCA frontend already emits hard-rounded SS-ADC integer counts; this
module makes the *digital* side match the extreme-edge deployment story:
per-channel symmetric int8 weights, int8 activations with statically
calibrated scales, int32 accumulation, and a requantise between stages —
selected via ``FPCAModelProgram(precision="int8")`` and flowing through
every compiled executable (fused run, pipeline serve, per-tick streaming,
``lax.scan`` segments).

Numerics contract (what the parity harness pins):

* **weights** — per-out-channel symmetric scales, ``s_w[c] =
  max|w[..., c]| / 127``; ``w_q = clip(round(w / s_w), -127, 127)``;
* **activations** — one symmetric scale per parameterized stage,
  calibrated from an f32 forward pass over sample counts (``s_x =
  max|x| / 127``); requantised at every stage input;
* **accumulation** — exact int8 x int8 -> int32.  On hosts without a
  native int8 GEMM the products ride *integer-valued f32 sgemm carriers*:
  each partial sum reduces at most :data:`_CHUNK` = 1024 terms, so its
  magnitude stays below ``1024 * 127 * 127 < 2**24`` — exactly
  representable in f32 — and partials are cast to int32 between chunks.
  This is bit-exact int8 semantics at sgemm speed (the same trick the
  basis backend's matmul bank uses for its int8 transfer LUT);
* **dequantise** — ``y = acc * (s_x * s_w) + b`` in f32, then the stage
  activation; pooling and joins run in f32 between stages.

Parity against the f32 reference is *bounded, not bit-exact*:
``tests/test_quant.py`` pins max logit divergence and top-1 agreement
across the dense / masked / zero-kept / bucket-edge grid.

The per-tensor leaf helpers (:func:`quantize_leaf_symmetric` /
:func:`dequantize_leaf`) are the single source of symmetric int8
numerics — :mod:`repro.training.compression` re-imports them for
gradient compression.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "quantize_symmetric",
    "quantize_leaf_symmetric",
    "dequantize_leaf",
    "quant_bank_dot",
    "linear_int8",
    "conv2d_int8",
    "calibrate_head_scales",
    "quantize_head_params",
    "bind_quant_head_params",
    "is_quantized_params",
    "apply_head_int8",
    "pack_act_scales",
    "unpack_act_scales",
    "logit_parity",
]

# Max reduction depth per f32-carrier partial sum: every partial stays
# below 1024 * 127 * 127 = 16 516 096 < 2**24, the largest contiguous
# integer range f32 represents exactly.
_CHUNK = 1024

_QUANT_KEYS = frozenset({"w_q", "w_scale", "b", "x_scale"})


# ---------------------------------------------------------------------------
# leaf numerics (shared with training/compression.py)
# ---------------------------------------------------------------------------

def quantize_symmetric(
    g: jax.Array, channel_axis: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantisation of one tensor.

    ``channel_axis=None`` is the per-tensor form (one scalar scale — the
    gradient-compression numerics); an integer axis yields per-channel
    scales with ``keepdims`` shape, ready to divide/multiply in place.
    """
    if channel_axis is None:
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    else:
        red = tuple(i for i in range(g.ndim) if i != channel_axis % g.ndim)
        scale = (
            jnp.maximum(jnp.max(jnp.abs(g), axis=red, keepdims=True), 1e-12)
            / 127.0
        )
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_leaf_symmetric(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantise: ``(q int8, scale f32 scalar)``."""
    return quantize_symmetric(g)


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_leaf_symmetric` (f32)."""
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# exact int8 matmul / conv on f32 carriers
# ---------------------------------------------------------------------------

def quant_bank_dot(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """Exact ``int8 x int8 -> int32`` matmul through the f32 sgemm bank.

    ``x_q`` is an *integer-valued* f32 carrier in [-127, 127] (shape
    ``(..., K)``), ``w_q`` an int8 ``(K, N)`` plane.  Reduction is chunked
    to :data:`_CHUNK` terms so every f32 partial is exactly representable;
    partials accumulate in int32 across chunks.  This is the head-side
    counterpart of the basis backend's matmul-bank lowering: int8 semantics
    at f32-GEMM speed on hosts whose native int8 dot is slower than sgemm.
    """
    K, N = w_q.shape
    wf = w_q.astype(jnp.float32)
    dn = (((x_q.ndim - 1,), (0,)), ((), ()))
    if K <= _CHUNK:
        out = jax.lax.dot_general(
            x_q, wf, dn, preferred_element_type=jnp.float32
        )
        return out.astype(jnp.int32)
    n_chunks = -(-K // _CHUNK)
    pad = n_chunks * _CHUNK - K
    if pad:
        x_q = jnp.pad(x_q, [(0, 0)] * (x_q.ndim - 1) + [(0, pad)])
        wf = jnp.pad(wf, [(0, pad), (0, 0)])
    lead = x_q.shape[:-1]
    xs = jnp.moveaxis(
        x_q.reshape(lead + (n_chunks, _CHUNK)), -2, 0
    ).reshape((n_chunks, -1, _CHUNK))               # (n_chunks, M, _CHUNK)
    ws = wf.reshape(n_chunks, _CHUNK, N)
    # one chunk-batched sgemm (batch dim = chunk index), each f32 partial
    # exactly representable, then an int32 reduction over chunks — much
    # faster than a sequential lax.scan of small GEMMs, identical result
    parts = jax.lax.dot_general(
        xs, ws, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                               # (n_chunks, M, N)
    return parts.astype(jnp.int32).sum(axis=0).reshape(lead + (N,))


def _requant(x: jax.Array, x_scale: jax.Array) -> jax.Array:
    """Quantise an f32 activation to an integer-valued f32 int8 carrier."""
    return jnp.clip(jnp.round(x / x_scale), -127.0, 127.0)


def linear_int8(qp: dict, x: jax.Array) -> jax.Array:
    """Quantised biased dense stage: requantise -> int32 GEMM -> dequant."""
    acc = quant_bank_dot(_requant(x, qp["x_scale"]), qp["w_q"])
    return acc.astype(jnp.float32) * (qp["x_scale"] * qp["w_scale"]) + qp["b"]


def conv2d_int8(
    qp: dict, x: jax.Array, stride: int = 1, padding: str = "VALID"
) -> jax.Array:
    """Quantised NHWC convolution (weights ``(c_out, k, k, c_in)`` int8).

    The ``k*k*c_in`` reduction is chunked over input channels so each f32
    partial reduces at most :data:`_CHUNK` terms (same exactness argument
    as :func:`quant_bank_dot`); chunk partials accumulate in int32.
    """
    x_q = _requant(x, qp["x_scale"])
    w = qp["w_q"]
    k = int(w.shape[1])
    c_in = int(w.shape[3])
    chunk = max(1, _CHUNK // (k * k))
    acc = None
    for lo in range(0, c_in, chunk):
        part = jax.lax.conv_general_dilated(
            x_q[..., lo:lo + chunk].transpose(0, 3, 1, 2),
            w[:, :, :, lo:lo + chunk].astype(jnp.float32).transpose(0, 3, 1, 2),
            window_strides=(stride, stride),
            padding=padding,
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        ).transpose(0, 2, 3, 1).astype(jnp.int32)
        acc = part if acc is None else acc + part
    return acc.astype(jnp.float32) * (qp["x_scale"] * qp["w_scale"]) + qp["b"]


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def _default_calib_counts(program) -> jax.Array:
    """Data-free calibration input: one full-scale SS-ADC count map (every
    count at ``levels - 1`` — the frontend's hard output ceiling)."""
    h_o, w_o, c_o = program.frontend.out_shape
    lv = float(program.frontend.adc.levels - 1)
    return jnp.full((1, h_o, w_o, c_o), lv, jnp.float32)


def _scale_of(x: jax.Array) -> float:
    return max(float(jnp.max(jnp.abs(x))), 1e-12) / 127.0


def calibrate_head_scales(program, params: Any, sample_counts: Any) -> Any:
    """Per-stage input activation scales from one f32 forward pass.

    ``params`` must be the *bound f32* head pytree.  Returns a list aligned
    with the chain stages (``None`` for parameterless stages), or a dict
    keyed by parameterized node name for graph heads.  Host-side — scales
    are concrete floats; they enter the quant pytree as traced f32 scalars
    (so :meth:`CompiledModel.reprogram` with freshly calibrated scales
    never recompiles).
    """
    from repro.fpca.program import (
        ConvSpec, DenseSpec, PoolSpec, _apply_activation,
    )
    from repro.models.layers import avg_pool2d, conv2d, linear, max_pool2d

    x = jnp.asarray(sample_counts, jnp.float32)
    if x.ndim == 3:
        x = x[None]
    x = x * jnp.float32(program.input_scale)
    if program.is_graph_head:
        return _calibrate_graph(program.head, params, x)
    scales: list[float | None] = []
    for layer, p in zip(program.head, params):
        if isinstance(layer, ConvSpec):
            scales.append(_scale_of(x))
            x = _apply_activation(
                layer.activation, conv2d(p, x, layer.stride, layer.padding)
            )
        elif isinstance(layer, PoolSpec):
            scales.append(None)
            pool = max_pool2d if layer.kind == "max" else avg_pool2d
            x = pool(x, layer.size, layer.stride)
        elif isinstance(layer, DenseSpec):
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            scales.append(_scale_of(x))
            x = _apply_activation(layer.activation, linear(p, x))
        else:
            scales.append(None)
            x = _apply_activation(layer.fn, x)
    return scales


def _calibrate_graph(graph, params: Any, x: jax.Array) -> dict[str, float]:
    from repro.fpca.program import (
        ConvSpec, DenseSpec, PoolSpec, _apply_activation,
    )
    from repro.models.heads import INPUT, AddSpec, ConcatSpec, DetectSpec
    from repro.models.layers import avg_pool2d, conv2d, linear, max_pool2d

    values: dict[str, Any] = {INPUT: x}
    scales: dict[str, float] = {}
    for node in graph.toposort():
        op = node.op
        ins = [values[r] for r in node.inputs]
        if isinstance(op, ConvSpec):
            scales[node.name] = _scale_of(ins[0])
            y = _apply_activation(
                op.activation,
                conv2d(params[node.name], ins[0], op.stride, op.padding),
            )
        elif isinstance(op, DetectSpec):
            scales[node.name] = _scale_of(ins[0])
            y = conv2d(params[node.name], ins[0], 1, "SAME")
        elif isinstance(op, PoolSpec):
            pool = max_pool2d if op.kind == "max" else avg_pool2d
            y = pool(ins[0], op.size, op.stride)
        elif isinstance(op, DenseSpec):
            v = ins[0]
            if v.ndim > 2:
                v = v.reshape(v.shape[0], -1)
            scales[node.name] = _scale_of(v)
            y = _apply_activation(op.activation, linear(params[node.name], v))
        elif isinstance(op, AddSpec):
            y = ins[0]
            for v in ins[1:]:
                y = y + v
            y = _apply_activation(op.activation, y)
        elif isinstance(op, ConcatSpec):
            y = _apply_activation(op.activation, jnp.concatenate(ins, axis=-1))
        else:                               # ActivationSpec
            y = _apply_activation(op.fn, ins[0])
        values[node.name] = y
    return scales


# ---------------------------------------------------------------------------
# head parameter quantisation / binding
# ---------------------------------------------------------------------------

def _quant_stage(p: dict, channel_axis: int, x_scale: float) -> dict:
    w_q, w_scale = quantize_symmetric(p["w"], channel_axis=channel_axis)
    return {
        "w_q": w_q,
        "w_scale": jnp.reshape(w_scale, (-1,)).astype(jnp.float32),
        "b": jnp.asarray(p["b"], jnp.float32),
        "x_scale": jnp.float32(x_scale),
    }


def is_quantized_params(params: Any) -> bool:
    """Whether a head pytree carries quantised stages (``w_q`` leaves)."""
    if isinstance(params, dict):
        vals = list(params.values())
    else:
        try:
            vals = list(params)
        except TypeError:
            return False
    return any(isinstance(p, dict) and "w_q" in p for p in vals)


def quantize_head_params(
    program,
    params: Any,
    *,
    sample_counts: Any | None = None,
    act_scales: Any | None = None,
) -> Any:
    """Quantise an f32 head pytree into the int8 serving pytree.

    ``act_scales`` (from :func:`calibrate_head_scales`, or round-tripped
    from an export bundle via :func:`unpack_act_scales`) takes precedence;
    otherwise scales are calibrated on ``sample_counts``, falling back to
    the data-free full-scale count map.  The result is what
    ``FPCAModelProgram(precision="int8").bind_head_params`` serves: one
    ``{"w_q", "w_scale", "b", "x_scale"}`` dict per parameterized stage
    (all leaves traced arrays — reprogramming never recompiles).
    """
    from repro.fpca.program import ConvSpec, DenseSpec
    from repro.models.heads import DetectSpec

    bound = program._bind_f32(params)
    if act_scales is None:
        if sample_counts is None:
            sample_counts = _default_calib_counts(program)
        act_scales = calibrate_head_scales(program, bound, sample_counts)
    if program.is_graph_head:
        out: dict[str, dict] = {}
        for node in program.head._param_nodes():
            axis = 0 if isinstance(node.op, (ConvSpec, DetectSpec)) else 1
            out[node.name] = _quant_stage(
                bound[node.name], axis, act_scales[node.name]
            )
        return out
    staged: list[dict] = []
    for layer, p, s in zip(program.head, bound, act_scales):
        if isinstance(layer, ConvSpec):
            staged.append(_quant_stage(p, 0, s))
        elif isinstance(layer, DenseSpec):
            staged.append(_quant_stage(p, 1, s))
        else:
            staged.append({})
    return staged


def _bind_quant_stage(p: Any, want_w: tuple, where: str) -> dict:
    p = dict(p)
    if set(p) != set(_QUANT_KEYS):
        raise ValueError(
            f"{where}: quantised stage needs keys {sorted(_QUANT_KEYS)}, "
            f"got {sorted(p)}"
        )
    out = {
        "w_q": jnp.asarray(p["w_q"], jnp.int8),
        "w_scale": jnp.asarray(p["w_scale"], jnp.float32),
        "b": jnp.asarray(p["b"], jnp.float32),
        "x_scale": jnp.asarray(p["x_scale"], jnp.float32),
    }
    c = want_w[0] if len(want_w) == 4 else want_w[1]
    got = {k: tuple(v.shape) for k, v in out.items()}
    want = {"w_q": want_w, "w_scale": (c,), "b": (c,), "x_scale": ()}
    if got != want:
        raise ValueError(
            f"{where}: quantised parameter shapes {got} do not match "
            f"expected {want}"
        )
    return out


def bind_quant_head_params(program, params: Any) -> Any:
    """Validate + coerce an int8 head pytree for serving (the ``precision=
    "int8"`` counterpart of the f32 binding path — same call sites, same
    fail-at-the-boundary contract)."""
    from repro.fpca.program import ConvSpec, DenseSpec

    if program.is_graph_head:
        if not isinstance(params, dict):
            raise ValueError(
                "graph head parameters must be a dict keyed by node name, "
                f"got {type(params).__name__}"
            )
        want_names = {n.name for n in program.head._param_nodes()}
        if set(params) != want_names:
            raise ValueError(
                f"graph head parameters keyed {sorted(params)} do not match "
                f"parameterized nodes {sorted(want_names)}"
            )
        shapes = program.head.shapes(program.frontend.out_shape)
        return {
            node.name: _bind_quant_stage(
                params[node.name],
                program.head._want_shapes(node, shapes)["w"],
                f"head node {node.name!r}",
            )
            for node in program.head._param_nodes()
        }
    bound = list(params)
    if len(bound) != len(program.head):
        raise ValueError(
            f"head has {len(program.head)} stages but got {len(bound)} "
            f"parameter entries"
        )
    shapes = program.head_shapes()
    out: list[dict] = []
    for i, (layer, p) in enumerate(zip(program.head, bound)):
        cur = shapes[i]
        if isinstance(layer, ConvSpec):
            want_w: tuple = (layer.out_channels, layer.kernel, layer.kernel,
                             cur[-1])
        elif isinstance(layer, DenseSpec):
            d_in = 1
            for d in cur:
                d_in *= int(d)
            want_w = (d_in, layer.features)
        else:
            if p:
                raise ValueError(
                    f"head[{i}] ({type(layer).__name__}): parameterless "
                    f"stage got parameters"
                )
            out.append({})
            continue
        out.append(_bind_quant_stage(
            p, want_w, f"head[{i}] ({type(layer).__name__})"
        ))
    return out


# ---------------------------------------------------------------------------
# int8 head apply (the precision="int8" numerics contract)
# ---------------------------------------------------------------------------

def apply_head_int8(program, params: Any, counts: jax.Array) -> jax.Array:
    """The int8 counterpart of ``FPCAModelProgram.apply_head`` — what every
    ``precision="int8"`` executable traces (fused model jit, head jit,
    patched streaming head, in-scan segment head)."""
    from repro.fpca.program import (
        ConvSpec, DenseSpec, PoolSpec, _apply_activation,
    )
    from repro.models.layers import avg_pool2d, max_pool2d

    x = jnp.asarray(counts, jnp.float32) * jnp.float32(program.input_scale)
    if program.is_graph_head:
        return _apply_graph_int8(program.head, params, x)
    if len(params) != len(program.head):
        raise ValueError(
            f"head has {len(program.head)} stages but got {len(params)} "
            f"parameter entries"
        )
    for layer, p in zip(program.head, params):
        if isinstance(layer, ConvSpec):
            x = _apply_activation(
                layer.activation, conv2d_int8(p, x, layer.stride, layer.padding)
            )
        elif isinstance(layer, PoolSpec):
            pool = max_pool2d if layer.kind == "max" else avg_pool2d
            x = pool(x, layer.size, layer.stride)
        elif isinstance(layer, DenseSpec):
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = _apply_activation(layer.activation, linear_int8(p, x))
        else:
            x = _apply_activation(layer.fn, x)
    return x


def _apply_graph_int8(graph, params: Any, x: jax.Array) -> jax.Array:
    from repro.fpca.program import (
        ConvSpec, DenseSpec, PoolSpec, _apply_activation,
    )
    from repro.models.heads import INPUT, AddSpec, ConcatSpec, DetectSpec
    from repro.models.layers import avg_pool2d, max_pool2d

    if x.ndim == 3:
        return _apply_graph_int8(graph, params, x[None])[0]
    values: dict[str, Any] = {INPUT: x}
    for node in graph.toposort():
        op = node.op
        ins = [values[r] for r in node.inputs]
        if isinstance(op, ConvSpec):
            y = _apply_activation(
                op.activation,
                conv2d_int8(params[node.name], ins[0], op.stride, op.padding),
            )
        elif isinstance(op, DetectSpec):
            y = conv2d_int8(params[node.name], ins[0], 1, "SAME")
        elif isinstance(op, PoolSpec):
            pool = max_pool2d if op.kind == "max" else avg_pool2d
            y = pool(ins[0], op.size, op.stride)
        elif isinstance(op, DenseSpec):
            v = ins[0]
            if v.ndim > 2:
                v = v.reshape(v.shape[0], -1)
            y = _apply_activation(
                op.activation, linear_int8(params[node.name], v)
            )
        elif isinstance(op, AddSpec):
            y = ins[0]
            for v in ins[1:]:
                y = y + v
            y = _apply_activation(op.activation, y)
        elif isinstance(op, ConcatSpec):
            y = _apply_activation(op.activation, jnp.concatenate(ins, axis=-1))
        else:                               # ActivationSpec
            y = _apply_activation(op.fn, ins[0])
        values[node.name] = y
    return values[graph.output]


# ---------------------------------------------------------------------------
# export bundle round-trip + parity metrics
# ---------------------------------------------------------------------------

def pack_act_scales(program, act_scales: Any) -> np.ndarray:
    """Flatten calibrated activation scales into one f32 array for an npz
    export bundle (chain: one slot per stage, 0 marking parameterless
    stages; graph: parameterized nodes in topological order)."""
    if program.is_graph_head:
        names = [n.name for n in program.head._param_nodes()]
        return np.asarray([act_scales[n] for n in names], np.float32)
    return np.asarray(
        [0.0 if s is None else float(s) for s in act_scales], np.float32
    )


def unpack_act_scales(program, arr: Any) -> Any:
    """Inverse of :func:`pack_act_scales`."""
    arr = np.asarray(arr, np.float32).reshape(-1)
    if program.is_graph_head:
        names = [n.name for n in program.head._param_nodes()]
        if arr.size != len(names):
            raise ValueError(
                f"expected {len(names)} activation scales, got {arr.size}"
            )
        return {n: float(s) for n, s in zip(names, arr)}
    if arr.size != len(program.head):
        raise ValueError(
            f"expected {len(program.head)} activation scales, got {arr.size}"
        )
    return [None if s == 0.0 else float(s) for s in arr]


def logit_parity(ref: Any, test: Any) -> dict[str, float]:
    """Bounded-parity metrics of an int8 lowering against its f32
    reference: ``max_abs_divergence`` over all outputs and ``top1_agreement``
    over the trailing class axis (1.0 for single-output maps)."""
    ref = np.asarray(ref, np.float32)
    test = np.asarray(test, np.float32)
    if ref.shape != test.shape:
        raise ValueError(
            f"shape mismatch: reference {ref.shape} vs test {test.shape}"
        )
    max_div = float(np.max(np.abs(ref - test))) if ref.size else 0.0
    if ref.ndim >= 2 and ref.shape[-1] > 1:
        a = np.argmax(ref.reshape(-1, ref.shape[-1]), axis=-1)
        b = np.argmax(test.reshape(-1, test.shape[-1]), axis=-1)
        top1 = float(np.mean(a == b))
    else:
        top1 = 1.0
    return {"max_abs_divergence": max_div, "top1_agreement": top1}
